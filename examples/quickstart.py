"""Quickstart: split annotations in 60 lines.

Annotate two "library" functions, let Mozart pipeline them through
cache-sized chunks, and inspect the plan.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import mozart, splittable, Along, Reduce, Generic
from repro.core import annotated_numpy as anp


# --- 1. annotate your own functions (the function bodies are UNMODIFIED) ---

@splittable(x=Along(0), y=Along(0), ret=Along(0), elementwise=True)
def saxpy(x, y):
    return 2.0 * x + y


@splittable(x=Generic("S"), ret=Reduce("add"))
def total(x):
    return jnp.sum(x)


def main():
    x = jnp.arange(1_000_000, dtype=jnp.float32) / 1e6
    y = jnp.ones(1_000_000, jnp.float32)

    # --- 2. run lazily under a Mozart session ------------------------------
    with mozart.session(executor="scan", log=False) as ctx:
        a = saxpy(x, y)                # -> Future (nothing ran yet)
        b = anp.exp(a)                 # library ops compose with yours
        c = anp.multiply(b, 0.5)
        s = total(c)

        # --- 3. inspect the plan: one pipelined stage ----------------------
        stages = ctx.last_plan()
        print("plan:", [[n.fn.name for n in st.nodes] for st in stages])

        # --- 4. force evaluation -------------------------------------------
        result = float(s)              # touch -> evaluate

    expected = float(np.sum(np.exp(2 * np.asarray(x) + 1) * 0.5))
    print(f"mozart={result:.2f} expected={expected:.2f}")
    print(f"stats: {dict(ctx.stats)}")
    assert abs(result - expected) / expected < 1e-5

    # --- 5. the AOT pipeline API: lower / compile / call -------------------
    # When the program is fixed (serving replicas), skip per-call planning
    # and retracing entirely: lower once, compile once, then every call only
    # splits, drives the pinned compiled drivers, and merges.
    def program(x, y):
        c = anp.multiply(anp.exp(saxpy(x, y)), 0.5)
        return total(c)

    p = mozart.pipeline(program, executor="auto")
    p.lower(x, y)                      # dataflow graph + plan, no execution
    p.compile()                        # pin batches, executors, executables
    result = float(p(x, y))            # warm: zero planner calls, 0 retraces
    print(f"pipeline={result:.2f} warm={p.warm()} "
          f"last_call={p.last_call_stats}")
    assert p.last_call_stats["jit_traces"] == 0
    assert abs(result - expected) / expected < 1e-5


if __name__ == "__main__":
    main()
