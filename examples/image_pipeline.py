"""The paper's Nashville filter through Mozart (ImageMagick integration).

    PYTHONPATH=src python examples/image_pipeline.py
"""

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.workloads import nashville, gotham
from repro import hardware
from repro.core import mozart


def main():
    im = jnp.asarray(np.random.RandomState(0).rand(1600, 1200, 3), jnp.float32)

    with mozart.session(executor="eager") as ctx:
        t0 = time.perf_counter()
        base = np.asarray(nashville(im))
        t_base = time.perf_counter() - t0

    with mozart.session(executor="scan", chip=hardware.CPU_HOST) as ctx:
        t0 = time.perf_counter()
        out = np.asarray(nashville(im))
        t_moz = time.perf_counter() - t0
        stages = ctx.stats["stages"]

    assert np.allclose(out, base, atol=2e-3)
    print(f"nashville 1600x1200: un-annotated {t_base*1e3:.0f}ms, "
          f"mozart {t_moz*1e3:.0f}ms ({t_base/t_moz:.2f}x) "
          f"[{stages} stage(s), row-split pipeline]")


if __name__ == "__main__":
    main()
