"""Batched serving example: prefill + decode over a request queue.

    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-1.6b
"""

import argparse

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.launch.serve import Request, Server
from repro.models import transformer as tfm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="rwkv6-1.6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, args.prompt_len),
                    max_new=args.max_new)
            for i in range(args.requests)]
    srv = Server(cfg, params, args.batch,
                 max_len=args.prompt_len + args.max_new + 1)
    stats = srv.run(reqs)
    for r in reqs[:2]:
        print(f"req {r.rid}: {r.out[:8]}...")
    print(f"{stats['tokens']} tokens in {stats['wall_s']:.2f}s "
          f"= {stats['tokens_per_s']:.1f} tok/s ({args.arch} smoke config)")


if __name__ == "__main__":
    main()
