"""End-to-end training driver example.

Trains a reduced gemma-family model for a few hundred steps on CPU with the
FULL production stack: mesh + pjit shardings, ZeRO-1 AdamW, SA-annotated
data pipeline, async checkpointing, straggler watchdog.  Scale --arch /
--steps / sizes up on a real fleet.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import logging

import jax.numpy as jnp

from repro.configs.registry import get_smoke_config
from repro.launch.train import train
from repro.models.config import AttnConfig, ModelConfig


def model_100m() -> ModelConfig:
    """~15M-param gemma-family model (a 100M config is one flag away but
    CPU-hour-hungry; pass --d-model 640 --layers 12 to get there)."""
    return ModelConfig(
        name="demo-lm", family="dense", n_layers=4, d_model=256, d_ff=1024,
        vocab_size=8192, dtype=jnp.float32,
        attn=AttnConfig(n_heads=8, n_kv_heads=4, head_dim=32),
        gated_mlp=True, activation="gelu", tie_embeddings=True,
    )


def main():
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = model_100m()
    if args.d_model:
        cfg = cfg.with_runtime(d_model=args.d_model,
                               d_ff=4 * args.d_model)
    if args.layers:
        cfg = cfg.with_runtime(n_layers=args.layers)

    out = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=20)
    first, last = out["losses"][0], out["losses"][-1]
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({out['wall_s']:.0f}s); checkpoints in {args.ckpt_dir}")
    assert last < first, "training did not reduce loss"


if __name__ == "__main__":
    main()
