"""Hardware constants for the roofline model and the Mozart batch heuristic.

The TARGET is TPU v5e (the runtime container is CPU-only; Pallas kernels are
validated in interpret mode).  The paper's batch-size heuristic sizes one
pipeline batch to fit in fast memory: L2 on CPU, VMEM on TPU.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Chip:
    name: str
    peak_bf16_flops: float      # FLOP/s per chip
    hbm_bandwidth: float        # bytes/s per chip
    ici_link_bandwidth: float   # bytes/s per link
    ici_links: int              # links per chip participating in a collective
    hbm_bytes: int              # HBM capacity per chip
    vmem_bytes: int             # fast scratch memory per core
    # Fraction of fast memory one Mozart pipeline batch should occupy
    # (paper: "C x L2CacheSize", C fixed constant; they found C s.t. batches
    # also leave room for intermediates in the shared LLC).
    mozart_c: float = 0.25
    # Per-dispatch overhead of launching ONE library call from the Python
    # driver loop (jit call + XLA launch).  The cost model weighs this
    # against memory traffic when scoring chunked executors.
    dispatch_overhead_s: float = 50e-6
    # One-time cost of tracing/compiling a new XLA program (scan drivers,
    # fused chains).  Amortized over a session; charged once per stage.
    compile_overhead_s: float = 50e-3


# Target accelerator (per the assignment brief):
#   197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.
TPU_V5E = Chip(
    name="tpu_v5e",
    peak_bf16_flops=197e12,
    hbm_bandwidth=819e9,
    ici_link_bandwidth=50e9,
    ici_links=4,                 # 2D torus, 2 axes x 2 directions
    hbm_bytes=16 * 2**30,
    vmem_bytes=128 * 2**20,
)

# The host this container runs on (used only so that the *paper-faithful*
# chunk heuristic is meaningful when benchmarks execute on CPU).  The fast
# tier is modelled as L3-scale rather than L2: unlike the paper's native
# Rust driver, our per-chunk dispatch goes through Python/XLA (~50us), which
# moves the optimal chunk size up by ~2 orders of magnitude — confirmed by
# the Fig 6 batch-size sweep (best ~256k elements on this host).
CPU_HOST = Chip(
    name="cpu_host",
    peak_bf16_flops=1e11,
    hbm_bandwidth=20e9,
    ici_link_bandwidth=10e9,
    ici_links=1,
    hbm_bytes=32 * 2**30,
    vmem_bytes=4 * 2**20,        # L3-scale fast tier (see note above)
    mozart_c=1.0,
)

TARGET = TPU_V5E


# ---------------------------------------------------------------------------
# Online dispatch-overhead calibration
# ---------------------------------------------------------------------------
#
# ``Chip.dispatch_overhead_s`` is a guess baked into a dataclass; the actual
# per-dispatch cost (Python jit-call + XLA launch) varies by an order of
# magnitude across hosts and runtime versions.  The cost model therefore
# blends the constant with a per-process measurement of a tiny jitted no-op:
# the geometric mean keeps the prior's scale when the measurement is noisy
# while still correcting a constant that is wrong by 10x.

_measured_dispatch_s: float | None = None


def measured_dispatch_overhead_s() -> float:
    """Wall seconds of one warm jitted no-op dispatch, measured once per
    process (median of a handful of calls; first call pays one compile)."""
    global _measured_dispatch_s
    if _measured_dispatch_s is None:
        import time

        import jax
        import jax.numpy as jnp

        f = jax.jit(lambda x: x + 1)
        x = jnp.zeros((), jnp.float32)
        jax.block_until_ready(f(x))          # compile outside the timed loop
        samples = []
        for _ in range(7):
            t0 = time.perf_counter()
            jax.block_until_ready(f(x))
            samples.append(time.perf_counter() - t0)
        _measured_dispatch_s = max(sorted(samples)[len(samples) // 2], 1e-9)
    return _measured_dispatch_s


def effective_dispatch_overhead_s(chip: Chip = TARGET) -> float:
    """Per-dispatch overhead the cost model should charge: the chip constant
    blended (geometric mean) with the measured per-process no-op dispatch."""
    import math

    return math.sqrt(chip.dispatch_overhead_s * measured_dispatch_overhead_s())


def fast_memory_bytes(chip: Chip = TARGET) -> int:
    """Size of the 'cache' tier Mozart batches must fit in."""
    return chip.vmem_bytes


def mozart_batch_elements(total_elem_bytes: int, chip: Chip = TARGET) -> int:
    """Paper Section 5.2: batch = C * FastMem / sum(sizeof(element)).

    ``total_elem_bytes`` is the summed per-element byte width across every
    live split value in the stage (inputs + intermediates + outputs).
    """
    if total_elem_bytes <= 0:
        return 1
    n = int(chip.mozart_c * fast_memory_bytes(chip) / total_elem_bytes)
    return max(n, 1)
