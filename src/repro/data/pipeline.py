"""Token data pipeline with SA-annotated preprocessing.

Sources: synthetic (seeded Zipfian tokens — deterministic across restarts,
indexable by step for exact resume) or a binary token file (memory-mapped
uint16/uint32).  Preprocessing transforms (dtype cast, clipping to vocab,
sequence packing into (B, S+1) windows) are ANNOTATED functions, so the
per-host slice of every global batch is produced by a Mozart pipeline —
chunked through fast memory and parallelizable across workers, exactly like
the paper's data-loading workloads (Pandas data cleaning).

A background prefetch thread keeps ``prefetch`` batches ahead of the
training loop (overlap of input pipeline with compute).
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mozart
from repro.core import split_types as st
from repro.core.annotation import annotate
from repro.models.config import ModelConfig


# -- annotated preprocessing ops (the "library") ------------------------------

def _mod_vocab(x, vocab):
    return jnp.mod(x, vocab)


def _to_i32(x):
    return x.astype(jnp.int32)


mod_vocab = annotate(_mod_vocab, name="mod_vocab", elementwise=True,
                     x=st.Generic("S"), vocab=st._, ret=st.Generic("S"))
to_i32 = annotate(_to_i32, name="to_i32", elementwise=True,
                  x=st.Generic("S"), ret=st.Generic("S"))


class TokenSource:
    """Deterministic, step-indexable token source."""

    def __init__(self, vocab_size: int, seed: int = 0,
                 token_file: str | None = None):
        self.vocab_size = vocab_size
        self.seed = seed
        if token_file:
            raw = np.memmap(token_file, dtype=np.uint16, mode="r")
            self._tokens = raw
        else:
            self._tokens = None

    def batch_at(self, step: int, batch: int, seq: int) -> np.ndarray:
        """The (batch, seq+1) token window for one global step."""
        n = batch * (seq + 1)
        if self._tokens is not None:
            start = (step * n) % max(len(self._tokens) - n, 1)
            flat = np.asarray(self._tokens[start:start + n], np.int64)
        else:
            rng = np.random.default_rng(self.seed + step)
            # Zipf-ish distribution bounded to vocab
            flat = rng.zipf(1.3, size=n).astype(np.int64)
        return flat.reshape(batch, seq + 1)


class DataPipeline:
    def __init__(self, cfg: ModelConfig, batch: int, seq: int, *,
                 seed: int = 0, token_file: str | None = None,
                 prefetch: int = 2, use_mozart: bool = True):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.source = TokenSource(cfg.vocab_size, seed, token_file)
        self.prefetch = prefetch
        self.use_mozart = use_mozart
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # -- single-batch path (exact resume: call with any step) ---------------
    def batch_for_step(self, step: int) -> dict:
        raw = self.source.batch_at(step, self.batch, self.seq)
        if self.use_mozart:
            with mozart.session(executor="fused") as _:
                x = to_i32(mod_vocab(jnp.asarray(raw), self.cfg.vocab_size))
                tokens = x.value
        else:
            tokens = jnp.asarray(raw % self.cfg.vocab_size, jnp.int32)
        batch = {"tokens": tokens}
        if self.cfg.encdec:
            rng = np.random.default_rng(step)
            batch["enc_embeds"] = jnp.asarray(
                rng.standard_normal((self.batch, 64, self.cfg.d_model)),
                self.cfg.dtype)
        elif self.cfg.family == "vlm":
            rng = np.random.default_rng(step)
            batch["input_embeds"] = jnp.asarray(
                rng.standard_normal(
                    (self.batch, self.seq + 1, self.cfg.d_model)) * 0.02,
                self.cfg.dtype)
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(self.seq + 1)[None, None],
                (3, self.batch, self.seq + 1)).astype(jnp.int32)
        return batch

    # -- prefetching iterator -------------------------------------------------
    def _worker(self, start_step: int):
        step = start_step
        while not self._stop.is_set():
            b = self.batch_for_step(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, b), timeout=0.2)
                    break
                except queue.Full:
                    continue
            step += 1

    def iterate(self, start_step: int = 0) -> Iterator[tuple[int, dict]]:
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._worker, args=(start_step,), daemon=True)
        self._thread.start()
        try:
            while True:
                yield self._q.get()
        finally:
            self.stop()

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            while not self._q.empty():
                try:
                    self._q.get_nowait()
                except queue.Empty:
                    break
            self._thread.join(timeout=2)
            self._thread = None
