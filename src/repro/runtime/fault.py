"""Fault tolerance: retries, step watchdog (straggler detection), restart loop.

On a real fleet the `on_straggler` / `on_failure` hooks trigger re-slicing
or pod eviction; on this CPU container they log and (for failures) restore
from the latest complete checkpoint — the control flow is identical and
unit-tested, only the actuator differs.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable

log = logging.getLogger("repro.fault")


class StepFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FaultConfig:
    max_retries_per_step: int = 2
    max_restarts: int = 3
    # straggler watchdog: a step slower than median * factor is flagged
    straggler_factor: float = 3.0
    straggler_window: int = 20
    min_steps_for_baseline: int = 5


class StepTimer:
    """Rolling per-step wall-clock stats + straggler flagging."""

    def __init__(self, cfg: FaultConfig,
                 on_straggler: Callable[[int, float, float], None] | None = None):
        self.cfg = cfg
        self.times: list[float] = []
        self.stragglers: list[int] = []
        self.on_straggler = on_straggler

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        window = self.times[-self.cfg.straggler_window:]
        is_straggler = False
        if len(window) >= self.cfg.min_steps_for_baseline:
            med = sorted(window)[len(window) // 2]
            if seconds > med * self.cfg.straggler_factor:
                is_straggler = True
                self.stragglers.append(step)
                log.warning("step %d took %.3fs (median %.3fs): straggler",
                            step, seconds, med)
                if self.on_straggler:
                    self.on_straggler(step, seconds, med)
        self.times.append(seconds)
        return is_straggler


def with_retries(fn: Callable[[], Any], *, retries: int,
                 on_retry: Callable[[int, Exception], None] | None = None) -> Any:
    """Run fn; retry transient failures (the paper-world analogue of a
    preempted host re-issuing a step)."""
    last: Exception | None = None
    for attempt in range(retries + 1):
        try:
            return fn()
        except (RuntimeError, OSError, StepFailure) as e:  # transient classes
            last = e
            log.warning("step attempt %d failed: %s", attempt, e)
            if on_retry:
                on_retry(attempt, e)
    raise StepFailure(f"exhausted {retries} retries") from last


def run_with_restarts(
    make_state: Callable[[int | None], tuple[Any, int]],
    run_from: Callable[[Any, int], Any],
    *,
    fault_cfg: FaultConfig,
    latest_step: Callable[[], int | None],
):
    """Full restart loop: build state (fresh or from latest checkpoint),
    run; on failure, rebuild from the newest complete checkpoint and
    continue.  Returns the final result of ``run_from``.

    make_state(step|None) -> (state, start_step)
    run_from(state, start_step) -> result       (raises on fatal error)
    """
    restarts = 0
    while True:
        ckpt = latest_step()
        state, start = make_state(ckpt)
        try:
            return run_from(state, start)
        except Exception as e:  # noqa: BLE001 — restart boundary
            restarts += 1
            log.error("training crashed at restart %d: %s", restarts, e)
            if restarts > fault_cfg.max_restarts:
                raise
            time.sleep(0.1)
