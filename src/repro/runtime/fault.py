"""Compatibility shim: the seed-era fault-tolerance helpers moved into the
runtime-wide resilience layer (``repro.core.resilience``), where they share
one transient-error taxonomy and backoff policy with the executor
degradation ladder and the serving failure domains.  Import from there; this
module re-exports the original names for existing callers
(``launch/train.py``)."""

from __future__ import annotations

from repro.core.resilience import (  # noqa: F401
    FaultConfig,
    StepFailure,
    StepTimer,
    TRANSIENT_ERRORS,
    run_with_restarts,
    with_retries,
)

__all__ = ["FaultConfig", "StepFailure", "StepTimer", "TRANSIENT_ERRORS",
           "run_with_restarts", "with_retries"]
