"""AdamW with ZeRO-1-style sharded optimizer state.

Three interchangeable update paths (same math, verified against each other):

* ``jnp``     — plain fused-by-XLA update (default for training runs);
* ``kernel``  — the Pallas fused_adamw kernel per flattened leaf (TPU path);
* ``mozart``  — the paper's technique: the update chain is expressed as
                annotated elementwise ops and Mozart pipelines it through
                fast memory in chunks (see optim/mozart_adamw.py).

ZeRO-1 is expressed through shardings (launch/shardings.py): m/v (and the
update computation) are sharded over data axes; GSPMD inserts the
reduce-scatter(grads) / all-gather(params) pair automatically.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array            # () int32
    m: Any                     # pytree like params, f32
    v: Any                     # pytree like params, f32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
    )


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((s - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def update(params, grads, state: AdamWState, cfg: AdamWConfig,
           path: str = "jnp"):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    gscale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    c1 = 1.0 / (1.0 - cfg.b1 ** step.astype(jnp.float32))
    c2 = 1.0 / (1.0 - cfg.b2 ** step.astype(jnp.float32))

    def upd_jnp(p, g, m, v):
        gf = g.astype(jnp.float32) * gscale
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        u = (m * c1) / (jnp.sqrt(v * c2) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    def upd_kernel(p, g, m, v):
        from repro.kernels.ops import fused_adamw
        sh = p.shape
        po, mo, vo = fused_adamw(
            p.reshape(-1), g.reshape(-1), m.reshape(-1), v.reshape(-1),
            lr=lr, b1=cfg.b1, b2=cfg.b2, eps=cfg.eps, wd=cfg.weight_decay,
            step=step, grad_scale=gscale)
        return po.reshape(sh), mo.reshape(sh), vo.reshape(sh)

    upd = {"jnp": upd_jnp, "kernel": upd_kernel}[path]
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics
