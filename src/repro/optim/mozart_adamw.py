"""AdamW expressed as a Mozart (split-annotation) pipeline — the paper's
technique applied to training.

The update for one parameter tensor is ~12 elementwise vector ops.  Executed
naively ("un-annotated library"), every op round-trips the full multi-GB
tensor through HBM — the exact data-movement pathology of the paper's MKL
Black Scholes motivating example.  Here each op is an *annotated* black-box
function; Mozart plans them into ONE stage and drives VMEM/L2-sized chunks
through the whole chain (or lowers the stage onto the split-pipeline Pallas
kernel with executor="pallas").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mozart
from repro.core import annotated_numpy as anp
from repro.optim.adamw import AdamWConfig, AdamWState, global_norm, schedule


def mozart_adamw_update(params, grads, state: AdamWState, cfg: AdamWConfig,
                        executor: str = "scan", batch_elements=None):
    """Same math as optim.adamw.update(path="jnp"), via Mozart pipelines."""
    step = state.step + 1
    lr = float(schedule(cfg, step))
    gnorm = float(global_norm(grads))
    gscale = min(1.0, cfg.clip_norm / max(gnorm, 1e-9))
    sf = float(step)
    c1 = 1.0 / (1.0 - cfg.b1 ** sf)
    c2 = 1.0 / (1.0 - cfg.b2 ** sf)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)

    new_p, new_m, new_v = [], [], []
    with mozart.session(executor=executor, batch_elements=batch_elements) as ctx:
        for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
            sh, dt = p.shape, p.dtype
            p1 = p.reshape(-1).astype(jnp.float32)
            g1 = g.reshape(-1).astype(jnp.float32)
            m1, v1 = m.reshape(-1), v.reshape(-1)

            # ---- the pipeline: 12 annotated black-box vector ops ----------
            gs = anp.multiply(g1, gscale)
            mn = anp.add(anp.multiply(m1, cfg.b1), anp.multiply(gs, 1 - cfg.b1))
            g2 = anp.multiply(gs, gs)
            vn = anp.add(anp.multiply(v1, cfg.b2), anp.multiply(g2, 1 - cfg.b2))
            mhat = anp.multiply(mn, c1)
            denom = anp.add(anp.sqrt(anp.multiply(vn, c2)), cfg.eps)
            upd = anp.add(anp.divide(mhat, denom),
                          anp.multiply(p1, cfg.weight_decay))
            pn = anp.subtract(p1, anp.multiply(upd, lr))
            # ---------------------------------------------------------------

            new_p.append(pn)        # futures; forced on exit below
            new_m.append(mn)
            new_v.append(vn)
        # leaving the session flushes every pending pipeline
    new_p = [jnp.asarray(f.value).reshape(s.shape).astype(s.dtype)
             for f, s in zip(new_p, flat_p)]
    new_m = [jnp.asarray(f.value).reshape(s.shape) for f, s in zip(new_m, flat_p)]
    new_v = [jnp.asarray(f.value).reshape(s.shape) for f, s in zip(new_v, flat_p)]
    state = AdamWState(step=step,
                       m=treedef.unflatten(new_m),
                       v=treedef.unflatten(new_v))
    return treedef.unflatten(new_p), state, {"lr": lr, "grad_norm": gnorm}
