"""Gradient compression with error feedback (int8 quantization).

For cross-pod data parallelism the gradient all-reduce crosses the slow
inter-pod links; 4x compression (f32->int8 blocks with per-block scales)
cuts that term of the roofline directly.  Error feedback keeps the residual
so compression error does not bias convergence (it is re-added next step).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 2048


class CompressState(NamedTuple):
    residual: Any          # pytree like grads, f32


def init(grads_like) -> CompressState:
    return CompressState(residual=jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def _quantize(x: jax.Array):
    """(N,) f32 -> (int8 codes, per-block f32 scales)."""
    n = x.shape[0]
    pad = (-n) % BLOCK
    xp = jnp.pad(x, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(xp), axis=1, keepdims=True) / 127.0
    safe = jnp.where(scale == 0, 1.0, scale)
    codes = jnp.clip(jnp.round(xp / safe), -127, 127).astype(jnp.int8)
    return codes, scale


def _dequantize(codes, scale, n):
    return (codes.astype(jnp.float32) * scale).reshape(-1)[:n]


def compress_decompress(g: jax.Array, residual: jax.Array):
    """One error-feedback round-trip for a single tensor.  Returns
    (decompressed gradient actually applied, new residual)."""
    flat = g.astype(jnp.float32).reshape(-1) + residual.reshape(-1)
    codes, scale = _quantize(flat)
    deq = _dequantize(codes, scale, flat.shape[0])
    new_res = (flat - deq).reshape(g.shape)
    return deq.reshape(g.shape), new_res


def apply(grads, state: CompressState):
    """Compress+decompress every leaf (the all-reduce would move the int8
    codes; here we model the numerics and count the bytes)."""
    outs = jax.tree_util.tree_map(compress_decompress, grads, state.residual)
    new_g = jax.tree_util.tree_map(lambda o: o[0], outs,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_r = jax.tree_util.tree_map(lambda o: o[1], outs,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_g, CompressState(residual=new_r)


def compressed_bytes(grads) -> int:
    total = 0
    for g in jax.tree_util.tree_leaves(grads):
        n = g.size
        blocks = -(-n // BLOCK)
        total += n + blocks * 4          # int8 codes + f32 scales
    return total
