"""rwkv6-1.6b [ssm] — 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536; Finch, data-dependent decay.  [arXiv:2404.05892; unverified]

long_500k RUNS: the WKV matrix state is O(1) per token."""

from repro.models.config import ModelConfig, RWKVConfig

ARCH_ID = "rwkv6-1.6b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        n_layers=24,
        d_model=2048,
        d_ff=7168,
        vocab_size=65536,
        attn=None,
        rwkv=RWKVConfig(head_dim=64),
        gated_mlp=False,
        activation="silu",
        subquadratic=True,
        max_seq_len=524288,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        d_ff=224,
        vocab_size=256,
        attn=None,
        rwkv=RWKVConfig(head_dim=16),
        gated_mlp=False,
        activation="silu",
        subquadratic=True,
    )
