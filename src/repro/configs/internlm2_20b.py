"""internlm2-20b [dense] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544.  [arXiv:2403.17297; hf]"""

from repro.models.config import AttnConfig, ModelConfig

ARCH_ID = "internlm2-20b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=48,
        d_model=6144,
        d_ff=16384,
        vocab_size=92544,
        attn=AttnConfig(n_heads=48, n_kv_heads=8, head_dim=128,
                        rope_theta=1000000.0),
        gated_mlp=True,
        activation="silu",
        subquadratic=False,
        max_seq_len=32768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=2,
        d_model=96,
        d_ff=256,
        vocab_size=256,
        attn=AttnConfig(n_heads=6, n_kv_heads=2, head_dim=16),
        gated_mlp=True,
        activation="silu",
    )
