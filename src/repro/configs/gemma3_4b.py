"""gemma3-4b [dense] — 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144; 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

long_500k RUNS: 5/6 of layers use a 1024-token sliding window (O(S*w)) and
the global layers are linear-in-KV at decode."""

from repro.models.config import AttnConfig, ModelConfig

ARCH_ID = "gemma3-4b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=34,
        d_model=2560,
        d_ff=10240,
        vocab_size=262144,
        attn=AttnConfig(n_heads=8, n_kv_heads=4, head_dim=256,
                        rope_theta=10000.0, window=1024, pattern_period=6,
                        qk_norm=True),
        gated_mlp=True,
        activation="gelu",
        tie_embeddings=True,
        embed_scale=True,
        subquadratic=True,
        max_seq_len=524288,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=6,                  # one full local:global period
        d_model=64,
        d_ff=128,
        vocab_size=256,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16, window=8,
                        pattern_period=6, qk_norm=True),
        gated_mlp=True,
        activation="gelu",
        tie_embeddings=True,
        embed_scale=True,
        subquadratic=True,
    )
