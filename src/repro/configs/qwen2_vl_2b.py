"""qwen2-vl-2b [vlm] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936; M-RoPE, dynamic resolution.  [arXiv:2409.12191; hf]

The vision frontend (ViT patch encoder) is a STUB per the brief:
``input_specs()`` supplies precomputed patch/text embeddings plus the three
M-RoPE position streams (t, h, w)."""

from repro.models.config import AttnConfig, ModelConfig

ARCH_ID = "qwen2-vl-2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        n_layers=28,
        d_model=1536,
        d_ff=8960,
        vocab_size=151936,
        attn=AttnConfig(n_heads=12, n_kv_heads=2, head_dim=128,
                        rope_theta=1000000.0, mrope=True,
                        mrope_sections=(16, 24, 24)),
        gated_mlp=True,
        activation="silu",
        subquadratic=False,
        max_seq_len=32768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=256,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16, mrope=True,
                        mrope_sections=(2, 3, 3)),
        gated_mlp=True,
        activation="silu",
    )
