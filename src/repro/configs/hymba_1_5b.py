"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16; parallel attention + Mamba heads.
[arXiv:2411.13676; hf]

Layers combine attention and SSM head outputs (mean), with sliding-window
attention on most layers (1 global layer per 16 approximates Hymba's three
full-attention layers).  long_500k RUNS (SSM state is O(1), window is
bounded)."""

from repro.models.config import AttnConfig, ModelConfig, SSMConfig

ARCH_ID = "hymba-1.5b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        n_layers=32,
        d_model=1600,
        d_ff=5504,
        vocab_size=32001,
        attn=AttnConfig(n_heads=25, n_kv_heads=5, head_dim=64,
                        rope_theta=10000.0, window=1024, pattern_period=16),
        ssm=SSMConfig(state_dim=16, conv_width=4, expand=2),
        gated_mlp=True,
        activation="silu",
        subquadratic=True,
        max_seq_len=524288,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="hybrid",
        n_layers=2,
        d_model=80,                  # 5 heads x 16
        d_ff=128,
        vocab_size=256,
        attn=AttnConfig(n_heads=5, n_kv_heads=1, head_dim=16, window=8),
        ssm=SSMConfig(state_dim=4, conv_width=4, expand=2),
        gated_mlp=True,
        activation="silu",
        subquadratic=True,
    )
