"""Assigned input shapes and per-arch applicability rules.

LM transformer shapes are seq_len x global_batch.  ``decode_*`` / ``long_*``
lower ``serve_step`` (one new token against a KV cache of seq_len), NOT
``train_step``.  ``long_500k`` requires sub-quadratic attention: it is
skipped for pure full-attention archs (recorded, not silently dropped) and
runs for SSM / hybrid / mostly-local archs.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # "train" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: 500k decode needs sub-quadratic attention (see DESIGN.md)"
    if shape.kind == "decode" and not cfg.decode_supported:
        return False, "encoder-only arch has no decode step"
    return True, ""


def enc_len_for(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """Stub speech-frontend length for enc-dec archs."""
    return min(shape.seq_len, 4096)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    train: the batch dict consumed by ``lm.loss_fn``.
    decode: (token, positions-free) — caches are produced separately via
    ``eval_shape`` on ``init_caches``.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        extra = 1 if shape.kind == "train" else 0    # labels need S+1 tokens
        batch: dict = {"tokens": jax.ShapeDtypeStruct((B, S + extra), i32)}
        if cfg.encdec:
            E = enc_len_for(cfg, shape)
            batch["enc_embeds"] = jax.ShapeDtypeStruct((B, E, cfg.d_model), cfg.dtype)
        elif cfg.family == "vlm":
            batch["input_embeds"] = jax.ShapeDtypeStruct((B, S + extra, cfg.d_model), cfg.dtype)
            batch["positions"] = jax.ShapeDtypeStruct((3, B, S + extra), i32)
        elif cfg.family == "audio" and not cfg.encdec:
            batch["input_embeds"] = jax.ShapeDtypeStruct((B, S + extra, cfg.d_model), cfg.dtype)
        return batch
    # decode: one new token against a cache of S tokens
    out = {"token": jax.ShapeDtypeStruct((B, 1), i32)}
    if cfg.encdec:
        E = enc_len_for(cfg, shape)
        out["enc_out"] = jax.ShapeDtypeStruct((B, E, cfg.d_model), cfg.dtype)
    return out
