"""granite-34b [dense] — 88L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152; code model.  [arXiv:2405.04324; hf]

MQA (kv=1): KV projections are replicated across the TP axis (they are tiny)
while Q heads shard 48/16; the decode KV cache seq-shards over "model"."""

from repro.models.config import AttnConfig, ModelConfig

ARCH_ID = "granite-34b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=88,
        d_model=6144,
        d_ff=24576,
        vocab_size=49152,
        attn=AttnConfig(n_heads=48, n_kv_heads=1, head_dim=128,
                        rope_theta=10000.0),
        gated_mlp=False,             # GPT-BigCode style 4x plain MLP
        activation="gelu",
        subquadratic=False,
        max_seq_len=32768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=3,
        d_model=64,
        d_ff=256,
        vocab_size=256,
        attn=AttnConfig(n_heads=4, n_kv_heads=1, head_dim=16),
        gated_mlp=False,
        activation="gelu",
    )
