"""gemma-7b [dense] — 28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000;
GeGLU, head_dim=256.  [arXiv:2403.08295; hf]"""

from repro.models.config import AttnConfig, ModelConfig

ARCH_ID = "gemma-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=28,
        d_model=3072,
        d_ff=24576,
        vocab_size=256000,
        attn=AttnConfig(n_heads=16, n_kv_heads=16, head_dim=256,
                        rope_theta=10000.0),
        gated_mlp=True,
        activation="gelu",           # GeGLU
        tie_embeddings=True,
        embed_scale=True,
        subquadratic=False,          # pure full attention: long_500k skipped
        max_seq_len=32768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        d_ff=256,
        vocab_size=256,
        attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=32),
        gated_mlp=True,
        activation="gelu",
        tie_embeddings=True,
        embed_scale=True,
    )
