"""seamless-m4t-large-v2 [audio] — enc-dec, 24L d_model=1024 16H (kv=16)
d_ff=8192 vocab=256206; multimodal.  [arXiv:2308.11596; hf]

The speech frontend (conformer feature extractor) is a STUB per the brief:
``input_specs()`` supplies precomputed frame embeddings to the encoder."""

from repro.models.config import AttnConfig, ModelConfig

ARCH_ID = "seamless-m4t-large-v2"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="audio",
        n_layers=24,                 # decoder layers
        n_encoder_layers=24,
        encdec=True,
        d_model=1024,
        d_ff=8192,
        vocab_size=256206,
        attn=AttnConfig(n_heads=16, n_kv_heads=16, head_dim=64,
                        rope_theta=10000.0),
        gated_mlp=False,
        activation="gelu",
        subquadratic=False,
        max_seq_len=8192,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="audio",
        n_layers=2,
        n_encoder_layers=2,
        encdec=True,
        d_model=64,
        d_ff=128,
        vocab_size=256,
        attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=16),
        gated_mlp=False,
        activation="gelu",
    )
