"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE 64 routed top-6 + 2 shared, fine-grained; first layer
dense.  [arXiv:2401.06066; hf]"""

from repro.models.config import AttnConfig, ModelConfig, MoEConfig

ARCH_ID = "deepseek-moe-16b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=28,
        d_model=2048,
        d_ff=1408,
        vocab_size=102400,
        attn=AttnConfig(n_heads=16, n_kv_heads=16, head_dim=128,
                        rope_theta=10000.0),
        moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                      first_k_dense=1),
        gated_mlp=True,
        activation="silu",
        subquadratic=False,
        max_seq_len=32768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        n_layers=3,
        d_model=64,
        d_ff=48,
        vocab_size=256,
        attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=16),
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=48, n_shared=2,
                      first_k_dense=1),
        gated_mlp=True,
        activation="silu",
    )
