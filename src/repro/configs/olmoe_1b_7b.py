"""olmoe-1b-7b [moe] — 16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304, MoE 64 experts top-8.  [arXiv:2409.02060; hf]"""

from repro.models.config import AttnConfig, ModelConfig, MoEConfig

ARCH_ID = "olmoe-1b-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=16,
        d_model=2048,
        d_ff=1024,
        vocab_size=50304,
        attn=AttnConfig(n_heads=16, n_kv_heads=16, head_dim=128,
                        rope_theta=10000.0, qk_norm=True),
        moe=MoEConfig(n_experts=64, top_k=8, d_expert=1024),
        gated_mlp=True,
        activation="silu",
        subquadratic=False,
        max_seq_len=32768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        d_ff=32,
        vocab_size=256,
        attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=16, qk_norm=True),
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=32),
        gated_mlp=True,
        activation="silu",
    )
