"""Architecture registry: ``--arch <id>`` -> ModelConfig."""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

_MODULES = {
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "gemma-7b": "repro.configs.gemma_7b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "granite-34b": "repro.configs.granite_34b",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "rwkv6-1.6b": "repro.configs.rwkv6_1_6b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).config()


def get_smoke_config(arch_id: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch_id]).smoke_config()
