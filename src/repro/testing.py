"""Hypothesis-optional property testing for the dependency-light test tier.

The test suite states its invariants as property tests.  When ``hypothesis``
is installed, ``given``/``settings``/``hst`` are re-exported unchanged and
the full shrinking machinery applies.  When it is not (the CI container is
dependency-light by design), the same decorated tests run as *deterministic
seeded loops*: each strategy draws ``max_examples`` pseudo-random samples
from a per-test seed derived from the test's qualified name, so failures are
reproducible run-to-run without any third-party package.

Usage (identical either way):

    from repro.testing import given, settings, hst

    @given(n=hst.integers(1, 200), batch=hst.integers(1, 64))
    @settings(max_examples=40, deadline=None)
    def test_roundtrip(n, batch): ...

Only the strategy surface the suite uses is mirrored by the fallback:
``integers``, ``sampled_from``, ``lists``, ``floats``, ``booleans``.
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib
from typing import Any, Callable, Sequence

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as hst

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A draw function over a ``random.Random`` source."""

        def __init__(self, draw: Callable[[random.Random], Any]):
            self._draw = draw

        def example(self, rng: random.Random) -> Any:
            return self._draw(rng)

    class _strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(lambda r: bool(r.getrandbits(1)))

        @staticmethod
        def sampled_from(elements: Sequence[Any]) -> _Strategy:
            elements = list(elements)
            return _Strategy(lambda r: r.choice(elements))

        @staticmethod
        def lists(elements: _Strategy, min_size: int = 0,
                  max_size: int = 10) -> _Strategy:
            return _Strategy(lambda r: [
                elements.example(r)
                for _ in range(r.randint(min_size, max_size))
            ])

    hst = _strategies

    def settings(*, max_examples: int = 20, **_ignored) -> Callable:
        """Record ``max_examples``; other hypothesis knobs are meaningless
        for the seeded fallback and accepted for source compatibility."""

        def deco(fn: Callable) -> Callable:
            fn._pc_max_examples = max_examples
            return fn

        return deco

    def given(*pos_strats: _Strategy, **kw_strats: _Strategy) -> Callable:
        def deco(fn: Callable) -> Callable:
            sig = inspect.signature(fn)
            names = list(sig.parameters)
            # hypothesis fills positional strategies from the right of the
            # signature; mirror that so both code paths accept either style.
            pos_names = names[len(names) - len(pos_strats):] if pos_strats else []
            strats = dict(zip(pos_names, pos_strats))
            strats.update(kw_strats)

            @functools.wraps(fn)
            def run(*args, **kwargs):
                n = (getattr(run, "_pc_max_examples", None)
                     or getattr(fn, "_pc_max_examples", 20))
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = random.Random(seed)
                for _ in range(n):
                    drawn = {k: s.example(rng) for k, s in strats.items()}
                    fn(*args, **kwargs, **drawn)

            # pytest introspects the signature for fixtures: hide the
            # strategy-drawn parameters (and the wrapped original).
            remaining = [p for name, p in sig.parameters.items()
                         if name not in strats]
            run.__signature__ = sig.replace(parameters=remaining)
            if hasattr(run, "__wrapped__"):
                del run.__wrapped__
            return run

        return deco
