"""Unified model configuration covering all 10 assigned architectures.

One frozen dataclass family; per-arch instances live in ``repro.configs``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0              # always-active shared experts (deepseek)
    first_k_dense: int = 0         # leading dense layers (deepseek layer 0)
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    conv_width: int = 4
    expand: int = 2                # d_inner = expand * d_model
    dt_rank: int = 0               # 0 -> ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    mrope: bool = False            # qwen2-vl 3-section rotary
    mrope_sections: tuple[int, ...] = (16, 24, 24)   # t,h,w (x2 = head_dim)
    window: int | None = None      # sliding window width for local layers
    # local:global pattern period, e.g. 6 with 1 global -> 5:1 (gemma3);
    # 0 = all layers global.
    pattern_period: int = 0
    qk_norm: bool = False
    logit_softcap: float | None = None


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attn: AttnConfig | None = None
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    rwkv: RWKVConfig | None = None
    # enc-dec (seamless): n_layers = decoder layers
    encdec: bool = False
    n_encoder_layers: int = 0
    # MLP
    gated_mlp: bool = True
    activation: str = "silu"       # silu | gelu
    # misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    embed_scale: bool = False      # gemma multiplies embeddings by sqrt(d)
    max_seq_len: int = 8192
    dtype: Any = jnp.bfloat16
    # capability flags (drive shape-cell applicability)
    subquadratic: bool = False     # can run long_500k
    decode_supported: bool = True
    # runtime knobs (overridden by launcher, not architecture identity)
    remat: bool = True
    scan_layers: bool = True
    attn_block_k: int = 1024       # KV block for jnp blocked attention
    dense_attn_threshold: int = 8192   # use dense softmax at/below this S_kv
    kv_cache_blocks: int = 1       # seq-sharded decode blocks (mesh model dim)
    vocab_pad: int = 1             # round vocab up for TP (padded cols masked)
    ce_chunk: int = 512            # sequence chunk for the CE loss
    layer_scan_inner: int = 0      # nested layer-scan chunk (0=auto, 1=flat)
    banded_attention: bool = False # O(S*w) exact sliding-window path
    seq_shard_residual: bool = True  # sequence-parallel residual stream
    remat_policy: str = "nothing"    # nothing | dots (save matmul outputs)
    moe_groups: int = 1            # token dispatch groups (mesh device count)

    def with_runtime(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def padded_vocab(self) -> int:
        m = max(self.vocab_pad, 1)
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def d_head_total(self) -> int:
        return self.attn.n_heads * self.attn.head_dim if self.attn else 0

    def active_params_per_token_factor(self) -> float:
        """Fraction of MoE expert params active per token (for MODEL_FLOPS)."""
        if self.moe is None:
            return 1.0
        act = self.moe.top_k + self.moe.n_shared
        return act / max(self.moe.n_experts + self.moe.n_shared, 1)


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (embeddings + layers), for roofline N."""
    D, F, V, L = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.n_layers
    total = V * D                                 # token embedding
    if not cfg.tie_embeddings:
        total += V * D                            # lm head

    def attn_params():
        a = cfg.attn
        qk = D * a.n_heads * a.head_dim
        kv = 2 * D * a.n_kv_heads * a.head_dim
        o = a.n_heads * a.head_dim * D
        return qk + kv + o

    def mlp_params(ff):
        mats = 3 if cfg.gated_mlp else 2
        return mats * D * ff

    def moe_params():
        m = cfg.moe
        mats = 3 if cfg.gated_mlp else 2
        routed = m.n_experts * mats * D * m.d_expert
        shared = m.n_shared * mats * D * m.d_expert
        router = D * m.n_experts
        return routed + shared + router

    def ssm_params():
        s = cfg.ssm
        d_in = s.expand * D
        dt_rank = s.dt_rank or -(-D // 16)
        return (D * 2 * d_in) + (d_in * s.conv_width) + \
               (d_in * (dt_rank + 2 * s.state_dim)) + (dt_rank * d_in) + \
               (d_in * D) + 2 * d_in

    def rwkv_params():
        # time-mix: r,k,v,g,o + decay/a/extras ~ 6*D*D ; channel-mix ~ 2*D*3.5D
        return 6 * D * D + int(2 * D * 3.5 * D)

    per_layer = 0
    if cfg.family == "ssm":       # rwkv
        per_layer = rwkv_params()
    else:
        if cfg.attn is not None:
            per_layer = attn_params()
        if cfg.family == "hybrid":
            per_layer += ssm_params()
        if cfg.moe is not None:
            per_layer += moe_params()
            total += cfg.moe.first_k_dense * (attn_params() + mlp_params(F))
            per_layer_count = L - cfg.moe.first_k_dense
        else:
            per_layer += mlp_params(F)
            per_layer_count = L
    if cfg.moe is None:
        per_layer_count = L
    total += per_layer * per_layer_count

    if cfg.encdec:
        enc_layer = attn_params() + mlp_params(F)
        total += cfg.n_encoder_layers * enc_layer
        total += L * attn_params()               # cross-attention per dec layer
    return int(total)


def active_param_count(cfg: ModelConfig) -> int:
    """Active-per-token parameters (MoE: only top-k + shared experts)."""
    if cfg.moe is None:
        return param_count(cfg)
    m = cfg.moe
    mats = 3 if cfg.gated_mlp else 2
    D, L = cfg.d_model, cfg.n_layers
    moe_layers = L - m.first_k_dense
    routed_all = m.n_experts * mats * D * m.d_expert
    routed_act = m.top_k * mats * D * m.d_expert
    return param_count(cfg) - moe_layers * (routed_all - routed_act)
