"""Unified transformer covering all 10 assigned architectures.

Design:
* one homogeneous per-layer block per family, stacked along a leading L axis
  and driven by ``lax.scan`` (bounded HLO for 88-layer configs) with
  ``jax.checkpoint`` remat per layer;
* layer heterogeneity that varies *within* a stack (gemma3's 5:1
  local:global pattern, hymba's window) is expressed as traced per-layer
  scalars (effective window length) fed through the scan, so the stack stays
  homogeneous;
* MoE stacks with leading dense layers (DeepSeekMoE) put the dense layers in
  an unscanned prefix.

Decode state is a pytree of stacked per-layer caches (KV blocks / SSM
states / RWKV states) driven through the same scan.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import KVCache
from repro.models.config import ModelConfig
from repro.models.layers import embed_tokens, init_rms_norm, rms_norm, unembed

BIG_WINDOW = 1 << 30      # "global attention" encoded as a huge window


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ModelConfig, kind: str) -> dict:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: dict[str, Any] = {"ln1": init_rms_norm(d), "ln2": init_rms_norm(d)}
    if kind == "rwkv":
        p["rwkv"] = rwkv_mod.init_rwkv(ks[0], cfg)
        return p
    p["attn"] = attn_mod.init_attn(ks[0], cfg)
    if kind == "hybrid":
        p["ssm"] = ssm_mod.init_ssm(ks[1], cfg)
    if kind == "moe":
        p["moe"] = mlp_mod.init_moe(ks[2], cfg)
    else:
        p["mlp"] = mlp_mod.init_mlp(ks[3], cfg)
    if kind == "cross":               # enc-dec decoder block
        p["cross"] = attn_mod.init_attn(ks[4], cfg)
        p["ln3"] = init_rms_norm(d)
    return p


def _stack_layers(key, cfg: ModelConfig, n: int, kind: str) -> dict:
    keys = jax.random.split(key, n)
    per = [_init_block(k, cfg, kind) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per)


def layer_kind(cfg: ModelConfig) -> str:
    if cfg.family == "ssm":
        return "rwkv"
    if cfg.family == "hybrid":
        return "hybrid"
    if cfg.moe is not None:
        return "moe"
    if cfg.encdec:
        return "cross"
    return "dense"


def layer_windows(cfg: ModelConfig) -> jax.Array:
    """Per-layer effective attention window (traced through the scan)."""
    L = cfg.n_layers
    a = cfg.attn
    if a is None:
        return jnp.full((L,), BIG_WINDOW, jnp.int32)
    if a.pattern_period and a.window:
        idx = jnp.arange(L)
        is_global = (idx % a.pattern_period) == (a.pattern_period - 1)
        return jnp.where(is_global, BIG_WINDOW, a.window).astype(jnp.int32)
    if a.window:
        return jnp.full((L,), a.window, jnp.int32)
    return jnp.full((L,), BIG_WINDOW, jnp.int32)


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------


def init_model(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 8)
    D, V = cfg.d_model, cfg.padded_vocab
    params: dict[str, Any] = {
        "embed": (jax.random.normal(ks[0], (V, D)) * 0.02).astype(jnp.float32),
        "final_norm": init_rms_norm(D),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(ks[1], (D, V)) * D ** -0.5
                             ).astype(cfg.dtype)
    kind = layer_kind(cfg)
    n_scan = cfg.n_layers
    if cfg.moe is not None and cfg.moe.first_k_dense:
        kd = cfg.moe.first_k_dense
        pre = [_init_block(k, cfg, "dense")
               for k in jax.random.split(ks[2], kd)]
        params["pre_layers"] = pre
        n_scan = cfg.n_layers - kd
    params["layers"] = _stack_layers(ks[3], cfg, n_scan, kind)
    if cfg.encdec:
        params["enc_layers"] = _stack_layers(ks[4], cfg, cfg.n_encoder_layers,
                                             "dense")
        params["enc_norm"] = init_rms_norm(D)
    return params


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


class BlockIO(NamedTuple):
    cache: Any            # KVCache | SSMState+KVCache | RWKVState | None
    window: jax.Array     # () int32 effective window
    cross_kv: Any         # (k, v) for enc-dec decoders | None


def _apply_block(p, x, cfg: ModelConfig, io: BlockIO, *, kind: str,
                 mode: str, causal: bool, positions, pad_mask=None):
    from repro.models.shard_ctx import constrain_residual
    x = constrain_residual(x)
    new_cache = io.cache
    if kind == "rwkv":
        st = io.cache if io.cache is not None else rwkv_mod.init_rwkv_state(
            cfg, x.shape[0])
        tm, st = rwkv_mod.time_mix(p["rwkv"], rms_norm(x, p["ln1"], cfg.norm_eps),
                                   cfg, st)
        x = x + tm
        cm, st = rwkv_mod.channel_mix(p["rwkv"], rms_norm(x, p["ln2"], cfg.norm_eps),
                                      cfg, st)
        return x + cm, st, jnp.float32(0.0)

    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    kv_cache = io.cache["kv"] if isinstance(io.cache, dict) else io.cache
    a_out, kv_new, _ = attn_mod.attention_block(
        p["attn"], h, cfg, positions=positions, causal=causal,
        window=io.window, cache=kv_cache, mode=mode, pad_mask=pad_mask)
    if kind == "hybrid":
        ssm_state = io.cache["ssm"] if isinstance(io.cache, dict) else None
        s_out, ssm_new = ssm_mod.ssm_block(p["ssm"], h, cfg, ssm_state,
                                           mode=mode)
        a_out = 0.5 * (a_out + s_out)           # parallel heads, mean combine
        new_cache = {"kv": kv_new, "ssm": ssm_new}
    else:
        new_cache = kv_new
    x = x + a_out

    if kind == "cross" and io.cross_kv is not None:
        c = rms_norm(x, p["ln3"], cfg.norm_eps)
        c_out, _, _ = attn_mod.attention_block(
            p["cross"], c, cfg, cross_kv=io.cross_kv, mode="train")
        x = x + c_out

    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    aux = jnp.float32(0.0)
    if kind == "moe":
        m_out, aux = mlp_mod.moe_block(p["moe"], h2, cfg)
    else:
        m_out = mlp_mod.mlp_block(p["mlp"], h2, cfg)
    return x + m_out, new_cache, aux


# ---------------------------------------------------------------------------
# Stack driver (scan over layers)
# ---------------------------------------------------------------------------


def _cross_kv_per_layer(params, enc_out, cfg: ModelConfig):
    """Precompute each decoder layer's cross-attention K/V from enc output."""
    a = cfg.attn

    def one(pl):
        k = enc_out @ pl["cross"]["wk"].astype(enc_out.dtype)
        v = enc_out @ pl["cross"]["wv"].astype(enc_out.dtype)
        B, S, _ = enc_out.shape
        k = k.reshape(B, S, a.n_kv_heads, a.head_dim).transpose(0, 2, 1, 3)
        v = v.reshape(B, S, a.n_kv_heads, a.head_dim).transpose(0, 2, 1, 3)
        return k, v

    from repro.models.shard_ctx import constrain_cross_kv
    k, v = jax.vmap(one)(params["layers"])      # (L, B, Hkv, S, D) pair
    return constrain_cross_kv(k), constrain_cross_kv(v)


def _scan_inner_size(cfg: ModelConfig, L: int) -> int:
    """Inner chunk for the nested (sqrt-depth) layer scan: the largest
    divisor of L not exceeding ~sqrt(L)*1.5 (0 disables nesting)."""
    if getattr(cfg, "layer_scan_inner", 0) == 1 or L < 8:
        return 1
    explicit = getattr(cfg, "layer_scan_inner", 0)
    if explicit > 1:
        return explicit if L % explicit == 0 else 1
    target = int((L ** 0.5) * 1.5)
    for k in range(min(target, L), 1, -1):
        if L % k == 0:
            return k
    return 1


def run_stack(params, x, cfg: ModelConfig, *, caches=None, mode="train",
              causal=True, positions=None, cross_kv=None, pad_mask=None):
    """Run the (optionally pre-staged +) scanned layer stack.

    Returns (x, new_caches, aux_sum).  ``caches`` is a stacked pytree with
    leading L axis (or None in train mode).  ``pad_mask`` (B, S) marks real
    tokens — identical for every layer, so it closes over the scan body
    rather than travelling through xs.
    """
    kind = layer_kind(cfg)
    aux_total = jnp.float32(0.0)

    if "pre_layers" in params:
        for i, pl in enumerate(params["pre_layers"]):
            io = BlockIO(
                cache=None if caches is None else jax.tree_util.tree_map(
                    lambda c, i=i: c[i], caches["pre"]),
                window=jnp.int32(BIG_WINDOW), cross_kv=None)
            x, new_c, aux = _apply_block(pl, x, cfg, io, kind="dense",
                                         mode=mode, causal=causal,
                                         positions=positions,
                                         pad_mask=pad_mask)
            aux_total = aux_total + aux
            if caches is not None:
                caches = dict(caches)
                caches["pre"] = jax.tree_util.tree_map(
                    lambda full, new, ii=i: full.at[ii].set(new),
                    caches["pre"], new_c)

    windows = layer_windows(cfg)
    if cfg.moe is not None and cfg.moe.first_k_dense:
        windows = windows[cfg.moe.first_k_dense:]

    scan_caches = caches["stack"] if isinstance(caches, dict) and "stack" in caches else caches

    has_cache = scan_caches is not None

    def body(carry, inp):
        # caches travel in the CARRY (not xs->ys): the per-layer
        # dynamic-update-slice then updates the stacked cache IN PLACE,
        # instead of paying a full copy from the read-only xs buffer into
        # the freshly-allocated ys buffer every step.
        x, cache_stack, li = carry
        if cross_kv is not None:
            layer_p, win, ckv = inp
        else:
            layer_p, win = inp
            ckv = None
        cache_l = (None if not has_cache else jax.tree_util.tree_map(
            lambda c: jax.lax.dynamic_index_in_dim(c, li, 0, keepdims=False),
            cache_stack))
        io = BlockIO(cache=cache_l, window=win, cross_kv=ckv)
        x, new_cache, aux = _apply_block(layer_p, x, cfg, io, kind=kind,
                                         mode=mode, causal=causal,
                                         positions=positions,
                                         pad_mask=pad_mask)
        if has_cache:
            cache_stack = jax.tree_util.tree_map(
                lambda c, n: jax.lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype) if hasattr(n, "dtype") else n, li, 0),
                cache_stack, new_cache)
        return (x, cache_stack, li + 1), aux

    if cfg.remat:
        # prevent_cse=False: inside scan the CSE barrier is unnecessary and
        # its optimization-barrier copies double the saved-carry memory.
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(body, policy=policy, prevent_cse=False)

    xs = (params["layers"], windows)
    if cross_kv is not None:
        xs = xs + (cross_kv,)
    carry0 = (x, scan_caches, jnp.int32(0))
    n_stack = jax.tree_util.tree_leaves(params["layers"])[0].shape[0]
    if cfg.scan_layers:
        inner = _scan_inner_size(cfg, n_stack)
        if inner > 1 and n_stack % inner == 0 and mode == "train":
            # sqrt-depth nesting: saved layer carries drop from O(L) to
            # O(L/inner + inner) (granite-34b: 88 -> ~19 saved carries)
            outer = n_stack // inner
            xs2 = jax.tree_util.tree_map(
                lambda a: a.reshape((outer, inner) + a.shape[1:]), xs)

            def outer_body(c, xin):
                return jax.lax.scan(body, c, xin)

            if cfg.remat:
                outer_body = jax.checkpoint(
                    outer_body,
                    policy=jax.checkpoint_policies.nothing_saveable,
                    prevent_cse=False)
            (x, new_caches, _), auxes = jax.lax.scan(outer_body, carry0, xs2)
            aux_total = aux_total + jnp.sum(auxes)
        else:
            (x, new_caches, _), auxes = jax.lax.scan(body, carry0, xs)
            aux_total = aux_total + jnp.sum(auxes)
    else:
        # unrolled path: every layer appears in the HLO (used by the roofline
        # cost variants, where scan bodies would be cost-counted only once)
        carry = carry0
        for i in range(n_stack):
            inp = jax.tree_util.tree_map(lambda a, i=i: a[i], xs)
            carry, aux = body(carry, inp)
            aux_total = aux_total + aux
        x, new_caches, _ = carry

    if isinstance(caches, dict) and "stack" in caches:
        out_caches = dict(caches)
        out_caches["stack"] = new_caches
    else:
        out_caches = new_caches
    return x, out_caches, aux_total


# ---------------------------------------------------------------------------
# Public model API
# ---------------------------------------------------------------------------


def encode(params, enc_embeds, cfg: ModelConfig):
    """Encoder stack (seamless): bidirectional, no cache."""
    x = enc_embeds.astype(cfg.dtype)
    windows = jnp.full((cfg.n_encoder_layers,), BIG_WINDOW, jnp.int32)

    def body(carry, inp):
        layer_p, win = inp
        io = BlockIO(cache=None, window=win, cross_kv=None)
        x, _, _ = _apply_block(layer_p, carry, cfg, io, kind="dense",
                               mode="train", causal=False, positions=None)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, (params["enc_layers"], windows))
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _inputs_to_embeds(params, cfg, tokens=None, input_embeds=None):
    if input_embeds is not None:
        return input_embeds.astype(cfg.dtype)
    return embed_tokens(params["embed"], tokens, cfg)


def logits_from_hidden(params, x, cfg: ModelConfig):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(x, head)
    if cfg.padded_vocab != cfg.vocab_size:      # mask the TP padding columns
        col = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
    return logits


def forward_hidden(params, cfg: ModelConfig, tokens=None, input_embeds=None,
                   enc_embeds=None, positions=None):
    """Teacher-forced forward up to the final norm: (hidden, aux_loss)."""
    x = _inputs_to_embeds(params, cfg, tokens, input_embeds)
    cross_kv = None
    if cfg.encdec:
        enc_out = encode(params, enc_embeds, cfg)
        cross_kv = _cross_kv_per_layer(params, enc_out, cfg)
    x, _, aux = run_stack(params, x, cfg, caches=None, mode="train",
                          causal=True, positions=positions, cross_kv=cross_kv)
    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def forward_train(params, cfg: ModelConfig, tokens=None, input_embeds=None,
                  enc_embeds=None, positions=None):
    """Teacher-forced forward: returns (logits, aux_loss)."""
    hidden, aux = forward_hidden(params, cfg, tokens=tokens,
                                 input_embeds=input_embeds,
                                 enc_embeds=enc_embeds, positions=positions)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return unembed(hidden, head), aux


# -- serving ------------------------------------------------------------------


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                per_slot: bool = False):
    """Decode-state pytree.  ``per_slot=True`` gives every batch row its own
    KV position counter (continuous batching: rows join/leave mid-flight)."""
    kind = layer_kind(cfg)
    L = cfg.n_layers
    n_scan = L - (cfg.moe.first_k_dense if cfg.moe else 0)

    def stacked(make_one, n):
        one = make_one()
        return jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l[None], (n,) + l.shape).copy(), one)

    if kind == "rwkv":
        return stacked(lambda: rwkv_mod.init_rwkv_state(cfg, batch), n_scan)
    kv = lambda: attn_mod.init_kv_cache(cfg, batch, max_len,
                                        per_slot=per_slot)
    if kind == "hybrid":
        return stacked(lambda: {"kv": kv(), "ssm": ssm_mod.init_ssm_state(cfg, batch)}, n_scan)
    caches = stacked(kv, n_scan)
    if cfg.moe is not None and cfg.moe.first_k_dense:
        return {"stack": caches,
                "pre": stacked(kv, cfg.moe.first_k_dense)}
    return caches


def prefill(params, cfg: ModelConfig, tokens=None, input_embeds=None,
            enc_embeds=None, caches=None, positions=None, pad_mask=None,
            last_pos=None):
    """Process the prompt, fill caches, return logits of the LAST position.

    ``pad_mask`` (B, S) bool, True = real token: pad key positions are
    masked out of every attention softmax and the cache records each row's
    valid span, so neither the prefill logits nor later decode steps attend
    padding.  ``last_pos`` (B,) int32 selects each row's own last REAL
    position for the returned logits (right-padded rows); default is the
    final array position (correct for unpadded and left-padded prompts)."""
    x = _inputs_to_embeds(params, cfg, tokens, input_embeds)
    cross_kv = None
    if cfg.encdec:
        enc_out = encode(params, enc_embeds, cfg)
        cross_kv = _cross_kv_per_layer(params, enc_out, cfg)
    x, caches, _ = run_stack(params, x, cfg, caches=caches, mode="prefill",
                             causal=True, positions=positions,
                             cross_kv=cross_kv, pad_mask=pad_mask)
    if last_pos is not None:
        x = jnp.take_along_axis(
            x, last_pos.astype(jnp.int32)[:, None, None], axis=1)
    else:
        x = x[:, -1:]
    return logits_from_hidden(params, x, cfg), caches


def decode_step(params, cfg: ModelConfig, token, caches, enc_out=None,
                positions=None):
    """One decode step.  token: (B, 1) int32.  Returns (logits, caches)."""
    x = embed_tokens(params["embed"], token, cfg)
    cross_kv = _cross_kv_per_layer(params, enc_out, cfg) if (
        cfg.encdec and enc_out is not None) else None
    x, caches, _ = run_stack(params, x, cfg, caches=caches, mode="decode",
                             causal=True, positions=positions,
                             cross_kv=cross_kv)
    return logits_from_hidden(params, x, cfg), caches
