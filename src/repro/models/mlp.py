"""MLP (gated / plain) and Mixture-of-Experts feed-forward layers.

MoE is capacity-based Switch-style dispatch: top-k routing, per-expert token
buffers of capacity C, scatter/gather combine.  Experts shard over the mesh
``model`` axis (expert parallelism); the dispatch einsums let GSPMD place
the all-to-all.  Shared experts (DeepSeekMoE) run densely for every token.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, MoEConfig


def _act(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(kind)


def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in, scale_out = d ** -0.5, f ** -0.5
    p = {
        "w_in": (jax.random.normal(k1, (d, f)) * scale_in).astype(cfg.dtype),
        "w_out": (jax.random.normal(k2, (f, d)) * scale_out).astype(cfg.dtype),
    }
    if cfg.gated_mlp:
        p["w_gate"] = (jax.random.normal(k3, (d, f)) * scale_in).astype(cfg.dtype)
    return p


def mlp_block(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = x @ p["w_in"].astype(x.dtype)
    if cfg.gated_mlp:
        h = _act(x @ p["w_gate"].astype(x.dtype), cfg.activation) * h
    else:
        h = _act(h, cfg.activation)
    return h @ p["w_out"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Mixture of experts
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig) -> dict:
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_expert, m.n_experts
    keys = jax.random.split(key, 5)
    scale_in, scale_out = d ** -0.5, f ** -0.5
    p = {
        "router": (jax.random.normal(keys[0], (d, e)) * scale_in).astype(jnp.float32),
        "w_in": (jax.random.normal(keys[1], (e, d, f)) * scale_in).astype(cfg.dtype),
        "w_out": (jax.random.normal(keys[2], (e, f, d)) * scale_out).astype(cfg.dtype),
    }
    if cfg.gated_mlp:
        p["w_gate"] = (jax.random.normal(keys[3], (e, d, f)) * scale_in).astype(cfg.dtype)
    if m.n_shared:
        p["shared"] = {
            "w_in": (jax.random.normal(keys[4], (d, f * m.n_shared)) * scale_in).astype(cfg.dtype),
            "w_out": (jax.random.normal(keys[4], (f * m.n_shared, d)) * scale_out).astype(cfg.dtype),
        }
        if cfg.gated_mlp:
            p["shared"]["w_gate"] = (
                jax.random.normal(keys[4], (d, f * m.n_shared)) * scale_in
            ).astype(cfg.dtype)
    return p


def _dispatch_one_group_sharded(xt, gate_vals, expert_idx, w_in, w_gate,
                                w_out, cfg: ModelConfig, capacity: int,
                                psum_axis):
    """Dispatch for one device-local token group inside shard_map.

    Expert weights arrive as their local TP shard (E, D, F/tp); the w_out
    contraction therefore produces partial sums that are ``psum``-ed over
    the model axis before the combine gather.
    """
    m = cfg.moe
    T, D = xt.shape
    E, k = m.n_experts, m.top_k
    Tk = T * k

    eidx = expert_idx.reshape(-1)
    order = jnp.argsort(eidx)
    sorted_tok = order // k
    counts = jnp.zeros((E,), jnp.int32).at[eidx].add(1)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(Tk, dtype=jnp.int32) - starts[eidx[order]]
    pos = jnp.zeros((Tk,), jnp.int32).at[order].set(pos_sorted)
    keep = pos < capacity

    slotpos = starts[:, None] + jnp.arange(capacity, dtype=jnp.int32)[None, :]
    slot_valid = jnp.arange(capacity)[None, :] < counts[:, None]
    src_tok = sorted_tok[jnp.clip(slotpos, 0, Tk - 1)]
    buf = xt[src_tok] * slot_valid[..., None].astype(xt.dtype)    # (E, C, D)

    h = jnp.einsum("ecd,edf->ecf", buf, w_in.astype(xt.dtype))
    if cfg.gated_mlp:
        g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(xt.dtype))
        h = _act(g, cfg.activation) * h
    else:
        h = _act(h, cfg.activation)
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_out.astype(xt.dtype))
    if psum_axis is not None:
        out_buf = jax.lax.psum(out_buf, psum_axis)                # F shards

    gathered = out_buf[eidx, jnp.clip(pos, 0, capacity - 1)]
    gathered = gathered * keep[:, None].astype(xt.dtype)
    weighted = gathered * gate_vals.reshape(-1, 1).astype(xt.dtype)
    return jnp.sum(weighted.reshape(T, k, D), axis=1)


def _dispatch_one_group(xt, gate_vals, expert_idx, p, cfg: ModelConfig,
                        capacity: int):
    """Capacity-based dispatch/compute/combine for ONE token group.

    GATHER-based formulation: per-expert buffers are built by *gathering*
    token rows (``xt[src_tok]``) rather than scatter-adding into them —
    GSPMD partitions batched gathers on the group axis, while data-dependent
    scatters fall back to replication (a 484 GiB lesson).  The ranking math
    is sort-based: O(Tk log Tk) time, O(Tk) memory.
    """
    m = cfg.moe
    T, D = xt.shape
    E, k = m.n_experts, m.top_k
    Tk = T * k

    eidx = expert_idx.reshape(-1)                                 # (Tk,)
    order = jnp.argsort(eidx)                                     # stable
    sorted_tok = order // k                                       # token per slot
    counts = jnp.zeros((E,), jnp.int32).at[eidx].add(1)
    starts = jnp.cumsum(counts) - counts                          # (E,)
    pos_sorted = jnp.arange(Tk, dtype=jnp.int32) - starts[eidx[order]]
    pos = jnp.zeros((Tk,), jnp.int32).at[order].set(pos_sorted)   # per (t,k)
    keep = pos < capacity

    # Dispatch by gather: slot (e, c) holds sorted entry starts[e] + c.
    slotpos = starts[:, None] + jnp.arange(capacity, dtype=jnp.int32)[None, :]
    slot_valid = jnp.arange(capacity)[None, :] < counts[:, None]  # (E, C)
    src_tok = sorted_tok[jnp.clip(slotpos, 0, Tk - 1)]            # (E, C)
    buf = xt[src_tok] * slot_valid[..., None].astype(xt.dtype)    # (E, C, D)

    # Expert FFN: batched einsum over experts.
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_in"].astype(xt.dtype))
    if cfg.gated_mlp:
        g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(xt.dtype))
        h = _act(g, cfg.activation) * h
    else:
        h = _act(h, cfg.activation)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(xt.dtype))

    # Combine by gather: token t slot k reads out_buf[e(t,k), pos(t,k)].
    gathered = out_buf[eidx, jnp.clip(pos, 0, capacity - 1)]      # (Tk, D)
    gathered = gathered * keep[:, None].astype(xt.dtype)
    weighted = gathered * gate_vals.reshape(-1, 1).astype(xt.dtype)
    return jnp.sum(weighted.reshape(T, k, D), axis=1)


def moe_block(p: dict, x: jax.Array, cfg: ModelConfig):
    """Returns (out, aux_loss).  x: (B, S, D).

    ``cfg.moe_groups`` > 1 splits tokens into independent dispatch groups
    (one per mesh device): per-group buffers stay device-local and capacity
    becomes per-group — the standard per-device-capacity EP approximation.
    """
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, k = m.n_experts, m.top_k
    xt = x.reshape(T, D)

    from repro.models import shard_ctx
    G = max(cfg.moe_groups, 1)
    if T % G != 0:
        G = 1

    moe_sharding = shard_ctx._MOE_GROUPS
    residual = shard_ctx._RESIDUAL
    if G > 1 and moe_sharding is not None and residual is not None:
        # EXPLICIT parallel dispatch: GSPMD replicates data-dependent
        # gather dispatch (observed 484 GiB/device at 1M tokens), and
        # resharding tokens into a separate group layout replicates the
        # activations on multi-pod meshes.  So the shard_map consumes x in
        # its NATIVE residual sharding (batch over data axes, seq over
        # model) — zero boundary reshard — and the router, top-k, dispatch,
        # expert FFN (local F shard) and combine all run device-locally,
        # with one psum for the F contraction and one for the aux loss.
        import functools
        from jax.sharding import PartitionSpec as P
        mesh = moe_sharding.mesh
        model_axis = "model" if "model" in mesh.axis_names else None
        xspec = P(residual.spec[0], model_axis, None)
        wspec_in = P(None, None, model_axis)
        wspec_out = P(None, model_axis, None)
        all_axes = tuple(mesh.axis_names)
        n_dev = int(mesh.devices.size)

        @functools.partial(
            jax.shard_map, mesh=mesh,
            in_specs=(xspec, P(None, None), wspec_in,
                      wspec_in if cfg.gated_mlp else P(None, None, None),
                      wspec_out),
            out_specs=(xspec, P()), check_vma=False)
        def grouped(x_l, router, w_in, w_gate, w_out):
            Bl, Sl, _ = x_l.shape
            Tl = Bl * Sl
            xt_l = x_l.reshape(Tl, D)
            logits = xt_l.astype(jnp.float32) @ router            # (Tl, E)
            probs = jax.nn.softmax(logits, axis=-1)
            gv, ei = jax.lax.top_k(probs, k)
            gv = gv / jnp.maximum(jnp.sum(gv, axis=-1, keepdims=True), 1e-9)
            # aux loss from global statistics (psum over the whole mesh)
            me_l = jnp.sum(probs, axis=0)
            ce_l = jnp.sum(jnp.sum(
                jax.nn.one_hot(ei, E, dtype=jnp.float32), axis=1), axis=0)
            me = jax.lax.psum(me_l, all_axes) / (Tl * n_dev)
            ce = jax.lax.psum(ce_l, all_axes) / (Tl * n_dev)
            aux_l = E * jnp.sum(me * ce) * m.aux_loss_weight
            cap = int(max(1, (Tl * k * m.capacity_factor) // E))
            out_l = _dispatch_one_group_sharded(
                xt_l, gv, ei, w_in, w_gate, w_out, cfg, cap, model_axis)
            return out_l.reshape(Bl, Sl, D), aux_l

        w_gate = p.get("w_gate", p["w_in"])
        out, aux = grouped(x, p["router"], p["w_in"], w_gate, p["w_out"])
        out = out.reshape(T, D)
    else:
        logits = (xt.astype(jnp.float32) @ p["router"])           # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, k)           # (T, k)
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

        # Load-balance aux loss (Switch): E * sum_e f_e * p_e
        me = jnp.mean(probs, axis=0)                              # (E,)
        ce = jnp.mean(jnp.sum(
            jax.nn.one_hot(expert_idx, E, dtype=jnp.float32), axis=1), axis=0)
        aux = E * jnp.sum(me * ce) * m.aux_loss_weight

        Tg = T // G
        capacity = int(max(1, (Tg * k * m.capacity_factor) // E))
        xg = xt.reshape(G, Tg, D)
        gg = gate_vals.reshape(G, Tg, k)
        eg = expert_idx.reshape(G, Tg, k)
        out = jax.vmap(_dispatch_one_group, in_axes=(0, 0, 0, None, None, None))(
            xg, gg, eg, p, cfg, capacity)
        out = out.reshape(T, D)

    if m.n_shared:
        sh = p["shared"]
        h = xt @ sh["w_in"].astype(x.dtype)
        if cfg.gated_mlp:
            h = _act(xt @ sh["w_gate"].astype(x.dtype), cfg.activation) * h
        else:
            h = _act(h, cfg.activation)
        out = out + h @ sh["w_out"].astype(x.dtype)

    return out.reshape(B, S, D), aux
