"""Selective state-space sub-layer (Mamba-style), used by Hymba's parallel
attention+SSM heads.

The recurrence h_t = dA_t * h_{t-1} + dB_t x_t ; y_t = C_t . h_t runs as a
``lax.scan`` over the sequence (train/prefill) or a single fused step
(decode, O(1) state — this is what makes the hybrid arch eligible for the
long_500k shape).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


class SSMState(NamedTuple):
    h: jax.Array          # (B, d_in, N)
    conv: jax.Array       # (B, conv_width-1, d_in) rolling input window


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    return d_in, dt_rank, s.state_dim, s.conv_width


def init_ssm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in, dt_rank, N, W = _dims(cfg)
    ks = jax.random.split(key, 7)
    sc = d ** -0.5
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * d_in)) * sc).astype(cfg.dtype),
        "conv_w": (jax.random.normal(ks[1], (W, d_in)) * 0.2).astype(cfg.dtype),
        "x_proj": (jax.random.normal(ks[2], (d_in, dt_rank + 2 * N)) * d_in ** -0.5).astype(cfg.dtype),
        "dt_proj": (jax.random.normal(ks[3], (dt_rank, d_in)) * dt_rank ** -0.5).astype(cfg.dtype),
        "dt_bias": jnp.zeros((d_in,), jnp.float32),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32), (d_in, 1))),
        "D_skip": jnp.ones((d_in,), jnp.float32),
        "out_proj": (jax.random.normal(ks[4], (d_in, d)) * d_in ** -0.5).astype(cfg.dtype),
    }


def init_ssm_state(cfg: ModelConfig, batch: int) -> SSMState:
    d_in, _, N, W = _dims(cfg)
    return SSMState(
        h=jnp.zeros((batch, d_in, N), jnp.float32),
        conv=jnp.zeros((batch, W - 1, d_in), cfg.dtype),
    )


def _ssm_core(p, xc, z, cfg: ModelConfig, h0):
    """xc: (B, S, d_in) post-conv activations; returns (y, hT)."""
    d_in, dt_rank, N, _ = _dims(cfg)
    A = -jnp.exp(p["A_log"])                                     # (d_in, N)
    proj = xc.astype(jnp.float32) @ p["x_proj"].astype(jnp.float32)
    dt, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"].astype(jnp.float32) + p["dt_bias"])

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp                                # (B,d_in) etc.
        dA = jnp.exp(dt_t[..., None] * A)                        # (B,d_in,N)
        dBx = (dt_t * x_t)[..., None] * B_t[:, None, :]          # (B,d_in,N)
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    xs = (
        jnp.moveaxis(xc.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(Bc, 1, 0),
        jnp.moveaxis(Cc, 1, 0),
    )
    from repro.models.layers import chunked_scan
    hT, ys = chunked_scan(step, h0, xs, chunk=128)
    y = jnp.moveaxis(ys, 0, 1)                                   # (B,S,d_in)
    y = y + xc.astype(jnp.float32) * p["D_skip"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return y, hT


def ssm_block(p, x, cfg: ModelConfig, state: SSMState | None = None,
              mode: str = "train"):
    """x: (B, S, D) -> (out, new_state).  decode: S == 1, O(1) step."""
    d_in, _, N, W = _dims(cfg)
    B, S, _ = x.shape
    xz = x @ p["in_proj"].astype(x.dtype)
    xs, z = jnp.split(xz, 2, axis=-1)                            # (B,S,d_in)

    if mode == "decode":
        assert state is not None and S == 1
        win = jnp.concatenate([state.conv, xs], axis=1)          # (B,W,d_in)
        xc = jnp.einsum("bwd,wd->bd", win.astype(jnp.float32),
                        p["conv_w"].astype(jnp.float32))[:, None]
        xc = jax.nn.silu(xc)
        y, hT = _ssm_core(p, xc, z, cfg, state.h)
        new_state = SSMState(h=hT, conv=win[:, 1:].astype(state.conv.dtype))
        return (y.astype(x.dtype) @ p["out_proj"].astype(x.dtype)), new_state

    # train / prefill: causal depthwise conv via padding
    pad = jnp.zeros((B, W - 1, d_in), xs.dtype) if state is None else state.conv
    xpad = jnp.concatenate([pad, xs], axis=1)                    # (B,S+W-1,d_in)
    stacked = jnp.stack([xpad[:, i:i + S] for i in range(W)], axis=0)
    xc = jnp.einsum("wbsd,wd->bsd", stacked.astype(jnp.float32),
                    p["conv_w"].astype(jnp.float32))
    xc = jax.nn.silu(xc)
    h0 = jnp.zeros((B, d_in, N), jnp.float32) if state is None else state.h
    y, hT = _ssm_core(p, xc, z, cfg, h0)
    new_state = SSMState(h=hT, conv=xpad[:, -(W - 1):].astype(cfg.dtype))
    return (y.astype(x.dtype) @ p["out_proj"].astype(x.dtype)), new_state
