"""RWKV-6 ("Finch") — attention-free token mixing with data-dependent decay.

Per layer: a time-mix block (the WKV matrix-state recurrence) and a
channel-mix block.  The per-head state S in R^{hd x hd} carries ALL context:
decode is O(1) per token regardless of history length, which is why rwkv6
runs the long_500k shape.

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

w_t is data-dependent (low-rank on x_t) — the Finch contribution.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


class RWKVState(NamedTuple):
    wkv: jax.Array        # (B, H, hd, hd)
    shift_t: jax.Array    # (B, D) last token (time-mix shift)
    shift_c: jax.Array    # (B, D) last token (channel-mix shift)


def _dims(cfg: ModelConfig):
    hd = cfg.rwkv.head_dim if cfg.rwkv else 64
    H = cfg.d_model // hd
    return H, hd


def init_rwkv(key, cfg: ModelConfig) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    H, hd = _dims(cfg)
    ks = jax.random.split(key, 10)
    sc = D ** -0.5
    lora = 64
    return {
        # time mix
        "mu_r": jnp.full((D,), 0.5, jnp.float32),
        "mu_k": jnp.full((D,), 0.5, jnp.float32),
        "mu_v": jnp.full((D,), 0.5, jnp.float32),
        "mu_g": jnp.full((D,), 0.5, jnp.float32),
        "mu_w": jnp.full((D,), 0.5, jnp.float32),
        "w_r": (jax.random.normal(ks[0], (D, D)) * sc).astype(cfg.dtype),
        "w_k": (jax.random.normal(ks[1], (D, D)) * sc).astype(cfg.dtype),
        "w_v": (jax.random.normal(ks[2], (D, D)) * sc).astype(cfg.dtype),
        "w_g": (jax.random.normal(ks[3], (D, D)) * sc).astype(cfg.dtype),
        "w_o": (jax.random.normal(ks[4], (D, D)) * sc).astype(cfg.dtype),
        # data-dependent decay (low-rank) + base
        "decay_base": jnp.full((D,), -6.0, jnp.float32),
        "decay_a": (jax.random.normal(ks[5], (D, lora)) * sc).astype(cfg.dtype),
        "decay_b": (jax.random.normal(ks[6], (lora, D)) * lora ** -0.5).astype(cfg.dtype),
        "bonus_u": jnp.zeros((H, hd), jnp.float32),
        "ln_x": jnp.ones((D,), jnp.float32),
        # channel mix
        "mu_ck": jnp.full((D,), 0.5, jnp.float32),
        "w_ck": (jax.random.normal(ks[7], (D, F)) * sc).astype(cfg.dtype),
        "w_cv": (jax.random.normal(ks[8], (F, D)) * F ** -0.5).astype(cfg.dtype),
        "w_cr": (jax.random.normal(ks[9], (D, D)) * sc).astype(cfg.dtype),
    }


def init_rwkv_state(cfg: ModelConfig, batch: int) -> RWKVState:
    H, hd = _dims(cfg)
    return RWKVState(
        wkv=jnp.zeros((batch, H, hd, hd), jnp.float32),
        shift_t=jnp.zeros((batch, cfg.d_model), cfg.dtype),
        shift_c=jnp.zeros((batch, cfg.d_model), cfg.dtype),
    )


def _shifted(x, last):
    """token shift: concat(last, x[:-1]) along seq."""
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1)


def time_mix(p, x, cfg: ModelConfig, state: RWKVState):
    B, S, D = x.shape
    H, hd = _dims(cfg)
    xprev = _shifted(x, state.shift_t)

    def lerp(mu):
        return x + (xprev - x) * mu.astype(x.dtype)

    r = (lerp(p["mu_r"]) @ p["w_r"].astype(x.dtype)).reshape(B, S, H, hd)
    k = (lerp(p["mu_k"]) @ p["w_k"].astype(x.dtype)).reshape(B, S, H, hd)
    v = (lerp(p["mu_v"]) @ p["w_v"].astype(x.dtype)).reshape(B, S, H, hd)
    g = jax.nn.silu(lerp(p["mu_g"]) @ p["w_g"].astype(x.dtype))

    # Finch: data-dependent decay in (0,1) per channel
    dd = (lerp(p["mu_w"]).astype(jnp.float32) @ p["decay_a"].astype(jnp.float32)
          ) @ p["decay_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(p["decay_base"] + dd))                  # (B,S,D)
    w = w.reshape(B, S, H, hd)
    u = p["bonus_u"]                                             # (H, hd)

    def step(Sst, inp):
        r_t, k_t, v_t, w_t = inp                                 # (B,H,hd)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)               # (B,H,hd,hd)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, Sst + u[None, :, :, None] * kv)
        Sst = w_t[..., None] * Sst + kv
        return Sst, y

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0)
               for t in (r, k, v, w))
    from repro.models.layers import chunked_scan
    ST, ys = chunked_scan(step, state.wkv, xs, chunk=128)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, D)

    # per-head group norm
    yh = y.reshape(B, S, H, hd)
    mean = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    y = ((yh - mean) * jax.lax.rsqrt(var + 1e-5)).reshape(B, S, D)
    y = y * p["ln_x"]
    out = (y.astype(x.dtype) * g) @ p["w_o"].astype(x.dtype)
    new_state = RWKVState(wkv=ST, shift_t=x[:, -1], shift_c=state.shift_c)
    return out, new_state


def channel_mix(p, x, cfg: ModelConfig, state: RWKVState):
    xprev = _shifted(x, state.shift_c)
    mk = x + (xprev - x) * p["mu_ck"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(mk @ p["w_ck"].astype(x.dtype)))
    rr = jax.nn.sigmoid(mk @ p["w_cr"].astype(x.dtype))
    out = rr * (kk @ p["w_cv"].astype(x.dtype))
    return out, RWKVState(wkv=state.wkv, shift_t=state.shift_t, shift_c=x[:, -1])
