"""Attention for every assigned architecture.

Three execution paths, chosen by sequence length and backend:

* dense softmax           — short sequences (compile-friendly);
* jnp blocked flash       — ``lax.scan`` over KV blocks with online softmax,
                            O(block) score memory (prefill_32k / train paths);
* Pallas flash kernel     — the TPU hot path (kernels/flash_attention.py).

Decode uses a *block-partitioned* KV cache laid out as
``(n_blk, blk, B, Hkv, D)``: each block computes a local partial softmax
(log-sum-exp form) and the partials combine exactly — so sharding n_blk over
the mesh "model" axis turns decode attention into embarrassingly-parallel
lookups plus a tiny cross-shard LSE combine (sequence-parallel decode), which
is what makes 500k-token KV caches feasible per-chip.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import AttnConfig, ModelConfig
from repro.models.layers import apply_mrope, apply_rope

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array          # (n_blk, blk, B, Hkv, D)
    v: jax.Array
    #: tokens currently stored.  Either () int32 — every row shares one
    #: timeline (train/fixed-group serving) — or (B,) int32 — each row has
    #: its own position (continuous batching: slots join/leave mid-flight,
    #: so their sequence lengths diverge).  Decode inserts at ``length`` and
    #: attends ``[start, length)``; with per-slot lengths both become
    #: per-row scatters/masks.
    length: jax.Array
    #: (B,) int32 — first VALID position per row.  Left-padded prefills set
    #: it to the pad width so decode attention never reads the pad K/V that
    #: prefill wrote into positions ``[0, start)``; everywhere else it is
    #: zeros (a no-op mask).
    start: jax.Array


def init_attn(key, cfg: ModelConfig, d_model: int | None = None) -> dict:
    a = cfg.attn
    d = d_model or cfg.d_model
    hq, hkv, hd = a.n_heads, a.n_kv_heads, a.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = d ** -0.5
    p = {
        "wq": (jax.random.normal(k1, (d, hq * hd)) * scale).astype(cfg.dtype),
        "wk": (jax.random.normal(k2, (d, hkv * hd)) * scale).astype(cfg.dtype),
        "wv": (jax.random.normal(k3, (d, hkv * hd)) * scale).astype(cfg.dtype),
        "wo": (jax.random.normal(k4, (hq * hd, d)) * scale).astype(cfg.dtype),
    }
    if a.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _split_heads(x, n, hd):
    B, S, _ = x.shape
    return x.reshape(B, S, n, hd).transpose(0, 2, 1, 3)     # (B, H, S, D)


def _merge_heads(x):
    B, H, S, D = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B, S, H * D)


def _qkv(p, x, a: AttnConfig, positions, cfg: ModelConfig):
    q = _split_heads(x @ p["wq"].astype(x.dtype), a.n_heads, a.head_dim)
    k = _split_heads(x @ p["wk"].astype(x.dtype), a.n_kv_heads, a.head_dim)
    v = _split_heads(x @ p["wv"].astype(x.dtype), a.n_kv_heads, a.head_dim)
    if a.qk_norm:
        from repro.models.layers import rms_norm
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if positions is not None:
        if a.mrope:
            pos3 = positions if positions.ndim == 3 else jnp.broadcast_to(
                positions, (3,) + positions.shape)
            q = apply_mrope(q, pos3, a.rope_theta, a.mrope_sections)
            k = apply_mrope(k, pos3, a.rope_theta, a.mrope_sections)
        else:
            pos = positions if positions.ndim == 2 else positions[0]
            q = apply_rope(q, pos, a.rope_theta)
            k = apply_rope(k, pos, a.rope_theta)
    return q, k, v


def _dense_attention(q, k, v, *, causal, window, offset=0, kv_len=None,
                     softcap=None, kv_mask=None):
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    group = Hq // Hkv
    qs = (q * (D ** -0.5)).astype(q.dtype)
    s = jnp.einsum("bghqd,bhkd->bghqk",
                   qs.reshape(B, group, Hkv, Sq, D), k,
                   preferred_element_type=jnp.float32)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    q_ids = jnp.arange(Sq)[:, None] + offset
    k_ids = jnp.arange(Sk)[None, :]
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m = m & (q_ids >= k_ids)
    if window is not None:
        m = m & (k_ids > q_ids - window)
    if kv_len is not None:
        m = m & (k_ids < kv_len)
    if kv_mask is not None:               # (B, Sk): pad keys drop per row
        mb = m[None] & kv_mask.astype(bool)[:, None, :]     # (B, Sq, Sk)
        s = jnp.where(mb[:, None, None], s, NEG_INF)
    else:
        s = jnp.where(m[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bghqk,bhkd->bghqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)


def _blocked_attention(q, k, v, *, causal, window, block_k, softcap=None,
                       kv_mask=None):
    """jnp flash: scan over KV blocks with online softmax (O(block) scores)."""
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    group = Hq // Hkv
    nk = -(-Sk // block_k)
    pad = nk * block_k - Sk
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = kp.reshape(B, Hkv, nk, block_k, D).transpose(2, 0, 1, 3, 4)
    vb = vp.reshape(B, Hkv, nk, block_k, D).transpose(2, 0, 1, 3, 4)
    qs = (q * (D ** -0.5)).astype(q.dtype).reshape(B, group, Hkv, Sq, D)
    q_ids = jnp.arange(Sq)[:, None]
    if kv_mask is not None:               # (B, Sk) -> per-block (nk, B, blk)
        kmb = jnp.pad(kv_mask.astype(bool), ((0, 0), (0, pad))
                      ).reshape(B, nk, block_k).transpose(1, 0, 2)
    else:
        kmb = jnp.ones((nk, 1, 1), bool)  # scanned placeholder (broadcasts)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        idx, kblk, vblk, km = inp
        s = jnp.einsum("bghqd,bhkd->bghqk", qs, kblk,
                       preferred_element_type=jnp.float32)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        k_ids = idx * block_k + jnp.arange(block_k)[None, :]
        msk = k_ids < Sk
        if causal:
            msk = msk & (q_ids >= k_ids)
        if window is not None:
            msk = msk & (k_ids > q_ids - window)
        # (B, Sq, blk): structural mask x per-row pad-key mask
        mb = msk[None] & km[:, None, :]
        s = jnp.where(mb[:, None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        pexp = jnp.exp(s - m_new)
        pexp = jnp.where(mb[:, None, None], pexp, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(pexp, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bghqk,bhkd->bghqd",
                                       pexp.astype(vblk.dtype), vblk,
                                       preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    # flash-style backward: the (.., Sq, block_k) score tensors are
    # recomputed per block instead of saved (they dominate attention bwd
    # memory at train time)
    step = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable,
                          prevent_cse=False)

    init = (
        jnp.full((B, group, Hkv, Sq, 1), NEG_INF, jnp.float32),
        jnp.zeros((B, group, Hkv, Sq, 1), jnp.float32),
        jnp.zeros((B, group, Hkv, Sq, D), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(step, init, (jnp.arange(nk), kb, vb, kmb))
    safe = jnp.where(l == 0, 1.0, l)
    return (acc / safe).reshape(B, Hq, Sq, D).astype(q.dtype)


def _banded_attention(q, k, v, *, window: int, softcap=None):
    """Exact sliding-window attention in O(S·w): queries in blocks of w
    attend only their own and the previous key block (causal window w means
    keys in (i-w, i] ⊂ those two blocks).  §Perf hillclimb H-1: at 32k/w=1024
    this removes 15/16 of attention compute AND score traffic vs blocked
    full attention."""
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    assert Sq == Sk, "banded path is for self-attention (train/prefill)"
    group = Hq // Hkv
    w = int(window)
    nb = -(-Sq // w)
    pad = nb * w - Sq
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))

    def blocks(x):                       # (B,H,nb,w,D)
        return x.reshape(B, x.shape[1], nb, w, D)

    qb, kb, vb = blocks(qp), blocks(kp), blocks(vp)
    kprev = jnp.pad(kb, ((0, 0), (0, 0), (1, 0), (0, 0), (0, 0)))[:, :, :-1]
    vprev = jnp.pad(vb, ((0, 0), (0, 0), (1, 0), (0, 0), (0, 0)))[:, :, :-1]
    k2 = jnp.concatenate([kprev, kb], axis=3)          # (B,Hkv,nb,2w,D)
    v2 = jnp.concatenate([vprev, vb], axis=3)

    qs = (qb * (D ** -0.5)).astype(q.dtype).reshape(B, group, Hkv, nb, w, D)
    s = jnp.einsum("bghnqd,bhnkd->bghnqk", qs, k2,
                   preferred_element_type=jnp.float32)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    # global positions: query i in block n is n*w+i; key j is (n-1)*w+j
    qi = jnp.arange(w)[:, None] + w                      # within [w, 2w)
    kj = jnp.arange(2 * w)[None, :]
    m = (qi >= kj) & (kj > qi - w)
    # padding keys (block -1 and tail) are masked by global positions
    kg = (jnp.arange(nb)[:, None] - 1) * w + jnp.arange(2 * w)[None, :]
    valid = (kg >= 0) & (kg < Sq)
    mask = m[None] & valid[:, None, :]
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bghnqk,bhnkd->bghnqd", p.astype(v2.dtype), v2,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, Hq, nb * w, D)[:, :, :Sq]
    return o.astype(q.dtype)


def _decode_attention_blocked(q, cache: KVCache, *, window=None, softcap=None):
    """One-token decode over the block-partitioned cache with exact LSE
    combination across blocks (sequence-parallel friendly)."""
    B, Hq, _, D = q.shape             # Sq == 1
    n_blk, blk = cache.k.shape[0], cache.k.shape[1]
    Hkv = cache.k.shape[3]
    group = Hq // Hkv
    qs = (q * (D ** -0.5)).astype(cache.k.dtype).reshape(B, group, Hkv, D)

    # scores per block: (n_blk, B, group, Hkv, blk)
    s = jnp.einsum("bghd,nkbhd->nbghk", qs, cache.k,
                   preferred_element_type=jnp.float32)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    # validity per (block, row, offset): rows may sit at different positions
    # (per-slot ``length``) and may start past 0 (left-pad ``start``).
    pos = jnp.arange(n_blk * blk).reshape(n_blk, 1, blk)          # (n,1,blk)
    length = cache.length
    lb = length[None, :, None] if length.ndim else length
    valid = pos < lb
    if window is not None:
        valid = valid & (pos > lb - 1 - window)
    valid = valid & (pos >= cache.start[None, :, None])
    valid = jnp.broadcast_to(valid, (n_blk, B, blk))
    s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)

    m_blk = jnp.max(s, axis=-1, keepdims=True)                    # (n,B,g,h,1)
    p = jnp.exp(s - m_blk)
    p = jnp.where(valid[:, :, None, None, :], p, 0.0)
    l_blk = jnp.sum(p, axis=-1, keepdims=True)
    o_blk = jnp.einsum("nbghk,nkbhd->nbghd", p.astype(cache.v.dtype), cache.v,
                       preferred_element_type=jnp.float32)

    m = jnp.max(m_blk, axis=0, keepdims=True)                     # global max
    w = jnp.exp(m_blk - m)                                        # (n,B,g,h,1)
    l = jnp.sum(l_blk * w, axis=0)                                # (B,g,h,1)
    o = jnp.sum(o_blk * w, axis=0)                                # (B,g,h,D)
    safe = jnp.where(l == 0, 1.0, l)
    out = (o / safe).reshape(B, Hq, 1, D)
    return out.astype(q.dtype)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  n_kv_heads: int | None = None,
                  per_slot: bool = False) -> KVCache:
    a = cfg.attn
    n_blk = max(cfg.kv_cache_blocks, 1)
    blk = -(-max_len // n_blk)
    hkv = n_kv_heads if n_kv_heads is not None else a.n_kv_heads
    shape = (n_blk, blk, batch, hkv, a.head_dim)
    return KVCache(
        k=jnp.zeros(shape, cfg.dtype),
        v=jnp.zeros(shape, cfg.dtype),
        # per_slot: every row tracks its own position (continuous batching)
        length=jnp.zeros((batch,) if per_slot else (), jnp.int32),
        start=jnp.zeros((batch,), jnp.int32),
    )


def cache_update_decode(cache: KVCache, k_new, v_new) -> KVCache:
    """Insert one token (S==1) at position ``length`` (per row if (B,))."""
    blk = cache.k.shape[1]
    pos = cache.length
    if pos.ndim:
        # per-slot positions: scatter each row's token at its own
        # (block, offset).  Rows past capacity scatter out of bounds and
        # are DROPPED (idle slots in a rolling batch decode dead air —
        # their writes must not wrap or clamp onto live rows' blocks).
        B = pos.shape[0]
        bi, off = pos // blk, pos % blk
        rows = jnp.arange(B)
        k = cache.k.at[bi, off, rows].set(
            k_new[:, :, 0].astype(cache.k.dtype), mode="drop")
        v = cache.v.at[bi, off, rows].set(
            v_new[:, :, 0].astype(cache.v.dtype), mode="drop")
        return cache._replace(k=k, v=v, length=pos + 1)
    bi, off = pos // blk, pos % blk
    # (B, Hkv, 1, D) -> (1, 1, B, Hkv, D) slab at (block, offset)
    k_slab = k_new.transpose(2, 0, 1, 3)[None].astype(cache.k.dtype)
    v_slab = v_new.transpose(2, 0, 1, 3)[None].astype(cache.v.dtype)
    k = jax.lax.dynamic_update_slice(cache.k, k_slab, (bi, off, 0, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_slab, (bi, off, 0, 0, 0))
    return cache._replace(k=k, v=v, length=pos + 1)


def cache_fill_prefill(cache: KVCache, k_full, v_full,
                       pad_mask=None) -> KVCache:
    """Write a full prefill (B, Hkv, S, D) into the blocked cache.

    ``pad_mask`` (B, S) bool, True = real token: rows record where their
    valid span begins (``start``, left-pad width) and — when the cache
    carries per-slot lengths — where it ends (right-pad rows stop at their
    true prompt length, so decode never attends the garbage tail).  A
    scalar-length cache keeps ``length = S`` and can therefore only mask
    LEFT pads; right-padded prefills require a per-slot cache."""
    n_blk, blk = cache.k.shape[0], cache.k.shape[1]
    B, Hkv, S, D = k_full.shape
    pad = n_blk * blk - S
    kp = jnp.pad(k_full, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v_full, ((0, 0), (0, 0), (0, pad), (0, 0)))
    k = kp.transpose(2, 0, 1, 3).reshape(n_blk, blk, B, Hkv, D)
    v = vp.transpose(2, 0, 1, 3).reshape(n_blk, blk, B, Hkv, D)
    if pad_mask is None:
        start = jnp.zeros((B,), jnp.int32)
        end = jnp.full((B,), S, jnp.int32)
    else:
        real = pad_mask.astype(bool)
        idx = jnp.arange(S, dtype=jnp.int32)[None, :]
        start = jnp.min(jnp.where(real, idx, S), axis=1).astype(jnp.int32)
        end = (jnp.max(jnp.where(real, idx, -1), axis=1) + 1).astype(jnp.int32)
    length = end if cache.length.ndim else jnp.asarray(S, jnp.int32)
    return KVCache(k=k.astype(cache.k.dtype), v=v.astype(cache.v.dtype),
                   length=length, start=start)


def attention_block(
    p: dict,
    x: jax.Array,                 # (B, S, d_model)
    cfg: ModelConfig,
    *,
    positions: jax.Array | None = None,
    causal: bool = True,
    window: int | None = None,
    cache: KVCache | None = None,
    mode: str = "train",          # train | prefill | decode
    cross_kv: tuple[jax.Array, jax.Array] | None = None,
    use_pallas: bool = False,
    pad_mask: jax.Array | None = None,   # (B, S) bool, True = real token
):
    """Full attention sub-layer.  Returns (out, new_cache|None, (k,v)|None).

    ``pad_mask`` (prefill/train): key positions that are padding are masked
    out of every query's softmax, and the prefill cache records each row's
    valid span so later decode steps skip the pad K/V too.  RoPE positions
    stay the plain ``arange`` — a left pad shifts every real token of a row
    by the same offset, and rotary scores depend only on relative distance,
    so the shift cancels; what does NOT cancel is attending pad K/V, which
    is exactly what the mask removes."""
    a = cfg.attn
    B, S, _ = x.shape

    if cross_kv is not None:
        q = _split_heads(x @ p["wq"].astype(x.dtype), a.n_heads, a.head_dim)
        k, v = cross_kv
        o = _dense_attention(q, k, v, causal=False, window=None,
                             softcap=a.logit_softcap)
        return _merge_heads(o) @ p["wo"].astype(x.dtype), None, None

    if positions is None:
        if mode == "decode" and cache is not None:
            length = cache.length
            positions = (length[:, None] if length.ndim
                         else jnp.broadcast_to(length[None, None], (B, 1)))
        else:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    q, k, v = _qkv(p, x, a, positions, cfg)

    new_cache = None
    if mode == "decode":
        assert cache is not None
        new_cache = cache_update_decode(cache, k, v)
        o = _decode_attention_blocked(q, new_cache, window=window,
                                      softcap=a.logit_softcap)
    else:
        if mode == "prefill" and cache is not None:
            new_cache = cache_fill_prefill(cache, k, v, pad_mask=pad_mask)
        if use_pallas and jax.default_backend() == "tpu" and pad_mask is None:
            from repro.kernels.flash_attention import flash_attention
            o = flash_attention(q, k, v, causal=causal, window=window)
        elif (cfg.banded_attention and a.window and not a.pattern_period
              and causal and k.shape[2] == S and S > 2 * a.window
              and pad_mask is None):
            o = _banded_attention(q, k, v, window=a.window,
                                  softcap=a.logit_softcap)
        elif k.shape[2] <= cfg.dense_attn_threshold:
            o = _dense_attention(q, k, v, causal=causal, window=window,
                                 softcap=a.logit_softcap, kv_mask=pad_mask)
        else:
            o = _blocked_attention(q, k, v, causal=causal, window=window,
                                   block_k=cfg.attn_block_k,
                                   softcap=a.logit_softcap, kv_mask=pad_mask)
    out = _merge_heads(o) @ p["wo"].astype(x.dtype)
    return out, new_cache, (k, v)
