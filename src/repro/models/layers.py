"""Shared building blocks: norms, embeddings, rotary position encodings."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(ms + eps)) * w.astype(jnp.float32)).astype(x.dtype)


def init_rms_norm(d: int) -> jax.Array:
    return jnp.ones((d,), jnp.float32)


def embed_tokens(embedding: jax.Array, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = embedding[tokens].astype(cfg.dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)
    return x


def unembed(x: jax.Array, head: jax.Array) -> jax.Array:
    return jnp.einsum("...d,dv->...v", x, head.astype(x.dtype))


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, H, S, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                  # (d/2,)
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,S,d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,         # (3, B, S): t/h/w position streams
    theta: float,
    sections: tuple[int, ...],
) -> jax.Array:
    """Qwen2-VL multimodal rotary: frequency bands partitioned into (t,h,w)
    sections, each rotated by its own position stream.  For pure text all
    three streams are equal and M-RoPE reduces to standard RoPE."""
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(d, theta)                                  # (half,)
    # section id per frequency: 0..len(sections)-1
    sec_ids = jnp.repeat(
        jnp.arange(len(sections)), jnp.asarray(sections), total_repeat_length=half)
    # pos_per_freq: (B, S, half)
    pos = jnp.take(positions, sec_ids, axis=0)                    # (half, B, S) -> via moveaxis
    pos = jnp.moveaxis(pos, 0, -1).astype(jnp.float32)            # (B, S, half)
    ang = pos[:, None, :, :] * freqs                              # (B,1,S,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def chunked_scan(step, init, xs, chunk: int = 128):
    """``lax.scan`` in two levels: an outer scan over sequence chunks whose
    body is ``jax.checkpoint``-ed, an inner scan over steps.

    Backward memory drops from O(S) saved carries to O(S/chunk + chunk):
    essential for the recurrent families (RWKV's (B,H,hd,hd) state saved at
    4096 steps is ~34 GiB; chunked it is ~0.8 GiB)."""
    leaves = jax.tree_util.tree_leaves(xs)
    S = leaves[0].shape[0]
    if S <= chunk:
        return jax.lax.scan(step, init, xs)
    n = S // chunk
    main = n * chunk
    xs_main = jax.tree_util.tree_map(
        lambda x: x[:main].reshape(n, chunk, *x.shape[1:]), xs)

    @jax.checkpoint
    def chunk_body(carry, xc):
        return jax.lax.scan(step, carry, xc)

    carry, ys_main = jax.lax.scan(chunk_body, init, xs_main)
    ys = jax.tree_util.tree_map(
        lambda y: y.reshape(main, *y.shape[2:]), ys_main)
    if main < S:
        xs_tail = jax.tree_util.tree_map(lambda x: x[main:], xs)
        carry, ys_tail = jax.lax.scan(step, carry, xs_tail)
        ys = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], axis=0), ys, ys_tail)
    return carry, ys


def causal_mask(sq: int, sk: int, offset: int = 0, window: int | None = None) -> jax.Array:
    """(sq, sk) bool mask; query i attends key j iff j <= i+offset (and within
    the sliding window when given)."""
    q_ids = jnp.arange(sq)[:, None] + offset
    k_ids = jnp.arange(sk)[None, :]
    m = q_ids >= k_ids
    if window is not None:
        m = m & (k_ids > q_ids - window)
    return m
