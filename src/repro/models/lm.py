"""Language-model training/serving entry points over the unified stack."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.config import ModelConfig


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None, z_loss: float = 1e-4):
    """Stable CE with optional z-loss.  logits (B,S,V), labels (B,S)."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    ce = lse - gold
    zl = z_loss * jnp.square(lse)
    loss = ce + zl
    if mask is not None:
        loss = loss * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        denom = jnp.asarray(loss.size, jnp.float32)
    return jnp.sum(loss) / denom


def make_batch_views(batch: dict[str, Any], cfg: ModelConfig):
    """Split a raw batch into model inputs + labels per family."""
    kw: dict[str, Any] = {}
    if cfg.encdec:
        kw["enc_embeds"] = batch["enc_embeds"]
        tokens = batch["tokens"]
        kw["tokens"] = tokens[:, :-1]
        labels = tokens[:, 1:]
    elif "input_embeds" in batch:     # vlm/audio stub frontends
        kw["input_embeds"] = batch["input_embeds"][:, :-1]
        labels = batch["tokens"][:, 1:]
        if "positions" in batch:
            kw["positions"] = batch["positions"][..., :-1]
    else:
        tokens = batch["tokens"]
        kw["tokens"] = tokens[:, :-1]
        labels = tokens[:, 1:]
    mask = batch.get("mask")
    if mask is not None:
        mask = mask[:, 1:]
    return kw, labels, mask


def chunked_cross_entropy(hidden, head, labels, mask=None, *,
                          chunk: int = 512, z_loss: float = 1e-4,
                          valid_vocab: int | None = None):
    """CE computed in sequence chunks so the (B, S, V) logits tensor is never
    materialized (vocab up to 262k x seq 4k would be tens of GB).  ``head``
    is (D, V); gradients flow through ``lax.map``."""
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    n = S // chunk
    main = n * chunk
    V = head.shape[-1]

    import functools

    # backward recomputes per-chunk logits (they are never stored)
    @functools.partial(jax.checkpoint, static_argnums=(2,))
    def ce_of(h, l, valid_vocab=None):
        from repro.models.shard_ctx import constrain_logits
        logits = constrain_logits(
            jnp.einsum("bsd,dv->bsv", h, head.astype(h.dtype)))
        lf = logits.astype(jnp.float32)
        if valid_vocab is not None and valid_vocab != V:
            lf = jnp.where(jnp.arange(V) < valid_vocab, lf, -1e30)
        lse = jax.scipy.special.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(lf, l[..., None], axis=-1)[..., 0]
        return (lse - gold) + z_loss * jnp.square(lse)

    hs = hidden[:, :main].reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    ls = labels[:, :main].reshape(B, n, chunk).transpose(1, 0, 2)
    losses = jax.lax.map(lambda hl: ce_of(hl[0], hl[1], valid_vocab),
                         (hs, ls))                               # (n,B,chunk)
    loss = losses.transpose(1, 0, 2).reshape(B, main)
    if main < S:
        loss = jnp.concatenate(
            [loss, ce_of(hidden[:, main:], labels[:, main:], valid_vocab)],
            axis=1)
    if mask is not None:
        loss = loss * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        denom = jnp.asarray(loss.size, jnp.float32)
    return jnp.sum(loss) / denom


def loss_fn(params, batch, cfg: ModelConfig, ce_chunk: int | None = None):
    kw, labels, mask = make_batch_views(batch, cfg)
    hidden, aux = tfm.forward_hidden(params, cfg, **kw)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return chunked_cross_entropy(hidden, head, labels, mask,
                                 chunk=ce_chunk or cfg.ce_chunk,
                                 valid_vocab=cfg.vocab_size) + aux


def greedy_generate(params, cfg: ModelConfig, prompt: jax.Array,
                    max_new: int, max_len: int | None = None):
    """Simple serving loop: prefill + greedy decode (CPU-scale demo)."""
    B, S = prompt.shape
    max_len = max_len or (S + max_new)
    caches = tfm.init_caches(cfg, B, max_len)
    logits, caches = tfm.prefill(params, cfg, tokens=prompt, caches=caches)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(prompt.dtype)
    outs = [tok]
    for _ in range(max_new - 1):
        logits, caches = tfm.decode_step(params, cfg, tok, caches)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(prompt.dtype)
        outs.append(tok)
    return jnp.concatenate(outs, axis=1)
