"""Activation-sharding context (sequence parallelism for the residual stream).

The launcher installs NamedShardings here before lowering; model code calls
``constrain_residual`` at layer boundaries.  With the residual stream
sharded (batch over data axes, sequence over "model"), the per-layer scan
carries saved for backward shrink by the TP extent — this is what lets the
48/88-layer configs fit HBM at seq 4096/32768.  When nothing is installed
(CPU tests) the calls are no-ops.
"""

from __future__ import annotations

from typing import Any

import jax

_RESIDUAL: Any = None      # NamedSharding for (B, S, D) activations
_CROSS_KV: Any = None      # NamedSharding for (L, B, Hkv, S, hd) enc-dec K/V
_MOE_GROUPS: Any = None    # NamedSharding for (G, ...) MoE dispatch groups
_LOGITS: Any = None        # NamedSharding for (B, S, V) logits chunks


def set_residual(sharding) -> None:
    global _RESIDUAL
    _RESIDUAL = sharding


def set_cross_kv(sharding) -> None:
    global _CROSS_KV
    _CROSS_KV = sharding


def set_moe_groups(sharding) -> None:
    global _MOE_GROUPS
    _MOE_GROUPS = sharding


def set_logits(sharding) -> None:
    global _LOGITS
    _LOGITS = sharding


def clear() -> None:
    set_residual(None)
    set_cross_kv(None)
    set_moe_groups(None)
    set_logits(None)


def constrain_residual(x: jax.Array) -> jax.Array:
    if _RESIDUAL is None or x.ndim != 3:
        return x
    return jax.lax.with_sharding_constraint(x, _RESIDUAL)


def constrain_cross_kv(x: jax.Array) -> jax.Array:
    if _CROSS_KV is None or x.ndim != 5:
        return x
    return jax.lax.with_sharding_constraint(x, _CROSS_KV)


def constrain_logits(x: jax.Array) -> jax.Array:
    if _LOGITS is None or x.ndim != 3:
        return x
    return jax.lax.with_sharding_constraint(x, _LOGITS)


def constrain_moe_groups(x: jax.Array) -> jax.Array:
    """Shard the leading group axis of (G, ...) MoE dispatch tensors."""
    if _MOE_GROUPS is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    base = _MOE_GROUPS
    spec = P(base.spec[0], *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(base.mesh, spec))
