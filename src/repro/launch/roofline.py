"""Roofline analysis from the compiled dry-run (§Roofline of EXPERIMENTS.md).

Methodology — XLA's ``cost_analysis`` counts a ``while`` (scan) body ONCE
regardless of trip count, so module-level numbers for a scan-over-layers
program undercount by ~L.  We therefore compile two *cost variants* of every
cell with layers UNROLLED (``scan_layers=False``) at n0/n1 layers and
extrapolate linearly:

    X_total = X(n1) + (L - n1) * (X(n1) - X(n0))

Variants also disable the two other inner loops that would be undercounted:
the chunked-CE ``lax.map`` (ce_chunk = full seq -> one iteration) and the
blocked-attention KV scan (dense_attn_threshold = inf).  The recurrent
families' per-token scans (RWKV WKV / Mamba SSM) cannot be unrolled at
S = 4k..500k; their FLOPs are added analytically (documented per-step op
counts) — they are linear-in-S elementwise updates, so the analytic model is
tight.  Memory/collective structure still comes from the REAL (production)
compile; the variants feed only the FLOP/byte extrapolation.

    terms (per chip; the SPMD module is the per-device program):
      compute    = flops / peak_bf16
      memory     = bytes / hbm_bw
      collective = sum(collective operand bytes) / (links * link_bw)
"""

from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "DRYRUN_XLA_FLAGS",
    "--xla_force_host_platform_device_count=512 "
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion")

import argparse
import json
from pathlib import Path

from repro import hardware
from repro.configs.registry import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, applicable
from repro.models.config import active_param_count, param_count

CHIP = hardware.TPU_V5E


# ---------------------------------------------------------------------------
# Analytic per-layer recurrent-scan FLOPs (see module docstring)
# ---------------------------------------------------------------------------


def moe_flops_per_device(cfg, shape, n_devices: int) -> float:
    """Expert FFN FLOPs inside the shard_map dispatch (cost_analysis does
    not descend into manual computations).  Capacity-based: per device,
    slots = (T/n_dev)*k*cf across E experts, each a (slots, D)x(D, F/tp)
    pair of matmuls (3 with gating), fwd x1 / train x4 (bwd 2x + remat)."""
    if cfg.moe is None:
        return 0.0
    m = cfg.moe
    if shape.kind == "decode":
        tokens = shape.global_batch
    else:
        tokens = shape.seq_len * shape.global_batch
    mult = 4.0 if shape.kind == "train" else 1.0
    mats = 3 if cfg.gated_mlp else 2
    slots_per_dev = (tokens / n_devices) * m.top_k * m.capacity_factor
    per_layer = 2.0 * mats * slots_per_dev * cfg.d_model * m.d_expert
    n_moe_layers = cfg.n_layers - m.first_k_dense
    return mult * per_layer * n_moe_layers


def recurrent_flops_per_device(cfg, shape, n_devices: int) -> float:
    """RWKV WKV / SSM scan FLOPs that no HLO variant can expose."""
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        mult = 4.0           # fwd + bwd(2x) + remat recompute(1x)
    elif shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        mult = 1.0
    else:
        tokens = shape.global_batch           # one token per sequence
        mult = 1.0
    total = 0.0
    if cfg.family == "ssm" and cfg.rwkv is not None:
        hd = cfg.rwkv.head_dim
        H = cfg.d_model // hd
        per_tok_layer = H * 8 * hd * hd       # kv outer + r·S + decay update
        total = cfg.n_layers * tokens * per_tok_layer
    if cfg.family == "hybrid" and cfg.ssm is not None:
        d_in = cfg.ssm.expand * cfg.d_model
        per_tok_layer = d_in * 8 * cfg.ssm.state_dim
        total = cfg.n_layers * tokens * per_tok_layer
    return mult * total / n_devices


def attention_score_bytes_per_device(cfg, shape, n_devices: int) -> float:
    """HBM traffic of materialized (Sq, Skv) attention scores in the cost
    variant (dense attention): ~4 f32 passes fwd+bwd per layer.  The Pallas
    flash kernel keeps these tiles in VMEM; subtracting them gives the
    flash-adjusted memory term."""
    if cfg.attn is None or shape.kind == "decode":
        return 0.0
    a = cfg.attn
    S = shape.seq_len
    B = shape.global_batch
    passes = 4.0 if shape.kind == "train" else 2.0
    per_layer = B * a.n_heads * S * S * 4.0 * passes
    return cfg.n_layers * per_layer / n_devices


def model_flops(cfg, shape, n_devices: int) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (fwd-only), N = active params."""
    n_active = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        per_tok = 6 * n_active
    elif shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        per_tok = 2 * n_active
    else:
        tokens = shape.global_batch
        per_tok = 2 * n_active
    return per_tok * tokens / n_devices


# ---------------------------------------------------------------------------
# Cost-variant compiles
# ---------------------------------------------------------------------------

VARIANT_OVERRIDES = dict(
    scan_layers=False,
    dense_attn_threshold=1 << 30,
    remat=True,
)


def variant_record(arch: str, shape_name: str, n_layers: int,
                   multi_pod: bool = False) -> dict:
    """Compile a cost variant (callable only inside a dryrun-flagged process)."""
    from repro.launch.dryrun import dryrun_cell   # requires 512-device env
    cfg = get_config(arch)
    over = dict(n_layers=n_layers)
    if cfg.encdec:
        over["n_encoder_layers"] = n_layers
    if cfg.moe is not None and cfg.moe.first_k_dense:
        import dataclasses
        over["moe"] = dataclasses.replace(cfg.moe, first_k_dense=1)
    cfg2 = cfg.with_runtime(**over)
    shape = SHAPES[shape_name]
    rt = dict(VARIANT_OVERRIDES)
    rt["ce_chunk"] = shape.seq_len + 1            # single CE map iteration
    return dryrun_cell(arch, shape_name, multi_pod=multi_pod,
                       cfg_override=cfg2, runtime_overrides=rt)


def extrapolate(rec0: dict, rec1: dict, n0: int, n1: int, L: int) -> dict:
    def lin(key):
        x0, x1 = rec0[key], rec1[key]
        return x1 + (L - n1) * ((x1 - x0) / (n1 - n0))

    out = {"flops": lin("flops"), "hlo_bytes": lin("hlo_bytes")}
    c0 = rec0["collective"]["bytes_by_op"]
    c1 = rec1["collective"]["bytes_by_op"]
    coll = {}
    for op in set(c0) | set(c1):
        a, b = c0.get(op, 0.0), c1.get(op, 0.0)
        coll[op] = max(b + (L - n1) * ((b - a) / (n1 - n0)), 0.0)
    out["collective_bytes_by_op"] = coll
    out["collective_bytes"] = sum(coll.values())
    return out


def roofline_terms(flops: float, bytes_: float, coll_bytes: float) -> dict:
    return {
        "compute_s": flops / CHIP.peak_bf16_flops,
        "memory_s": bytes_ / CHIP.hbm_bandwidth,
        "collective_s": coll_bytes / (CHIP.ici_links * CHIP.ici_link_bandwidth),
    }


def analyze_cell(arch: str, shape_name: str, real_rec: dict,
                 rec0: dict, rec1: dict, n0: int = 2, n1: int = 3) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    L = cfg.n_layers
    n_dev = real_rec["n_devices"]
    ext = extrapolate(rec0, rec1, n0, n1, L)
    rec_flops = recurrent_flops_per_device(cfg, shape, n_dev)
    rec_flops += moe_flops_per_device(cfg, shape, n_dev)
    flops = ext["flops"] + rec_flops
    terms = roofline_terms(flops, ext["hlo_bytes"], ext["collective_bytes"])
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape, n_dev)
    score_bytes = attention_score_bytes_per_device(cfg, shape, n_dev)
    mem_flash = max(ext["hlo_bytes"] - score_bytes, 0.0)
    total = sum(terms.values())
    peak_term = terms["compute_s"]
    return {
        "arch": arch, "shape": shape_name, "mesh": real_rec["mesh"],
        "n_devices": n_dev,
        "flops_per_device": flops,
        "bytes_per_device": ext["hlo_bytes"],
        "collective_bytes_per_device": ext["collective_bytes"],
        "collective_by_op": ext["collective_bytes_by_op"],
        "recurrent_flops_per_device": rec_flops,
        **terms,
        # the Pallas flash kernel (kernels/flash_attention.py) keeps scores
        # in VMEM: the memory term without materialized score traffic
        "memory_flash_s": mem_flash / CHIP.hbm_bandwidth,
        "attention_score_bytes": score_bytes,
        "dominant": dominant.replace("_s", ""),
        "model_flops_per_device": mf,
        "useful_flops_ratio": mf / max(flops, 1.0),
        # perfect overlap bound: step >= max(term); roofline fraction =
        # compute term / max-term (1.0 when compute-bound with full overlap)
        "roofline_fraction": peak_term / max(max(terms.values()), 1e-12),
        "memory_peak_gib": real_rec["memory"]["peak_bytes"] / 2**30,
        "params": real_rec["params"],
        "active_params": real_rec["active_params"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--variants-dir", default="results/roofline_variants")
    ap.add_argument("--out", default="results/roofline")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    args = ap.parse_args()

    vdir = Path(args.variants_dir)
    vdir.mkdir(parents=True, exist_ok=True)
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells = []
    for arch in ([args.arch] if args.arch else ARCH_IDS):
        for shape in ([args.shape] if args.shape else SHAPES):
            cells.append((arch, shape))

    for arch, shape in cells:
        real_path = Path(args.dryrun_dir) / f"{arch}__{shape}__sp.json"
        out_path = outdir / f"{arch}__{shape}.json"
        if out_path.exists():
            continue
        if not real_path.exists():
            continue
        real = json.loads(real_path.read_text())
        if real["status"] != "ok":
            out_path.write_text(json.dumps(real, indent=2))
            continue
        recs = {}
        fail = None
        for n in (2, 3):
            vpath = vdir / f"{arch}__{shape}__L{n}.json"
            if vpath.exists():
                recs[n] = json.loads(vpath.read_text())
            else:
                recs[n] = variant_record(arch, shape, n)
                vpath.write_text(json.dumps(recs[n], indent=2))
            if recs[n]["status"] != "ok":
                fail = recs[n]
        if fail is not None:
            out_path.write_text(json.dumps(
                {"arch": arch, "shape": shape, "status": "variant_error",
                 "error": fail.get("error")}, indent=2))
            print(f"[roofline] {arch} x {shape}: VARIANT FAIL")
            continue
        cell = analyze_cell(arch, shape, real, recs[2], recs[3])
        out_path.write_text(json.dumps(cell, indent=2))
        print(f"[roofline] {arch} x {shape}: dominant={cell['dominant']} "
              f"compute={cell['compute_s']*1e3:.1f}ms "
              f"memory={cell['memory_s']*1e3:.1f}ms "
              f"coll={cell['collective_s']*1e3:.1f}ms "
              f"useful={cell['useful_flops_ratio']:.2f}")


if __name__ == "__main__":
    main()
