"""Batched serving driver: prefill + decode with a request queue.

Continuous-batching-lite: requests are grouped into fixed decode batches;
each slot decodes until its request finishes, then a queued request takes
the slot at the next refill boundary.  The decode step is the same
``serve_step`` that the dry-run lowers for the production mesh.

Two drivers:

* ``--driver jit``     — raw ``jax.jit`` around prefill/decode (baseline).
* ``--driver mozart``  — the decode loop rides the AOT pipeline API
  (``mozart.pipeline``): prefill and decode are annotated library calls,
  lowered + compiled ahead of the request loop, and every decode step is a
  warm ``Pipeline.__call__`` (zero planner calls, zero retraces).  With
  ``MOZART_PLAN_CACHE`` set, a restarted replica replays the pinned plan.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
        --requests 8 --batch 4 --prompt-len 16 --max-new 16 --driver mozart
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.models import lm
from repro.models import transformer as tfm
from repro.models.config import ModelConfig

log = logging.getLogger("repro.serve")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


def _mozart_steps(cfg: ModelConfig):
    """Annotate prefill/decode as opaque library calls for the pipeline API.

    Every argument broadcasts ("_" — the values are whole-model state, not
    splittable rows) and the return is ``Unknown`` (logits + caches pytree):
    each step forms its own stage and runs the unmodified jitted function.
    What the pipeline API adds over raw ``jax.jit`` is the lifecycle: the
    plan is resolved ahead of the request loop and persists via the plan
    cache, so a restarted replica's first decode is already planned."""
    from repro.core import annotate
    from repro.core.split_types import Unknown, _

    decode = annotate(
        lambda p, tok, caches: tfm.decode_step(p, cfg, tok, caches),
        name="serve_decode_step", ret=Unknown(), p=_, tok=_, caches=_)
    prefill = annotate(
        lambda p, toks, caches: tfm.prefill(p, cfg, tokens=toks, caches=caches),
        name="serve_prefill", ret=Unknown(), p=_, toks=_, caches=_)
    return prefill, decode


class Server:
    def __init__(self, cfg: ModelConfig, params, batch: int, max_len: int,
                 driver: str = "jit", plan_cache_path: str | None = None):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.driver = driver
        if driver == "mozart":
            from repro.core import mozart
            prefill_fn, decode_fn = _mozart_steps(cfg)
            self._prefill = mozart.pipeline(
                prefill_fn, executor="eager", plan_cache_path=plan_cache_path)
            self._decode = mozart.pipeline(
                decode_fn, executor="eager", plan_cache_path=plan_cache_path)
        else:
            self._decode = jax.jit(
                lambda p, tok, caches: tfm.decode_step(p, cfg, tok, caches))
            self._prefill = jax.jit(
                lambda p, toks, caches: tfm.prefill(p, cfg, tokens=toks,
                                                    caches=caches))

    def warmup(self, prompt_len: int) -> None:
        """AOT: lower + compile both pipelines before the first request."""
        if self.driver != "mozart":
            return
        caches = tfm.init_caches(self.cfg, self.batch, self.max_len)
        toks = jnp.zeros((self.batch, prompt_len), jnp.int32)
        logits, caches = self._prefill.lower(self.params, toks, caches) \
                                      .compile()(self.params, toks, caches)
        tok = jnp.zeros((self.batch, 1), jnp.int32)
        self._decode.lower(self.params, tok, caches).compile()

    def run(self, requests: list[Request]) -> dict:
        t0 = time.time()
        queue = list(requests)
        tokens_out = 0
        decode_calls = 0
        decode_s = 0.0
        while queue:
            group = queue[: self.batch]
            queue = queue[self.batch:]
            # pad group to fixed batch
            while len(group) < self.batch:
                group.append(Request(rid=-1, prompt=group[0].prompt,
                                     max_new=group[0].max_new))
            plen = max(len(r.prompt) for r in group)
            prompts = np.stack([
                np.pad(r.prompt, (plen - len(r.prompt), 0)) for r in group])
            caches = tfm.init_caches(self.cfg, self.batch, self.max_len)
            logits, caches = self._prefill(self.params,
                                           jnp.asarray(prompts, jnp.int32),
                                           caches)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            steps = max(r.max_new for r in group)
            for _ in range(steps):
                for r, t in zip(group, np.asarray(tok)[:, 0]):
                    if r.rid >= 0 and not r.done:
                        r.out.append(int(t))
                        tokens_out += 1
                        if len(r.out) >= r.max_new:
                            r.done = True
                td = time.perf_counter()
                logits, caches = self._decode(self.params, tok, caches)
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
                decode_s += time.perf_counter() - td
                decode_calls += 1
        wall = time.time() - t0
        stats = {"wall_s": wall, "tokens": tokens_out,
                 "tokens_per_s": tokens_out / max(wall, 1e-9),
                 "decode_us_per_call": decode_s * 1e6 / max(decode_calls, 1)}
        if self.driver == "mozart":
            stats["decode_warm"] = self._decode.warm()
            stats["decode_last_call"] = dict(self._decode.last_call_stats)
        return stats


def main():
    logging.basicConfig(level=logging.INFO)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--driver", choices=("jit", "mozart"), default="jit")
    ap.add_argument("--plan-cache", default=None,
                    help="plan-cache path for --driver mozart (also honours "
                         "MOZART_PLAN_CACHE)")
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke else get_config(args.arch))
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, args.prompt_len),
                    max_new=args.max_new)
            for i in range(args.requests)]
    srv = Server(cfg, params, args.batch,
                 max_len=args.prompt_len + args.max_new + 1,
                 driver=args.driver, plan_cache_path=args.plan_cache)
    srv.warmup(args.prompt_len)
    stats = srv.run(reqs)
    print(f"served {stats['tokens']} tokens in {stats['wall_s']:.2f}s "
          f"({stats['tokens_per_s']:.1f} tok/s, "
          f"{stats['decode_us_per_call']:.0f}us/decode, driver={args.driver})")
    if args.driver == "mozart":
        print(f"decode warm={stats['decode_warm']} "
              f"last_call={stats['decode_last_call']}")


if __name__ == "__main__":
    main()
