"""Batched serving driver: prefill + decode with a request queue.

Continuous-batching-lite: requests are grouped into fixed decode batches;
each slot decodes until its request finishes, then a queued request takes
the slot at the next refill boundary.  The decode step is the same
``serve_step`` that the dry-run lowers for the production mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
        --requests 8 --batch 4 --prompt-len 16 --max-new 16
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.models import lm
from repro.models import transformer as tfm
from repro.models.config import ModelConfig

log = logging.getLogger("repro.serve")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    def __init__(self, cfg: ModelConfig, params, batch: int, max_len: int):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self._decode = jax.jit(
            lambda p, tok, caches: tfm.decode_step(p, cfg, tok, caches))
        self._prefill = jax.jit(
            lambda p, toks, caches: tfm.prefill(p, cfg, tokens=toks,
                                                caches=caches))

    def run(self, requests: list[Request]) -> dict:
        t0 = time.time()
        queue = list(requests)
        tokens_out = 0
        while queue:
            group = queue[: self.batch]
            queue = queue[self.batch:]
            # pad group to fixed batch
            while len(group) < self.batch:
                group.append(Request(rid=-1, prompt=group[0].prompt,
                                     max_new=group[0].max_new))
            plen = max(len(r.prompt) for r in group)
            prompts = np.stack([
                np.pad(r.prompt, (plen - len(r.prompt), 0)) for r in group])
            caches = tfm.init_caches(self.cfg, self.batch, self.max_len)
            logits, caches = self._prefill(self.params,
                                           jnp.asarray(prompts, jnp.int32),
                                           caches)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            steps = max(r.max_new for r in group)
            for _ in range(steps):
                for r, t in zip(group, np.asarray(tok)[:, 0]):
                    if r.rid >= 0 and not r.done:
                        r.out.append(int(t))
                        tokens_out += 1
                        if len(r.out) >= r.max_new:
                            r.done = True
                logits, caches = self._decode(self.params, tok, caches)
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        wall = time.time() - t0
        return {"wall_s": wall, "tokens": tokens_out,
                "tokens_per_s": tokens_out / max(wall, 1e-9)}


def main():
    logging.basicConfig(level=logging.INFO)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke else get_config(args.arch))
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, args.prompt_len),
                    max_new=args.max_new)
            for i in range(args.requests)]
    srv = Server(cfg, params, args.batch,
                 max_len=args.prompt_len + args.max_new + 1)
    stats = srv.run(reqs)
    print(f"served {stats['tokens']} tokens in {stats['wall_s']:.2f}s "
          f"({stats['tokens_per_s']:.1f} tok/s)")


if __name__ == "__main__":
    main()
