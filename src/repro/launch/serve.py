"""Batched serving driver: prefill + decode with a request queue.

Two scheduling modes:

* ``--mode continuous`` (default) — ``Server.run`` rides the
  continuous-batching scheduler (``repro.core.serving``): requests join a
  rolling decode batch at step boundaries and leave the moment they finish,
  so a finished request's slot refills immediately instead of decoding dead
  air until the group's ``max(r.max_new)``.
* ``--mode fixed`` — the legacy fixed-group batcher (baseline): requests
  are grouped into fixed decode batches; each group drains fully before the
  next is admitted.  Prompts are left-padded to the group's longest prompt
  and prefill masks the pad keys out of every attention softmax.

Two drivers, orthogonal to the mode:

* ``--driver jit``     — raw ``jax.jit`` around prefill/decode (baseline).
* ``--driver mozart``  — the decode loop rides the AOT pipeline API
  (``mozart.pipeline``): prefill and decode are annotated library calls,
  lowered + compiled ahead of the request loop, and every decode step is a
  warm ``Pipeline.__call__`` (zero planner calls, zero retraces).  With
  ``MOZART_PLAN_CACHE`` set, a restarted replica replays the pinned plan.

``decode_us_per_call`` is honest per-step latency: the timer spans the
decode dispatch AND the host sync on the sampled token (``np.asarray`` of
the argmax), not just the async dispatch.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
        --requests 8 --batch 4 --prompt-len 16 --max-new 16 --driver mozart
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.models import lm
from repro.models import transformer as tfm
from repro.models.config import ModelConfig

log = logging.getLogger("repro.serve")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


def _mozart_steps(cfg: ModelConfig):
    """Annotate prefill/decode as opaque library calls for the pipeline API.

    Every argument broadcasts ("_" — the values are whole-model state, not
    splittable rows) and the return is ``Unknown`` (logits + caches pytree):
    each step forms its own stage and runs the unmodified jitted function.
    What the pipeline API adds over raw ``jax.jit`` is the lifecycle: the
    plan is resolved ahead of the request loop and persists via the plan
    cache, so a restarted replica's first decode is already planned."""
    from repro.core import annotate
    from repro.core.split_types import Unknown, _

    decode = annotate(
        lambda p, tok, caches: tfm.decode_step(p, cfg, tok, caches),
        name="serve_decode_step", ret=Unknown(), p=_, tok=_, caches=_)
    prefill = annotate(
        lambda p, toks, mask, caches: tfm.prefill(p, cfg, tokens=toks,
                                                  caches=caches,
                                                  pad_mask=mask),
        name="serve_prefill", ret=Unknown(), p=_, toks=_, mask=_, caches=_)
    return prefill, decode


class Server:
    def __init__(self, cfg: ModelConfig, params, batch: int, max_len: int,
                 driver: str = "jit", plan_cache_path: str | None = None,
                 mode: str = "continuous"):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.driver = driver
        self.mode = mode
        self._batcher = None
        if mode == "continuous":
            from repro.core.serving import ContinuousBatcher
            self._batcher = ContinuousBatcher(
                cfg, params, batch, max_len, driver=driver,
                plan_cache_path=plan_cache_path)
            return
        if driver == "mozart":
            from repro.core import mozart
            prefill_fn, decode_fn = _mozart_steps(cfg)
            self._prefill = mozart.pipeline(
                prefill_fn, executor="eager", plan_cache_path=plan_cache_path)
            self._decode = mozart.pipeline(
                decode_fn, executor="eager", plan_cache_path=plan_cache_path)
        else:
            self._decode = jax.jit(
                lambda p, tok, caches: tfm.decode_step(p, cfg, tok, caches))
            self._prefill = jax.jit(
                lambda p, toks, mask, caches: tfm.prefill(
                    p, cfg, tokens=toks, caches=caches, pad_mask=mask))

    def warmup(self, prompt_len: int) -> None:
        """AOT: lower + compile the pipelines before the first request."""
        if self.mode == "continuous":
            if self._batcher.pad_free:
                self._batcher.warmup(prompt_lens=[prompt_len])
            else:
                self._batcher.warmup(max_prompt_len=prompt_len)
            return
        if self.driver != "mozart":
            return
        caches = tfm.init_caches(self.cfg, self.batch, self.max_len)
        toks = jnp.zeros((self.batch, prompt_len), jnp.int32)
        mask = jnp.ones((self.batch, prompt_len), bool)
        logits, caches = self._prefill.lower(self.params, toks, mask, caches) \
                                      .compile()(self.params, toks, mask,
                                                 caches)
        tok = jnp.zeros((self.batch, 1), jnp.int32)
        self._decode.lower(self.params, tok, caches).compile()

    def run(self, requests: list[Request]) -> dict:
        if self.mode == "continuous":
            return self._run_continuous(requests)
        return self._run_fixed(requests)

    def _run_continuous(self, requests: list[Request]) -> dict:
        from repro.core.serving import ServeRequest
        sreqs = [ServeRequest(rid=r.rid, prompt=np.asarray(r.prompt, np.int32),
                              max_new=r.max_new) for r in requests]
        stats = self._batcher.run(sreqs)
        for r, s in zip(requests, sreqs):
            r.out[:] = s.out
            r.done = True
        if self.driver == "mozart":
            stats["decode_warm"] = self._batcher._decode.warm()
            stats["decode_last_call"] = dict(
                self._batcher._decode.last_call_stats)
        return stats

    def _run_fixed(self, requests: list[Request]) -> dict:
        t0 = time.time()
        queue = list(requests)
        tokens_out = 0
        decode_calls = 0
        decode_s = 0.0
        while queue:
            group = queue[: self.batch]
            queue = queue[self.batch:]
            # pad group to fixed batch
            while len(group) < self.batch:
                group.append(Request(rid=-1, prompt=group[0].prompt,
                                     max_new=group[0].max_new))
            plen = max(len(r.prompt) for r in group)
            # left-pad to the group's longest prompt; the mask keeps the pad
            # keys out of every attention softmax and out of the KV cache's
            # valid span (True = real token).
            prompts = np.stack([
                np.pad(r.prompt, (plen - len(r.prompt), 0)) for r in group])
            mask = np.stack([
                np.arange(plen) >= plen - len(r.prompt) for r in group])
            caches = tfm.init_caches(self.cfg, self.batch, self.max_len)
            logits, caches = self._prefill(self.params,
                                           jnp.asarray(prompts, jnp.int32),
                                           jnp.asarray(mask),
                                           caches)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            steps = max(r.max_new for r in group)
            for _ in range(steps):
                for r, t in zip(group, np.asarray(tok)[:, 0]):
                    if r.rid >= 0 and not r.done:
                        r.out.append(int(t))
                        tokens_out += 1
                        if len(r.out) >= r.max_new:
                            r.done = True
                # time through the host sync on the sampled token: dispatch
                # alone would report async-enqueue cost, not decode latency.
                td = time.perf_counter()
                logits, caches = self._decode(self.params, tok, caches)
                tok_host = np.asarray(
                    jnp.argmax(logits[:, -1], axis=-1)).astype(np.int32)
                decode_s += time.perf_counter() - td
                tok = jnp.asarray(tok_host)[:, None]
                decode_calls += 1
        wall = time.time() - t0
        stats = {"wall_s": wall, "tokens": tokens_out,
                 "tokens_per_s": tokens_out / max(wall, 1e-9),
                 "decode_us_per_call": decode_s * 1e6 / max(decode_calls, 1)}
        if self.driver == "mozart":
            stats["decode_warm"] = self._decode.warm()
            stats["decode_last_call"] = dict(self._decode.last_call_stats)
        return stats


def main():
    logging.basicConfig(level=logging.INFO)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--driver", choices=("jit", "mozart"), default="jit")
    ap.add_argument("--mode", choices=("continuous", "fixed"),
                    default="continuous")
    ap.add_argument("--plan-cache", default=None,
                    help="plan-cache path for --driver mozart (also honours "
                         "MOZART_PLAN_CACHE)")
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke else get_config(args.arch))
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, args.prompt_len),
                    max_new=args.max_new)
            for i in range(args.requests)]
    srv = Server(cfg, params, args.batch,
                 max_len=args.prompt_len + args.max_new + 1,
                 driver=args.driver, plan_cache_path=args.plan_cache,
                 mode=args.mode)
    srv.warmup(args.prompt_len)
    stats = srv.run(reqs)
    print(f"served {stats['tokens']} tokens in {stats['wall_s']:.2f}s "
          f"({stats['tokens_per_s']:.1f} tok/s, "
          f"{stats['decode_us_per_call']:.0f}us/decode, driver={args.driver}, "
          f"mode={args.mode})")
    if args.mode == "continuous":
        print(f"decode p50={stats['decode_p50_us']:.0f}us "
              f"p99={stats['decode_p99_us']:.0f}us  "
              f"request p50={stats['request_p50_ms']:.1f}ms "
              f"p99={stats['request_p99_ms']:.1f}ms  "
              f"occupancy={stats['mean_occupancy']:.2f}")
    if args.driver == "mozart":
        print(f"decode warm={stats['decode_warm']} "
              f"last_call={stats['decode_last_call']}")


if __name__ == "__main__":
    main()
