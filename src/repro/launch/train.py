"""End-to-end training driver.

CPU-scale runs train a real (reduced or full) config with the full
production stack: pjit + mesh, ZeRO-1 AdamW, SA-annotated data pipeline,
async checkpointing, straggler watchdog, and crash-restart.  The same
driver, pointed at a TPU fleet and the full mesh, is the production
entry point.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-7b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import functools
import logging
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.ckpt import checkpoint as ckpt
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.data.pipeline import DataPipeline
from repro.launch import shardings as shd
from repro.launch.mesh import data_axes_of, dp_extent, make_host_mesh, set_mesh
from repro.models import lm
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.runtime.fault import FaultConfig, StepTimer, with_retries

log = logging.getLogger("repro.train")


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig, mesh,
                    p_shard, o_shard, b_shard):
    def step_fn(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lm.loss_fn)(params, batch, cfg)
        new_p, new_s, metrics = adamw.update(params, grads, opt_state, opt_cfg)
        return new_p, new_s, {"loss": loss, **metrics}

    metric_shard = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()),
        {"loss": 0.0, "lr": 0.0, "grad_norm": 0.0})
    return jax.jit(step_fn, donate_argnums=(0, 1),
                   in_shardings=(p_shard, o_shard, b_shard),
                   out_shardings=(p_shard, o_shard, metric_shard))


def train(cfg: ModelConfig, *, steps: int, batch: int, seq: int,
          ckpt_dir: str | None = None, ckpt_every: int = 20,
          lr: float = 3e-4, seed: int = 0, mesh=None,
          log_every: int = 10, resume: bool = True):
    mesh = mesh or make_host_mesh(n_data=1, n_model=1)
    opt_cfg = adamw.AdamWConfig(lr=lr, total_steps=max(steps, 2),
                                warmup_steps=max(steps // 20, 1))

    params_aval = jax.eval_shape(
        functools.partial(tfm.init_model, cfg=cfg), jax.random.PRNGKey(seed))
    p_specs = shd.param_specs(params_aval, mesh)
    p_shard = shd.named(p_specs, mesh)
    m_specs = shd.zero1_specs(params_aval, mesh)
    o_shard = shd.named(adamw.AdamWState(step=P(), m=m_specs, v=m_specs), mesh)

    pipe = DataPipeline(cfg, batch, seq, seed=seed)
    b0 = pipe.batch_for_step(0)
    b_specs = shd.batch_specs(jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), b0), mesh)
    b_shard = shd.named(b_specs, mesh)

    step_fn = make_train_step(cfg, opt_cfg, mesh, p_shard, o_shard, b_shard)

    # -- init or resume -------------------------------------------------------
    start = 0
    saver = ckpt.AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    if ckpt_dir and resume and ckpt.latest_step(ckpt_dir) is not None:
        start = ckpt.latest_step(ckpt_dir)
        meta_tree = {"params": params_aval,
                     "opt": jax.eval_shape(adamw.init, params_aval)}
        restored = ckpt.restore(ckpt_dir, start, meta_tree,
                                {"params": p_shard, "opt": o_shard})
        params, opt_state = restored["params"], restored["opt"]
        log.info("resumed from step %d", start)
    else:
        with set_mesh(mesh):
            params = jax.jit(functools.partial(tfm.init_model, cfg=cfg),
                             out_shardings=p_shard)(jax.random.PRNGKey(seed))
            opt_state = jax.jit(adamw.init, out_shardings=o_shard)(params)

    timer = StepTimer(FaultConfig())
    losses = []
    t_start = time.time()
    for step, raw in pipe.iterate(start):
        if step >= steps:
            break
        hbatch = jax.device_put(raw, b_shard)

        def one():
            return step_fn(params, opt_state, hbatch)

        t0 = time.time()
        params, opt_state, metrics = with_retries(one, retries=1)
        loss = float(metrics["loss"])
        timer.record(step, time.time() - t0)
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            log.info("step %d loss %.4f lr %.2e gnorm %.2f (%.2fs)",
                     step, loss, float(metrics["lr"]),
                     float(metrics["grad_norm"]), time.time() - t0)
        if saver and step > 0 and step % ckpt_every == 0:
            saver.save_async(step, {"params": params, "opt": opt_state},
                             meta={"arch": cfg.name})
    pipe.stop()
    if saver:
        saver.save_async(steps, {"params": params, "opt": opt_state},
                         meta={"arch": cfg.name})
        saver.wait()
    wall = time.time() - t_start
    return {"params": params, "opt_state": opt_state, "losses": losses,
            "wall_s": wall, "stragglers": timer.stragglers}


def main():
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke else get_config(args.arch))
    out = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                lr=args.lr, resume=not args.no_resume)
    print(f"final loss {out['losses'][-1]:.4f} "
          f"(first {out['losses'][0]:.4f}) in {out['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
