"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from results/."""

from __future__ import annotations

import json
from pathlib import Path

from repro.configs.registry import ARCH_IDS
from repro.configs.shapes import SHAPES


def _load(path: Path) -> dict | None:
    return json.loads(path.read_text()) if path.exists() else None


def dryrun_table(dryrun_dir="results/dryrun") -> str:
    d = Path(dryrun_dir)
    lines = [
        "| arch | shape | 16x16: status / peak GiB / compile s | 2x16x16: status / peak GiB |",
        "|------|-------|----------------------------------|---------------------------|",
    ]
    n_ok_sp = n_ok_mp = n_skip = 0
    for arch in ARCH_IDS:
        for shape in SHAPES:
            sp = _load(d / f"{arch}__{shape}__sp.json")
            mp = _load(d / f"{arch}__{shape}__mp.json")

            def fmt(r, with_compile=False):
                if r is None:
                    return "—"
                if r["status"] == "skipped":
                    return "skip (sub-quadratic rule)"
                if r["status"] != "ok":
                    return f"ERROR {r.get('error','')[:40]}"
                peak = r["memory"]["peak_bytes"] / 2**30
                s = f"ok / {peak:.1f}"
                if with_compile:
                    s += f" / {r.get('compile_s', 0):.0f}s"
                return s

            if sp and sp["status"] == "ok":
                n_ok_sp += 1
            if sp and sp["status"] == "skipped":
                n_skip += 1
            if mp and mp["status"] == "ok":
                n_ok_mp += 1
            lines.append(f"| {arch} | {shape} | {fmt(sp, True)} | {fmt(mp)} |")
    lines.append("")
    lines.append(f"Single-pod: **{n_ok_sp} ok**, {n_skip} documented skips; "
                 f"multi-pod: **{n_ok_mp} ok**.")
    return "\n".join(lines)


def roofline_table(roofline_dir="results/roofline") -> str:
    d = Path(roofline_dir)
    lines = [
        "| arch | shape | compute ms | memory ms | collective ms | dominant | useful FLOP ratio | roofline frac | peak GiB |",
        "|------|-------|-----------|-----------|---------------|----------|-------------------|---------------|----------|",
    ]
    for arch in ARCH_IDS:
        for shape in SHAPES:
            r = _load(d / f"{arch}__{shape}.json")
            if r is None:
                continue
            if "compute_s" not in r:
                reason = r.get("reason", r.get("error", r.get("status", "")))
                lines.append(f"| {arch} | {shape} | — | — | — | skip | — | — | — |")
                continue
            lines.append(
                f"| {arch} | {shape} | {r['compute_s']*1e3:.1f} | "
                f"{r['memory_s']*1e3:.1f} | {r['collective_s']*1e3:.2f} | "
                f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
                f"{r['roofline_fraction']:.2f} | {r['memory_peak_gib']:.1f} |")
    return "\n".join(lines)


def collective_detail(roofline_dir="results/roofline", top=6) -> str:
    d = Path(roofline_dir)
    rows = []
    for f in sorted(d.glob("*.json")):
        r = _load(f)
        if r and "collective_s" in r:
            rows.append((r["collective_s"], r))
    rows.sort(reverse=True, key=lambda t: t[0])
    lines = ["Most collective-bound cells (per-device bytes by op):", ""]
    for _, r in rows[:top]:
        ops = {k: f"{v/2**30:.2f}GiB" for k, v in r["collective_by_op"].items()
               if v > 2**20}
        lines.append(f"* {r['arch']} × {r['shape']}: {ops}")
    return "\n".join(lines)


if __name__ == "__main__":
    print("## Dry-run\n")
    print(dryrun_table())
    print("\n## Roofline\n")
    print(roofline_table())
    print()
    print(collective_detail())
