"""Sharding rules: DP/TP/EP/SP for every architecture (GSPMD partition specs).

Rules are divisibility-guarded: a dimension shards over "model" only when the
extent divides (e.g. granite's single KV head and hymba's 25 q-heads stay
replicated while their MLP/SSM inner dims shard).  Optimizer moments
additionally shard over the data axes on the first shardable dimension
(ZeRO-1): GSPMD then renders the update as reduce-scatter(grad) -> sharded
update -> all-gather(param).

Decode KV caches are laid out (n_blk, blk, B, Hkv, hd) with n_blk == TP
extent and sharded over "model": sequence-parallel decode (the LSE-combined
attention in models/attention.py keeps the math exact).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes_of, dp_extent
from repro.models.config import ModelConfig


def _dp(mesh):
    axes = data_axes_of(mesh)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def _model_extent(mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1


def _shard_if(mesh, dim_size: int) -> Any:
    """'model' if divisible (and the axis exists), else None."""
    me = _model_extent(mesh)
    return "model" if me > 1 and dim_size % me == 0 else None


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def _leaf_spec(path_names: tuple[str, ...], shape: tuple[int, ...], mesh) -> P:
    name = path_names[-1]
    nd = len(shape)
    # A leading layer-stack axis exists when 'layers'/'enc_layers' is on the
    # path AND the leaf has one more dim than its logical rank.
    stacked = any(n in ("layers", "enc_layers") for n in path_names)

    def wrap(*spec):
        if stacked:
            return P(None, *spec)
        return P(*spec)

    core = shape[1:] if stacked else shape
    cnd = len(core)

    if name == "embed":
        return P(_shard_if(mesh, shape[0]), None)
    if name == "lm_head":
        return P(None, _shard_if(mesh, shape[1]))

    # attention / general (in, out) matrices — shard the "wide" dim
    if name in ("wq", "wk", "wv", "w_in", "w_gate", "w_r", "w_k", "w_v",
                "w_g", "w_ck", "in_proj", "dt_proj", "x_proj_unused"):
        if cnd == 2:
            return wrap(None, _shard_if(mesh, core[1]))
        if cnd == 3:     # MoE experts (E, D, F): TP on the expert FFN dim —
            # composes with grouped dispatch (groups take the device axes)
            return wrap(None, None, _shard_if(mesh, core[2]))
    if name in ("wo", "w_out", "w_o", "w_cv", "out_proj", "x_proj"):
        if cnd == 2:
            return wrap(_shard_if(mesh, core[0]), None)
        if cnd == 3:     # MoE (E, F, D)
            return wrap(None, _shard_if(mesh, core[1]), None)
    if name == "router":
        return wrap(None, None)
    if name in ("conv_w",):          # (W, d_in)
        return wrap(None, _shard_if(mesh, core[1]))
    if name in ("A_log",):           # (d_in, N)
        return wrap(_shard_if(mesh, core[0]), None)
    if name in ("dt_bias", "D_skip"):
        return wrap(_shard_if(mesh, core[0]))
    if name in ("decay_a",):         # (D, lora)
        return wrap(None, None)
    if name in ("decay_b",):
        return wrap(None, None)
    # norms, mu_*, biases, bonus: replicate
    return wrap(*([None] * cnd))


def _path_names(path) -> tuple[str, ...]:
    names = []
    for e in path:
        if isinstance(e, jax.tree_util.DictKey):
            names.append(str(e.key))
        elif isinstance(e, jax.tree_util.SequenceKey):
            names.append(str(e.idx))
        elif isinstance(e, jax.tree_util.GetAttrKey):
            names.append(str(e.name))
        else:
            names.append(str(e))
    return tuple(names)


def param_specs(param_avals, mesh) -> Any:
    def spec(path, leaf):
        return _leaf_spec(_path_names(path), tuple(leaf.shape), mesh)
    return jax.tree_util.tree_map_with_path(spec, param_avals)


def zero1_specs(param_avals, mesh) -> Any:
    """Optimizer-moment specs: param spec + data sharding on the first
    still-unsharded, divisible dimension (ZeRO-1)."""
    dpa = _dp(mesh)
    dpe = dp_extent(mesh)
    base = param_specs(param_avals, mesh)

    def add_dp(leaf, spec):
        if dpa is None or dpe <= 1:
            return spec
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for i, (s, dim) in enumerate(zip(parts, leaf.shape)):
            if s is None and dim % dpe == 0 and dim >= dpe:
                parts[i] = dpa
                return P(*parts)
        return spec

    return jax.tree_util.tree_map(add_dp, param_avals, base)


# ---------------------------------------------------------------------------
# Batch / cache / activation specs
# ---------------------------------------------------------------------------


def batch_specs(batch_avals, mesh) -> Any:
    dpa = _dp(mesh)
    dpe = dp_extent(mesh)

    def spec(path, leaf):
        names = _path_names(path)
        nd = len(leaf.shape)
        if names[-1] == "positions":           # (3, B, S)
            b = leaf.shape[1]
            return P(None, dpa if b % dpe == 0 else None, None)
        b = leaf.shape[0]
        return P(dpa if b % dpe == 0 else None, *([None] * (nd - 1)))

    return jax.tree_util.tree_map_with_path(spec, batch_avals)


def cache_specs(cache_avals, mesh, cfg: ModelConfig) -> Any:
    """Stacked (L, ...) decode state.  KV: (L, n_blk, blk, B, Hkv, hd)."""
    dpa = _dp(mesh)
    dpe = dp_extent(mesh)
    me = _model_extent(mesh)

    def dp_if(b):
        return dpa if b % dpe == 0 else None

    def spec(path, leaf):
        names = _path_names(path)
        nd = len(leaf.shape)
        if names and names[-1] in ("k", "v") and nd == 6:
            nblk, b = leaf.shape[1], leaf.shape[3]
            return P(None, "model" if me > 1 and nblk % me == 0 else None,
                     None, dp_if(b), None, None)
        if names and names[-1] == "length":
            return P(*([None] * nd))
        if names and names[-1] == "h" and nd == 4:       # SSM (L,B,d_in,N)
            return P(None, dp_if(leaf.shape[1]), _shard_if(mesh, leaf.shape[2]), None)
        if names and names[-1] == "conv" and nd == 4:    # (L,B,W-1,d_in)
            return P(None, dp_if(leaf.shape[1]), None, _shard_if(mesh, leaf.shape[3]))
        if names and names[-1] == "wkv" and nd == 5:     # (L,B,H,hd,hd)
            return P(None, dp_if(leaf.shape[1]), _shard_if(mesh, leaf.shape[2]), None, None)
        if names and names[-1] in ("shift_t", "shift_c") and nd == 3:
            return P(None, dp_if(leaf.shape[1]), None)
        if nd >= 1:
            return P(None, *([None] * (nd - 1)))
        return P()
    return jax.tree_util.tree_map_with_path(spec, cache_avals)


def named(tree_specs, mesh):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), tree_specs)
