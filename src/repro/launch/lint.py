"""Mozart lint CLI: run the annotation verifier over the whole repo.

    PYTHONPATH=src python -m repro.launch.lint [-v] [--json out.json]

Three sweeps, all through ``repro.core.analysis``:

* **contract** — every shipped split type against the MZ1xx laws, every
  integration's annotated ops against the SA condition (MZ108), plus the
  plan-cache guard audit (MZ205);
* **examples** — representative pipelines (the same shapes as examples/:
  numpy chain, image chain, table chain, NLP chain) traced and run through
  the dataflow analyzer (MZ2xx) on stream-capable and chunk-loop executors;
* **configs** — every architecture in ``configs/registry.py`` must
  construct in both full and smoke flavors (MZ110).

Exit status is nonzero iff any MZ *error* was found — warnings and info
notes never gate (``make lint`` / CI run exactly this).
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core import analysis


def _example_pipelines() -> list[tuple[str, Any, tuple, dict]]:
    """(name, fn, args, config) cells mirroring the examples/ scripts.

    Kept in-file (not imported from examples/) so lint never executes
    example __main__ blocks and stays fast; the pipelines use the same ops
    and the same stage shapes."""
    from repro.core import annotated_image as img
    from repro.core import annotated_nlp as nlp
    from repro.core import annotated_numpy as anp
    from repro.core import annotated_table as tbl

    n = 64
    x = jnp.linspace(0.1, 0.9, n, dtype=jnp.float32)
    y = jnp.linspace(0.2, 1.1, n, dtype=jnp.float32)

    def numpy_chain(x, y):                   # examples/quickstart.py shape
        a = anp.exp(x)
        b = anp.add(a, y)
        c = anp.multiply(b, 0.5)
        return anp.sum(c)

    im = (jnp.arange(n * 8 * 3, dtype=jnp.float32).reshape(n, 8, 3)
          / float(n * 8 * 3))

    def image_chain(im):                     # examples/image_pipeline.py shape
        a = img.colortone(im, (0.2, 0.3, 0.5), 0.5, True)
        b = img.gamma(a, 2.2)
        return img.contrast(b, 1.4)

    t = tbl.Table({"k": jnp.asarray(np.arange(n) % 5, jnp.int32),
                   "v": jnp.linspace(0.5, 2.0, n, dtype=jnp.float32)})

    def table_chain(t):
        t2 = tbl.with_column(t, "v2",
                             jnp.linspace(1.0, 3.0, n, dtype=jnp.float32))
        f = tbl.filter_rows(t2, jnp.asarray(np.arange(n) % 2 == 0))
        return tbl.groupby_agg(f, "k", "v", "sum")

    corpus = nlp.make_corpus(n, max_len=16, vocab=50, seed=0)
    r = np.random.RandomState(1)
    emb = jnp.asarray(r.standard_normal((50, 8)).astype(np.float32))
    head = jnp.asarray(r.standard_normal((8, 5)).astype(np.float32))

    def nlp_chain(corpus):
        c = nlp.normalize_case(corpus, 50)
        tags = nlp.pos_tag(c, emb, head)
        return anp.sum(tags), nlp.token_counts(c)

    cells = []
    for executor in ("fused", "scan"):
        cells.append((f"numpy_chain/{executor}", numpy_chain, (x, y),
                      {"executor": executor}))
    cells.append(("image_chain/fused", image_chain, (im,),
                  {"executor": "fused"}))
    cells.append(("table_chain/fused", table_chain, (t,),
                  {"executor": "fused"}))
    cells.append(("nlp_chain/fused", nlp_chain, (corpus,),
                  {"executor": "fused"}))
    cells.append(("numpy_chain/eager-nopipe", numpy_chain, (x, y),
                  {"executor": "eager", "pipeline": False}))
    return cells


def check_examples() -> analysis.Report:
    rep = analysis.Report()
    for name, fn, args, config in _example_pipelines():
        sub = analysis.verify_pipeline(fn, *args, **config)
        for d in sub.diagnostics:
            rep.diagnostics.append(analysis.Diagnostic(
                d.code, d.severity, f"{name}: {d.subject}", d.message,
                d.where))
        rep.checked += 1
    return rep


def rewrite_report() -> analysis.Report:
    """Dry-run the static rewrite pass over every example/config pipeline
    and report the MZ5xx rewrites that WOULD apply (with cost-model deltas)
    — no execution, no plan-cache mutation (``--rewrite-report``)."""
    rep = analysis.Report()
    for name, fn, args, config in _example_pipelines():
        sub = analysis.rewrite_report(fn, *args, **config)
        for d in sub.diagnostics:
            rep.diagnostics.append(analysis.Diagnostic(
                d.code, d.severity, f"{name}: {d.subject}", d.message,
                d.where))
        rep.checked += 1
    return rep


def check_configs() -> analysis.Report:
    from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config

    rep = analysis.Report()
    for aid in ARCH_IDS:
        rep.checked += 1
        for flavor, getter in (("config", get_config),
                               ("smoke_config", get_smoke_config)):
            try:
                getter(aid)
            except Exception as e:  # noqa: BLE001 - the raise is the finding
                rep.diagnostics.append(analysis.Diagnostic(
                    "MZ110", "error", f"configs.{aid}",
                    f"{flavor}() raised {type(e).__name__}: {e}"))
    return rep


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.launch.lint",
        description="Mozart annotation verifier (zero-MZ-error gate)")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="show info-severity notes too")
    ap.add_argument("--json", metavar="PATH",
                    help="also dump the structured report as JSON")
    ap.add_argument("--skip-contract", action="store_true",
                    help="skip the split-type/annotated-op law sweep")
    ap.add_argument("--skip-examples", action="store_true",
                    help="skip the example-pipeline dataflow sweep")
    ap.add_argument("--skip-configs", action="store_true",
                    help="skip the architecture-config construction sweep")
    ap.add_argument("--plan-cache", metavar="PATH", default=None,
                    help="persisted plan-cache file to audit (MZ205)")
    ap.add_argument("--rewrite-report", action="store_true",
                    help="dry-run only the static graph rewrite pass over "
                         "the example pipelines and print the MZ5xx "
                         "rewrites it would apply (no plan-cache mutation)")
    args = ap.parse_args(argv)

    rep = analysis.Report()
    if args.rewrite_report:
        print("== rewrite report: static graph rewrite dry-run (MZ5xx) ==")
        rep.extend(rewrite_report())
        # MZ5xx notes are info severity: always show them — they ARE the
        # requested output, not noise to be hidden behind -v.
        print(rep.render(verbose=True))
        if args.json:
            with open(args.json, "w", encoding="utf-8") as f:
                json.dump(rep.to_json(), f, indent=2)
            print(f"wrote {args.json}")
        return 0 if rep.ok else 1
    if not args.skip_contract:
        print("== contract: split-type laws + SA condition ==")
        rep.extend(analysis.check_split_types())
        rep.extend(analysis.check_annotated_ops())
        rep.extend(analysis.check_plan_cache(args.plan_cache))
    if not args.skip_examples:
        print("== examples: pipeline dataflow analysis ==")
        rep.extend(check_examples())
    if not args.skip_configs:
        print("== configs: registry construction ==")
        rep.extend(check_configs())

    print(rep.render(verbose=args.verbose))
    if args.json:
        with open(args.json, "w", encoding="utf-8") as f:
            json.dump(rep.to_json(), f, indent=2)
        print(f"wrote {args.json}")
    return 0 if rep.ok else 1


if __name__ == "__main__":
    sys.exit(main())
