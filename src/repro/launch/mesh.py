"""Production meshes.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (data, model).
Multi-pod: 2x16x16 = 512 chips (pod, data, model); the pod axis composes
with data for DP (gradient all-reduce crosses the inter-pod links).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    n = len(jax.devices())
    n_data = min(n_data, n)
    n_model = max(min(n_model, n // n_data), 1)
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def set_mesh(mesh):
    """``jax.set_mesh`` across jax versions: older releases spell the same
    context manager as entering the Mesh object itself."""
    sm = getattr(jax, "set_mesh", None)
    if sm is not None:
        return sm(mesh)
    return mesh


def data_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_extent(mesh) -> int:
    out = 1
    for a in data_axes_of(mesh):
        out *= mesh.shape[a]
    return out


def tp_extent(mesh) -> int:
    return mesh.shape.get("model", 1) if hasattr(mesh.shape, "get") else mesh.shape["model"]
