"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be imported/run as a fresh process: the first two lines force 512
placeholder host devices BEFORE jax initializes.  Do not import this module
from tests or benchmarks (they must see the real 1-device CPU).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
        --out results/dryrun
"""

import os
# NOTE: while-loop LICM is disabled because XLA:CPU hoists per-layer
# dtype converts out of the (scan) loops, materializing a full f32 copy of
# the stacked layer carries / KV cache and inflating the reported peak by
# 2-3x (see EXPERIMENTS.md "Dry-run methodology").
os.environ["XLA_FLAGS"] = os.environ.get(
    "DRYRUN_XLA_FLAGS",
    "--xla_force_host_platform_device_count=512 "
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion")

# ruff: noqa: E402  (env var must precede any jax import)
import argparse
import functools
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, applicable, enc_len_for, input_specs
from repro.launch.mesh import data_axes_of, dp_extent, make_production_mesh, set_mesh
from repro.launch import shardings as shd
from repro.models import lm
from repro.models import shard_ctx
from repro.models import transformer as tfm
from repro.models.config import ModelConfig, active_param_count, param_count
from repro.optim import adamw


def runtime_config(cfg: ModelConfig, mesh, shape) -> ModelConfig:
    """Install mesh-dependent runtime knobs on the arch config."""
    me = mesh.shape["model"] if "model" in mesh.axis_names else 1
    return cfg.with_runtime(
        kv_cache_blocks=me,
        moe_groups=int(mesh.devices.size),
        # train uses the blocked (flash-style, rematerialized-bwd) attention;
        # decode attends through the blocked-LSE cache path anyway
        dense_attn_threshold=2048 if shape.kind == "train" else 8192,
        attn_block_k=1024,
        vocab_pad=16 * 16,     # logits shard over TP even for odd vocabs
    )


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lm.loss_fn)(params, batch, cfg)
        new_p, new_s, metrics = adamw.update(params, grads, opt_state, opt_cfg)
        return new_p, new_s, {"loss": loss, **metrics}
    return train_step


def build_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, batch, caches):
        kw = {k: v for k, v in batch.items() if k != "tokens"}
        logits, caches = tfm.prefill(params, cfg, tokens=batch.get("tokens"),
                                     caches=caches, **kw)
        return jnp.argmax(logits[:, -1], axis=-1), caches
    return prefill_step


def build_serve_step(cfg: ModelConfig):
    def serve_step(params, token, caches, enc_out=None):
        logits, caches = tfm.decode_step(params, cfg, token, caches,
                                         enc_out=enc_out)
        return jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32), caches
    return serve_step


# ---------------------------------------------------------------------------
# Collective-bytes extraction (for §Roofline)
# ---------------------------------------------------------------------------

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:\S+\s*=\s*)?(?:\([^)]*\)|\S+)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)",
)

_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|f64|s64|c64)\[([\d,]*)\]")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "c64": 8}


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in compiled HLO text."""
    totals: dict[str, float] = {}
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"^(?:%?[\w.\-]+\s*=\s*)(.*)$", stripped)
        body = m.group(1) if m else stripped
        op = None
        for name in ("all-gather-start", "all-reduce-start",
                     "reduce-scatter", "all-to-all", "collective-permute-start",
                     "all-gather", "all-reduce", "collective-permute"):
            if body.startswith(name + "(") or (" " + name + "(") in body[:80] \
                    or body.split("(")[0].strip().endswith(name):
                op = name.replace("-start", "")
                break
        if op is None:
            continue
        # output shapes on the line (result types precede the op name)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(stripped.split("(")[0]):
            n = 1
            if dims:
                for d in dims.split(","):
                    if d:
                        n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        totals[op] = totals.get(op, 0) + nbytes
        counts[op] = counts.get(op, 0) + 1
    return {"bytes_by_op": totals, "counts_by_op": counts,
            "total_bytes": sum(totals.values())}


# ---------------------------------------------------------------------------
# One dry-run cell
# ---------------------------------------------------------------------------


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                save_hlo: str | None = None, cfg_override=None,
                runtime_overrides: dict | None = None) -> dict:
    shape = SHAPES[shape_name]
    base_cfg = cfg_override or get_config(arch)
    ok, reason = applicable(base_cfg, shape)
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = runtime_config(base_cfg, mesh, shape)
    if runtime_overrides:
        cfg = cfg.with_runtime(**runtime_overrides)
    dpa = data_axes_of(mesh)
    dpe = dp_extent(mesh)
    t0 = time.time()

    params_aval = jax.eval_shape(
        functools.partial(tfm.init_model, cfg=cfg), jax.random.PRNGKey(0))
    p_specs = shd.param_specs(params_aval, mesh)
    p_shard = shd.named(p_specs, mesh)

    # sequence-parallel residual stream
    dp_spec = dpa if len(dpa) > 1 else (dpa[0] if dpa else None)
    bspec_act = dp_spec if shape.global_batch % dpe == 0 else None
    seq_spec = "model" if cfg.seq_shard_residual else None
    shard_ctx.set_residual(NamedSharding(mesh, P(bspec_act, seq_spec, None)))
    if cfg.encdec and cfg.attn is not None:
        me_ = mesh.shape["model"] if "model" in mesh.axis_names else 1
        hspec = "model" if me_ > 1 and cfg.attn.n_kv_heads % me_ == 0 else None
        shard_ctx.set_cross_kv(NamedSharding(
            mesh, P(None, bspec_act, hspec, None, None)))
    if cfg.moe is not None:
        all_axes = tuple(mesh.axis_names)
        shard_ctx.set_moe_groups(NamedSharding(mesh, P(all_axes)))
    if cfg.padded_vocab % (mesh.shape.get("model", 1) if hasattr(mesh.shape, "get") else mesh.shape["model"]) == 0:
        shard_ctx.set_logits(NamedSharding(mesh, P(bspec_act, None, "model")))

    try:
        if shape.kind == "train":
            opt_cfg = adamw.AdamWConfig()
            opt_aval = jax.eval_shape(adamw.init, params_aval)
            o_specs = jax.tree_util.tree_map(
                lambda l, s=None: None, opt_aval)  # placeholder, replaced below
            m_specs = shd.zero1_specs(params_aval, mesh)
            o_specs = adamw.AdamWState(step=P(), m=m_specs, v=m_specs)
            batch_aval = input_specs(cfg, shape)
            b_specs = shd.batch_specs(batch_aval, mesh)
            step = build_train_step(cfg, opt_cfg)
            jitted = jax.jit(
                step,
                donate_argnums=(0, 1),          # params + opt state reuse
                in_shardings=(p_shard, shd.named(o_specs, mesh),
                              shd.named(b_specs, mesh)),
                out_shardings=(p_shard, shd.named(o_specs, mesh),
                               shd.named(jax.tree_util.tree_map(
                                   lambda _: P(), jax.eval_shape(
                                       lambda: {"loss": jnp.float32(0),
                                                "lr": jnp.float32(0),
                                                "grad_norm": jnp.float32(0)})),
                                   mesh)),
            )
            with set_mesh(mesh):
                lowered = jitted.lower(params_aval, opt_aval, batch_aval)
        elif shape.kind == "prefill":
            batch_aval = input_specs(cfg, shape)
            b_specs = shd.batch_specs(batch_aval, mesh)
            caches_aval = jax.eval_shape(functools.partial(
                tfm.init_caches, cfg, shape.global_batch, shape.seq_len))
            c_specs = shd.cache_specs(caches_aval, mesh, cfg)
            step = build_prefill_step(cfg, shape.seq_len)
            tok_spec = P(dp_spec if shape.global_batch % dpe == 0 else None)
            jitted = jax.jit(
                step,
                donate_argnums=(2,),            # caches are consumed
                in_shardings=(p_shard, shd.named(b_specs, mesh),
                              shd.named(c_specs, mesh)),
                out_shardings=(NamedSharding(mesh, tok_spec),
                               shd.named(c_specs, mesh)),
            )
            with set_mesh(mesh):
                lowered = jitted.lower(params_aval, batch_aval, caches_aval)
        else:  # decode
            spec_in = input_specs(cfg, shape)
            caches_aval = jax.eval_shape(functools.partial(
                tfm.init_caches, cfg, shape.global_batch, shape.seq_len))
            c_specs = shd.cache_specs(caches_aval, mesh, cfg)
            bspec = dp_spec if shape.global_batch % dpe == 0 else None
            tok_aval = spec_in["token"]
            step = build_serve_step(cfg)
            donate = (2,)
            in_shardings = [p_shard,
                            NamedSharding(mesh, P(bspec, None)),
                            shd.named(c_specs, mesh)]
            args = [params_aval, tok_aval, caches_aval]
            if cfg.encdec:
                enc_aval = spec_in["enc_out"]
                in_shardings.append(NamedSharding(mesh, P(bspec, None, None)))
                args.append(enc_aval)
            jitted = jax.jit(
                step,
                donate_argnums=donate,          # caches are consumed
                in_shardings=tuple(in_shardings),
                out_shardings=(NamedSharding(mesh, P(bspec, None)),
                               shd.named(c_specs, mesh)),
            )
            with set_mesh(mesh):
                lowered = jitted.lower(*args)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes_from_hlo(hlo)
        if save_hlo:
            Path(save_hlo).write_text(hlo)

        n_devices = mesh.devices.size
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            flops=float(cost.get("flops", 0.0)),
            hlo_bytes=float(cost.get("bytes accessed", 0.0)),
            collective=coll,
            memory=dict(
                argument_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
                output_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
                temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
                alias_bytes=int(getattr(mem, "alias_size_in_bytes", 0)),
                # donated outputs alias their argument buffers
                peak_bytes=int(
                    getattr(mem, "temp_size_in_bytes", 0)
                    + getattr(mem, "argument_size_in_bytes", 0)
                    + getattr(mem, "output_size_in_bytes", 0)
                    - getattr(mem, "alias_size_in_bytes", 0)),
            ),
            n_devices=int(n_devices),
            params=param_count(base_cfg),
            active_params=active_param_count(base_cfg),
        )
        print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}: OK "
              f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s, "
              f"temp/device {rec['memory']['temp_bytes']/2**30:.2f} GiB)")
    except Exception as e:  # noqa: BLE001 — recorded as cell failure
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        print(f"[dryrun] {arch} x {shape_name}: FAIL {type(e).__name__}: {e}")
    finally:
        shard_ctx.clear()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    for arch, shape in cells:
        tag = f"{arch}__{shape}__{'mp' if args.multi_pod else 'sp'}"
        path = outdir / f"{tag}.json"
        if path.exists():
            print(f"[dryrun] {tag}: cached, skipping")
            continue
        rec = dryrun_cell(arch, shape, multi_pod=args.multi_pod,
                          save_hlo=args.save_hlo)
        path.write_text(json.dumps(rec, indent=2))


if __name__ == "__main__":
    main()
