"""Mesh execution of Mozart stages: splits = shards (beyond-paper scale-out).

The paper parallelizes chunks over threads of one CPU.  Here the *first*
level of splitting maps onto devices of a ``jax.make_mesh`` via
``shard_map`` — Mozart's split function becomes the sharding rule, and its
associative merge becomes either "already sharded correctly" (concat-style
merges) or a ``psum``-family collective (ReduceSplit).  Within each device
the stage still runs the fast-memory chunk loop, so the two memory tiers
(HBM across devices, VMEM within one) are both handled by the same SA.

The jitted ``shard_map`` closure is built capture-safe (from ``chain_plan``)
and pinned into the plan cache via ``pinned_jit``; the inner per-shard chunk
loop participates in chunk-size auto-tuning (``tunable = True``), with
sample slices rounded to the mesh extent so they stay shardable.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
from jax.sharding import PartitionSpec as P

from repro.core import split_types as st
from repro.core.planner import Stage
from repro.core.stage_exec import (
    PedanticError,
    SAMPLE_CHUNKS,
    StageExecutor,
    batch_ranges,
    chain_plan,
    effective_elements,
    note_trace,
    pinned_jit,
    register_executor,
    run_plan,
    split_axis_of,
    stage_num_elements,
)


@register_executor("sharded")
class ShardedExecutor(StageExecutor):
    """Splits = mesh shards; per-device chunk loop handles the VMEM tier."""

    tunable = True           # tunes the INNER per-shard chunk loop
    # shard_map partitions one whole array across the mesh; a host-side chunk
    # list has no sharding story, so handed-off streams materialize on ingest
    # (resolve_stage_inputs) before the shard_map launch.
    stream_capable = False

    def execute(self, stage: Stage, concrete: dict[tuple, Any], ctx) -> None:
        execute_stage_sharded(stage, concrete, ctx, self)

    # -- tuner integration ---------------------------------------------------
    def _mesh_extent(self, ctx) -> int:
        m = 1
        if ctx.mesh is not None:
            for a in ctx.data_axes:
                m *= ctx.mesh.shape[a]
        return m

    def tuning_candidates(self, stage: Stage, concrete: dict[tuple, Any], ctx,
                          est: int, n: int) -> list[int]:
        # The tuned quantity is the PER-SHARD chunk size: bracket the §5.2
        # estimate within one local shard's element count.
        from repro.core.stage_exec import candidate_batches
        n_local = max(1, n // max(self._mesh_extent(ctx), 1))
        return candidate_batches(est, n_local)

    def sample_elems(self, ctx, batch: int, n: int) -> int:
        # Sample slices must stay divisible by the mesh extent or the
        # shard_map split rejects them: give every shard SAMPLE_CHUNKS
        # chunks and round to a multiple of the extent.
        if n <= 0:
            return 0
        m = max(self._mesh_extent(ctx), 1)
        s = min(n, SAMPLE_CHUNKS * batch * m)
        return max(m, (s // m) * m)


def _shard_map(fn, mesh, in_specs, out_specs):
    """shard_map across jax versions: top-level ``jax.shard_map`` with
    ``check_vma`` (new) vs ``jax.experimental.shard_map`` with ``check_rep``."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    try:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
    except TypeError:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)


def _pspec_for(split_type: st.SplitType, ndim: int, axes: tuple[str, ...]):
    ax = split_axis_of(split_type)
    if ax is None:
        return P()
    spec = [None] * ndim
    spec[ax] = axes if len(axes) > 1 else axes[0]
    return P(*spec)


def _build_sharded_driver(stage: Stage, mesh, axes, in_specs, out_specs,
                          in_ckeys: list[tuple], in_split_types: list,
                          esc_pos: list[int], out_types_by_pos: dict,
                          n_local: int, batch: int, whole: bool) -> Callable:
    plan = chain_plan(stage)
    axis_name = axes if len(axes) > 1 else axes[0]

    def local_fn(*vals):
        note_trace()
        env = dict(zip(in_ckeys, vals))
        # Per-device fast-memory chunk loop over the local shard.
        if whole or batch >= n_local:
            run_plan(plan, env)
            chunk_outs = {p: [env[("n", p)]] for p in esc_pos}
        else:
            chunk_outs = {p: [] for p in esc_pos}
            for (s, e) in batch_ranges(n_local, batch):
                cenv = {}
                for ck, t in zip(in_ckeys, in_split_types):
                    cenv[ck] = t.split(env[ck], s, e) if t is not None else env[ck]
                run_plan(plan, cenv)
                for p in esc_pos:
                    chunk_outs[p].append(cenv[("n", p)])

        outs = []
        for p in esc_pos:
            t = out_types_by_pos[p]
            merged = t.merge(chunk_outs[p])
            if split_axis_of(t) is None:
                # ReduceSplit & friends: combine partials across shards.
                if isinstance(t, st.ReduceSplit):
                    merged = _psum_like(t, merged, axis_name)
            outs.append(merged)
        return tuple(outs)

    return jax.jit(
        _shard_map(
            local_fn,
            mesh=mesh,
            in_specs=tuple(in_specs),
            out_specs=tuple(out_specs),
        )
    )


def execute_stage_sharded(stage: Stage, concrete: dict[tuple, Any], ctx,
                          executor: StageExecutor | None = None) -> None:
    mesh = ctx.mesh
    if mesh is None:
        raise ValueError("sharded executor requires mozart.session(mesh=...)")
    axes = ctx.data_axes
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]

    n = effective_elements(ctx, stage_num_elements(stage, concrete, ctx.pedantic))
    if n % n_shards != 0:
        raise PedanticError(
            f"stage element count {n} not divisible by mesh data extent {n_shards}"
        )
    n_local = n // n_shards
    from repro.core.stage_exec import get_executor
    executor = executor or get_executor("sharded")
    # Inner per-shard chunk size: explicit override > auto-tuner pin > §5.2.
    batch = executor.choose_batch(stage, concrete, ctx, max(n_local, 1))
    whole = ctx.inner_executor == "whole"

    # Any input/output we cannot express as an axis-sharding falls back to
    # replicated-in / merged-out handling.
    in_keys = list(stage.inputs)
    in_specs = []
    for k in in_keys:
        si = stage.inputs[k]
        aval = concrete[k]
        ndim = getattr(aval, "ndim", None)
        if si.split_type.splittable and ndim is not None:
            in_specs.append(_pspec_for(si.split_type, ndim, axes))
        else:
            in_specs.append(
                jax.tree_util.tree_map(lambda _: P(), aval)
                if not hasattr(aval, "ndim") else P()
            )

    out_ids = sorted(stage.escaping)
    esc_pos = [stage.pos[nid] for nid in out_ids]
    out_specs = []
    for nid in out_ids:
        t = stage.out_types[nid]
        aval = _aval_of_node(stage, nid)
        if split_axis_of(t) is not None:
            out_specs.append(jax.tree_util.tree_map(
                lambda l: _pspec_for(t, len(l.shape), axes), aval))
        else:
            out_specs.append(jax.tree_util.tree_map(lambda l: P(), aval))

    in_ckeys = [stage.ckey(k) for k in in_keys]
    in_split_types = [stage.inputs[k].split_type
                      if stage.inputs[k].split_type.splittable else None
                      for k in in_keys]
    out_types_by_pos = {stage.pos[nid]: stage.out_types[nid] for nid in out_ids}

    # The plan-cache key records only mesh axis names/extents; the driver
    # bakes the concrete Mesh into the shard_map closure, so two same-shape
    # meshes over DIFFERENT devices must compile separate executables.
    mesh_devices = tuple(d.id for d in mesh.devices.flat)
    shard_fn = pinned_jit(
        stage, ctx, "sharded",
        (tuple(esc_pos), batch, n_local, whole, mesh_devices),
        lambda: _build_sharded_driver(
            stage, mesh, axes, in_specs, out_specs, in_ckeys, in_split_types,
            esc_pos, out_types_by_pos, n_local, batch, whole))
    results = shard_fn(*[concrete[k] for k in in_keys])
    ctx.stats["sharded_stages"] += 1
    # merge() of a single piece is the identity for concat-style types.
    by_pos = dict(zip(esc_pos, results))
    for node in stage.nodes:
        p = stage.pos[node.id]
        if p in by_pos:
            node.result = by_pos[p]
        node.done = True


def _aval_of_node(stage: Stage, nid: int):
    for n in stage.nodes:
        if n.id == nid:
            return n.out_aval
    raise KeyError(nid)


def _psum_like(t: st.ReduceSplit, value, axis_name):
    if t.op_name == "add":
        return jax.lax.psum(value, axis_name)
    if t.op_name == "max":
        return jax.lax.pmax(value, axis_name)
    if t.op_name == "min":
        return jax.lax.pmin(value, axis_name)
    if t.op_name == "mul":
        # no pprod primitive: log-domain trick is wrong for negatives; use
        # all_gather + sequential combine (rare path).
        g = jax.lax.all_gather(value, axis_name)
        out = g[0]
        for i in range(1, g.shape[0]):
            out = out * g[i]
        return out
    raise ValueError(t.op_name)
