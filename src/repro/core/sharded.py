"""Mesh execution of Mozart stages: splits = shards (beyond-paper scale-out).

The paper parallelizes chunks over threads of one CPU.  Here the *first*
level of splitting maps onto devices of a ``jax.make_mesh`` via
``shard_map`` — Mozart's split function becomes the sharding rule, and its
associative merge becomes either "already sharded correctly" (concat-style
merges) or a ``psum``-family collective (ReduceSplit).  Within each device
the stage still runs the fast-memory chunk loop, so the two memory tiers
(HBM across devices, VMEM within one) are both handled by the same SA.

The jitted ``shard_map`` closure is built capture-safe (from ``chain_plan``)
and pinned into the plan cache via ``pinned_jit``; the inner per-shard chunk
loop participates in chunk-size auto-tuning (``tunable = True``), with
sample slices rounded to the mesh extent so they stay shardable.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import split_types as st
from repro.core.planner import Stage
from repro.core.stage_exec import (
    ChunkStream,
    PedanticError,
    SAMPLE_CHUNKS,
    StageExecutor,
    batch_ranges,
    chain_plan,
    effective_elements,
    note_materialized,
    note_trace,
    pinned_jit,
    register_executor,
    run_plan,
    split_axis_of,
    stage_num_elements,
)


@register_executor("sharded")
class ShardedExecutor(StageExecutor):
    """Splits = mesh shards; per-device chunk loop handles the VMEM tier."""

    tunable = True           # tunes the INNER per-shard chunk loop
    # Handed-off streams enter WITHOUT a host-side merge: chunk lists are
    # placed per shard (``_ingest_streams`` — device_put on the shard grid,
    # ``rechunk`` at most once for disagreeing grids) and SHARDED-form
    # streams from an earlier sharded stage pass the device-resident global
    # array straight through (zero interior bytes, no all-gather).
    stream_capable = True
    shard_capable = True

    def execute(self, stage: Stage, concrete: dict[tuple, Any], ctx) -> None:
        execute_stage_sharded(stage, concrete, ctx, self)

    # -- tuner integration ---------------------------------------------------
    def _mesh_extent(self, ctx) -> int:
        m = 1
        if ctx.mesh is not None:
            for a in ctx.data_axes:
                m *= ctx.mesh.shape[a]
        return m

    def tuning_candidates(self, stage: Stage, concrete: dict[tuple, Any], ctx,
                          est: int, n: int) -> list[int]:
        # The tuned quantity is the PER-SHARD chunk size: bracket the §5.2
        # estimate within one local shard's element count.
        from repro.core.stage_exec import candidate_batches
        n_local = max(1, n // max(self._mesh_extent(ctx), 1))
        return candidate_batches(est, n_local)

    def sample_elems(self, ctx, batch: int, n: int) -> int:
        # Sample slices must stay divisible by the mesh extent or the
        # shard_map split rejects them: give every shard SAMPLE_CHUNKS
        # chunks and round to a multiple of the extent.
        if n <= 0:
            return 0
        m = max(self._mesh_extent(ctx), 1)
        s = min(n, SAMPLE_CHUNKS * batch * m)
        return max(m, (s // m) * m)


def _shard_map(fn, mesh, in_specs, out_specs):
    """shard_map across jax versions: top-level ``jax.shard_map`` with
    ``check_vma`` (new) vs ``jax.experimental.shard_map`` with ``check_rep``."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    try:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
    except TypeError:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)


def _pspec_for(split_type: st.SplitType, ndim: int, axes: tuple[str, ...]):
    ax = split_axis_of(split_type)
    if ax is None:
        return P()
    spec = [None] * ndim
    spec[ax] = axes if len(axes) > 1 else axes[0]
    return P(*spec)


def _build_sharded_driver(stage: Stage, mesh, axes, in_specs, out_specs,
                          in_ckeys: list[tuple], in_split_types: list,
                          esc_pos: list[int], out_types_by_pos: dict,
                          n_local: int, batch: int, whole: bool) -> Callable:
    plan = chain_plan(stage)
    axis_name = axes if len(axes) > 1 else axes[0]

    def local_fn(*vals):
        note_trace()
        env = dict(zip(in_ckeys, vals))
        # Per-device fast-memory chunk loop over the local shard.
        if whole or batch >= n_local:
            run_plan(plan, env)
            chunk_outs = {p: [env[("n", p)]] for p in esc_pos}
        else:
            chunk_outs = {p: [] for p in esc_pos}
            for (s, e) in batch_ranges(n_local, batch):
                cenv = {}
                for ck, t in zip(in_ckeys, in_split_types):
                    cenv[ck] = t.split(env[ck], s, e) if t is not None else env[ck]
                run_plan(plan, cenv)
                for p in esc_pos:
                    chunk_outs[p].append(cenv[("n", p)])

        outs = []
        for p in esc_pos:
            t = out_types_by_pos[p]
            merged = t.merge(chunk_outs[p])
            if split_axis_of(t) is None:
                # ReduceSplit & friends: combine partials across shards.
                if isinstance(t, st.ReduceSplit):
                    merged = _psum_like(t, merged, axis_name)
            outs.append(merged)
        return tuple(outs)

    return jax.jit(
        _shard_map(
            local_fn,
            mesh=mesh,
            in_specs=tuple(in_specs),
            out_specs=tuple(out_specs),
        )
    )


def _ingest_streams(stage: Stage, concrete: dict[tuple, Any], ctx, mesh,
                    axes, n: int, n_local: int,
                    shard_ranges: list[tuple[int, int]], ho) -> None:
    """Place handed-off ChunkStream inputs onto the mesh without merging.

    Three paths, in order of preference: a SHARDED-form stream whose layout
    already matches the target (same Sharding, shard-grid ranges) passes its
    device-resident global array through untouched (zero interior bytes, no
    all-gather); a chunk-list/stacked stream is regrouped onto the shard
    grid (``rechunk`` at most once — counted) and ``device_put`` per shard
    into one global array (device placement is inherent to sharding, like
    splitting an external input, so it is NOT counted as interior traffic);
    anything the shard grid cannot express (pytree leaves, zero-element
    grids, foreign meshes) materializes — correct, merely the old cost,
    counted honestly by ``ChunkStream.materialize``."""
    for i, (key, si) in enumerate(stage.inputs.items()):
        v = concrete.get(key)
        if not isinstance(v, ChunkStream):
            continue
        t = si.split_type
        ax = split_axis_of(t)
        leaves = jax.tree_util.tree_leaves(v.aval)
        if (ax is None or n_local <= 0 or len(leaves) != 1
                or v.n != n or len(leaves[0].shape) <= ax):
            concrete[key] = v.materialize()
            ctx.stats["stream_materialized"] += 1
            continue
        global_shape = tuple(leaves[0].shape)
        sharding = NamedSharding(mesh, _pspec_for(t, len(global_shape), axes))
        if v.sharded is not None:
            # Sharded-form stream: reuse the global array as-is when the
            # plan permits it and the layout agrees; a foreign layout
            # (different mesh/spec) gathers and re-splits through shard_map.
            if (ho is not None and i in ho.shard_in
                    and v.sharding == sharding
                    and list(v.ranges) == shard_ranges):
                concrete[key] = v.sharded
                ctx.stats["shard_passthrough"] += 1
            else:
                concrete[key] = v.materialize()
                ctx.stats["stream_materialized"] += 1
            continue
        chunks = list(v.chunks)
        if list(v.ranges) != shard_ranges:
            if len(chunks) != len(v.ranges):
                concrete[key] = v.materialize()
                ctx.stats["stream_materialized"] += 1
                continue
            chunks, copied = t.rechunk(chunks, list(v.ranges), shard_ranges)
            if copied:
                note_materialized(copied, kind="rechunk",
                                  where=f"stage {stage.id} shard ingest "
                                        f"input {i}")
            ctx.stats["handoff_rechunks"] += 1
        arrays = []
        ok = True
        for dev, idx in sharding.devices_indices_map(global_shape).items():
            j = (idx[ax].start or 0) // n_local
            if j >= len(chunks):
                ok = False
                break
            arrays.append(jax.device_put(chunks[j], dev))
        if not ok:
            concrete[key] = v.materialize()
            ctx.stats["stream_materialized"] += 1
            continue
        concrete[key] = jax.make_array_from_single_device_arrays(
            global_shape, sharding, arrays)
        ctx.stats["shard_ingests"] += 1


def execute_stage_sharded(stage: Stage, concrete: dict[tuple, Any], ctx,
                          executor: StageExecutor | None = None) -> None:
    mesh = ctx.mesh
    if mesh is None:
        raise ValueError("sharded executor requires mozart.session(mesh=...)")
    axes = ctx.data_axes
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]

    n = effective_elements(ctx, stage_num_elements(stage, concrete, ctx.pedantic))
    if n % n_shards != 0:
        raise PedanticError(
            f"stage element count {n} not divisible by mesh data extent {n_shards}"
        )
    n_local = n // n_shards
    shard_ranges = [(i * n_local, (i + 1) * n_local) for i in range(n_shards)]
    plan_ho = getattr(ctx, "_handoff", None)
    ho = plan_ho.get(stage.id) if plan_ho else None
    concrete = dict(concrete)
    _ingest_streams(stage, concrete, ctx, mesh, axes, n, n_local,
                    shard_ranges, ho)
    from repro.core.stage_exec import get_executor
    executor = executor or get_executor("sharded")
    # Inner per-shard chunk size: explicit override > auto-tuner pin > §5.2.
    batch = executor.choose_batch(stage, concrete, ctx, max(n_local, 1))
    whole = ctx.inner_executor == "whole"

    # Any input/output we cannot express as an axis-sharding falls back to
    # replicated-in / merged-out handling.
    in_keys = list(stage.inputs)
    in_specs = []
    for k in in_keys:
        si = stage.inputs[k]
        aval = concrete[k]
        ndim = getattr(aval, "ndim", None)
        if si.split_type.splittable and ndim is not None:
            in_specs.append(_pspec_for(si.split_type, ndim, axes))
        else:
            in_specs.append(
                jax.tree_util.tree_map(lambda _: P(), aval)
                if not hasattr(aval, "ndim") else P()
            )

    out_ids = sorted(stage.escaping)
    esc_pos = [stage.pos[nid] for nid in out_ids]
    out_specs = []
    for nid in out_ids:
        t = stage.out_types[nid]
        aval = _aval_of_node(stage, nid)
        if split_axis_of(t) is not None:
            out_specs.append(jax.tree_util.tree_map(
                lambda l: _pspec_for(t, len(l.shape), axes), aval))
        else:
            out_specs.append(jax.tree_util.tree_map(lambda l: P(), aval))

    in_ckeys = [stage.ckey(k) for k in in_keys]
    in_split_types = [stage.inputs[k].split_type
                      if stage.inputs[k].split_type.splittable else None
                      for k in in_keys]
    out_types_by_pos = {stage.pos[nid]: stage.out_types[nid] for nid in out_ids}

    # The plan-cache key records only mesh axis names/extents; the driver
    # bakes the concrete Mesh into the shard_map closure, so two same-shape
    # meshes over DIFFERENT devices must compile separate executables.
    mesh_devices = tuple(d.id for d in mesh.devices.flat)
    shard_fn = pinned_jit(
        stage, ctx, "sharded",
        (tuple(esc_pos), batch, n_local, whole, mesh_devices),
        lambda: _build_sharded_driver(
            stage, mesh, axes, in_specs, out_specs, in_ckeys, in_split_types,
            esc_pos, out_types_by_pos, n_local, batch, whole))
    results = shard_fn(*[concrete[k] for k in in_keys])
    ctx.stats["sharded_stages"] += 1
    # merge() of a single piece is the identity for concat-style types.
    by_pos = dict(zip(esc_pos, results))
    for node in stage.nodes:
        p = stage.pos[node.id]
        if p in by_pos:
            res = by_pos[p]
            t = out_types_by_pos[p]
            if (ho is not None and p in ho.stream_out and n_shards > 1
                    and n_local > 0 and split_axis_of(t) is not None
                    and getattr(res, "sharding", None) is not None):
                # Emit a device-resident stream: the global array stays on
                # the mesh carrying its Sharding, so a downstream sharded
                # stage passes it through with zero interior bytes and no
                # all-gather; any other consumer gathers lazily (counted).
                node.result = ChunkStream.from_sharded(
                    res, shard_ranges, t, node.out_aval, res.sharding)
                ctx.stats["streamed_outputs"] += 1
            else:
                node.result = res
        node.done = True


def _aval_of_node(stage: Stage, nid: int):
    for n in stage.nodes:
        if n.id == nid:
            return n.out_aval
    raise KeyError(nid)


def _psum_like(t: st.ReduceSplit, value, axis_name):
    if t.op_name == "add":
        return jax.lax.psum(value, axis_name)
    if t.op_name == "max":
        return jax.lax.pmax(value, axis_name)
    if t.op_name == "min":
        return jax.lax.pmin(value, axis_name)
    if t.op_name == "mul":
        # no pprod primitive: log-domain trick is wrong for negatives; use
        # all_gather + sequential combine (rare path).
        g = jax.lax.all_gather(value, axis_name)
        out = g[0]
        for i in range(1, g.shape[0]):
            out = out * g[i]
        return out
    raise ValueError(t.op_name)
