"""Mesh execution of Mozart stages: splits = shards (beyond-paper scale-out).

The paper parallelizes chunks over threads of one CPU.  Here the *first*
level of splitting maps onto devices of a ``jax.make_mesh`` via
``shard_map`` — Mozart's split function becomes the sharding rule, and its
associative merge becomes either "already sharded correctly" (concat-style
merges) or a ``psum``-family collective (ReduceSplit).  Within each device
the stage still runs the fast-memory chunk loop, so the two memory tiers
(HBM across devices, VMEM within one) are both handled by the same SA.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from repro import hardware
from repro.core import split_types as st
from repro.core.planner import Stage
from repro.core.stage_exec import (
    PedanticError,
    StageExecutor,
    batch_ranges,
    effective_elements,
    register_executor,
    run_chain,
    split_axis_of,
    stage_elem_bytes,
    stage_num_elements,
)


@register_executor("sharded")
class ShardedExecutor(StageExecutor):
    """Splits = mesh shards; per-device chunk loop handles the VMEM tier."""

    tunable = False          # batch feeds the inner per-shard loop only

    def execute(self, stage: Stage, concrete: dict[tuple, Any], ctx) -> None:
        execute_stage_sharded(stage, concrete, ctx)


def _shard_map(fn, mesh, in_specs, out_specs):
    """shard_map across jax versions: top-level ``jax.shard_map`` with
    ``check_vma`` (new) vs ``jax.experimental.shard_map`` with ``check_rep``."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    try:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
    except TypeError:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)


def _pspec_for(split_type: st.SplitType, ndim: int, axes: tuple[str, ...]):
    ax = split_axis_of(split_type)
    if ax is None:
        return P()
    spec = [None] * ndim
    spec[ax] = axes if len(axes) > 1 else axes[0]
    return P(*spec)


def execute_stage_sharded(stage: Stage, concrete: dict[tuple, Any], ctx) -> None:
    mesh = ctx.mesh
    if mesh is None:
        raise ValueError("sharded executor requires mozart.session(mesh=...)")
    axes = ctx.data_axes
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]

    n = effective_elements(ctx, stage_num_elements(stage, concrete, ctx.pedantic))
    if n % n_shards != 0:
        raise PedanticError(
            f"stage element count {n} not divisible by mesh data extent {n_shards}"
        )

    # Any input/output we cannot express as an axis-sharding falls back to
    # replicated-in / merged-out handling.
    in_keys = list(stage.inputs)
    in_specs = []
    for k in in_keys:
        si = stage.inputs[k]
        aval = concrete[k]
        ndim = getattr(aval, "ndim", None)
        if si.split_type.splittable and ndim is not None:
            in_specs.append(_pspec_for(si.split_type, ndim, axes))
        else:
            in_specs.append(
                jax.tree_util.tree_map(lambda _: P(), aval)
                if not hasattr(aval, "ndim") else P()
            )

    out_ids = sorted(stage.escaping)
    out_specs = []
    for nid in out_ids:
        t = stage.out_types[nid]
        aval = _aval_of_node(stage, nid)
        if split_axis_of(t) is not None:
            out_specs.append(jax.tree_util.tree_map(
                lambda l: _pspec_for(t, len(l.shape), axes), aval))
        else:
            out_specs.append(jax.tree_util.tree_map(lambda l: P(), aval))

    axis_name = axes if len(axes) > 1 else axes[0]

    def local_fn(*vals):
        env = {k: v for k, v in zip(in_keys, vals)}
        # Per-device fast-memory chunk loop over the local shard.
        n_local = n // n_shards
        elem_bytes = stage_elem_bytes(stage, env, n)
        batch = ctx.batch_elements or hardware.mozart_batch_elements(elem_bytes, ctx.chip)
        batch = max(1, min(batch, n_local))

        if ctx.inner_executor == "whole" or batch >= n_local:
            run_chain(stage, env, jit_each=False)
            chunk_outs = {nid: [env[("node", nid)]] for nid in out_ids}
        else:
            chunk_outs = {nid: [] for nid in out_ids}
            for (s, e) in batch_ranges(n_local, batch):
                cenv = {}
                for k in in_keys:
                    t = stage.inputs[k].split_type
                    cenv[k] = t.split(env[k], s, e) if t.splittable else env[k]
                run_chain(stage, cenv, jit_each=False)
                for nid in out_ids:
                    chunk_outs[nid].append(cenv[("node", nid)])

        outs = []
        for nid in out_ids:
            t = stage.out_types[nid]
            merged = t.merge(chunk_outs[nid])
            if split_axis_of(t) is None:
                # ReduceSplit & friends: combine partials across shards.
                if isinstance(t, st.ReduceSplit):
                    merged = _psum_like(t, merged, axis_name)
            outs.append(merged)
        return tuple(outs)

    shard_fn = jax.jit(
        _shard_map(
            local_fn,
            mesh=mesh,
            in_specs=tuple(in_specs),
            out_specs=tuple(out_specs),
        )
    )
    results = shard_fn(*[concrete[k] for k in in_keys])
    ctx.stats["sharded_stages"] += 1
    partials = {nid: [res] for nid, res in zip(out_ids, results)}
    # merge() of a single piece is the identity for concat-style types.
    for node in stage.nodes:
        if node.id in partials:
            node.result = partials[node.id][0]
        node.done = True


def _aval_of_node(stage: Stage, nid: int):
    for n in stage.nodes:
        if n.id == nid:
            return n.out_aval
    raise KeyError(nid)


def _psum_like(t: st.ReduceSplit, value, axis_name):
    if t.op_name == "add":
        return jax.lax.psum(value, axis_name)
    if t.op_name == "max":
        return jax.lax.pmax(value, axis_name)
    if t.op_name == "min":
        return jax.lax.pmin(value, axis_name)
    if t.op_name == "mul":
        # no pprod primitive: log-domain trick is wrong for negatives; use
        # all_gather + sequential combine (rare path).
        g = jax.lax.all_gather(value, axis_name)
        out = g[0]
        for i in range(1, g.shape[0]):
            out = out * g[i]
        return out
    raise ValueError(t.op_name)
