"""Cost-model-driven executor auto-selection (``executor="auto"``).

The paper's Mozart commits to ONE execution strategy per session.  Weld-style
adaptive systems show the win comes from choosing the materialized plan per
callsite from *measured* cost.  This module scores every registered
``StageExecutor`` per stage and dispatches each stage to the cheapest:

1. **Analytic prior.**  ``analytic_seconds`` combines the stage's runtime
   features (element count, per-element bytes, chain length, the SA's
   arithmetic-intensity hint) with chip constants (``hardware.Chip``: HBM
   bandwidth, peak FLOPs, per-dispatch overhead) into an estimated wall time
   per strategy — eager pays one HBM round-trip per *function*, chunked
   drivers pay one dispatch per chunk (×chain length when not fused), scan
   compiles the loop away, pallas in interpret mode is penalized into
   oblivion, sharded divides bandwidth across mesh devices.

2. **Measured feedback.**  On the first execution of a *cached* plan the
   ``AutoExecutor`` times a bounded sample of chunks under each viable
   candidate (``StageExecutor.sampled_time``), records the extrapolated
   seconds into the plan-cache entry (``PlanEntry.exec_timings``) and pins
   the winner (``PlanEntry.chosen_exec``).  Fresh measurements *overwrite*
   recorded timings, so a stale or poisoned cost entry is corrected the next
   time the measurement pass runs.  Pinned choices persist across processes
   via ``plan_cache.save/load``.

Selection is deterministic: candidates are scored in a fixed preference
order and ties keep the earlier candidate, so identical pipelines with
identical recorded timings always pick the same executor.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax

from repro import hardware
from repro.core import resilience
from repro.core import split_types as st
from repro.core.graph import DataflowGraph
from repro.core.planner import Stage
from repro.core.stage_exec import (
    StageExecutor,
    get_executor,
    has_dynamic,
    materialize_inputs,
    register_executor,
    resolve_stage_inputs,
    stage_elem_bytes,
    stage_num_elements,
)

#: fixed preference order = deterministic tie-break.  Cheap-dispatch
#: strategies first: on equal estimated cost the fewer-moving-parts
#: strategy wins.
CANDIDATE_ORDER = ("scan", "fused", "pipelined", "pallas", "sharded", "eager")

#: interpret-mode pallas runs the kernel body per block in pure Python —
#: orders of magnitude off; keep it out of measurement candidates too.
_INTERPRET_PENALTY_S_PER_ELEM = 1e-4

#: measure only candidates whose analytic estimate is within this factor of
#: the best candidate's — no point timing a strategy the model puts 100x off.
_MEASURE_RATIO = 50.0

#: FLOPs one unit of SA ``cost_hint`` stands for (one elementwise op).
_FLOPS_PER_HINT = 8.0


@dataclasses.dataclass(frozen=True)
class StageFeatures:
    """Everything the cost model knows about one stage at dispatch time."""

    n: int                     # splittable element count
    elem_bytes: int            # Σ bytes per element over live pipeline values
    n_nodes: int               # chain length
    flops_per_elem: float      # arithmetic-intensity proxy from SA cost hints
    dynamic: bool              # chain contains dynamic-shape (un-jittable) fns
    pallas_eligible: bool      # stage lowers onto the split-pipeline kernel
    mesh_devices: int          # data-mesh extent (0: no mesh configured)
    on_tpu: bool               # pallas runs compiled, not interpreted


def features_of(stage: Stage, concrete: dict[tuple, Any], ctx) -> StageFeatures:
    n = stage_num_elements(stage, concrete, ctx.pedantic)
    mesh_devices = 0
    if ctx.mesh is not None:
        mesh_devices = 1
        for a in ctx.data_axes:
            mesh_devices *= ctx.mesh.shape[a]
    from repro.core.pallas_exec import _eligible as pallas_eligible
    return StageFeatures(
        n=n,
        elem_bytes=stage_elem_bytes(stage, concrete, n),
        n_nodes=len(stage.nodes),
        flops_per_elem=stage.flops_hint() * _FLOPS_PER_HINT,
        dynamic=has_dynamic(stage),
        pallas_eligible=n > 0 and pallas_eligible(stage, concrete),
        mesh_devices=mesh_devices,
        on_tpu=jax.default_backend() == "tpu",
    )


def analytic_seconds(name: str, f: StageFeatures, chip: hardware.Chip) -> float:
    """Estimated stage wall time under ``name``; ``inf`` = not applicable.

    Only the *relative* ordering matters; the absolute scale is the roofline
    ``bytes/bandwidth`` + ``flops/peak`` plus dispatch overheads."""
    total_bytes = max(f.n, 1) * f.elem_bytes
    bw = chip.hbm_bandwidth
    compute = f.n * f.flops_per_elem / chip.peak_bf16_flops
    # Online-calibrated: the hardcoded Chip constant blended with a once-per-
    # process measurement of a real jitted no-op dispatch (ROADMAP follow-up).
    dispatch = hardware.effective_dispatch_overhead_s(chip)
    est_batch = hardware.mozart_batch_elements(f.elem_bytes, chip)
    chunks = max(1, math.ceil(max(f.n, 1) / max(est_batch, 1)))
    stream = max(total_bytes / bw, compute)

    if name == "eager":
        # every function round-trips its full operands through slow memory
        return f.n_nodes * (total_bytes / bw) + f.n_nodes * dispatch
    if f.dynamic and name != "pipelined":
        return math.inf                  # dynamic chains run un-jitted chunks
    if name == "pipelined":
        # chunks stay cache-resident between functions, but every function of
        # every chunk is a separate black-box dispatch
        return stream + chunks * f.n_nodes * dispatch
    if name == "fused":
        return stream + chunks * dispatch
    if name == "scan":
        # the chunk loop compiles into one XLA program: one dispatch total
        return stream + dispatch
    if name == "pallas":
        if not f.pallas_eligible:
            return math.inf
        if not f.on_tpu:
            return f.n * _INTERPRET_PENALTY_S_PER_ELEM + dispatch
        return stream + dispatch
    if name == "sharded":
        if f.mesh_devices < 1 or f.n % max(f.mesh_devices, 1) != 0:
            return math.inf
        return stream / f.mesh_devices + 2 * dispatch
    return math.inf                      # strategies the model cannot score


def candidates(f: StageFeatures, ctx,
               blocked: "set | frozenset" = frozenset()) -> list[str]:
    """Applicable executors in deterministic preference order.  ``blocked``
    removes quarantined names (resilience degradation ladder) — unless that
    would leave nothing, in which case the quarantine is overridden (a wrong
    answer is never an option; a retried crash is recoverable)."""
    out = []
    for name in CANDIDATE_ORDER:
        if math.isfinite(analytic_seconds(name, f, ctx.chip)):
            out.append(name)
    if blocked:
        unblocked = [n for n in out if n not in blocked]
        if unblocked:
            out = unblocked
    return out or ["pipelined"]


def choose(f: StageFeatures, ctx, timings: dict[str, float] | None = None,
           blocked: "set | frozenset" = frozenset()) -> str:
    """Pick the cheapest applicable executor.

    Measured seconds (plan-cache feedback) are authoritative: when any
    applicable candidate has a recorded timing, the choice is the fastest
    *measured* one — analytic estimates are idealized and not comparable to
    wall-clock numbers.  Candidates are scanned in fixed order with strict
    improvement, so the choice is a pure function of (features, chip,
    recorded timings) — never of dict iteration order or wall clock."""
    cands = candidates(f, ctx, blocked)
    if timings:
        best, best_s = None, math.inf
        for name in cands:
            if name in timings and timings[name] < best_s:
                best, best_s = name, timings[name]
        if best is not None:
            return best
    best, best_s = None, math.inf
    for name in cands:
        s = analytic_seconds(name, f, ctx.chip)
        if s < best_s:
            best, best_s = name, s
    return best or "pipelined"


@register_executor("auto")
class AutoExecutor(StageExecutor):
    """Per-stage dispatch: score, (optionally) measure, pin, delegate.

    The session-level ``executor="auto"`` resolves to a concrete strategy for
    every stage independently — one pipeline may run an elementwise stage on
    ``scan`` and a whole-array stage on ``eager``.  Decisions are pinned into
    the plan-cache entry, so later hits (and restarted processes, via
    ``plan_cache.save/load``) replay them with zero extra work."""

    tunable = False              # the delegate's own tuner handles batch size

    def run(self, stage: Stage, graph: DataflowGraph, ctx) -> None:
        # Streams pass through for scoring (features read types + avals, not
        # values); the delegate's own run() re-resolves with its capability
        # and owns the ingest/materialize stats (tally=False here).
        # shard_ok too: a sharded-form stream must not be gathered just to
        # score the stage — the delegate decides whether to gather it.
        concrete = resolve_stage_inputs(stage, graph, ctx, streams_ok=True,
                                        tally=False, shard_ok=True)
        entry = getattr(ctx, "_plan_entry", None)
        # Quarantined executors (resilience ladder) sit out selection —
        # read-only here: run_stage already aged the quarantine this dispatch.
        blocked = (entry.quarantined_execs(stage.id)
                   if entry is not None else set())
        name = entry.chosen_exec.get(stage.id) if entry is not None else None
        if name is not None and name in blocked:
            # A pinned choice that later crashed: skip it (the pin stays —
            # when the quarantine ages out, warm calls resume replaying it).
            ctx.stats["auto_quarantine_skips"] += 1
            name = None
        elif name is not None and self._recheck_due(stage, concrete, ctx,
                                                    entry):
            name = None              # periodic re-analysis: pin drifted
        elif name is not None and self._aged_out(stage, concrete, ctx, entry):
            name = None              # shape drift past a crossover: re-measure
        if name is not None:
            ctx.stats["auto_pinned_replays"] += 1
        elif (entry is not None and entry.hits > 0
                and getattr(ctx, "autotune", True)
                and not has_dynamic(stage)
                and entry.try_claim_exec(stage.id)):
            concrete = materialize_inputs(stage, concrete, ctx)
            name = self._measure_and_pin(stage, concrete, ctx, entry, blocked)
        if name is None:
            feats = features_of(stage, concrete, ctx)
            timings = entry.exec_timings.get(stage.id) if entry is not None else None
            name = choose(feats, ctx, timings, blocked)
        ctx.stats["auto_stages"] += 1
        ctx.stats[f"auto_pick_{name}"] += 1
        if ctx.log:
            print(f"[mozart] stage {stage.id}: auto -> {name}")
        # Delegate through the degradation ladder (no re-tick: this dispatch
        # already aged the quarantine at the outer run_stage).
        resilience.run_stage(name, stage, graph, ctx, _tick=False)

    def _aged_out(self, stage: Stage, concrete: dict[tuple, Any], ctx,
                  entry) -> bool:
        """Re-measurement aging (ROADMAP follow-up): a pinned choice recorded
        its measurement-time shape bucket (``PlanEntry.exec_meta``); when a
        warm call's element count has drifted to a different power-of-two
        bucket AND the analytic model's ranking flips between the two sizes
        (a cost crossover was passed), the pin is dropped and the next
        execution re-measures instead of blindly replaying."""
        meta = entry.exec_meta.get(stage.id) if entry is not None else None
        if not meta:
            return False                      # pre-aging pin: nothing recorded
        n = stage_num_elements(stage, concrete, ctx.pedantic)
        if int(n).bit_length() == meta["bucket"]:
            return False                      # same shape regime: replay
        feats_now = features_of(stage, concrete, ctx)
        if not drifted_past_crossover(feats_now, meta, ctx):
            # drifted, but the model ranks the same winner at both sizes:
            # refresh the recorded regime and keep replaying the pin
            entry.pin_exec(stage.id, entry.chosen_exec[stage.id], n=n)
            return False
        if not (getattr(ctx, "autotune", True) and not has_dynamic(stage)):
            return False                      # cannot re-measure here
        entry.unpin_exec(stage.id)
        ctx.stats["auto_repinned_drift"] += 1
        return True


    def _recheck_due(self, stage: Stage, concrete: dict[tuple, Any], ctx,
                     entry) -> bool:
        """Periodic re-analysis (``MOZART_REANALYZE_EVERY``): the tick in
        ``plan_cache._maybe_reanalyze`` flags every stage for one drift
        re-check; here the flag is consumed.  Unlike ``_aged_out`` this does
        not wait for the shape bucket to change — the tick exists precisely
        to revisit pins whose *cost inputs* may have drifted while the shape
        stayed put.  The pin is dropped only when the analytic model's
        winner actually flipped between the measured and current shapes."""
        if entry is None:
            return False
        with entry._lock:
            due = stage.id in entry.recheck_stages
            entry.recheck_stages.discard(stage.id)
        if not due:
            return False
        meta = entry.exec_meta.get(stage.id)
        if not meta:
            return False                      # pre-aging pin: nothing recorded
        feats_now = features_of(stage, concrete, ctx)
        if not drifted_past_crossover(feats_now, meta, ctx):
            return False                      # pin still justified: replay it
        entry.unpin_exec(stage.id)
        ctx.stats["auto_repinned_periodic"] += 1
        return True

    def _measure_and_pin(self, stage: Stage, concrete: dict[tuple, Any], ctx,
                         entry, blocked: "set | frozenset" = frozenset()) -> str:
        """Time a bounded chunk sample under each viable candidate, record the
        extrapolated seconds (overwriting stale/poisoned values) and pin the
        measured winner.  Quarantined candidates are neither measured nor
        pinned — no point timing a strategy known to crash here."""
        pinned = False
        try:
            feats = features_of(stage, concrete, ctx)
            cands = candidates(feats, ctx, blocked)
            scores = {c: analytic_seconds(c, feats, ctx.chip) for c in cands}
            floor = min(scores.values())
            cands = [c for c in cands
                     if scores[c] <= floor * _MEASURE_RATIO] or cands[:1]
            if feats.n == 0 or len(cands) == 1:
                entry.pin_exec(stage.id, cands[0], n=feats.n)
                pinned = True
                return cands[0]
            n = feats.n
            for c in cands:
                d = get_executor(c)
                batch = d.choose_batch(stage, concrete, ctx, n)
                try:
                    secs = d.sampled_time(stage, concrete, ctx, batch, n)
                except resilience.PROBE_ERRORS as e:
                    # unmeasurable here: keep it unscored (but visibly)
                    resilience.note_swallowed("auto_measure", e, ctx)
                    continue
                entry.record_exec_timing(stage.id, c, secs)
            measured = entry.exec_timings.get(stage.id, {})
            name = choose(feats, ctx, measured, blocked)
            entry.pin_exec(stage.id, name, n=feats.n)
            pinned = True
            ctx.stats["auto_measured_stages"] += 1
            return name
        finally:
            if not pinned:
                entry.release_exec(stage.id)


def drifted_past_crossover(feats_now: StageFeatures, meta: dict, ctx) -> bool:
    """True when the analytic model's winner differs between the shape a
    pinned executor choice was measured at (``meta["n"]``) and the shape a
    warm call is seeing now — i.e. the drift crossed a cost-model crossover
    and the old measurement no longer supports the pin."""
    feats_then = dataclasses.replace(feats_now, n=int(meta["n"]))
    return choose(feats_now, ctx) != choose(feats_then, ctx)
