"""The StageExecutor subsystem: shared split → drive → merge machinery.

Every Mozart execution strategy follows the same three-phase shape from the
paper (§5.2): split the stage inputs into fast-memory-sized batches, drive
each batch through the unmodified library functions, and merge the partial
results associatively.  This module extracts that machinery into one base
class and a registry so that strategies are *pluggable*:

    @register_executor("my-strategy")
    class MyExecutor(StageExecutor):
        def execute(self, stage, concrete, ctx):
            ...split / drive / merge using the shared helpers...

``runtime.MozartContext.evaluate`` dispatches through ``get_executor`` — no
string ``if/elif`` chains.  The built-in strategies live in
``core/executor.py`` ("eager", "pipelined", "fused", "scan"),
``core/sharded.py`` ("sharded") and ``core/pallas_exec.py`` ("pallas") and
are registered as a side effect of importing those modules.

Batch sizing goes through ``StageExecutor.choose_batch`` which layers, in
priority order: an explicit per-context override (``batch_elements``), the
auto-tuner's pinned size for a cached plan (``core/plan_cache.py``), and the
paper's §5.2 fast-memory estimate (``hardware.mozart_batch_elements``).
"""

from __future__ import annotations

import time
from typing import Any, Callable

import jax

from repro import hardware
from repro.core import split_types as st
from repro.core.graph import DataflowGraph, Node, NodeRef
from repro.core.planner import Stage, _value_key


class PedanticError(RuntimeError):
    pass


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, type["StageExecutor"]] = {}
_INSTANCES: dict[str, "StageExecutor"] = {}


def register_executor(name: str) -> Callable[[type], type]:
    """Class decorator: make a StageExecutor reachable as ``executor=name``."""

    def deco(cls: type) -> type:
        cls.name = name
        _REGISTRY[name] = cls
        _INSTANCES.pop(name, None)
        return cls

    return deco


def _ensure_builtin_executors() -> None:
    # Importing these modules registers their executor classes.
    import repro.core.executor      # noqa: F401  (eager/pipelined/fused/scan)
    import repro.core.pallas_exec   # noqa: F401  (pallas)
    import repro.core.sharded       # noqa: F401  (sharded)
    import repro.core.cost_model    # noqa: F401  (auto)


def get_executor(name: str) -> "StageExecutor":
    if name not in _REGISTRY:
        _ensure_builtin_executors()
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown executor {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None
    inst = _INSTANCES.get(name)
    if inst is None or type(inst) is not cls:
        inst = _INSTANCES[name] = cls()
    return inst


def available_executors() -> tuple[str, ...]:
    _ensure_builtin_executors()
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# Runtime parameter discovery (paper §5.2 step 1)
# ---------------------------------------------------------------------------


def stage_num_elements(stage: Stage, concrete: dict[tuple, Any], pedantic: bool) -> int:
    counts = set()
    for key, si in stage.inputs.items():
        if not si.split_type.splittable:
            continue
        info = si.split_type.info(concrete[key])
        if info is not None:
            counts.add(info.num_elements)
    if len(counts) > 1:
        raise PedanticError(f"stage {stage.id}: inputs disagree on element count: {counts}")
    return counts.pop() if counts else 1


def stage_elem_bytes(stage: Stage, concrete: dict[tuple, Any], n: int) -> int:
    """Σ sizeof(element) over live pipeline values (inputs + outputs)."""
    total = 0
    for key, si in stage.inputs.items():
        if not si.split_type.splittable:
            continue
        info = si.split_type.info(concrete[key])
        if info is not None:
            total += info.elem_bytes
    for node in stage.nodes:
        t = stage.out_types[node.id]
        if t.splittable and node.out_aval is not None:
            leaves = jax.tree_util.tree_leaves(node.out_aval)
            nb = sum(st.nbytes_of(l) for l in leaves)
            total += max(nb // max(n, 1), 1)
    return total


def batch_ranges(n: int, batch: int) -> list[tuple[int, int]]:
    if n <= 0:
        # Empty splits: one degenerate chunk, so the chain still runs (on
        # zero-size slices) and merges produce the library's empty-input
        # result instead of crashing on an empty partial list.
        return [(0, 0)]
    return [(s, min(s + batch, n)) for s in range(0, n, batch)]


def effective_elements(ctx, n: int) -> int:
    """Stage element count, clamped during sampled tuning measurements.

    Split-type ``info`` reports the FULL value's element count (it reads the
    type's recorded geometry, not the concrete value), so executors running
    on a sliced sample must cap their chunk ranges explicitly."""
    cap = getattr(ctx, "_n_cap", None)
    return n if cap is None else min(n, cap)


# ---------------------------------------------------------------------------
# Jit trace accounting
# ---------------------------------------------------------------------------

#: process-global count of jax traces of Mozart-built drivers and annotated
#: library functions.  The driver bodies call ``note_trace()`` as a Python
#: side effect: it runs while jax is *tracing*, never on a compiled-cache
#: hit, so the delta across a call counts exactly the (re)traces that call
#: caused.  The zero-retrace guarantee of warm ``mozart.pipeline`` calls is
#: asserted against this counter (tests/test_pipeline.py, the smoke gate).
_TRACES = 0


def note_trace() -> None:
    global _TRACES
    _TRACES += 1


def trace_count() -> int:
    return _TRACES


# ---------------------------------------------------------------------------
# Per-chunk chain driving (position-keyed)
# ---------------------------------------------------------------------------
#
# Chunk envs are keyed CANONICALLY — ``("in", input_position)`` for stage
# inputs and ``("n", node_position)`` for node outputs (``Stage.ckey``) —
# never by per-call node ids or value ids.  Two instantiations of the same
# plan template therefore produce envs with the identical pytree structure,
# which is what lets a pinned jitted driver from an earlier call accept this
# call's env without retracing.


def chunk_env_for(stage: Stage, concrete: dict[tuple, Any], s: int, e: int,
                  pedantic: bool) -> dict[tuple, Any]:
    env: dict[tuple, Any] = {}
    for key, si in stage.inputs.items():
        v = concrete[key]
        if si.split_type.splittable:
            piece = si.split_type.split(v, s, e)
            if pedantic and hasattr(piece, "shape") and 0 in piece.shape:
                raise PedanticError(f"empty split for {key} range [{s},{e})")
            env[stage.ckey(key)] = piece
        else:
            env[stage.ckey(key)] = v          # "_" values: pointer copy
    return env


def chain_plan(stage: Stage) -> tuple:
    """Capture-safe driving recipe for the stage chain.

    Per node: ``(fn, out_key, ((argname, env_key | None, static_value), ...),
    raw)``.  The plan holds only ``AnnotatedFn`` identities, static argument
    values and canonical env keys — no concrete call data and no ``Stage`` —
    so a jitted driver closed over it can be pinned in the plan cache and
    reused by every later instantiation of the same template without
    retaining the first call's input arrays.
    """
    cached = getattr(stage, "_chain_plan", None)
    if cached is not None:
        return cached
    steps = []
    for node in stage.nodes:
        srcs = []
        for name, v in node.bound.items():
            if name in node.fn.sa.static:
                srcs.append((name, None, v))
            else:
                srcs.append((name, stage.ckey(_value_key(v)), None))
        raw = getattr(node.fn.sa, "dynamic", False) or node.out_aval is None
        steps.append((node.fn, stage.out_key(node), tuple(srcs), raw))
    stage._chain_plan = tuple(steps)
    return stage._chain_plan


def run_plan(plan: tuple, env: dict[tuple, Any], jit_each: bool = False) -> None:
    """Drive one chunk env through every function of a chain plan in order."""
    for fn, out_key, srcs, raw in plan:
        kw = {name: (static if key is None else env[key])
              for name, key, static in srcs}
        if raw:
            res = fn.call_raw(kw)
        elif jit_each:
            res = fn.jitted(**kw)             # black-box library call
        else:
            res = fn.fn(**kw)                 # traced into enclosing jit
        env[out_key] = res


def run_chain(stage: Stage, env: dict[tuple, Any], jit_each: bool) -> None:
    """Drive one (canonically keyed) chunk env through the stage chain."""
    run_plan(chain_plan(stage), env, jit_each=jit_each)


def finish_stage(stage: Stage, partials: dict[int, list[Any]]) -> None:
    """Merge per-chunk partials (keyed by stage-local node POSITION)."""
    for node in stage.nodes:
        p = stage.pos[node.id]
        if p in partials:
            node.result = stage.out_types[node.id].merge(partials[p])
        node.done = True


# ---------------------------------------------------------------------------
# Pinned compiled executables
# ---------------------------------------------------------------------------


def pinned_jit(stage: Stage, ctx, kind: str, extra_key: tuple,
               build: Callable[[], Callable]) -> Callable:
    """One compiled driver per (plan entry, stage position, kind, extra_key).

    When the stage belongs to a cached plan, the driver built by ``build()``
    is pinned into the plan cache's in-process executable table
    (``PlanEntry.exec_table``, keyed by the persisted fingerprint): every
    later instantiation of the same template — this session or any other —
    reuses the SAME callable, so warm calls hit jax's compile cache instead
    of retracing a fresh closure.  ``build`` must return a capture-safe
    callable (close over ``chain_plan``, never over the Stage or concrete
    values).  Without an entry (uncacheable pipeline) the driver is cached on
    the Stage instance, preserving same-call reuse (tuner candidates,
    warmup-then-time runs).
    """
    key = (stage.id, kind) + tuple(extra_key)
    entry = getattr(ctx, "_plan_entry", None)
    table = entry.exec_table() if entry is not None else None
    if table is None:
        table = getattr(stage, "_jit_cache", None)
        if table is None:
            table = stage._jit_cache = {}
    fn = table.get(key)
    if fn is None:
        fn = table[key] = build()
        ctx.stats["exec_builds"] += 1
    return fn


def has_dynamic(stage: Stage) -> bool:
    return any(
        getattr(n.fn.sa, "dynamic", False) or n.out_aval is None
        for n in stage.nodes
    )


def split_axis_of(t: st.SplitType) -> int | None:
    if isinstance(t, st.ArraySplit):
        return t.axis
    if isinstance(t, st.PytreeSplit):
        return t.axis
    return None


def _block_stage_outputs(stage: Stage) -> None:
    """Best-effort device sync so tuner timings measure real work."""
    for node in stage.nodes:
        if node.id in stage.escaping and node.result is not None:
            try:
                jax.block_until_ready(node.result)
            except Exception:
                pass  # non-array results (tables, corpora): nothing async


def candidate_batches(est: int, n: int) -> list[int]:
    """2–3 chunk sizes around the §5.2 fast-memory estimate."""
    if n <= 0:
        return [1]                    # empty split: nothing to tune
    est = max(1, min(est, n))
    if est >= n:
        return [n]                    # one chunk: nothing to tune
    cands = {max(1, est // 2), est, min(est * 2, n)}
    return sorted(cands)


#: chunks per timed sample when the tuner measures a candidate.  Sampling a
#: couple of chunks and extrapolating replaces the old protocol of two FULL
#: stage executions per candidate, bounding first-cached-run overhead to well
#: under one extra full execution (see ``StageExecutor.sampled_time``).
SAMPLE_CHUNKS = 2


# ---------------------------------------------------------------------------
# Base class
# ---------------------------------------------------------------------------


class StageExecutor:
    """One execution strategy: split inputs → drive chunks → merge partials.

    Subclasses implement ``execute``; ``run`` is the template method the
    runtime calls per stage.  It resolves concrete inputs, optionally runs
    the chunk-size auto-tuner (first execution of a *cached* plan), and does
    the done/stats bookkeeping shared by every strategy.
    """

    name: str = "abstract"
    #: whether ``choose_batch`` output meaningfully affects this strategy —
    #: only tunable executors participate in chunk-size auto-tuning.
    tunable: bool = False

    # -- template method ----------------------------------------------------
    def run(self, stage: Stage, graph: DataflowGraph, ctx) -> None:
        concrete = {key: graph.resolve(si.value) for key, si in stage.inputs.items()}
        entry = getattr(ctx, "_plan_entry", None)
        if self._should_tune(stage, ctx, entry):
            self._tune(stage, concrete, ctx, entry)
        else:
            self.execute(stage, concrete, ctx)
        ctx.stats["stages"] += 1
        for node in stage.nodes:
            node.done = True

    def execute(self, stage: Stage, concrete: dict[tuple, Any], ctx) -> None:
        raise NotImplementedError

    # -- batch sizing (paper §5.2 + auto-tuner) -----------------------------
    def estimate_batch(self, stage: Stage, concrete: dict[tuple, Any], ctx,
                       n: int) -> int:
        elem_bytes = stage_elem_bytes(stage, concrete, n)
        return hardware.mozart_batch_elements(elem_bytes, ctx.chip)

    def choose_batch(self, stage: Stage, concrete: dict[tuple, Any], ctx,
                     n: int) -> int:
        override = getattr(ctx, "_batch_override", None)
        if override is not None:
            return max(1, min(override, n))
        if ctx.batch_elements:
            return max(1, min(ctx.batch_elements, n))
        entry = getattr(ctx, "_plan_entry", None)
        if entry is not None:
            pinned = entry.tuned_batch.get(stage.id)
            if pinned:
                return max(1, min(pinned, n))
        return max(1, min(self.estimate_batch(stage, concrete, ctx, n), n))

    # -- auto-tuner ---------------------------------------------------------
    def _should_tune(self, stage: Stage, ctx, entry) -> bool:
        return (
            self.tunable
            and entry is not None
            and entry.hits > 0                      # first execution of a CACHED plan
            and getattr(ctx, "autotune", True)
            and not ctx.batch_elements
            and getattr(ctx, "_batch_override", None) is None
            and stage.id not in entry.tuned_batch
            # dynamic (call_raw) functions may carry side effects and their
            # runtime is value-dependent: never re-execute them to time them
            and not has_dynamic(stage)
            # claim atomically so concurrent sessions never tune in duplicate
            and entry.try_claim_tuning(stage.id)
        )

    def _tune(self, stage: Stage, concrete: dict[tuple, Any], ctx, entry) -> None:
        pinned = False
        try:
            n = stage_num_elements(stage, concrete, ctx.pedantic)
            est = self.estimate_batch(stage, concrete, ctx, n)
            cands = self.tuning_candidates(stage, concrete, ctx, est, n)
            if len(cands) == 1:
                entry.pin(stage.id, cands[0])
                pinned = True
                self.execute(stage, concrete, ctx)
                return
            best, best_dt = None, None
            for b in cands:
                try:
                    dt = self.sampled_time(stage, concrete, ctx, b, n)
                except Exception:
                    continue            # unsampleable candidate: skip it
                entry.record_trial(stage.id, b, dt)
                if best_dt is None or dt < best_dt:
                    best, best_dt = b, dt
            entry.pin(stage.id, best if best is not None else est)
            pinned = True
            if best is not None:
                ctx.stats["autotuned_stages"] += 1
        finally:
            if not pinned:
                entry.release_tuning(stage.id)
        # One real execution with the pinned size produces the stage results
        # (sampled runs above computed throwaway partial outputs only).
        self.execute(stage, concrete, ctx)

    # -- sampled measurement ------------------------------------------------
    def tuning_candidates(self, stage: Stage, concrete: dict[tuple, Any], ctx,
                          est: int, n: int) -> list[int]:
        """Chunk-size candidates the tuner measures (§5.2 bracket by default;
        executors with extra geometry constraints — e.g. ``sharded``'s
        per-shard loop — override to reshape the candidate space)."""
        return candidate_batches(est, n)

    def sample_elems(self, ctx, batch: int, n: int) -> int:
        """Elements one timed sample re-executes.  ``sharded`` rounds this to
        the mesh extent so sample slices stay shardable."""
        return min(n, SAMPLE_CHUNKS * batch) if n > 0 else 0

    def sampled_time(self, stage: Stage, concrete: dict[tuple, Any], ctx,
                     batch: int, n: int) -> float:
        """Estimated seconds for a full stage execution at ``batch``, measured
        on a bounded sample of chunks.

        Splits every splittable input down to ``SAMPLE_CHUNKS`` chunks, runs
        the chain twice (warmup absorbs per-chunk-shape jit tracing; the
        second run is timed) and extrapolates linearly to ``n`` elements.
        ``ctx.stats["tuning_sample_elems"]`` accrues the elements actually
        re-executed so tests can assert the overhead bound structurally."""
        batch = max(1, min(batch, n)) if n > 0 else 1
        s = self.sample_elems(ctx, batch, n)
        sample: dict[tuple, Any] = {}
        for key, si in stage.inputs.items():
            v = concrete[key]
            sample[key] = (si.split_type.split(v, 0, s)
                           if si.split_type.splittable else v)
        prev_cap = getattr(ctx, "_n_cap", None)
        prev_override = ctx._batch_override
        ctx._n_cap = s
        ctx._batch_override = batch
        try:
            self.execute(stage, sample, ctx)
            _block_stage_outputs(stage)
            t0 = time.perf_counter()
            self.execute(stage, sample, ctx)
            _block_stage_outputs(stage)
            dt = time.perf_counter() - t0
        finally:
            ctx._n_cap = prev_cap
            ctx._batch_override = prev_override
        ctx.stats["tuning_sample_elems"] += 2 * s
        return dt * (n / s) if s else dt
