"""The StageExecutor subsystem: shared split → drive → merge machinery.

Every Mozart execution strategy follows the same three-phase shape from the
paper (§5.2): split the stage inputs into fast-memory-sized batches, drive
each batch through the unmodified library functions, and merge the partial
results associatively.  This module extracts that machinery into one base
class and a registry so that strategies are *pluggable*:

    @register_executor("my-strategy")
    class MyExecutor(StageExecutor):
        def execute(self, stage, concrete, ctx):
            ...split / drive / merge using the shared helpers...

``runtime.MozartContext.evaluate`` dispatches through ``get_executor`` — no
string ``if/elif`` chains.  The built-in strategies live in
``core/executor.py`` ("eager", "pipelined", "fused", "scan"),
``core/sharded.py`` ("sharded") and ``core/pallas_exec.py`` ("pallas") and
are registered as a side effect of importing those modules.

Batch sizing goes through ``StageExecutor.choose_batch`` which layers, in
priority order: an explicit per-context override (``batch_elements``), the
auto-tuner's pinned size for a cached plan (``core/plan_cache.py``), and the
paper's §5.2 fast-memory estimate (``hardware.mozart_batch_elements``).
"""

from __future__ import annotations

import collections
import contextlib
import os
import threading
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import hardware
from repro.core import resilience
from repro.core import split_types as st
from repro.core.graph import DataflowGraph, Node, NodeRef
from repro.core.planner import Stage, _count_of_type, _value_key


class PedanticError(RuntimeError):
    pass


def sanitize_active() -> bool:
    """True when ``MOZART_SANITIZE`` is set (and not "0"): the boundary
    sanitizer poisons donated chunk buffers, validates stream grids before
    ingest, and cross-checks scoped counters (codes MZ301/MZ302/MZ303,
    ``core/analysis.py``).  Read per call — tests flip it mid-process."""
    return os.environ.get("MOZART_SANITIZE", "") not in ("", "0")


class SanitizerError(RuntimeError):
    """A boundary invariant the sanitizer caught red-handed (MZ3xx)."""


class _PoisonedChunks(list):
    """Donated chunk list stand-in under MOZART_SANITIZE=1.

    Stays EMPTY (``len`` 0 keeps ``__repr__`` and consumed-first code paths
    benign) but any attempt to read a chunk out of it — iterating or
    indexing — raises with the donating stage/edge, instead of silently
    handing back buffers XLA has already reused."""

    def __init__(self, donor: str):
        super().__init__()
        self.donor = donor

    def _blow(self) -> None:
        raise SanitizerError(
            f"[MZ301] use-after-donate: chunk buffers were donated at "
            f"{self.donor or 'an unknown stage/edge'} and then read")

    def __getitem__(self, i):
        self._blow()

    def __iter__(self):
        self._blow()


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, type["StageExecutor"]] = {}
_INSTANCES: dict[str, "StageExecutor"] = {}


def register_executor(name: str) -> Callable[[type], type]:
    """Class decorator: make a StageExecutor reachable as ``executor=name``."""

    def deco(cls: type) -> type:
        cls.name = name
        _REGISTRY[name] = cls
        _INSTANCES.pop(name, None)
        return cls

    return deco


def _ensure_builtin_executors() -> None:
    # Importing these modules registers their executor classes.
    import repro.core.executor      # noqa: F401  (eager/pipelined/fused/scan)
    import repro.core.pallas_exec   # noqa: F401  (pallas)
    import repro.core.sharded       # noqa: F401  (sharded)
    import repro.core.cost_model    # noqa: F401  (auto)


def get_executor(name: str) -> "StageExecutor":
    if name not in _REGISTRY:
        _ensure_builtin_executors()
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown executor {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None
    inst = _INSTANCES.get(name)
    if inst is None or type(inst) is not cls:
        inst = _INSTANCES[name] = cls()
    return inst


def available_executors() -> tuple[str, ...]:
    _ensure_builtin_executors()
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# Runtime parameter discovery (paper §5.2 step 1)
# ---------------------------------------------------------------------------


def _info_arg(v: Any) -> Any:
    """What to hand a split type's ``info``: a handed-off ChunkStream stands
    in for its full value via its aval (same shapes/dtypes, and pytree avals
    flatten where the stream object itself would not)."""
    return v.aval if isinstance(v, ChunkStream) else v


def stage_num_elements(stage: Stage, concrete: dict[tuple, Any], pedantic: bool) -> int:
    counts = set()
    for key, si in stage.inputs.items():
        if not si.split_type.splittable:
            continue
        info = si.split_type.info(_info_arg(concrete[key]))
        if info is not None:
            counts.add(info.num_elements)
    if len(counts) > 1:
        raise PedanticError(f"stage {stage.id}: inputs disagree on element count: {counts}")
    return counts.pop() if counts else 1


def stage_elem_bytes(stage: Stage, concrete: dict[tuple, Any], n: int) -> int:
    """Σ sizeof(element) over live pipeline values (inputs + outputs)."""
    total = 0
    for key, si in stage.inputs.items():
        if not si.split_type.splittable:
            continue
        info = si.split_type.info(_info_arg(concrete[key]))
        if info is not None:
            total += info.elem_bytes
    for node in stage.nodes:
        t = stage.out_types[node.id]
        if t.splittable and node.out_aval is not None:
            leaves = jax.tree_util.tree_leaves(node.out_aval)
            nb = sum(st.nbytes_of(l) for l in leaves)
            total += max(nb // max(n, 1), 1)
    return total


def batch_ranges(n: int, batch: int) -> list[tuple[int, int]]:
    if n <= 0:
        # Empty splits: one degenerate chunk, so the chain still runs (on
        # zero-size slices) and merges produce the library's empty-input
        # result instead of crashing on an empty partial list.
        return [(0, 0)]
    return [(s, min(s + batch, n)) for s in range(0, n, batch)]


def effective_elements(ctx, n: int) -> int:
    """Stage element count, clamped during sampled tuning measurements.

    Split-type ``info`` reports the FULL value's element count (it reads the
    type's recorded geometry, not the concrete value), so executors running
    on a sliced sample must cap their chunk ranges explicitly."""
    cap = getattr(ctx, "_n_cap", None)
    return n if cap is None else min(n, cap)


# ---------------------------------------------------------------------------
# Trace + stage-boundary traffic accounting (scoped per execution context)
# ---------------------------------------------------------------------------

#: bounded trail of recent materialization events ``(kind, where, nbytes)``
#: — enough context for the smoke gate to NAME the offending boundary in a
#: diff-style message instead of failing on a bare byte count.
_EVENT_LIMIT = 256


class BoundaryCounters:
    """One scope's view of trace and stage-boundary traffic accounting.

    TRACES count jax traces of Mozart-built drivers and annotated library
    functions: the driver bodies call ``note_trace()`` as a Python side
    effect — it runs while jax is *tracing*, never on a compiled-cache hit,
    so the delta across a call counts exactly the (re)traces that call
    caused.  The zero-retrace guarantee of warm ``mozart.pipeline`` calls is
    asserted against this (tests/test_pipeline.py, the smoke gate).

    BOUNDARY BYTES split into two components.  INTERIOR bytes are the round
    trips the handoff subsystem exists to remove: merges of multi-chunk
    partials (``finish_stage``, ``SplitType.rechunk`` copies,
    materialize-on-ingest by a stream-incapable executor) plus bytes
    re-sliced when a stage splits a value that another stage produced.
    TERMINAL bytes are the lazy ``ChunkStream.materialize`` of an *observed*
    pipeline output (``Future.value`` forcing the merge) — inherent to
    observation, not a boundary round trip, and therefore accounted
    separately so gates never pass or fail for the wrong reason.  Splitting
    EXTERNAL pipeline inputs is counted by neither (that split is inherent
    to chunking).

    Every ``MozartContext`` owns one of these (``ctx.counters``): executor
    dispatch and terminal observation run inside ``counter_scope``, so two
    concurrent sessions/pipelines never pollute each other's gates.  The
    module-level functions below (``trace_count``, ``bytes_interior``, …)
    read the PROCESS-GLOBAL aggregate, which every event also updates —
    single-session callers and cross-session totals keep working unchanged.
    """

    __slots__ = ("traces", "interior", "terminal", "events")

    def __init__(self) -> None:
        self.traces = 0
        self.interior = 0
        self.terminal = 0
        self.events: "collections.deque[tuple[str, str, int]]" = \
            collections.deque(maxlen=_EVENT_LIMIT)

    # -- the same read surface as the module-level aggregate ----------------
    def trace_count(self) -> int:
        return self.traces

    def bytes_interior(self) -> int:
        return self.interior

    def bytes_terminal(self) -> int:
        return self.terminal

    def bytes_materialized(self) -> int:
        return self.interior + self.terminal

    def materialize_events(self) -> list[tuple[str, str, int]]:
        return list(self.events)

    def reset(self) -> None:
        self.traces = 0
        self.interior = 0
        self.terminal = 0
        self.events.clear()


#: the process-global aggregate: every note_* call lands here in addition to
#: whatever scopes are active.
_GLOBAL_COUNTERS = BoundaryCounters()

_scope_tls = threading.local()


def _scopes() -> list:
    s = getattr(_scope_tls, "stack", None)
    if s is None:
        s = _scope_tls.stack = []
    return s


@contextlib.contextmanager
def counter_scope(counters: "BoundaryCounters | None"):
    """Attribute trace/boundary events to ``counters`` for the duration.

    Scopes nest (a dynamic node re-entering ``evaluate`` keeps one
    attribution, not two: re-entering with a scope already active is a
    no-op), and distinct scopes stack — an outer session observing a value
    while an inner session runs each see only their own events.  Thread
    local; the process-global aggregate is always updated regardless."""
    if counters is None:
        yield
        return
    stack = _scopes()
    if any(c is counters for c in stack):
        yield                             # already attributed: no double count
        return
    stack.append(counters)
    snap = None
    if sanitize_active():
        with _counts_lock:
            snap = (counters.traces, counters.interior, counters.terminal,
                    _GLOBAL_COUNTERS.traces, _GLOBAL_COUNTERS.interior,
                    _GLOBAL_COUNTERS.terminal)
    clean = False
    try:
        yield
        clean = True
    finally:
        stack.remove(counters)
        if snap is not None and clean:
            # MZ303: every event lands on the global aggregate AND every
            # active scope under one lock, so a scope can never see MORE
            # than the global did over the same window.  (Other threads may
            # inflate the global side; that is fine and expected.)
            with _counts_lock:
                deltas = (
                    ("traces", counters.traces - snap[0],
                     _GLOBAL_COUNTERS.traces - snap[3]),
                    ("interior", counters.interior - snap[1],
                     _GLOBAL_COUNTERS.interior - snap[4]),
                    ("terminal", counters.terminal - snap[2],
                     _GLOBAL_COUNTERS.terminal - snap[5]),
                )
            for field, scoped, global_ in deltas:
                if scoped > global_:
                    raise SanitizerError(
                        f"[MZ303] scoped BoundaryCounters recorded more "
                        f"{field} ({scoped}) than the process-global "
                        f"aggregate ({global_}) over the same scope — "
                        "counter attribution is corrupt")


#: guards counter increments: concurrent pipelines (the serving scheduler's
#: pattern) must never lose an increment to a racing ``+=`` — a dropped
#: trace count would let a real retrace read as warm.
_counts_lock = threading.Lock()


def note_trace() -> None:
    with _counts_lock:
        _GLOBAL_COUNTERS.traces += 1
        for c in _scopes():
            c.traces += 1


def note_materialized(nbytes: int, terminal: bool = False,
                      kind: str = "merge", where: str = "") -> None:
    nbytes = int(nbytes)
    event = (("terminal:" if terminal else "interior:") + kind, where, nbytes)
    with _counts_lock:
        for c in (_GLOBAL_COUNTERS, *_scopes()):
            if terminal:
                c.terminal += nbytes
            else:
                c.interior += nbytes
            c.events.append(event)


def trace_count() -> int:
    """Process-global trace count (aggregate across every scope)."""
    return _GLOBAL_COUNTERS.traces


def bytes_materialized() -> int:
    """Total boundary bytes (interior + terminal), process-global."""
    return _GLOBAL_COUNTERS.bytes_materialized()


def bytes_interior() -> int:
    """Interior-boundary bytes only (must be 0 on a fully handed-off chain).
    Process-global; per-session gates read ``ctx.counters`` instead."""
    return _GLOBAL_COUNTERS.interior


def bytes_terminal() -> int:
    """Bytes merged lazily at *observed* terminal outputs only (global)."""
    return _GLOBAL_COUNTERS.terminal


def reset_materialized() -> None:
    """Zero the GLOBAL byte counters and drop its event trail (tests).
    Scoped counters are unaffected — reset those via ``ctx.counters.reset()``."""
    _GLOBAL_COUNTERS.interior = 0
    _GLOBAL_COUNTERS.terminal = 0
    _GLOBAL_COUNTERS.events.clear()


def materialize_events() -> list[tuple[str, str, int]]:
    """Recent ``(kind, where, nbytes)`` materialization events (global)."""
    return _GLOBAL_COUNTERS.materialize_events()


def _value_nbytes(v: Any) -> int:
    return sum(st.nbytes_of(l) for l in jax.tree_util.tree_leaves(v)
               if hasattr(l, "shape") or isinstance(l, (int, float, complex, bool)))


# ---------------------------------------------------------------------------
# ChunkStream: the unmerged stage-output value form (cross-stage handoff)
# ---------------------------------------------------------------------------


#: pinned message of the donated-stream late-merge backstop raise.  The
#: plan-time veto in ``handoff.analyze`` (observable producers never donate)
#: should make this unreachable; it stays as the runtime guard of last
#: resort and its text is asserted by tests/test_handoff.py.
DONATED_MERGE_ERROR = (
    "[MZ301] ChunkStream buffers were donated to a driver and can no longer "
    "be merged (handoff analysis bug: a donated stream was observed "
    "afterwards)")


class ChunkStream:
    """A stage output left as its chunk list + grid metadata.

    When every consumer of a node can ingest the producer's chunk grid
    directly (``core/handoff.py`` records the decision in the plan entry),
    ``finish_stage`` stores one of these instead of merging — the
    merge→re-split round trip at the stage boundary disappears.  The merge
    happens lazily, and only if the value is actually *observed* (a
    ``Future`` forces it, or a stream-incapable executor resolves it);
    ``materialize`` caches the merged value so it is paid at most once.

    Three storage forms share this class.  The chunk-LIST form holds one
    buffer per grid range (the chunk-loop executors' native output).  The
    STACKED form (``from_stacked``) holds the ``scan`` driver's carry layout
    directly — one ``(n_chunks, batch, …)`` leaf per pytree leaf plus an
    optional ragged ``tail`` chunk — so a scan→scan boundary hands the carry
    buffer over with zero slicing; a chunk-loop consumer derives the chunk
    list lazily (paying, and counting, one slice pass).  The SHARDED form
    (``from_sharded``) holds the sharded driver's device-resident global
    ``jax.Array`` plus its ``Sharding`` — one grid range per mesh shard — so
    a sharded→sharded boundary passes the global array straight through
    (zero interior bytes, no all-gather) and a chunk-loop consumer derives
    per-shard chunk views from ``addressable_shards`` without copying.
    """

    __slots__ = ("_chunks", "ranges", "split_type", "aval", "_merged",
                 "consumed", "donor", "stacked", "tail", "sharded", "sharding")

    def __init__(self, chunks: list | None, ranges: list,
                 split_type: st.SplitType, aval: Any):
        self._chunks = list(chunks) if chunks is not None else None
        self.ranges = list(ranges)
        self.split_type = split_type
        self.aval = aval                   # full-value ShapeDtypeStruct pytree
        self._merged = None
        self.consumed = False              # chunk buffers donated to a driver
        self.donor = ""                    # "stage N input K" that donated them
        self.stacked = None                # (n_chunks, batch, …) carry layout
        self.tail = None                   # ragged tail chunk (chunk-shaped)
        self.sharded = None                # device-resident global jax.Array
        self.sharding = None               # its jax.sharding.Sharding

    @classmethod
    def from_stacked(cls, stacked: Any, tail: Any, ranges: list,
                     split_type: st.SplitType, aval: Any) -> "ChunkStream":
        """Wrap a scan driver's carry layout without unstacking it.

        ``stacked`` leaves are ``(n_chunks, batch, …)`` with the split axis
        already moved to position 1 (the scan stacking convention); ``tail``
        is the ragged last chunk in normal chunk form, or None."""
        s = cls(None, ranges, split_type, aval)
        s.stacked = stacked
        s.tail = tail
        return s

    @classmethod
    def from_sharded(cls, sharded: Any, ranges: list,
                     split_type: st.SplitType, aval: Any,
                     sharding: Any) -> "ChunkStream":
        """Wrap the sharded driver's global array without gathering it.

        ``sharded`` is a device-resident ``jax.Array`` laid out by
        ``sharding`` along the stream's split axis; ``ranges`` is the
        per-shard grid (one range per mesh shard).  A sharded consumer with
        the same layout takes ``sharded`` as-is; any other consumer either
        derives the per-shard chunk views (``.chunks``, zero-copy) or
        materializes (counted ``interior:gather`` — the honest cost of
        leaving the mesh)."""
        s = cls(None, ranges, split_type, aval)
        s.sharded = sharded
        s.sharding = sharding
        return s

    # -- aval-like surface (batch sizing reads .shape/.dtype) ---------------
    @property
    def shape(self):
        return self.aval.shape

    @property
    def dtype(self):
        return self.aval.dtype

    @property
    def ndim(self):
        return len(self.aval.shape)

    @property
    def n(self) -> int:
        return self.ranges[-1][1] if self.ranges else 0

    def _axis(self) -> int:
        ax = split_axis_of(self.split_type)
        return 0 if ax is None else ax

    def _empty_value(self) -> Any:
        """A zero-element value shaped like the aval (zero-chunk streams)."""
        return jax.tree_util.tree_map(
            lambda a: jnp.zeros(a.shape, a.dtype), self.aval)

    @property
    def chunks(self) -> list:
        """The chunk list, deriving (and counting) it from stacked storage.

        A stacked stream only pays this slice pass when a chunk-loop
        consumer actually iterates it; a scan consumer uses ``stacked``
        directly and the derivation never happens.  A sharded stream derives
        zero-copy per-shard views (``addressable_shards`` in grid order) —
        the buffers stay committed to their devices, so only shard-aware
        consumers may iterate them."""
        if self._chunks is None:
            ax = self._axis()
            if self.sharded is not None:
                shards = sorted(self.sharded.addressable_shards,
                                key=lambda sh: sh.index[ax].start or 0)
                self._chunks = [sh.data for sh in shards]
                return self._chunks
            k = len(self.ranges) - (1 if self.tail is not None else 0)

            def unstack_one(i):
                return jax.tree_util.tree_map(
                    lambda l: jnp.moveaxis(l[i], 0, ax), self.stacked)

            derived = [unstack_one(i) for i in range(k)]
            if self.tail is not None:
                derived.append(self.tail)
            self._chunks = derived
            nb = sum(_value_nbytes(c) for c in derived)
            note_materialized(nb, kind="unstack",
                              where=f"stream n={self.n} {self.split_type}")
        return self._chunks

    def chunk(self, i: int) -> Any:
        """Chunk ``i`` of the grid without deriving the whole list.

        Degenerate zero-element grids (``ranges == [(0, 0)]``) may carry no
        buffer at all; they resolve to an empty value built from the aval."""
        if self._chunks is None and self.sharded is not None:
            return self.chunks[i]          # zero-copy per-shard views
        if self._chunks is None and self.stacked is not None:
            k = len(self.ranges) - (1 if self.tail is not None else 0)
            if i >= k and self.tail is not None:
                return self.tail
            ax = self._axis()
            piece = jax.tree_util.tree_map(
                lambda l: jnp.moveaxis(l[i], 0, ax), self.stacked)
            s, e = self.ranges[i]
            note_materialized(_value_nbytes(piece), kind="unstack",
                              where=f"stream chunk [{s},{e})")
            return piece
        if not self._chunks and self.n == 0:
            return self._empty_value()
        return self._chunks[i]

    def uniform_batch(self) -> int | None:
        """Chunk size when the grid is regular (ragged tail allowed)."""
        if not self.ranges:
            return None
        sizes = [e - s for s, e in self.ranges]
        body = sizes[:-1] or sizes
        return body[0] if len(set(body)) == 1 else None

    def compatible(self, consumer_type: st.SplitType) -> bool:
        return (not self.consumed
                and self.split_type.can_handoff(consumer_type))

    def materialize(self, terminal: bool = False) -> Any:
        """Merge (once) and return the full value; counts boundary bytes.

        ``terminal=True`` marks the merge as observation of a pipeline
        output (``Future.value``) — accounted under ``bytes_terminal`` so
        the interior-boundary gate never charges observation costs."""
        if self._merged is None:
            if self.consumed:
                raise RuntimeError(
                    DONATED_MERGE_ERROR
                    + f" [donated at {self.donor or 'unknown stage/edge'}]")
            if self.sharded is not None:
                # The global array IS the merged value; returning it is free
                # NOW, but a non-mesh consumer forces XLA to gather/reshard
                # it on use — count that honestly as a "gather" event (the
                # sharded→sharded smoke gate asserts no interior:gather).
                self._merged = self.sharded
                note_materialized(_value_nbytes(self._merged),
                                  terminal=terminal, kind="gather",
                                  where=f"stream n={self.n} {self.split_type}")
                return self._merged
            if self.stacked is not None and self._chunks is None:
                self._merged = self._merge_stacked()
            elif not self._chunks:
                # Zero-chunk stream (empty pipeline): merge([]) would crash
                # in the library's concat; the aval names the empty result.
                self._merged = self._empty_value()
            else:
                self._merged = self.split_type.merge(self._chunks)
            if (self._chunks is None and self.stacked is not None) \
                    or len(self._chunks or ()) > 1:
                note_materialized(_value_nbytes(self._merged),
                                  terminal=terminal,
                                  kind="materialize",
                                  where=f"stream n={self.n} {self.split_type}")
        return self._merged

    def _merge_stacked(self) -> Any:
        ax = self._axis()

        def flat(l):
            body = l.reshape((l.shape[0] * l.shape[1],) + l.shape[2:])
            return jnp.moveaxis(body, 0, ax)

        main = jax.tree_util.tree_map(flat, self.stacked)
        if self.tail is None:
            return main
        return self.split_type.merge([main, self.tail])

    def __repr__(self) -> str:
        if self.sharded is not None:
            form = f"sharded×{len(self.ranges)}"
        elif self._chunks is None and self.stacked is not None:
            form = "stacked"
        else:
            form = f"{len(self._chunks or ())} chunks"
        return f"ChunkStream({form}, n={self.n}, {self.split_type})"


def materialize(v: Any) -> Any:
    """ChunkStream -> merged value; anything else passes through."""
    return v.materialize() if isinstance(v, ChunkStream) else v


# ---------------------------------------------------------------------------
# Per-chunk chain driving (position-keyed)
# ---------------------------------------------------------------------------
#
# Chunk envs are keyed CANONICALLY — ``("in", input_position)`` for stage
# inputs and ``("n", node_position)`` for node outputs (``Stage.ckey``) —
# never by per-call node ids or value ids.  Two instantiations of the same
# plan template therefore produce envs with the identical pytree structure,
# which is what lets a pinned jitted driver from an earlier call accept this
# call's env without retracing.


def chunk_env_for(stage: Stage, concrete: dict[tuple, Any], s: int, e: int,
                  pedantic: bool, chunk_index: int | None = None,
                  force_slice: frozenset | tuple = ()) -> dict[tuple, Any]:
    """Build one chunk's canonical env.  ``force_slice`` lists canonical keys
    that must be REAL slices even for identity ranges — buffers about to be
    donated must never alias a producer's retained result."""
    resilience.maybe_fail("split", f"stage {stage.id} range [{s},{e})")
    env: dict[tuple, Any] = {}
    for key, si in stage.inputs.items():
        v = concrete[key]
        if isinstance(v, ChunkStream):
            # Handed-off input: chunk ``chunk_index`` of the producer's grid
            # IS this range's piece — no slice, no boundary traffic.
            env[stage.ckey(key)] = v.chunk(chunk_index)
            continue
        if si.split_type.splittable:
            if s == 0 and not pedantic and stage.ckey(key) not in force_slice:
                info = si.split_type.info(v)
                if info is not None and e == info.num_elements:
                    # Identity slice (single-chunk stage): pass the whole
                    # value through — no dispatch, no boundary traffic.
                    env[stage.ckey(key)] = v
                    continue
            piece = si.split_type.split(v, s, e)
            if isinstance(si.value, NodeRef):
                # Re-slicing another stage's merged output: the round trip
                # the handoff subsystem exists to remove.
                note_materialized(_value_nbytes(piece), kind="resplit",
                                  where=f"stage {stage.id} input {stage.ckey(key)}"
                                        f" range [{s},{e})")
            if pedantic and hasattr(piece, "shape") and 0 in piece.shape:
                raise PedanticError(f"empty split for {key} range [{s},{e})")
            env[stage.ckey(key)] = piece
        else:
            env[stage.ckey(key)] = v          # "_" values: pointer copy
    return env


def chain_plan(stage: Stage) -> tuple:
    """Capture-safe driving recipe for the stage chain.

    Per node: ``(fn, out_key, ((argname, env_key | None, static_value), ...),
    raw)``.  The plan holds only ``AnnotatedFn`` identities, static argument
    values and canonical env keys — no concrete call data and no ``Stage`` —
    so a jitted driver closed over it can be pinned in the plan cache and
    reused by every later instantiation of the same template without
    retaining the first call's input arrays.
    """
    cached = getattr(stage, "_chain_plan", None)
    if cached is not None:
        return cached
    steps = []
    for node in stage.nodes:
        srcs = []
        for name, v in node.bound.items():
            if name in node.fn.sa.static:
                srcs.append((name, None, v))
            else:
                srcs.append((name, stage.ckey(_value_key(v)), None))
        raw = getattr(node.fn.sa, "dynamic", False) or node.out_aval is None
        steps.append((node.fn, stage.out_key(node), tuple(srcs), raw))
    stage._chain_plan = tuple(steps)
    return stage._chain_plan


def run_plan(plan: tuple, env: dict[tuple, Any], jit_each: bool = False) -> None:
    """Drive one chunk env through every function of a chain plan in order."""
    for fn, out_key, srcs, raw in plan:
        kw = {name: (static if key is None else env[key])
              for name, key, static in srcs}
        if raw:
            res = fn.call_raw(kw)
        elif jit_each:
            res = fn.jitted(**kw)             # black-box library call
        else:
            res = fn.fn(**kw)                 # traced into enclosing jit
        env[out_key] = res


def run_chain(stage: Stage, env: dict[tuple, Any], jit_each: bool) -> None:
    """Drive one (canonically keyed) chunk env through the stage chain."""
    run_plan(chain_plan(stage), env, jit_each=jit_each)


def finish_stage(stage: Stage, partials: dict[int, list[Any]],
                 ranges: list[tuple[int, int]] | None = None,
                 ctx=None) -> None:
    """Merge per-chunk partials (keyed by stage-local node POSITION).

    With a handoff plan active (``ctx._handoff``), nodes whose every
    consumer accepts the producer grid are left UNMERGED as a
    :class:`ChunkStream` over ``ranges`` — the boundary merge happens lazily
    and only if the value is actually observed."""
    resilience.maybe_fail("merge", f"stage {stage.id}")
    ho = None
    if ctx is not None and ranges is not None:
        plan = getattr(ctx, "_handoff", None)
        ho = plan.get(stage.id) if plan else None
    for node in stage.nodes:
        p = stage.pos[node.id]
        if p in partials:
            t = stage.out_types[node.id]
            pieces = partials[p]
            if (ho is not None and p in ho.stream_out
                    and len(pieces) == len(ranges) and len(pieces) > 1):
                node.result = ChunkStream(pieces, ranges, t, node.out_aval)
                ctx.stats["streamed_outputs"] += 1
            else:
                node.result = t.merge(pieces)
                if len(pieces) > 1 and not isinstance(t, st.ScalarSplit):
                    note_materialized(_value_nbytes(node.result), kind="merge",
                                      where=f"stage {stage.id} node {p}")
        node.done = True


# ---------------------------------------------------------------------------
# Pinned compiled executables
# ---------------------------------------------------------------------------


def pinned_jit(stage: Stage, ctx, kind: str, extra_key: tuple,
               build: Callable[[], Callable]) -> Callable:
    """One compiled driver per (plan entry, stage position, kind, extra_key).

    When the stage belongs to a cached plan, the driver built by ``build()``
    is pinned into the plan cache's in-process executable table
    (``PlanEntry.exec_table``, keyed by the persisted fingerprint): every
    later instantiation of the same template — this session or any other —
    reuses the SAME callable, so warm calls hit jax's compile cache instead
    of retracing a fresh closure.  ``build`` must return a capture-safe
    callable (close over ``chain_plan``, never over the Stage or concrete
    values).  Without an entry (uncacheable pipeline) the driver is cached on
    the Stage instance, preserving same-call reuse (tuner candidates,
    warmup-then-time runs).
    """
    key = (stage.id, kind) + tuple(extra_key)
    entry = getattr(ctx, "_plan_entry", None)
    table = entry.exec_table() if entry is not None else None
    if table is None:
        table = getattr(stage, "_jit_cache", None)
        if table is None:
            table = stage._jit_cache = {}
    fn = table.get(key)
    if fn is None:
        resilience.maybe_fail("compile", f"stage {stage.id} {kind}")
        fn = table[key] = build()
        ctx.stats["exec_builds"] += 1
    return fn


def has_dynamic(stage: Stage) -> bool:
    return any(
        getattr(n.fn.sa, "dynamic", False) or n.out_aval is None
        for n in stage.nodes
    )


# ---------------------------------------------------------------------------
# Stream-aware input resolution (cross-stage handoff)
# ---------------------------------------------------------------------------


def adapt_stream(v: "ChunkStream", consumer: st.SplitType) -> "ChunkStream | None":
    """Reinterpret a fresh-output (ConcatSplit) stream under the consumer's
    concrete grid — the runtime half of the ConcatSplit→{ArraySplit,
    PytreeSplit} handoff rules.

    A ConcatSplit producer's piece sizes are unknowable at plan time, so the
    analysis only records that the conversion is *permitted*
    (``StageHandoff.convert_in``); here the sizes are read off the concrete
    chunk buffers, and when they tile the consumer's geometry exactly the
    SAME buffers are re-wrapped under the consumer's split type — zero
    copies.  An ArraySplit consumer requires single-leaf chunks; a
    PytreeSplit consumer accepts pytree chunks, deciding PER LEAF — every
    leaf of a chunk must agree on its split-axis extent for the chunk to
    contribute one grid range.  Returns None when the pieces do not form
    the consumer's grid (axis out of range, leaves disagree, total
    mismatch); the caller materializes instead, which is always correct."""
    if not isinstance(v.split_type, st.ConcatSplit):
        return None
    if v._chunks is None:              # stacked ConcatSplit streams don't exist
        return None
    if isinstance(consumer, st.ArraySplit) and consumer.shape:
        ax, total = consumer.axis, consumer.shape[consumer.axis]
        sizes = []
        for c in v._chunks:
            leaves = jax.tree_util.tree_leaves(c)
            if len(leaves) != 1 or len(getattr(leaves[0], "shape", ())) <= ax:
                return None
            sizes.append(int(leaves[0].shape[ax]))
    elif isinstance(consumer, st.PytreeSplit):
        ax, total = consumer.axis, consumer.length
        sizes = []
        for c in v._chunks:
            leaf_sizes = set()
            for l in jax.tree_util.tree_leaves(c):
                shp = getattr(l, "shape", ())
                if len(shp) <= ax:
                    return None
                leaf_sizes.add(int(shp[ax]))
            if len(leaf_sizes) != 1:   # leaves disagree (or chunk is leafless)
                return None
            sizes.append(leaf_sizes.pop())
    else:
        return None
    if sum(sizes) != total:
        return None
    ranges, s = [], 0
    for z in sizes:
        ranges.append((s, s + z))
        s += z
    if not ranges:                     # zero-chunk stream of an empty value
        ranges = [(0, 0)]
    return ChunkStream(v._chunks, ranges, consumer, v.aval)


def resolve_stage_inputs(stage: Stage, graph: DataflowGraph, ctx,
                         streams_ok: bool, tally: bool = True,
                         shard_ok: bool = False) -> dict[tuple, Any]:
    """Resolve stage inputs, ingesting producer ChunkStreams where allowed.

    An input keeps its stream form only when (a) the executor can iterate a
    chunk list (``streams_ok``), (b) the handoff plan marked this input
    position as a stream ingest, and (c) the stream's grid actually fits the
    input's split type at run time (always re-checked: cross-evaluation
    edges carry whatever grid the *previous* evaluation produced).  A
    permitted ConcatSplit→{ArraySplit,PytreeSplit} edge re-wraps the
    producer's fresh pieces under the consumer's grid (``adapt_stream``).
    SHARDED-form streams (device-resident global array) additionally require
    ``shard_ok`` — their chunks are committed to different devices, so a
    single-device chunk loop must not iterate them; materializing instead
    lets XLA reshard (counted ``interior:gather``).  Anything else is
    materialized — correct by construction, merely the old cost.
    ``tally=False`` skips the ingest/materialize stats (scoring-only
    resolves, e.g. ``AutoExecutor``, whose delegate re-resolves and counts)."""
    if tally:
        resilience.maybe_fail("ingest", f"stage {stage.id}")
    plan = getattr(ctx, "_handoff", None)
    ho = plan.get(stage.id) if plan else None
    sanitize = sanitize_active()
    concrete: dict[tuple, Any] = {}
    for i, (key, si) in enumerate(stage.inputs.items()):
        v = graph.resolve(si.value)
        if isinstance(v, ChunkStream):
            reason = _stream_fallback_reason(v, si, i, ho, streams_ok,
                                             shard_ok)
            if reason is None and type(v.split_type) is not type(si.split_type):
                # Grid conversion only where the PLAN permitted it — the
                # recorded ``convert_in`` decision replays, never a fresh
                # type-level judgement.
                if i in getattr(ho, "convert_in", frozenset()):
                    adapted = adapt_stream(v, si.split_type)
                    if adapted is None:
                        reason = "non-tiling ConcatSplit pieces"
                    else:
                        v = adapted
                        if tally:
                            ctx.stats["stream_converted"] += 1
                else:
                    reason = "grid conversion not planned"
            if reason is None:
                if sanitize:
                    _check_stream_tiles(v, si.split_type,
                                        f"stage {stage.id} input "
                                        f"{stage.ckey(key)}")
                if tally:
                    ctx.stats["stream_ingests"] += 1
            else:
                if tally:
                    # Zero-byte breadcrumb: the dataflow analyzer predicts
                    # fallbacks from the plan (MZ203); this event records
                    # the ones that actually happened, with the reason.
                    note_materialized(
                        0, kind="fallback",
                        where=f"[MZ203] stage {stage.id} input "
                              f"{stage.ckey(key)}: {reason}")
                v = v.materialize()
                if tally:
                    ctx.stats["stream_materialized"] += 1
        concrete[key] = v
    return concrete


def _stream_fallback_reason(v: "ChunkStream", si, i: int, ho,
                            streams_ok: bool, shard_ok: bool) -> str | None:
    """Why this stream input must materialize, or None to ingest it.

    The SAME predicate ``resolve_stage_inputs`` always applied — decomposed
    so the fallback event (and ``core/analysis.py``) can say WHY."""
    if not streams_ok:
        return "stream-incapable executor"
    if ho is None or i not in ho.stream_in:
        return "edge not planned for streaming"
    if v.consumed:
        return "stream already donated"
    if not v.split_type.can_handoff(si.split_type):
        pa, ca = split_axis_of(v.split_type), split_axis_of(si.split_type)
        if pa is not None and ca is not None and pa != ca:
            return f"axis mismatch (producer axis {pa}, consumer axis {ca})"
        return f"grid geometry mismatch ({v.split_type} vs {si.split_type})"
    if v.sharded is not None and not shard_ok:
        return "shard-incapable consumer"
    return None


def _check_stream_tiles(v: "ChunkStream", consumer_type: st.SplitType,
                        where: str) -> None:
    """MZ302 (MOZART_SANITIZE=1): a stream about to be ingested must carry
    sorted, contiguous ranges tiling [0, n) — and n must match the extent
    the consumer's split type declares.  A hole or overlap here means the
    consumer would silently skip or double-process rows."""
    prev = 0
    for s, e in v.ranges:
        if s != prev or e < s:
            raise SanitizerError(
                f"[MZ302] {where}: stream ranges {v.ranges} do not tile "
                f"[0, {v.n}) (hole/overlap at ({s}, {e}))")
        prev = e
    expect = _count_of_type(consumer_type)
    if expect is not None and prev != expect:
        raise SanitizerError(
            f"[MZ302] {where}: stream extent {prev} != consumer extent "
            f"{expect} declared by {consumer_type}")


# ---------------------------------------------------------------------------
# Chunk-buffer donation (shared by the fused / scan / pallas drivers)
# ---------------------------------------------------------------------------


def _aval_sig(aval) -> tuple:
    return tuple((tuple(l.shape), str(l.dtype))
                 for l in jax.tree_util.tree_leaves(aval)
                 if hasattr(l, "shape"))


def donatable_input_keys(stage: Stage, ctx) -> tuple:
    """Canonical env keys of inputs whose per-chunk buffers die here.

    STRUCTURAL only — a pure function of the handoff plan (this stage is
    the handed-off value's LAST in-plan consumer, and the plan-time veto in
    ``handoff.analyze`` already excluded observable producers) and the stage
    template (NodeRef-sourced, splittable, some escaping output chunk can
    absorb the buffer) — so a pinned driver's donate variant is identical on
    every call and the zero-retrace warm-call invariant holds.  Whether a
    producer is still observable *now* is a runtime question answered by
    ``undonatable_stream_keys`` (an observable stream donates a defensive
    COPY, never its own buffers)."""
    plan = getattr(ctx, "_handoff", None)
    ho = plan.get(stage.id) if plan else None
    if ho is None or not ho.last_use:
        return ()

    # XLA can only reuse a donated buffer for an output of the same
    # shape/dtype: donate at most ONE input per matching escaping output
    # (else jax warns about unusable donations).
    out_sigs: dict[tuple, int] = {}
    for n in stage.nodes:
        if (n.id in stage.escaping and n.out_aval is not None
                and stage.out_types[n.id].splittable):
            sig = _aval_sig(n.out_aval)
            out_sigs[sig] = out_sigs.get(sig, 0) + 1
    keys = []
    for i, (key, si) in enumerate(stage.inputs.items()):
        if not (i in ho.last_use and isinstance(si.value, NodeRef)
                and si.split_type.splittable):
            continue
        node = ctx.graph.nodes.get(si.value.node_id)
        aval = node.out_aval if node is not None else None
        if aval is not None and out_sigs.get(_aval_sig(aval), 0) > 0:
            out_sigs[_aval_sig(aval)] -= 1
            keys.append(stage.ckey(key))
    return tuple(sorted(keys))


def undonatable_stream_keys(stage: Stage, concrete: dict[tuple, Any], ctx,
                            donate: tuple) -> set:
    """Donate-marked keys whose ChunkStream may still be observed (the
    producer's Future is alive): their chunks are copied before donation so
    the stream's own buffers survive.  The plan-time veto makes this rare —
    it still fires when liveness flapped between analysis and this call."""
    unsafe = set()
    for key, si in stage.inputs.items():
        ck = stage.ckey(key)
        if ck in donate and isinstance(concrete.get(key), ChunkStream):
            node = ctx.graph.nodes.get(si.value.node_id)
            if node is None or node.future_alive():
                unsafe.add(ck)
    return unsafe


def mark_stream_consumed(stage: Stage, concrete: dict[tuple, Any], ctx,
                         consumed: "set | frozenset | tuple") -> None:
    """After real (non-copy) donation of the canonical keys in ``consumed``:
    flag the stream AND its graph-node original so a late ``materialize``
    hits the pinned backstop error instead of returning freed buffers.
    Under ``MOZART_SANITIZE=1`` the chunk storage itself is also poisoned
    (``_PoisonedChunks``): any read raises MZ301 naming this stage/edge,
    instead of depending on every consumer checking ``consumed`` first."""
    sanitize = sanitize_active()
    for key, si in stage.inputs.items():
        v = concrete.get(key)
        if stage.ckey(key) in consumed and isinstance(v, ChunkStream):
            donor = f"stage {stage.id} input {stage.ckey(key)}"
            targets = [v]                  # the stream and its graph-node
            orig = ctx.graph.nodes[si.value.node_id].result
            if isinstance(orig, ChunkStream) and orig is not v:
                targets.append(orig)       # original / adapted aliases
            for t in targets:
                t.consumed = True
                t.donor = t.donor or donor
                if sanitize:
                    t._chunks = _PoisonedChunks(t.donor)
                    t.stacked = t.tail = t.sharded = None
            if sanitize:
                note_materialized(0, kind="donate", where=donor)


def materialize_inputs(stage: Stage, concrete: dict[tuple, Any],
                       ctx=None) -> dict[tuple, Any]:
    """Merge any stream inputs (tuning/measurement paths need real arrays)."""
    out = dict(concrete)
    for key, v in concrete.items():
        if isinstance(v, ChunkStream):
            out[key] = v.materialize()
            if ctx is not None:
                ctx.stats["stream_materialized"] += 1
    return out


def split_axis_of(t: st.SplitType) -> int | None:
    if isinstance(t, st.ArraySplit):
        return t.axis
    if isinstance(t, st.PytreeSplit):
        return t.axis
    return None


def _block_stage_outputs(stage: Stage) -> None:
    """Best-effort device sync so tuner timings measure real work."""
    for node in stage.nodes:
        if node.id in stage.escaping and node.result is not None:
            try:
                r = node.result
                if isinstance(r, ChunkStream):
                    # Raw storage, never the derived chunk list: blocking must
                    # not charge an unstack pass to the boundary counters.
                    r = [x for x in (r._chunks, r.stacked, r.tail, r.sharded)
                         if x is not None]
                jax.block_until_ready(r)
            except resilience.PROBE_ERRORS as e:
                # non-array results (tables, corpora): nothing async
                resilience.note_swallowed("block_stage_outputs", e)


def candidate_batches(est: int, n: int) -> list[int]:
    """2–3 chunk sizes around the §5.2 fast-memory estimate."""
    if n <= 0:
        return [1]                    # empty split: nothing to tune
    est = max(1, min(est, n))
    if est >= n:
        return [n]                    # one chunk: nothing to tune
    cands = {max(1, est // 2), est, min(est * 2, n)}
    return sorted(cands)


#: chunks per timed sample when the tuner measures a candidate.  Sampling a
#: couple of chunks and extrapolating replaces the old protocol of two FULL
#: stage executions per candidate, bounding first-cached-run overhead to well
#: under one extra full execution (see ``StageExecutor.sampled_time``).
SAMPLE_CHUNKS = 2


# ---------------------------------------------------------------------------
# Base class
# ---------------------------------------------------------------------------


class StageExecutor:
    """One execution strategy: split inputs → drive chunks → merge partials.

    Subclasses implement ``execute``; ``run`` is the template method the
    runtime calls per stage.  It resolves concrete inputs, optionally runs
    the chunk-size auto-tuner (first execution of a *cached* plan), and does
    the done/stats bookkeeping shared by every strategy.
    """

    name: str = "abstract"
    #: whether ``choose_batch`` output meaningfully affects this strategy —
    #: only tunable executors participate in chunk-size auto-tuning.
    tunable: bool = False
    #: whether ``execute`` can iterate a ChunkStream input directly (the
    #: chunk-loop drivers can; whole-array strategies materialize instead).
    stream_capable: bool = False
    #: whether ``execute`` accepts SHARDED-form streams (chunks committed to
    #: different mesh devices).  Only the sharded executor places per-shard
    #: buffers; everything else materializes and lets XLA reshard.
    shard_capable: bool = False

    # -- template method ----------------------------------------------------
    def run(self, stage: Stage, graph: DataflowGraph, ctx) -> None:
        concrete = resolve_stage_inputs(stage, graph, ctx, self.stream_capable,
                                        shard_ok=self.shard_capable)
        entry = getattr(ctx, "_plan_entry", None)
        if self._should_tune(stage, ctx, entry):
            # Sampled tuning re-slices inputs at arbitrary offsets: a one-time
            # event, so streams are merged rather than complicating sampling.
            concrete = materialize_inputs(stage, concrete, ctx)
            self._tune(stage, concrete, ctx, entry)
        else:
            self.execute(stage, concrete, ctx)
        ctx.stats["stages"] += 1
        for node in stage.nodes:
            node.done = True

    def execute(self, stage: Stage, concrete: dict[tuple, Any], ctx) -> None:
        raise NotImplementedError

    # -- batch sizing (paper §5.2 + auto-tuner) -----------------------------
    def estimate_batch(self, stage: Stage, concrete: dict[tuple, Any], ctx,
                       n: int) -> int:
        elem_bytes = stage_elem_bytes(stage, concrete, n)
        return hardware.mozart_batch_elements(elem_bytes, ctx.chip)

    def choose_batch(self, stage: Stage, concrete: dict[tuple, Any], ctx,
                     n: int) -> int:
        override = getattr(ctx, "_batch_override", None)
        if override is not None:
            return max(1, min(override, n))
        if ctx.batch_elements:
            return max(1, min(ctx.batch_elements, n))
        entry = getattr(ctx, "_plan_entry", None)
        if entry is not None:
            pinned = entry.tuned_batch.get(stage.id)
            if pinned:
                return max(1, min(pinned, n))
        return max(1, min(self.estimate_batch(stage, concrete, ctx, n), n))

    # -- auto-tuner ---------------------------------------------------------
    def _should_tune(self, stage: Stage, ctx, entry) -> bool:
        return (
            self.tunable
            and entry is not None
            and entry.hits > 0                      # first execution of a CACHED plan
            and getattr(ctx, "autotune", True)
            and not ctx.batch_elements
            and getattr(ctx, "_batch_override", None) is None
            and stage.id not in entry.tuned_batch
            # dynamic (call_raw) functions may carry side effects and their
            # runtime is value-dependent: never re-execute them to time them
            and not has_dynamic(stage)
            # claim atomically so concurrent sessions never tune in duplicate
            and entry.try_claim_tuning(stage.id)
        )

    def _tune(self, stage: Stage, concrete: dict[tuple, Any], ctx, entry) -> None:
        pinned = False
        try:
            n = stage_num_elements(stage, concrete, ctx.pedantic)
            est = self.estimate_batch(stage, concrete, ctx, n)
            cands = self.tuning_candidates(stage, concrete, ctx, est, n)
            if len(cands) == 1:
                entry.pin(stage.id, cands[0])
                self.note_pinned(stage, ctx, entry, cands[0], n)
                pinned = True
                self.execute(stage, concrete, ctx)
                return
            best, best_dt = None, None
            for b in cands:
                try:
                    dt = self.sampled_time(stage, concrete, ctx, b, n)
                except resilience.PROBE_ERRORS as e:
                    # unsampleable candidate: skip it (but visibly)
                    resilience.note_swallowed("tune_sample", e, ctx)
                    continue
                entry.record_trial(stage.id, b, dt)
                if best_dt is None or dt < best_dt:
                    best, best_dt = b, dt
            chosen = best if best is not None else est
            entry.pin(stage.id, chosen)
            self.note_pinned(stage, ctx, entry, chosen, n)
            pinned = True
            if best is not None:
                ctx.stats["autotuned_stages"] += 1
        finally:
            if not pinned:
                entry.release_tuning(stage.id)
        # One real execution with the pinned size produces the stage results
        # (sampled runs above computed throwaway partial outputs only).
        self.execute(stage, concrete, ctx)

    # -- sampled measurement ------------------------------------------------
    def tuning_candidates(self, stage: Stage, concrete: dict[tuple, Any], ctx,
                          est: int, n: int) -> list[int]:
        """Chunk-size candidates the tuner measures (§5.2 bracket by default;
        executors with extra geometry constraints — e.g. ``sharded``'s
        per-shard loop, ``pallas``'s hardware block multiples — override to
        reshape the candidate space)."""
        return candidate_batches(est, n)

    def note_pinned(self, stage: Stage, ctx, entry, batch: int, n: int) -> None:
        """Hook after the tuner pins ``batch`` (e.g. ``pallas`` records the
        hardware block *shape* the winning element count resolves to)."""

    def sample_elems(self, ctx, batch: int, n: int) -> int:
        """Elements one timed sample re-executes.  ``sharded`` rounds this to
        the mesh extent so sample slices stay shardable."""
        return min(n, SAMPLE_CHUNKS * batch) if n > 0 else 0

    def sampled_time(self, stage: Stage, concrete: dict[tuple, Any], ctx,
                     batch: int, n: int) -> float:
        """Estimated seconds for a full stage execution at ``batch``, measured
        on a bounded sample of chunks.

        Splits every splittable input down to ``SAMPLE_CHUNKS`` chunks, runs
        the chain twice (warmup absorbs per-chunk-shape jit tracing; the
        second run is timed) and extrapolates linearly to ``n`` elements.
        ``ctx.stats["tuning_sample_elems"]`` accrues the elements actually
        re-executed so tests can assert the overhead bound structurally."""
        batch = max(1, min(batch, n)) if n > 0 else 1
        s = self.sample_elems(ctx, batch, n)
        sample: dict[tuple, Any] = {}
        for key, si in stage.inputs.items():
            v = concrete[key]
            sample[key] = (si.split_type.split(v, 0, s)
                           if si.split_type.splittable else v)
        prev_cap = getattr(ctx, "_n_cap", None)
        prev_override = ctx._batch_override
        ctx._n_cap = s
        ctx._batch_override = batch
        try:
            self.execute(stage, sample, ctx)
            _block_stage_outputs(stage)
            t0 = time.perf_counter()
            self.execute(stage, sample, ctx)
            _block_stage_outputs(stage)
            dt = time.perf_counter() - t0
        finally:
            ctx._n_cap = prev_cap
            ctx._batch_override = prev_override
        ctx.stats["tuning_sample_elems"] += 2 * s
        return dt * (n / s) if s else dt
