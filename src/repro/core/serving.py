"""Continuous-batching serving runtime over pinned AOT pipelines.

``launch/serve.py``'s fixed-group batcher drains whole groups: every slot
decodes until ``max(r.max_new)`` even after its own request finished, and a
queued request waits for the entire group to drain.  This module replaces
that with a **rolling decode batch**: the decode batch has a fixed ``batch``
slots; requests join a free slot at the next step boundary (prefilled into
the slot's rows of a shared per-slot KV cache) and leave the moment they
finish, freeing the slot for the next queued request.  Occupancy varies
step to step, but the decode computation never changes shape — idle slots
decode dead air whose cache writes are dropped (``mode="drop"`` scatter) —
so every warm step replays the same pinned executable: zero planner calls,
zero retraces, at any occupancy.

Shape discipline (the bucketed-batch pinning contract):

* **Decode** is always ``(batch, 1)`` tokens against the full per-slot
  cache — exactly one plan entry, pinned once, labelled
  ``("decode", batch)``.
* **Prefill** is bucketed: prompts are right-padded to a power-of-two
  length bucket and grouped into a power-of-two batch bucket; each
  ``(batch_bucket, len_bucket)`` pair fingerprints to its own plan entry,
  compiled ahead of serving (``warmup``) and labelled
  ``("prefill", bb, lb)`` via ``Pipeline.compile(bucket=...)``.  A
  half-empty admission group replays the pinned executable of its bucket
  instead of retracing.
* Right-padding + per-slot ``length`` keeps prefill correct without
  position arithmetic: causal masking already ignores the future, the
  ``pad_mask`` keeps garbage keys out of every real query's softmax, and
  ``last_pos`` gathers each row's true last-position logits.  Recurrent
  families (ssm/hybrid) scan state over every position, so padding would
  corrupt them — for those the scheduler buckets by *exact* prompt length
  (pad-free groups, one plan entry per distinct length).

Prefilled caches are scattered into the rolling cache slot-by-slot with a
jitted per-leaf batch-axis scatter (axes inferred once by diffing
``jax.eval_shape`` of ``init_caches`` at two batch sizes).  Dummy rows in a
padded admission group scatter to slot index ``batch`` — out of bounds,
dropped.

Latency is honest: each decode step is timed through the host sync
(``np.asarray`` of the argmax), so ``decode_us_per_call`` measures compute,
not dispatch.  Per-request latency runs submit -> final token.

``AsyncServer`` is the async front-end: a daemon thread drives
``ContinuousBatcher.step()`` while any number of ``asyncio`` callers
``await generate(...)`` — submissions multiplex into the rolling batch and
resolve independently.
"""

from __future__ import annotations

import asyncio
import collections
import dataclasses
import itertools
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.config import ModelConfig

__all__ = ["ServeRequest", "ContinuousBatcher", "AsyncServer"]

#: model families whose per-position recurrence makes padded prefill
#: incorrect (state integrates every position, real or pad) — bucketed by
#: exact prompt length instead.
_PAD_FREE_FAMILIES = ("ssm", "hybrid")


@dataclasses.dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray                    # (S,) int32
    max_new: int
    eos: int | None = None
    out: list = dataclasses.field(default_factory=list)
    submitted_s: float = 0.0
    first_token_s: float | None = None
    done_s: float | None = None
    done: threading.Event = dataclasses.field(default_factory=threading.Event)
    #: wall-clock budget from submit; the batcher enforces it at step
    #: boundaries — an expired request frees its slot and resolves with
    #: whatever tokens it produced, flagged ``timed_out``.
    timeout_s: float | None = None
    deadline_s: float | None = None       # absolute (perf_counter), at submit
    timed_out: bool = False
    cancelled: bool = False
    #: terminal failure (overload shed, serving-step exception): the request
    #: resolved WITHOUT completing; awaiting callers re-raise this.
    error: BaseException | None = None

    @property
    def finished(self) -> bool:
        return self.done.is_set()

    def cancel(self) -> None:
        """Request cancellation: the slot is freed (or the queue entry
        dropped) at the next step boundary and ``done`` is set with the
        partial output.  Thread-safe, idempotent."""
        self.cancelled = True


def _pow2_buckets(lo: int, hi: int) -> list:
    """Powers of two covering [lo, hi]: smallest bucket >= any n in range."""
    out, b = [], max(1, lo)
    while b < hi:
        out.append(b)
        b *= 2
    out.append(b)
    return out


def _bucket_for(n: int, buckets: list) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


def _cache_batch_axes(cfg: ModelConfig, batch: int, max_len: int):
    """Per-leaf batch-axis index of the per-slot cache pytree.

    Found structurally: evaluate ``init_caches`` shapes at ``batch`` and
    ``batch + 1`` and diff each leaf — exactly one dim differs (the batch
    dim), whatever the leaf layout (KV blocks put it at axis 3, lengths at
    axis 1, recurrent states elsewhere)."""
    a = jax.eval_shape(lambda: tfm.init_caches(cfg, batch, max_len,
                                               per_slot=True))
    b = jax.eval_shape(lambda: tfm.init_caches(cfg, batch + 1, max_len,
                                               per_slot=True))
    la, _ = jax.tree_util.tree_flatten(a)
    lb = jax.tree_util.tree_leaves(b)
    axes = []
    for sa, sb in zip(la, lb):
        diff = [i for i, (x, y) in enumerate(zip(sa.shape, sb.shape))
                if x != y]
        if len(diff) != 1:
            raise ValueError(
                f"cache leaf {sa.shape} has no unique batch axis vs "
                f"{sb.shape}")
        axes.append(diff[0])
    return axes


def _make_join(axes: list):
    """Jitted scatter of a prefill-group cache into rolling-cache slots.

    ``slots`` maps group row -> rolling slot index; rows whose slot index
    is out of bounds (dummy padding rows pointed at slot ``batch``) are
    dropped, not clamped."""
    def join(roll, pref, slots):
        rl, td = jax.tree_util.tree_flatten(roll)
        pl = jax.tree_util.tree_leaves(pref)
        out = [
            r.at[(slice(None),) * ax + (slots,)].set(
                p.astype(r.dtype), mode="drop")
            for r, p, ax in zip(rl, pl, axes)
        ]
        return jax.tree_util.tree_unflatten(td, out)
    return jax.jit(join)


def _make_prefill_bucket(cfg: ModelConfig, masked: bool):
    """The scheduler's prefill step for one admission group.

    ``masked=True`` (attention families): prompts are right-padded to the
    length bucket, a pad mask keeps garbage keys out of every softmax and
    ``last_pos`` gathers each row's own last real position.  ``masked=False``
    (pad-free recurrent families): the group is exact-length, no padding
    exists, and the fast unmasked attention paths stay eligible."""
    def prefill_bucket(p, toks, plens, caches):
        if not masked:
            return tfm.prefill(p, cfg, tokens=toks, caches=caches)
        S = toks.shape[1]
        mask = jnp.arange(S, dtype=jnp.int32)[None, :] < plens[:, None]
        return tfm.prefill(p, cfg, tokens=toks, caches=caches,
                           pad_mask=mask,
                           last_pos=jnp.maximum(plens - 1, 0))
    return prefill_bucket


def _annotated_steps(cfg: ModelConfig, masked: bool):
    """Scheduler prefill/decode as annotated opaque library calls."""
    from repro.core import annotate
    from repro.core.split_types import Unknown, _

    decode = annotate(
        lambda p, tok, caches: tfm.decode_step(p, cfg, tok, caches),
        name="sched_decode_step", ret=Unknown(), p=_, tok=_, caches=_)
    prefill = annotate(
        _make_prefill_bucket(cfg, masked),
        name="sched_prefill_bucket", ret=Unknown(),
        p=_, toks=_, plens=_, caches=_)
    return prefill, decode


class ContinuousBatcher:
    """Rolling decode batch with step-boundary admission.

    Single-driver: ``step()`` (and ``run``/``warmup``) must be called from
    one thread at a time; ``submit()`` is thread-safe and may be called
    from anywhere (the async front-end's pattern)."""

    def __init__(self, cfg: ModelConfig, params, batch: int, max_len: int,
                 driver: str = "mozart",
                 prompt_buckets: list | None = None,
                 plan_cache_path: str | None = None,
                 max_queue: int | None = None):
        if batch < 1:
            raise ValueError("batch must be >= 1")
        self.max_queue = max_queue
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.driver = driver
        self.pad_free = cfg.family in _PAD_FREE_FAMILIES
        self.prompt_buckets = (sorted(prompt_buckets)
                               if prompt_buckets else None)
        self.batch_buckets = _pow2_buckets(1, batch)

        self.slots: list = [None] * batch
        self.caches = tfm.init_caches(cfg, batch, max_len, per_slot=True)
        self._tok = np.zeros((batch, 1), np.int32)
        self._queue: collections.deque = collections.deque()
        self._qlock = threading.Lock()
        self._rids = itertools.count()

        self.stats: collections.Counter = collections.Counter()
        self.decode_lat_s: list = []
        self.request_lat_s: list = []
        self.occupancy: list = []

        self._join = _make_join(_cache_batch_axes(cfg, batch, max_len))
        if driver == "mozart":
            from repro.core import mozart
            prefill_fn, decode_fn = _annotated_steps(
                cfg, masked=not self.pad_free)
            self._prefill = mozart.pipeline(
                prefill_fn, executor="eager",
                plan_cache_path=plan_cache_path)
            self._decode = mozart.pipeline(
                decode_fn, executor="eager",
                plan_cache_path=plan_cache_path)
        else:
            self._prefill = jax.jit(
                _make_prefill_bucket(cfg, masked=not self.pad_free))
            self._decode = jax.jit(
                lambda p, tok, caches: tfm.decode_step(p, cfg, tok, caches))

    # -- driver dispatch -----------------------------------------------------
    def _call_prefill(self, toks, plens, caches):
        if self.driver == "mozart":
            out, delta = self._prefill.call_with_stats(
                self.params, toks, plens, caches)
            return out, delta
        return self._prefill(self.params, toks, plens, caches), {}

    def _call_decode(self, tok, caches):
        if self.driver == "mozart":
            out, delta = self._decode.call_with_stats(
                self.params, tok, caches)
            return out, delta
        return self._decode(self.params, tok, caches), {}

    def _note_delta(self, delta: dict) -> None:
        for k in ("planner_calls", "jit_traces", "autotuned_stages",
                  "auto_measured_stages"):
            if delta.get(k, 0):
                self.stats[k] += delta[k]

    # -- admission -----------------------------------------------------------
    def submit(self, req: ServeRequest) -> ServeRequest:
        """Thread-safe enqueue.  A full bounded queue SHEDS the request:
        it resolves immediately with ``req.error`` set (never hangs, never
        silently drops) — backpressure the caller can see and retry."""
        if req.max_new < 1:
            raise ValueError(f"rid {req.rid}: max_new must be >= 1")
        if len(req.prompt) + req.max_new > self.max_len:
            raise ValueError(
                f"rid {req.rid}: prompt + max_new exceeds max_len "
                f"({len(req.prompt)} + {req.max_new} > {self.max_len})")
        req.submitted_s = time.perf_counter()
        if req.timeout_s is not None:
            req.deadline_s = req.submitted_s + req.timeout_s
        with self._qlock:
            if (self.max_queue is not None
                    and len(self._queue) >= self.max_queue):
                req.error = RuntimeError(
                    f"rid {req.rid}: admission queue full "
                    f"({self.max_queue}); request shed")
                self.stats["shed_requests"] += 1
                req.done.set()
                return req
            self._queue.append(req)
        return req

    def _bucket_len(self, plen: int) -> int:
        if self.pad_free:
            return plen                      # exact length: no padding at all
        if self.prompt_buckets:
            return _bucket_for(plen, self.prompt_buckets)
        return _pow2_buckets(1, plen)[-1]

    def _admit(self) -> None:
        while self._admit_once():
            pass

    def _admit_once(self) -> int:
        """Admit one same-length-bucket group into free slots; 0 = nothing."""
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free:
            return 0
        group: list = []
        with self._qlock:
            if not self._queue:
                return 0
            lb = self._bucket_len(len(self._queue[0].prompt))
            kept: collections.deque = collections.deque()
            while self._queue and len(group) < len(free):
                r = self._queue.popleft()
                if self._bucket_len(len(r.prompt)) == lb:
                    group.append(r)
                else:
                    kept.append(r)
            while kept:                       # preserve arrival order
                self._queue.appendleft(kept.pop())
        if not group:
            return 0

        bb = _bucket_for(len(group), self.batch_buckets)
        toks = np.zeros((bb, lb), np.int32)
        plens = np.ones((bb,), np.int32)      # dummy rows: 1-token prompt
        slots = np.full((bb,), self.batch, np.int32)   # default: dropped
        for i, r in enumerate(group):
            toks[i, : len(r.prompt)] = r.prompt
            plens[i] = len(r.prompt)
            slots[i] = free[i]

        pref_caches = tfm.init_caches(self.cfg, bb, self.max_len,
                                      per_slot=True)
        t0 = time.perf_counter()
        (logits, pref_caches), delta = self._call_prefill(
            jnp.asarray(toks), jnp.asarray(plens), pref_caches)
        first = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        self.caches = self._join(self.caches, pref_caches,
                                 jnp.asarray(slots))
        dt = time.perf_counter() - t0

        self.stats["prefill_calls"] += 1
        self.stats["prefill_s_x1e6"] += int(dt * 1e6)
        self._note_delta(delta)
        now = time.perf_counter()
        for i, r in enumerate(group):
            s = int(slots[i])
            t = int(first[i])
            self.slots[s] = r
            r.first_token_s = now
            r.out.append(t)
            self._tok[s, 0] = t
            self.stats["tokens"] += 1
            self._retire_if_done(r, s, now)
        return len(group)

    # -- decode --------------------------------------------------------------
    def _retire_if_done(self, r: ServeRequest, slot: int, now: float) -> None:
        if len(r.out) >= r.max_new or (r.eos is not None
                                       and r.out[-1] == r.eos):
            r.done_s = now
            self.request_lat_s.append(now - r.submitted_s)
            self.slots[slot] = None
            self.stats["completed"] += 1
            r.done.set()

    def _decode_once(self) -> bool:
        active = [(i, r) for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return False
        t0 = time.perf_counter()
        (logits, new_caches), delta = self._call_decode(
            jnp.asarray(self._tok), self.caches)
        tok = np.asarray(jnp.argmax(logits[:, -1], axis=-1)).astype(np.int32)
        dt = time.perf_counter() - t0        # through the host sync: honest
        self.caches = new_caches
        self.decode_lat_s.append(dt)
        self.occupancy.append(len(active))
        self.stats["decode_steps"] += 1
        self._note_delta(delta)
        now = time.perf_counter()
        for i, r in active:
            t = int(tok[i])
            r.out.append(t)
            self._tok[i, 0] = t
            self.stats["tokens"] += 1
            self._retire_if_done(r, i, now)
        return True

    # -- deadlines / cancellation / failure domains --------------------------
    def _expire(self, r: ServeRequest, now: float) -> None:
        """Resolve a deadline-expired or cancelled request with its partial
        output (slot/queue position already released by the caller)."""
        if r.cancelled:
            self.stats["cancelled_requests"] += 1
        else:
            r.timed_out = True
            self.stats["timed_out_requests"] += 1
        r.done_s = now
        r.done.set()

    def _sweep_expired(self) -> None:
        """Step-boundary enforcement of deadlines and cancellation: expired
        queued requests leave the queue, expired active requests free their
        slot (the next ``_admit`` refills it) and keep their partial output."""
        now = time.perf_counter()
        with self._qlock:
            if self._queue:
                kept = collections.deque()
                expired = []
                for r in self._queue:
                    if r.cancelled or (r.deadline_s is not None
                                       and now >= r.deadline_s):
                        expired.append(r)
                    else:
                        kept.append(r)
                self._queue = kept
            else:
                expired = []
        for r in expired:
            self._expire(r, now)
        for i, r in enumerate(self.slots):
            if r is not None and (r.cancelled or (
                    r.deadline_s is not None and now >= r.deadline_s)):
                self.slots[i] = None
                self._expire(r, now)

    def fail_pending(self, exc: BaseException) -> int:
        """Resolve EVERY in-flight request (active slots + queue) with
        ``exc`` — the serving failure domain's backstop: after a step
        exception nothing may stay blocked on ``done.wait`` forever.
        Returns the number of requests failed."""
        with self._qlock:
            doomed = list(self._queue)
            self._queue.clear()
        for i, r in enumerate(self.slots):
            if r is not None:
                self.slots[i] = None
                doomed.append(r)
        now = time.perf_counter()
        for r in doomed:
            r.error = exc
            r.done_s = now
            self.stats["failed_requests"] += 1
            r.done.set()
        return len(doomed)

    def step(self) -> bool:
        """Admit at the step boundary, then decode once; False when idle.

        Deadline/cancellation sweeps run first — a request never occupies a
        slot past the boundary after its deadline."""
        from repro.core import resilience
        resilience.maybe_fail("serve_step")
        self._sweep_expired()
        self._admit()
        return self._decode_once()

    # -- warmup --------------------------------------------------------------
    def warmup(self, max_prompt_len: int | None = None,
               prompt_lens: list | None = None) -> None:
        """Pin every (batch, length) bucket's executable ahead of serving.

        For pad-free (recurrent) families pass ``prompt_lens`` — the exact
        lengths expected; otherwise ``max_prompt_len`` bounds the pow-2
        length buckets (defaults to the largest bucket under ``max_len``)."""
        if self.pad_free:
            len_buckets = sorted(set(prompt_lens or []))
            if not len_buckets:
                raise ValueError(
                    f"{self.cfg.family} prefill is pad-free: warmup needs "
                    "the exact prompt_lens it will serve")
        else:
            if self.prompt_buckets is None:
                hi = max_prompt_len or max(1, self.max_len - 1)
                self.prompt_buckets = _pow2_buckets(8, hi)
            len_buckets = self.prompt_buckets

        # Decode: one bucket, full batch.
        caches = tfm.init_caches(self.cfg, self.batch, self.max_len,
                                 per_slot=True)
        tok = jnp.zeros((self.batch, 1), jnp.int32)
        if self.driver == "mozart":
            self._decode.lower(self.params, tok, caches)
            self._decode.compile(bucket=("decode", self.batch))
        (logits, _), _d = self._call_decode(tok, caches)
        np.asarray(jnp.argmax(logits[:, -1], axis=-1))   # warm the argmax

        # Prefill: one bucket per (batch_bucket, len_bucket); also warm the
        # slot-join scatter at each batch bucket (all rows dropped).
        for bb in self.batch_buckets:
            for lb in len_buckets:
                toks = jnp.zeros((bb, lb), jnp.int32)
                plens = jnp.full((bb,), min(lb, 2), jnp.int32)
                pc = tfm.init_caches(self.cfg, bb, self.max_len,
                                     per_slot=True)
                if self.driver == "mozart":
                    self._prefill.lower(self.params, toks, plens, pc)
                    self._prefill.compile(bucket=("prefill", bb, lb))
                else:
                    self._call_prefill(toks, plens, pc)
            pc = tfm.init_caches(self.cfg, bb, self.max_len, per_slot=True)
            slots = jnp.full((bb,), self.batch, jnp.int32)
            self.caches = self._join(self.caches, pc, slots)
        # Serving-phase counters start clean: warmup planner/trace activity
        # is expected, warm steps after this point must add zero.
        for k in ("planner_calls", "jit_traces", "autotuned_stages",
                  "auto_measured_stages"):
            self.stats.pop(k, None)

    # -- batch front-end -----------------------------------------------------
    def reset_metrics(self) -> None:
        """Zero the per-run counters (stats, latency samples, occupancy)."""
        self.stats.clear()
        self.decode_lat_s.clear()
        self.request_lat_s.clear()
        self.occupancy.clear()

    def run(self, requests: list) -> dict:
        """Serve a request list to completion; returns the summary stats.

        Metrics are per-run: counters reset on entry, so a reused batcher
        (the warm-measurement pattern) reports this run alone."""
        self.reset_metrics()
        for r in requests:
            self.submit(r)
        t0 = time.perf_counter()
        while True:
            try:
                busy = self.step()
            except Exception as e:
                # Batch front-end: the error propagates to the caller, but
                # every in-flight request resolves first — nothing hangs.
                self.fail_pending(e)
                raise
            if not busy:
                with self._qlock:
                    if not self._queue:
                        break
        return self.summary(time.perf_counter() - t0)

    def summary(self, wall_s: float) -> dict:
        def pct(xs, p):
            if not xs:
                return 0.0
            ys = sorted(xs)
            return ys[min(len(ys) - 1, int(round(p / 100 * (len(ys) - 1))))]

        toks = int(self.stats["tokens"])
        out = {
            "wall_s": wall_s,
            "tokens": toks,
            "tokens_per_s": toks / max(wall_s, 1e-9),
            "decode_steps": int(self.stats["decode_steps"]),
            "decode_us_per_call": (
                sum(self.decode_lat_s) * 1e6
                / max(len(self.decode_lat_s), 1)),
            "decode_p50_us": pct(self.decode_lat_s, 50) * 1e6,
            "decode_p99_us": pct(self.decode_lat_s, 99) * 1e6,
            "request_p50_ms": pct(self.request_lat_s, 50) * 1e3,
            "request_p99_ms": pct(self.request_lat_s, 99) * 1e3,
            "mean_occupancy": (sum(self.occupancy)
                               / max(len(self.occupancy), 1)),
            "prefill_calls": int(self.stats["prefill_calls"]),
            "completed": int(self.stats["completed"]),
            "timed_out": int(self.stats["timed_out_requests"]),
            "cancelled": int(self.stats["cancelled_requests"]),
            "shed": int(self.stats["shed_requests"]),
            "failed": int(self.stats["failed_requests"]),
            "planner_calls": int(self.stats["planner_calls"]),
            "jit_traces": int(self.stats["jit_traces"]),
        }
        out["warm"] = (out["planner_calls"] == 0 and out["jit_traces"] == 0)
        if self.driver == "mozart":
            out["buckets"] = sorted(
                list(self._prefill.buckets) + list(self._decode.buckets))
        return out

    def make_request(self, prompt, max_new: int, eos: int | None = None,
                     timeout_s: float | None = None) -> ServeRequest:
        return ServeRequest(rid=next(self._rids),
                            prompt=np.asarray(prompt, np.int32),
                            max_new=max_new, eos=eos, timeout_s=timeout_s)


class AsyncServer:
    """``asyncio`` front-end: a daemon thread drives the batcher's steps
    while any number of coroutines await ``generate()``."""

    def __init__(self, batcher: ContinuousBatcher, idle_poll_s: float = 1e-3):
        self.batcher = batcher
        self.idle_poll_s = idle_poll_s
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def start(self) -> "AsyncServer":
        self._thread = threading.Thread(target=self._drive, daemon=True,
                                        name="serving-driver")
        self._thread.start()
        return self

    def _drive(self) -> None:
        # The driver thread is the serving failure domain's root: it must
        # survive ANY step exception, or every awaiting coroutine blocks on
        # ``done.wait`` forever.  A failing step fails exactly the requests
        # that were in flight (visible errors, no hangs) and keeps driving.
        from repro.core import resilience
        while not self._stop.is_set():
            try:
                busy = self.batcher.step()
            except Exception as e:    # route into requests, never die silent
                n = self.batcher.fail_pending(e)
                self.batcher.stats["step_failures"] += 1
                resilience.record_event(
                    "MZ405", f"serving step failed ({type(e).__name__}: "
                             f"{e}); {n} requests failed")
                continue
            if not busy:
                time.sleep(self.idle_poll_s)

    async def generate(self, prompt, max_new: int, eos: int | None = None,
                       timeout_s: float | None = None) -> list:
        """Generate tokens for one prompt; resolves when the request leaves
        the batcher.  ``timeout_s`` bounds the wait: the batcher enforces
        the deadline at a step boundary (partial output, ``timed_out`` on
        the request); if even that never resolves (wedged driver), the
        await itself gives up shortly after and cancels the request.
        Raises the request's error (shed / step failure) if it failed."""
        req = self.batcher.make_request(prompt, max_new, eos=eos,
                                        timeout_s=timeout_s)
        self.batcher.submit(req)
        loop = asyncio.get_running_loop()
        if timeout_s is None:
            await loop.run_in_executor(None, req.done.wait)
        else:
            # Grace past the deadline for the step-boundary sweep to run.
            resolved = await loop.run_in_executor(
                None, req.done.wait, timeout_s + 5.0)
            if not resolved:
                req.cancel()
                await loop.run_in_executor(None, req.done.wait, 5.0)
                if not req.done.is_set():
                    raise TimeoutError(
                        f"rid {req.rid}: driver did not resolve the request "
                        f"within its deadline (thread wedged?)")
        if req.error is not None:
            raise req.error
        return list(req.out)

    def close(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=30)

    def __enter__(self) -> "AsyncServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()
