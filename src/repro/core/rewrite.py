"""Static dataflow rewrite pass: optimize the captured graph before planning.

``Pipeline.lower()`` captures a whole dataflow graph; everything downstream
(planner, handoff plane, cost model) optimizes *execution* of that graph
as-given.  This pass closes the loop the ROADMAP's "graph rewrite" item
asks for: a Dias-style (PAPERS.md) source-level rewrite of the captured
graph itself, run between capture and planning (``plan_cache.lookup_or_plan``
calls :func:`apply` first), so the planner only ever sees the optimized
graph and warm calls replay it with zero re-analysis.

Four rewrite kinds, each justified by ``cost_model.analytic_seconds`` and
recorded as a structured :class:`RewriteRecord` (surfaced as MZ5xx
``Diagnostic``s and persisted on the plan entry, schema v7):

* **MZ501 dead-stage elimination** — the MZ201 predicate (no in-graph
  consumer, no live ``Future``) applied transitively: unobservable nodes
  are retired before they ever reach a stage.
* **MZ502 common-subexpression sharing** — annotated calls are
  value-numbered (fn identity + static values + input VNs + normalized
  split types); structurally identical repeats collapse onto one node with
  fanned-out edges.  Never merges across distinct split types, distinct
  captured scalars, dynamic-shape fns, or fns with donation (``mut``)
  hints.
* **MZ503 filter pushdown** — a selective stage (``sa.selective`` names
  the filtered data argument: ``compress``, ``filter_rows``) hoists ahead
  of an elementwise map when the SA contracts prove commutation
  (elementwise + scalar-broadcast operands ⇒ ``F(filter(x)) ==
  filter(F(x))`` elementwise), shrinking the interior bytes the handoff
  plane meters.
* **MZ504 splitting-friendly reassociation** — independent chains whose
  program order interleaves are regrouped (justified by the MZ102
  merge-associativity law: stage merges are associative, so chain-local
  regrouping preserves results) when the planner simulation
  (``planner.simulate_stage_breaks``) proves strictly fewer stages — fewer
  boundaries for ``can_handoff`` to lose.

Rewrites that *almost* apply are recorded as **MZ505 declines** with the
failing condition spelled out, so ``repro.launch.lint --rewrite-report``
explains why a pipeline was left alone — and the periodic re-analysis tick
(``MOZART_REANALYZE_EVERY``, see ``plan_cache``) revisits them once cost
inputs drift.

The pass is deterministic and idempotent: re-applying it to its own output
is a no-op (retired nodes are ``done`` and leave ``pending``; pushed-down
patterns no longer match; the clustering order is a fixpoint), which the
Pipeline fast-path build relies on (it re-enters ``lookup_or_plan`` once
more when it declines a call).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax

from repro import hardware
from repro.core import split_types as st
from repro.core.graph import DataflowGraph, Node, NodeRef

#: assumed selectivity of a filter whose mask is unknowable statically; the
#: cost-model justification for a pushdown states it explicitly.
ASSUMED_SELECTIVITY = 0.5

#: rewrite-kind -> MZ5xx diagnostic code.
REWRITE_CODES = {
    "dead": "MZ501",
    "cse": "MZ502",
    "pushdown": "MZ503",
    "reassoc": "MZ504",
    "declined": "MZ505",
}


@dataclasses.dataclass(frozen=True)
class RewriteRecord:
    """One applied (or declined) rewrite, with its cost-model justification.

    JSON-stable: persisted verbatim on the plan entry (``PlanEntry.rewrites``,
    schema v7) so a warm-started process can report why its replayed graph
    looks the way it does."""

    code: str            # MZ501..MZ505
    kind: str            # "dead" | "cse" | "pushdown" | "reassoc" | "declined"
    subject: str         # e.g. "exp#3" or "exp#3 -> compress#5"
    detail: str          # human-readable justification / decline reason
    saved_s: float       # analytic_seconds delta (0.0 for declines)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "RewriteRecord":
        return cls(code=str(d["code"]), kind=str(d["kind"]),
                   subject=str(d["subject"]), detail=str(d["detail"]),
                   saved_s=float(d.get("saved_s", 0.0)))


@dataclasses.dataclass
class RewriteResult:
    pending: list                    # the (possibly reordered) surviving nodes
    records: list                    # [RewriteRecord]

    @property
    def applied(self) -> list:
        return [r for r in self.records if r.kind != "declined"]


def records_to_diagnostics(records: list) -> list:
    """RewriteRecords as MZ5xx ``analysis.Diagnostic``s (all info-severity:
    rewrites are optimizations, never gate failures)."""
    from repro.core.analysis import Diagnostic
    out = []
    for r in records:
        msg = r.detail
        if r.kind != "declined" and r.saved_s > 0:
            msg = f"{msg} (est {r.saved_s * 1e6:.1f}us saved)"
        out.append(Diagnostic(r.code, "info", r.subject, msg))
    return out


# ---------------------------------------------------------------------------
# Cost-model justification
# ---------------------------------------------------------------------------


def _node_cost_features(node: Node, graph: DataflowGraph) -> tuple[int, int]:
    """(element count, bytes per element) estimated for one node's work,
    from its output aval/split type, falling back to its first array-shaped
    input.  Conservative defaults when nothing is shaped."""
    n = None
    total = 0
    t = node.out_type
    if isinstance(t, st.ArraySplit) and t.shape:
        n = t.shape[t.axis]
    try:
        if node.out_aval is not None:
            total = sum(st.nbytes_of(l)
                        for l in jax.tree_util.tree_leaves(node.out_aval))
    except (TypeError, ValueError):
        total = 0
    if n is None or not total:
        for name, v in node.bound.items():
            if name in node.fn.sa.static:
                continue
            src = graph.nodes.get(v.node_id) if isinstance(v, NodeRef) else None
            a = src.out_aval if src is not None else v
            shape = tuple(getattr(a, "shape", ()) or ())
            if not shape:
                continue
            if n is None:
                n = shape[0]
            if not total:
                try:
                    total = sum(st.nbytes_of(l)
                                for l in jax.tree_util.tree_leaves(a))
                except (TypeError, ValueError):
                    total = 0
            break
    n = max(int(n) if n is not None else 1, 1)
    elem_bytes = max(total // n, 1) if total else 4
    return n, elem_bytes


def node_seconds(node: Node, graph: DataflowGraph, ctx,
                 n_override: int | None = None) -> float:
    """Analytic wall-time estimate of executing ``node`` alone — the
    justification yardstick every rewrite record carries.  Scored under a
    fixed representative strategy (fused; pipelined for dynamic chains) so
    deltas are comparable across records regardless of the session's
    executor knob."""
    from repro.core import cost_model
    n, elem_bytes = _node_cost_features(node, graph)
    if n_override is not None:
        n = max(int(n_override), 1)
    dynamic = node.out_aval is None or getattr(node.fn.sa, "dynamic", False)
    feats = cost_model.StageFeatures(
        n=n, elem_bytes=elem_bytes, n_nodes=1,
        flops_per_elem=float(getattr(node.fn.sa, "cost_hint", 1.0))
        * cost_model._FLOPS_PER_HINT,
        dynamic=dynamic, pallas_eligible=False, mesh_devices=0, on_tpu=False)
    name = "pipelined" if dynamic else "fused"
    s = cost_model.analytic_seconds(name, feats, ctx.chip)
    return s if math.isfinite(s) else 0.0


# ---------------------------------------------------------------------------
# MZ501: dead-stage elimination
# ---------------------------------------------------------------------------


def _retire(node: Node) -> None:
    """Remove a node from execution: marked done with no result, it leaves
    ``graph.pending()`` immediately and ``graph.prune()`` collects it."""
    node.done = True
    node.result = None
    node.future_ref = None
    node.alias_refs = []


def _live_consumers(pending: list) -> dict[int, list[int]]:
    """Consumer map over NOT-yet-executed nodes only.  ``graph.consumers()``
    also counts edges from done nodes — including nodes this very pass just
    retired — which would keep a dead producer "live" forever and stall the
    elimination fixpoint (or wrongly fail a sole-consumer check)."""
    out: dict[int, list[int]] = {}
    for n in pending:
        for d in n.deps():
            out.setdefault(d, []).append(n.id)
    return out


def _eliminate_dead(pending: list, graph: DataflowGraph, ctx,
                    records: list) -> list:
    """Transitively retire nodes with no consumer and no live Future (the
    MZ201 predicate, enforced instead of advised)."""
    while True:
        cons = _live_consumers(pending)
        dead = [n for n in pending
                if not cons.get(n.id) and not n.future_alive()]
        if not dead:
            return pending
        for n in dead:
            saved = node_seconds(n, graph, ctx)
            records.append(RewriteRecord(
                "MZ501", "dead", f"{n.fn.name}#{n.id}",
                "output has no consumer and no live Future; "
                "eliminated before planning", saved))
            _retire(n)
        pending = [n for n in pending if not n.done]


# ---------------------------------------------------------------------------
# MZ502: common-subexpression sharing
# ---------------------------------------------------------------------------

_HASHABLE_SCALARS = (bool, int, float, complex, str, bytes, type(None))


def _vn_key(node: Node, vn: dict[int, int]) -> tuple | None:
    """Structural value number of one annotated call, or None (never merge).

    Two calls share a key iff they call the SAME function object on the
    same value-numbered inputs with equal static values, equal captured
    scalars, identical external-array identities and identical (normalized)
    split types — the conditions under which a pure annotated call is
    guaranteed to produce the same value."""
    sa = node.fn.sa
    if getattr(sa, "dynamic", False) or node.out_aval is None or sa.mut:
        return None                      # dynamic output / donation hint
    from repro.core.plan_cache import (_aval_fingerprint, _type_fingerprint,
                                       value_fingerprint)
    parts: list = [("fn", id(node.fn))]
    varmap: dict[int, int] = {}
    for name, v in node.bound.items():
        if name in sa.static:
            f = value_fingerprint(v, with_value=True)
            if f is None:
                return None
            parts.append(("static", name, f))
        elif isinstance(v, NodeRef):
            if v.node_id in vn:
                parts.append(("ref", name, vn[v.node_id]))
            else:
                parts.append(("done", name, v.node_id))
        elif isinstance(v, _HASHABLE_SCALARS):
            # captured Python scalars: by value AND type — 1 never merges
            # with 1.0, and distinct values never merge.
            parts.append(("pyval", name, type(v).__name__, v))
        else:
            # external arrays/containers: identity only — equal-shaped but
            # distinct objects may hold different data.
            parts.append(("extid", name, id(v)))
        if name not in sa.static:
            tf = _type_fingerprint(node.arg_types[name], varmap)
            if tf is None:
                return None
            parts.append(("T", name, tf))
    of = _type_fingerprint(node.out_type, varmap)
    af = _aval_fingerprint(node.out_aval)
    if of is None or af is None:
        return None
    parts.append(("out", of, af))
    return tuple(parts)


def _merge_into(rep: Node, dupe: Node, pending: list) -> None:
    """Redirect every consumer and live Future of ``dupe`` onto ``rep``,
    then retire ``dupe``."""
    for c in pending:
        if c is dupe:
            continue
        for name, v in c.bound.items():
            if isinstance(v, NodeRef) and v.node_id == dupe.id:
                c.bound[name] = NodeRef(rep.id)
    if dupe.future_ref is not None:
        fut = dupe.future_ref()
        if fut is not None:
            fut._node = rep              # observation now reads the shared node
        # keep the weakref on the representative: while the dupe's Future
        # lives, the shared output must stay escaping/mergeable.
        rep.alias_refs = list(rep.alias_refs) + [dupe.future_ref]
    rep.alias_refs = list(rep.alias_refs) + list(dupe.alias_refs)
    dupe.future_ref = None
    dupe.alias_refs = []
    _retire(dupe)


def _share_common(pending: list, graph: DataflowGraph, ctx,
                  records: list) -> list:
    vn: dict[int, int] = {}
    table: dict[tuple, Node] = {}
    changed = False
    for n in pending:
        key = _vn_key(n, vn)
        vn[n.id] = n.id
        if key is None:
            continue
        rep = table.get(key)
        if rep is None:
            table[key] = n
            continue
        saved = node_seconds(n, graph, ctx)
        records.append(RewriteRecord(
            "MZ502", "cse", f"{n.fn.name}#{n.id}",
            f"structurally identical to {rep.fn.name}#{rep.id}; "
            "collapsed onto the shared call", saved))
        _merge_into(rep, n, pending)
        vn[n.id] = rep.id
        changed = True
    if changed:
        pending = [n for n in pending if not n.done]
    return pending


# ---------------------------------------------------------------------------
# MZ503: filter pushdown (selective stage ahead of an elementwise map)
# ---------------------------------------------------------------------------


def _is_scalarish(v: Any, graph: DataflowGraph) -> bool:
    if isinstance(v, NodeRef):
        return False
    return not tuple(getattr(v, "shape", ()) or ())


def _rebuild_types(node: Node, graph: DataflowGraph) -> None:
    """Re-run the node's split-type constructors after its bound arguments
    changed (the same construction ``runtime.register_call`` performs)."""
    avals: dict[str, Any] = {}
    ctor: dict[str, Any] = {}
    for name, v in node.bound.items():
        if isinstance(v, NodeRef):
            src = graph.nodes.get(v.node_id)
            a = src.out_aval if src is not None else None
        else:
            a = v
        avals[name] = a
        ctor[name] = a
    node.out_aval = None if (getattr(node.fn.sa, "dynamic", False)
                             or any(a is None for a in avals.values())) \
        else node.fn.abstract_eval(avals)
    node.arg_types, node.out_type = node.fn.construct_types(
        ctor, avals, node.out_aval)


def _reorder_graph(graph: DataflowGraph, new_pending: list) -> None:
    """Rebuild the node dict so ``graph.pending()`` iterates the rewritten
    order (done nodes first — they never consume pending ones, so the
    result stays topological)."""
    order = {n.id for n in new_pending}
    rebuilt: dict[int, Node] = {}
    for n in graph.nodes.values():
        if n.id not in order:
            rebuilt[n.id] = n
    for n in new_pending:
        rebuilt[n.id] = n
    graph.nodes = rebuilt


def _pushdown(pending: list, graph: DataflowGraph, ctx,
              records: list) -> list:
    """Hoist ``sa.selective`` stages ahead of elementwise maps.

    Pattern: ``flt = F(sel..., data=M(...))`` where M is elementwise with a
    single array operand, F is M's only consumer and M's own output is
    never observed.  The SA contracts prove commutation — an elementwise
    map applied per row commutes with any row-subset selection — so the
    edge becomes ``M(F(sel..., data=x))`` and M runs on the filtered
    (smaller) extent."""
    declined: set[tuple] = set()      # (map id, filter id): record MZ505 once
    # Reduce-past-map is the pattern the ISSUE's "filter/reduce pushdown"
    # names but the SA contracts CANNOT license: a ReduceSplit consumer
    # collapses the extent, and ``reduce(map(x)) == map(reduce(x))`` needs a
    # distributivity law no annotation states.  Record the decline so the
    # report explains why the hoist did not happen (and the periodic
    # re-analysis tick revisits it if a future contract ever proves it).
    cons0 = _live_consumers(pending)
    for r_node in pending:
        if not isinstance(r_node.out_type, st.ReduceSplit):
            continue
        for v in r_node.bound.values():
            if not isinstance(v, NodeRef):
                continue
            p = graph.nodes.get(v.node_id)
            if (p is None or p.done or not p.fn.sa.elementwise
                    or cons0.get(p.id, []) != [r_node.id]):
                continue
            if (p.id, r_node.id) not in declined:
                declined.add((p.id, r_node.id))
                records.append(RewriteRecord(
                    "MZ505", "declined",
                    f"{p.fn.name}#{p.id} -> {r_node.fn.name}#{r_node.id}",
                    "pushdown declined: reduction past a map — "
                    "reduce/map commutation is not provable from SA "
                    "contracts (no distributivity law)", 0.0))
    for _ in range(len(pending)):
        cons = _live_consumers(pending)
        pos = {n.id: i for i, n in enumerate(pending)}
        swap = None
        for f_node in pending:
            data_arg = getattr(f_node.fn.sa, "selective", None)
            if not data_arg:
                continue
            v = f_node.bound.get(data_arg)
            if not isinstance(v, NodeRef) or v.node_id not in pos:
                continue
            m_node = graph.nodes[v.node_id]
            reason = _pushdown_blocker(f_node, m_node, data_arg, cons,
                                       pos, graph)
            if reason is not None:
                if (m_node.id, f_node.id) not in declined:
                    declined.add((m_node.id, f_node.id))
                    records.append(RewriteRecord(
                        "MZ505", "declined",
                        f"{m_node.fn.name}#{m_node.id} -> "
                        f"{f_node.fn.name}#{f_node.id}",
                        f"pushdown declined: {reason}", 0.0))
                continue
            swap = (f_node, m_node, data_arg)
            break
        if swap is None:
            return pending
        f_node, m_node, data_arg = swap
        n_full = _node_cost_features(m_node, graph)[0]
        n_filtered = max(int(math.ceil(n_full * ASSUMED_SELECTIVITY)), 1)
        saved = (node_seconds(m_node, graph, ctx, n_override=n_full)
                 - node_seconds(m_node, graph, ctx, n_override=n_filtered))
        m_data = next(name for name, mv in m_node.bound.items()
                      if name not in m_node.fn.sa.static
                      and not _is_scalarish(mv, graph))
        # Downstream consumers of the filter now read the (filtered) map.
        for c in pending:
            if c is m_node or c is f_node:
                continue
            for name, cv in c.bound.items():
                if isinstance(cv, NodeRef) and cv.node_id == f_node.id:
                    c.bound[name] = NodeRef(m_node.id)
        f_node.bound[data_arg] = m_node.bound[m_data]
        m_node.bound[m_data] = NodeRef(f_node.id)
        _rebuild_types(f_node, graph)
        _rebuild_types(m_node, graph)
        # The observable final value moves from the filter to the map.
        if f_node.future_ref is not None:
            fut = f_node.future_ref()
            if fut is not None:
                fut._node = m_node
            m_node.future_ref = f_node.future_ref
            f_node.future_ref = None
        m_node.alias_refs = list(m_node.alias_refs) + list(f_node.alias_refs)
        f_node.alias_refs = []
        # Reorder: the filter takes the map's slot (its remaining deps all
        # precede it — checked by _pushdown_blocker).
        new_pending = [n for n in pending if n is not f_node]
        new_pending.insert(new_pending.index(m_node), f_node)
        pending = new_pending
        _reorder_graph(graph, pending)
        records.append(RewriteRecord(
            "MZ503", "pushdown",
            f"{f_node.fn.name}#{f_node.id} <- {m_node.fn.name}#{m_node.id}",
            f"selective stage hoisted ahead of elementwise map "
            f"{m_node.fn.name} (assumed selectivity "
            f"{ASSUMED_SELECTIVITY:g}: {n_full} -> {n_filtered} elements)",
            max(saved, 0.0)))
    return pending


def _pushdown_blocker(f_node: Node, m_node: Node, data_arg: str,
                      cons: dict, pos: dict, graph: DataflowGraph
                      ) -> str | None:
    """Why F cannot hoist ahead of M, or None when the commutation holds."""
    sa = m_node.fn.sa
    if isinstance(m_node.out_type, st.ReduceSplit):
        return ("producer is a reduction; filter/reduce commutation is not "
                "provable from SA contracts (no distributivity law)")
    if not sa.elementwise:
        return (f"producer {m_node.fn.name} is not elementwise; the SA "
                "contracts cannot prove commutation with a row filter")
    if sa.mut:
        return "producer carries a donation (mut) hint"
    if sa.static:
        return "producer has static parameters; commutation unproven"
    array_args = [name for name, v in m_node.bound.items()
                  if name not in sa.static and not _is_scalarish(v, graph)]
    if len(array_args) != 1:
        return ("producer has multiple array operands; filtering one "
                "operand does not commute with the map")
    consumers = cons.get(m_node.id, [])
    if len(consumers) != 1 or consumers[0] != f_node.id:
        return ("producer's full (unfiltered) output has other consumers")
    if m_node.future_alive():
        return "producer's full output is observed (live Future)"
    # Every remaining dependency of F must already precede M in program
    # order, or hoisting F to M's slot would break topological order.
    for name, v in f_node.bound.items():
        if name == data_arg or not isinstance(v, NodeRef):
            continue
        if v.node_id in pos and pos[v.node_id] >= pos[m_node.id]:
            return (f"selector argument {name!r} is defined after the map; "
                    "hoisting would break program order")
    return None


# ---------------------------------------------------------------------------
# MZ504: splitting-friendly reassociation
# ---------------------------------------------------------------------------


def _cluster(pending: list) -> list:
    """Chain-clustered topological order: after emitting a node, prefer a
    ready consumer of it (continue the chain); otherwise the earliest ready
    node in program order.  Deterministic, and a fixpoint of itself."""
    ids = {n.id for n in pending}
    order = {n.id: i for i, n in enumerate(pending)}
    deps = {n.id: [d for d in n.deps() if d in ids] for n in pending}
    consumers: dict[int, list[int]] = {n.id: [] for n in pending}
    for n in pending:
        for d in deps[n.id]:
            consumers[d].append(n.id)
    by_id = {n.id: n for n in pending}
    emitted: set[int] = set()
    out: list = []
    last: int | None = None
    while len(out) < len(pending):
        ready = [nid for nid in ids - emitted
                 if all(d in emitted for d in deps[nid])]
        pick = None
        if last is not None:
            chain = [c for c in consumers[last] if c in ready]
            if chain:
                pick = min(chain, key=lambda c: order[c])
        if pick is None:
            pick = min(ready, key=lambda c: order[c])
        out.append(by_id[pick])
        emitted.add(pick)
        last = pick
    return out


def _reassociate(pending: list, graph: DataflowGraph, ctx,
                 records: list) -> list:
    if len(pending) < 3:
        return pending
    clustered = _cluster(pending)
    if [n.id for n in clustered] == [n.id for n in pending]:
        return pending
    from repro.core.planner import simulate_stage_breaks
    max_nodes = None if getattr(ctx, "pipeline", True) else 1
    base = simulate_stage_breaks(pending, graph, max_stage_nodes=max_nodes)
    alt = simulate_stage_breaks(clustered, graph, max_stage_nodes=max_nodes)
    if len(alt) >= len(base):
        records.append(RewriteRecord(
            "MZ505", "declined",
            f"{len(pending)}-node graph",
            f"reassociation declined: chain clustering yields {len(alt)} "
            f"stage(s) vs {len(base)} — no boundary eliminated", 0.0))
        return pending
    # Each eliminated boundary skips one merge + one re-split round trip of
    # roughly a stage's interior bytes through HBM, plus a dispatch.
    bytes_est = max(_node_cost_features(n, graph)[0]
                    * _node_cost_features(n, graph)[1] for n in pending)
    eliminated = len(base) - len(alt)
    saved = eliminated * (2.0 * bytes_est / ctx.chip.hbm_bandwidth
                          + hardware.effective_dispatch_overhead_s(ctx.chip))
    _reorder_graph(graph, clustered)
    records.append(RewriteRecord(
        "MZ504", "reassoc",
        ",".join(f"{n.fn.name}#{n.id}" for n in clustered),
        f"independent chains regrouped: {len(base)} -> {len(alt)} stage(s) "
        f"({eliminated} boundary(ies) eliminated; merge associativity "
        "[MZ102] preserves results)", max(saved, 0.0)))
    return clustered


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def apply(pending: list, graph: DataflowGraph, ctx) -> RewriteResult:
    """Rewrite the pending graph in place; returns the surviving node order
    plus the justification records.  Gated by the context's ``rewrite``
    knob; a disabled (or empty) pass returns the input untouched."""
    if not getattr(ctx, "rewrite", True) or not pending:
        return RewriteResult(list(pending), [])
    records: list[RewriteRecord] = []
    pending = _eliminate_dead(pending, graph, ctx, records)
    if pending:
        pending = _share_common(pending, graph, ctx, records)
    if pending:
        pending = _pushdown(pending, graph, ctx, records)
    if pending:
        pending = _reassociate(pending, graph, ctx, records)
    applied = [r for r in records if r.kind != "declined"]
    if applied:
        ctx.stats["rewrites_applied"] += len(applied)
    if len(applied) != len(records):
        ctx.stats["rewrites_declined"] += len(records) - len(applied)
    return RewriteResult(pending, records)
