"""Lazy value handles (paper §4: ``Future<T>``).

Accessing a ``Future`` — converting to numpy, printing, indexing, or using
it with un-annotated code — forces evaluation of the pending dataflow graph
(the Python-client design of §4.2: interception via dunder methods).

Arithmetic dunders are routed through the *annotated* jnp ops registered by
``repro.core.annotated_numpy`` so that ``a + b`` on futures extends the
dataflow graph instead of forcing it (the TypeScript-style ergonomics the
paper aims for).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import numpy as np


#: populated by repro.core.annotated_numpy at import time:
#:   name ("add", "mul", ...) -> annotated binary/unary callable.
_OPERATOR_TABLE: dict[str, Callable] = {}


def register_operator(name: str, fn: Callable) -> None:
    _OPERATOR_TABLE[name] = fn


class Future:
    """Placeholder for the output of a not-yet-executed annotated call."""

    __slots__ = ("_ctx", "_node", "__weakref__")

    def __init__(self, ctx, node):
        object.__setattr__(self, "_ctx", ctx)
        object.__setattr__(self, "_node", node)

    # -- metadata available without forcing --------------------------------
    @property
    def aval(self):
        return self._node.out_aval

    @property
    def shape(self):
        return self._node.out_aval.shape

    @property
    def dtype(self):
        return self._node.out_aval.dtype

    @property
    def ndim(self):
        return len(self._node.out_aval.shape)

    @property
    def done(self) -> bool:
        return self._node.done

    @property
    def split_type(self):
        """Split type the producing call constructed for this value (may be a
        generic var until the planner resolves it) — inspection/EXPLAIN aid."""
        return self._node.out_type

    # -- forcing ------------------------------------------------------------
    @property
    def value(self) -> Any:
        """Evaluate the pending graph (if needed) and return the result.

        A result left unmerged by cross-stage chunk handoff (a
        ``ChunkStream``) merges here, lazily, exactly once — observation is
        the only point a handed-off intermediate ever materializes."""
        if not self._node.done:
            self._ctx.evaluate()
        res = self._node.result
        from repro.core.stage_exec import ChunkStream, counter_scope
        if isinstance(res, ChunkStream):
            # Observation of a pipeline output: accounted as TERMINAL bytes
            # (inherent to observing), never as interior boundary traffic —
            # attributed to the owning context's scoped counters.
            with counter_scope(getattr(self._ctx, "counters", None)):
                res = res.materialize(terminal=True)
            self._node.result = res
        return res

    def block(self) -> Any:
        return self.value

    def __array__(self, dtype=None, copy=None):
        arr = np.asarray(self.value)
        return arr.astype(dtype) if dtype is not None else arr

    def __jax_array__(self):
        return self.value

    def __repr__(self) -> str:
        if self._node.done:
            return f"Future(done, {self._node.result!r})"
        return f"Future(pending {self._node}, aval={self._node.out_aval})"

    def __len__(self) -> int:
        return self.shape[0]

    def __getitem__(self, idx):
        return self.value[idx]

    def __iter__(self):
        return iter(self.value)

    def __float__(self):
        return float(self.value)

    def __int__(self):
        return int(self.value)

    def __bool__(self):
        return bool(self.value)

    # -- lazy arithmetic ------------------------------------------------------
    def _binop(self, name: str, other, reverse=False):
        fn = _OPERATOR_TABLE.get(name)
        if fn is None:                       # annotated ops not imported
            a = self.value
            b = other.value if isinstance(other, Future) else other
            return getattr(np, name)(b, a) if reverse else getattr(np, name)(a, b)
        return fn(other, self) if reverse else fn(self, other)

    def __add__(self, o):
        return self._binop("add", o)

    def __radd__(self, o):
        return self._binop("add", o, reverse=True)

    def __sub__(self, o):
        return self._binop("subtract", o)

    def __rsub__(self, o):
        return self._binop("subtract", o, reverse=True)

    def __mul__(self, o):
        return self._binop("multiply", o)

    def __rmul__(self, o):
        return self._binop("multiply", o, reverse=True)

    def __truediv__(self, o):
        return self._binop("divide", o)

    def __rtruediv__(self, o):
        return self._binop("divide", o, reverse=True)

    def __pow__(self, o):
        return self._binop("power", o)

    def __neg__(self):
        fn = _OPERATOR_TABLE.get("negative")
        return fn(self) if fn is not None else -self.value
