"""The "Pandas" integration (paper §7): a columnar Table + SAs.

The Table library itself is deliberately plain (dict of equal-length
columns, numpy/jnp kernels) — it stands in for Pandas' C internals.  The
annotator's contribution is ONLY the split types and SAs:

* ``TableSplit``  — split a Table by rows (the paper's DataFrame/Series
  row split).  Column extraction yields ordinary arrays, whose ArraySplit
  pipelines with the NumPy integration inside one stage.
* ``GroupSplit``  — groupBy partials: chunks aggregate locally, the merge
  re-groups and re-aggregates (commutative aggregations only, like the
  paper).
* filters and joins return ``unknown``; joins split one side and broadcast
  the other.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import split_types as st
from repro.core.annotation import annotate


# ---------------------------------------------------------------------------
# The "library": a minimal columnar table
# ---------------------------------------------------------------------------


class Table:
    """Dict of equal-length columns.  Registered as a JAX pytree."""

    def __init__(self, cols: dict[str, Any]):
        self.cols = dict(cols)

    @property
    def nrows(self) -> int:
        for v in self.cols.values():
            return int(v.shape[0])
        return 0

    def column(self, name: str):
        return self.cols[name]

    def __repr__(self) -> str:
        return f"Table({list(self.cols)}, nrows={self.nrows})"

    def to_numpy(self) -> dict[str, np.ndarray]:
        return {k: np.asarray(v) for k, v in self.cols.items()}

    def mozart_fingerprint(self) -> tuple:
        """Plan-cache identity: column names + shapes/dtypes, never values."""
        return ("table", tuple(
            (k, tuple(v.shape), str(v.dtype)) for k, v in sorted(self.cols.items())
        ))


def _table_flatten(t: Table):
    keys = sorted(t.cols)
    return [t.cols[k] for k in keys], tuple(keys)


def _table_unflatten(keys, vals):
    return Table(dict(zip(keys, vals)))


jax.tree_util.register_pytree_node(Table, _table_flatten, _table_unflatten)


# ---------------------------------------------------------------------------
# Split types
# ---------------------------------------------------------------------------


class TableSplit(st.SplitType):
    """Split a Table by rows.  Params: (nrows,)."""

    name = "TableSplit"

    def __init__(self, nrows: int):
        super().__init__(int(nrows))
        self.nrows = int(nrows)

    def info(self, value: Table) -> st.RuntimeInfo:
        eb = sum(np.dtype(v.dtype).itemsize for v in value.cols.values())
        return st.RuntimeInfo(num_elements=self.nrows, elem_bytes=max(eb, 1))

    def split(self, value: Table, start: int, end: int) -> Table:
        return Table({k: v[start:end] for k, v in value.cols.items()})

    def merge(self, pieces: Sequence[Table]) -> Table:
        st._require_pieces(pieces, self.name)
        if len(pieces) == 1:
            return pieces[0]
        keys = pieces[0].cols.keys()
        return Table({k: jnp.concatenate([p.cols[k] for p in pieces]) for k in keys})


class GroupSplit(st.SplitType):
    """Partial group-aggregations; merge re-groups and re-aggregates.

    Params: (op, key column, value column) — partial sums from different
    aggregations never pipeline into each other.
    """

    name = "GroupSplit"

    def __init__(self, op: str, key: str, val: str):
        super().__init__(op, key, val)
        # NOT ``self.key``/``self.val``: those would shadow SplitType.key(),
        # breaking __eq__/__hash__ for every GroupSplit (caught by MZ107).
        self.op, self.key_col, self.val_col = op, key, val

    @property
    def splittable(self) -> bool:
        return False

    def info(self, value: Any) -> None:
        return None

    def split(self, value, start, end):
        raise TypeError("GroupSplit values are partials; merge first")

    def merge(self, pieces: Sequence[Table]) -> Table:
        st._require_pieces(pieces, self.name)
        cat = Table({
            k: np.concatenate([np.asarray(p.cols[k]) for p in pieces])
            for k in pieces[0].cols
        })
        # Re-aggregate the partials.  Partial columns already hold partial
        # sums/counts/extrema, so the second-level reduction is sum for
        # sum/count/mean and the op itself for max/min (associativity).
        keys = np.asarray(cat.cols[self.key_col])
        uniq, inv = np.unique(keys, return_inverse=True)

        def resum(colname):
            out = np.zeros(len(uniq), np.float64)
            np.add.at(out, inv, np.asarray(cat.cols[colname], np.float64))
            return out

        if self.op == "sum":
            return Table({self.key_col: uniq, "sum": resum("sum")})
        if self.op == "count":
            return Table({self.key_col: uniq, "count": resum("count").astype(np.int64)})
        if self.op == "mean":
            return Table({self.key_col: uniq, "mean": resum("mean"), "_cnt": resum("_cnt")})
        vals = np.asarray(cat.cols[self.op], np.float64)
        out = np.full(len(uniq), -np.inf if self.op == "max" else np.inf)
        (np.maximum if self.op == "max" else np.minimum).at(out, inv, vals)
        return Table({self.key_col: uniq, self.op: out})


class TableUnknown(st.UnknownSplit):
    """unknown for Tables: merge concatenates rows of every column."""

    name = "unknown"

    def merge(self, pieces: Sequence[Table]) -> Table:
        st._require_pieces(pieces, self.name)
        if len(pieces) == 1:
            return pieces[0]
        keys = pieces[0].cols.keys()
        return Table({
            k: np.concatenate([np.asarray(p.cols[k]) for p in pieces])
            for k in keys
        })


st.register_default_split(Table, lambda t: TableSplit(t.nrows))


class TableRows(st.SplitSpec):
    def construct(self, value, bound, generics):
        if value is None:
            # downstream of a dynamic op: fresh unknown
            return TableUnknown()
        nrows = value.nrows if isinstance(value, Table) else _tree_nrows(value)
        return TableSplit(nrows)


class TableUnknownSpec(st.SplitSpec):
    def construct(self, value, bound, generics):
        return TableUnknown()


def _tree_nrows(aval_tree) -> int:
    leaves = jax.tree_util.tree_leaves(aval_tree)
    return int(leaves[0].shape[0])


# ---------------------------------------------------------------------------
# Aggregation kernels (numpy; the "C internals")
# ---------------------------------------------------------------------------

_AGG_COLS = {"sum": "sum", "count": "count", "mean": "mean", "max": "max", "min": "min"}


def _group_reduce(t: Table, key: str, valcol: str, op: str) -> Table:
    keys = np.asarray(t.cols[key])
    uniq, inv = np.unique(keys, return_inverse=True)
    if op in ("sum", "mean", "count"):
        sums = np.zeros(len(uniq), np.float64)
        cnts = np.zeros(len(uniq), np.int64)
        if op != "count":
            np.add.at(sums, inv, np.asarray(t.cols[valcol], np.float64))
        np.add.at(cnts, inv, 1)
        if op == "sum":
            return Table({key: uniq, "sum": sums})
        if op == "count":
            return Table({key: uniq, "count": cnts})
        # mean partials carry (sum, count); final mean computed by caller
        return Table({key: uniq, "mean": sums, "_cnt": cnts.astype(np.float64)})
    vals = np.asarray(t.cols[valcol], np.float64)
    out = np.full(len(uniq), -np.inf if op == "max" else np.inf)
    (np.maximum if op == "max" else np.minimum).at(out, inv, vals)
    return Table({key: uniq, op: out})


def _group_reduce_partial(t: Table, key: str, valcol: str, op: str) -> Table:
    """Per-chunk partial.  mean -> (sum in 'mean', count in '_cnt')."""
    return _group_reduce(t, key, valcol, op)


# ---------------------------------------------------------------------------
# Annotated operators (the SAs)
# ---------------------------------------------------------------------------

__all_ops__: dict[str, Any] = {}


def _reg(name, fn):
    __all_ops__[name] = fn
    globals()[name] = fn
    return fn


def _col(t: Table, name: str):
    return t.column(name)


_reg("col", annotate(_col, name="col", static=("name",),
                     t=st.Generic("S"), ret=st.Along(0)))


def _with_column(t: Table, name: str, values):
    cols = dict(t.cols)
    cols[name] = values
    return Table(cols)


class _SameTableSplit(st.SplitSpec):
    """with_column keeps the row split of its input table."""

    def construct(self, value, bound, generics):
        if "S" not in generics:
            generics["S"] = st.GenericVar("S")
        return generics["S"]


_reg("with_column", annotate(
    _with_column, name="with_column", static=("name",),
    t=_SameTableSplit(), values=st.Along(0), ret=_SameTableSplit()))


def _select(t: Table, names: tuple):
    return Table({n: t.cols[n] for n in names})


_reg("select", annotate(_select, name="select", static=("names",),
                        t=st.Generic("S"), ret=st.Generic("S")))


def _filter_rows(t: Table, mask):
    m = np.asarray(mask)
    return Table({k: np.asarray(v)[m] for k, v in t.cols.items()})


# NOTE: mask uses its own generic M — a Series mask and a Table split by rows
# advance in lockstep (same element counts) but carry different split types.
_filter = annotate(_filter_rows, name="filter_rows",
                   t=st.Generic("S"), mask=st.Generic("M"), ret=TableUnknownSpec())
_filter.sa.dynamic = True
_filter.sa.selective = "t"           # row-subset of t: pushdown-eligible
_reg("filter_rows", _filter)


def _groupby_agg(t: Table, key: str, val: str, op: str):
    return _group_reduce_partial(t, key, val, op)


class _GroupRet(st.SplitSpec):
    def construct(self, value, bound, generics):
        return GroupSplit(bound["op"], bound["key"], bound["val"])


_gb = annotate(_groupby_agg, name="groupby_agg", static=("key", "val", "op"),
               t=st.Generic("S"), ret=_GroupRet())
_gb.sa.dynamic = True
_reg("groupby_agg", _gb)


def finalize_mean(t: Table, key: str) -> Table:
    """Resolve mean partials (sum,count) into the final mean column."""
    return Table({key: t.cols[key], "mean": np.asarray(t.cols["mean"]) /
                  np.maximum(np.asarray(t.cols["_cnt"]), 1)})


def _join_inner(left: Table, right: Table, on: str):
    """Inner join; splits LEFT, broadcasts RIGHT (right keys unique)."""
    lk = np.asarray(left.cols[on])
    rk = np.asarray(right.cols[on])
    order = np.argsort(rk, kind="stable")
    rk_sorted = rk[order]
    pos = np.searchsorted(rk_sorted, lk)
    pos = np.clip(pos, 0, len(rk_sorted) - 1)
    hit = rk_sorted[pos] == lk
    ridx = order[pos[hit]]
    out = {k: np.asarray(v)[hit] for k, v in left.cols.items()}
    for k, v in right.cols.items():
        if k != on:
            out[f"{k}_r" if k in out else k] = np.asarray(v)[ridx]
    return Table(out)


_join = annotate(_join_inner, name="join_inner", static=("on",),
                 left=st.Generic("S"), right=st._, ret=TableUnknownSpec())
_join.sa.dynamic = True
_reg("join_inner", _join)


def __probe_examples__(n: int = 12) -> dict[str, Any]:
    """Tiny concrete inputs per op for the annotation contract checker."""
    t = Table({"k": jnp.asarray(np.arange(n) % 3, jnp.int32),
               "v": jnp.linspace(0.5, 2.0, n, dtype=jnp.float32)})
    right = Table({"k": jnp.asarray([0, 1, 2], jnp.int32),
                   "w": jnp.asarray([1.0, 2.0, 3.0], jnp.float32)})
    return {
        "col": {"t": t, "name": "v"},
        "with_column": {"t": t, "name": "v2",
                        "values": jnp.linspace(1.0, 3.0, n, dtype=jnp.float32)},
        "select": {"t": t, "names": ("k",)},
        "filter_rows": {"t": t, "mask": jnp.asarray(np.arange(n) % 2 == 0)},
        "groupby_agg": [{"t": t, "key": "k", "val": "v", "op": op}
                        for op in ("sum", "count", "mean", "max", "min")],
        "join_inner": {"left": t, "right": right, "on": "k"},
    }
