"""Lower a planned Mozart stage onto the split-pipeline Pallas kernel.

Eligibility (checked, with graceful fallback to the fused executor):
  * every node is annotated ``elementwise=True``, or is a whole-array
    reduction whose output type is ``ReduceSplit`` (sum/max/min/prod);
  * every splittable stage input is a 1-D ``ArraySplit`` along axis 0 and
    all agree on length;
  * broadcast inputs are scalars ();
  * reductions are only consumed outside the stage (they produce partials).

The stage chain itself is *reused as-is*: the kernel body calls each
annotated function's original implementation on VMEM-resident tiles — the
library function is still unmodified, it simply runs on a (1, BLOCK) block.

The whole kernel launch (pad → pallas_call → unpad/combine) is wrapped in
one jitted driver and pinned into the plan cache (``pinned_jit``), so warm
executions of a cached plan reuse the compiled program instead of re-tracing
``pallas_call`` every evaluation.
"""

from __future__ import annotations

from typing import Any, Callable

import jax

from repro.core import split_types as st
from repro.core.graph import NodeRef
from repro.core.planner import Stage
from repro.core.stage_exec import (
    StageExecutor,
    chain_plan,
    effective_elements,
    get_executor,
    note_trace,
    pinned_jit,
    register_executor,
    stage_num_elements,
)


def _effective_block(batch: int, n: int) -> int:
    """The hardware block an element-count candidate actually compiles to
    (mirrors ``split_pipeline_call``: clamp to n, round up to the 8x128
    sublane x lane tile)."""
    from repro.kernels.split_pipeline import MIN_BLOCK, _round_up
    return max(MIN_BLOCK, _round_up(min(batch, max(n, 1)), MIN_BLOCK))


@register_executor("pallas")
class PallasExecutor(StageExecutor):
    """Lower eligible elementwise stages onto the split-pipeline TPU kernel;
    anything the kernel cannot express falls back to the fused driver."""

    tunable = True
    # The kernel pads + reshapes whole arrays into its (grid, BLOCK) layout;
    # a chunk list would be concatenated first anyway, so streams materialize.
    stream_capable = False

    def execute(self, stage: Stage, concrete: dict[tuple, Any], ctx) -> None:
        if not try_execute_stage_pallas(stage, concrete, ctx, self):
            get_executor("fused").execute(stage, concrete, ctx)

    # -- block-shape-aware tuning (ROADMAP follow-up) ------------------------
    def tuning_candidates(self, stage: Stage, concrete: dict[tuple, Any], ctx,
                          est: int, n: int) -> list[int]:
        """Round the §5.2 bracket to valid hardware block multiples.

        The kernel only ever launches BLOCK = k x 1024 (8 sublanes x 128
        lanes), so raw element-count candidates that resolve to the SAME
        block are duplicates — measuring them would time one compiled shape
        twice and call the timer noise a tuning decision.  Candidates are
        therefore rounded to their effective block first and deduplicated;
        the chosen block *shape* is recorded in the plan entry
        (``PlanEntry.block_shape``)."""
        from repro.core.stage_exec import candidate_batches
        if n <= 0:
            return [1]
        seen: dict[int, int] = {}
        for c in candidate_batches(est, n):
            b = _effective_block(c, n)
            seen.setdefault(b, min(b, n))
        return sorted(set(seen.values()))

    def note_pinned(self, stage: Stage, ctx, entry, batch: int, n: int) -> None:
        entry.pin_block_shape(stage.id, (1, _effective_block(batch, n)))


def _eligible(stage: Stage, concrete: dict[tuple, Any]) -> bool:
    for node in stage.nodes:
        t = stage.out_types[node.id]
        if node.fn.sa.elementwise:
            continue
        if isinstance(t, st.ReduceSplit):
            continue
        return False
    for key, si in stage.inputs.items():
        v = concrete[key]
        if si.split_type.splittable:
            if not isinstance(si.split_type, st.ArraySplit):
                return False
            if si.split_type.axis != 0 or len(si.split_type.shape) != 1:
                return False
        else:
            if getattr(v, "shape", ()) not in ((), (1,)):
                return False
    # reductions must not feed later nodes inside this stage
    node_ids = {n.id for n in stage.nodes}
    for node in stage.nodes:
        if isinstance(stage.out_types[node.id], st.ReduceSplit):
            for other in stage.nodes:
                for v in other.bound.values():
                    if isinstance(v, NodeRef) and v.node_id == node.id:
                        return False
    return True


def _build_pallas_driver(stage: Stage, split_ckeys: list[tuple],
                         bcast_ckeys: list[tuple], esc_pos: list[int],
                         out_kinds: list[tuple[str, str]], out_dtypes: list,
                         batch: int, interpret: bool) -> Callable:
    from repro.kernels.split_pipeline import split_pipeline_call

    plan = chain_plan(stage)
    reduce_keys = {("n", stage.pos[n.id]) for n in stage.nodes
                   if isinstance(stage.out_types[n.id], st.ReduceSplit)}

    def chain_fn(blocks, bcasts):
        env: dict[Any, Any] = dict(zip(split_ckeys, blocks))
        env.update(zip(bcast_ckeys, bcasts))
        reduce_src: dict[tuple, Any] = {}
        for fn, out_key, srcs, _raw in plan:
            kw = {}
            src = None
            for name, key, static in srcs:
                if key is None:
                    kw[name] = static
                    continue
                kw[name] = env[key]
                if src is None:
                    src = kw[name]
            env[out_key] = fn.fn(**kw)        # unmodified library fn
            if out_key in reduce_keys:
                # The kernel applies the masked reduction itself (padding must
                # be excluded), so hand it the PRE-reduction block.
                reduce_src[out_key] = src
        outs = []
        for p, (kind, _) in zip(esc_pos, out_kinds):
            outs.append(reduce_src[("n", p)] if kind == "reduce" else env[("n", p)])
        return outs

    def driver(split_vals, bcast_vals):
        note_trace()
        return split_pipeline_call(
            chain_fn, split_vals, bcast_vals, out_kinds, out_dtypes,
            block_elems=batch, interpret=interpret)

    return jax.jit(driver)


def try_execute_stage_pallas(stage: Stage, concrete: dict[tuple, Any], ctx,
                             executor: StageExecutor | None = None) -> bool:
    if not _eligible(stage, concrete):
        return False

    split_keys = [k for k, si in stage.inputs.items() if si.split_type.splittable]
    bcast_keys = [k for k, si in stage.inputs.items() if not si.split_type.splittable]
    if not split_keys:
        return False

    executor = executor or get_executor("pallas")
    n = effective_elements(ctx, stage_num_elements(stage, concrete, ctx.pedantic))
    if n == 0:
        return False                   # empty split: no grid to launch
    batch = executor.choose_batch(stage, concrete, ctx, n)

    escape_ids = sorted(stage.escaping)
    esc_pos = [stage.pos[nid] for nid in escape_ids]
    out_kinds = []
    out_dtypes = []
    for nid in escape_ids:
        t = stage.out_types[nid]
        node = next(nd for nd in stage.nodes if nd.id == nid)
        if isinstance(t, st.ReduceSplit):
            out_kinds.append(("reduce", t.op_name))
        else:
            out_kinds.append(("concat", ""))
        out_dtypes.append(node.out_aval.dtype)

    interpret = jax.default_backend() != "tpu"
    entry = getattr(ctx, "_plan_entry", None)
    if entry is not None:
        # The block SHAPE this launch compiles to, persisted for warm starts
        # and EXPLAIN tooling (idempotent: no-op when already recorded).
        entry.pin_block_shape(stage.id, (1, _effective_block(batch, n)))
    driver = pinned_jit(
        stage, ctx, "pallas", (tuple(esc_pos), batch, interpret),
        lambda: _build_pallas_driver(
            stage, [stage.ckey(k) for k in split_keys],
            [stage.ckey(k) for k in bcast_keys], esc_pos,
            out_kinds, out_dtypes, batch, interpret))

    results = driver([concrete[k] for k in split_keys],
                     [concrete[k] for k in bcast_keys])
    for nid, res in zip(escape_ids, results):
        node = next(nd for nd in stage.nodes if nd.id == nid)
        node.result = res
    for node in stage.nodes:
        node.done = True
    ctx.stats["pallas_stages"] += 1
    return True
