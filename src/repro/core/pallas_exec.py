"""Lower a planned Mozart stage onto the split-pipeline Pallas kernel.

Eligibility (checked, with graceful fallback to the fused executor):
  * every node is annotated ``elementwise=True``, or is a whole-array
    reduction whose output type is ``ReduceSplit`` (sum/max/min/prod);
  * every splittable stage input is a 1-D ``ArraySplit`` along axis 0 and
    all agree on length;
  * broadcast inputs are scalars ();
  * reductions are only consumed outside the stage (they produce partials).

The stage chain itself is *reused as-is*: the kernel body calls each
annotated function's original implementation on VMEM-resident tiles — the
library function is still unmodified, it simply runs on a (1, BLOCK) block.
"""

from __future__ import annotations

from typing import Any

import jax

from repro.core import split_types as st
from repro.core.graph import NodeRef
from repro.core.planner import Stage, _value_key
from repro.core.stage_exec import (
    StageExecutor,
    effective_elements,
    get_executor,
    register_executor,
    stage_num_elements,
)


@register_executor("pallas")
class PallasExecutor(StageExecutor):
    """Lower eligible elementwise stages onto the split-pipeline TPU kernel;
    anything the kernel cannot express falls back to the fused driver."""

    tunable = True

    def execute(self, stage: Stage, concrete: dict[tuple, Any], ctx) -> None:
        if not try_execute_stage_pallas(stage, concrete, ctx, self):
            get_executor("fused").execute(stage, concrete, ctx)


def _eligible(stage: Stage, concrete: dict[tuple, Any]) -> bool:
    for node in stage.nodes:
        t = stage.out_types[node.id]
        if node.fn.sa.elementwise:
            continue
        if isinstance(t, st.ReduceSplit):
            continue
        return False
    for key, si in stage.inputs.items():
        v = concrete[key]
        if si.split_type.splittable:
            if not isinstance(si.split_type, st.ArraySplit):
                return False
            if si.split_type.axis != 0 or len(si.split_type.shape) != 1:
                return False
        else:
            if getattr(v, "shape", ()) not in ((), (1,)):
                return False
    # reductions must not feed later nodes inside this stage
    node_ids = {n.id for n in stage.nodes}
    for node in stage.nodes:
        if isinstance(stage.out_types[node.id], st.ReduceSplit):
            for other in stage.nodes:
                for v in other.bound.values():
                    if isinstance(v, NodeRef) and v.node_id == node.id:
                        return False
    return True


def try_execute_stage_pallas(stage: Stage, concrete: dict[tuple, Any], ctx,
                             executor: StageExecutor | None = None) -> bool:
    from repro.kernels.split_pipeline import split_pipeline_call

    if not _eligible(stage, concrete):
        return False

    split_keys = [k for k, si in stage.inputs.items() if si.split_type.splittable]
    bcast_keys = [k for k, si in stage.inputs.items() if not si.split_type.splittable]
    if not split_keys:
        return False

    executor = executor or get_executor("pallas")
    n = effective_elements(ctx, stage_num_elements(stage, concrete, ctx.pedantic))
    if n == 0:
        return False                   # empty split: no grid to launch
    batch = executor.choose_batch(stage, concrete, ctx, n)

    escape_ids = sorted(stage.escaping)
    out_kinds = []
    out_dtypes = []
    for nid in escape_ids:
        t = stage.out_types[nid]
        node = next(nd for nd in stage.nodes if nd.id == nid)
        if isinstance(t, st.ReduceSplit):
            out_kinds.append(("reduce", t.op_name))
        else:
            out_kinds.append(("concat", ""))
        out_dtypes.append(node.out_aval.dtype)

    def chain_fn(blocks, bcasts):
        env: dict[Any, Any] = {}
        for k, b in zip(split_keys, blocks):
            env[k] = b
        for k, b in zip(bcast_keys, bcasts):
            env[k] = b
        reduce_src: dict[int, Any] = {}
        for node in stage.nodes:
            kw = {}
            src = None
            for name, v in node.bound.items():
                if name in node.fn.sa.static:
                    kw[name] = v
                    continue
                if isinstance(v, NodeRef) and ("node", v.node_id) in env:
                    kw[name] = env[("node", v.node_id)]
                else:
                    kw[name] = env[_value_key(v)]
                if src is None:
                    src = kw[name]
            if isinstance(stage.out_types[node.id], st.ReduceSplit):
                # The kernel applies the masked reduction itself (padding must
                # be excluded), so hand it the PRE-reduction block.
                reduce_src[node.id] = src
                env[("node", node.id)] = node.fn.fn(**kw)
            else:
                env[("node", node.id)] = node.fn.fn(**kw)  # unmodified library fn
        outs = []
        for nid, (kind, _) in zip(escape_ids, out_kinds):
            outs.append(reduce_src[nid] if kind == "reduce" else env[("node", nid)])
        return outs

    results = split_pipeline_call(
        chain_fn,
        [concrete[k] for k in split_keys],
        [concrete[k] for k in bcast_keys],
        out_kinds,
        out_dtypes,
        block_elems=batch,
        interpret=(jax.default_backend() != "tpu"),
    )
    for nid, res in zip(escape_ids, results):
        node = next(nd for nd in stage.nodes if nd.id == nid)
        node.result = res
    for node in stage.nodes:
        node.done = True
    ctx.stats["pallas_stages"] += 1
    return True
