"""Lower a planned Mozart stage onto the split-pipeline Pallas kernel.

Eligibility (checked, with graceful fallback to the fused executor):
  * every node is annotated ``elementwise=True``, or is a whole-array
    reduction whose output type is ``ReduceSplit`` (sum/max/min/prod);
  * every splittable stage input is a 1-D ``ArraySplit`` along axis 0 and
    all agree on length;
  * broadcast inputs are scalars ();
  * reductions are only consumed outside the stage (they produce partials).

The stage chain itself is *reused as-is*: the kernel body calls each
annotated function's original implementation on VMEM-resident tiles — the
library function is still unmodified, it simply runs on a (1, BLOCK) block.

The whole kernel launch (pad → pallas_call → unpad/combine) is wrapped in
one jitted driver and pinned into the plan cache (``pinned_jit``), so warm
executions of a cached plan reuse the compiled program instead of re-tracing
``pallas_call`` every evaluation.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import split_types as st
from repro.core.graph import NodeRef
from repro.core.planner import Stage
from repro.core.stage_exec import (
    ChunkStream,
    StageExecutor,
    batch_ranges,
    chain_plan,
    donatable_input_keys,
    effective_elements,
    get_executor,
    mark_stream_consumed,
    note_materialized,
    note_trace,
    pinned_jit,
    register_executor,
    stage_num_elements,
    undonatable_stream_keys,
)


def _effective_block(batch: int, n: int) -> int:
    """The hardware block an element-count candidate actually compiles to
    (mirrors ``split_pipeline_call``: clamp to n, round up to the 8x128
    sublane x lane tile)."""
    from repro.kernels.split_pipeline import MIN_BLOCK, _round_up
    return max(MIN_BLOCK, _round_up(min(batch, max(n, 1)), MIN_BLOCK))


@register_executor("pallas")
class PallasExecutor(StageExecutor):
    """Lower eligible elementwise stages onto the split-pipeline TPU kernel;
    anything the kernel cannot express falls back to the fused driver.

    Chunk handoff: an incoming ``ChunkStream`` is stacked DIRECTLY into the
    kernel's padded ``(grid, BLOCK)`` launch layout (equal-grid fast path;
    ``rechunk`` for disagreeing grids) instead of being merged and re-padded;
    launch buffers the stage's handoff plan proves dead here are donated to
    the jitted launch driver under the same structural donate-key rules as
    the fused/scan drivers."""

    tunable = True
    stream_capable = True

    def execute(self, stage: Stage, concrete: dict[tuple, Any], ctx) -> None:
        if not try_execute_stage_pallas(stage, concrete, ctx, self):
            get_executor("fused").execute(stage, concrete, ctx)

    # -- block-shape-aware tuning (ROADMAP follow-up) ------------------------
    def tuning_candidates(self, stage: Stage, concrete: dict[tuple, Any], ctx,
                          est: int, n: int) -> list[int]:
        """Round the §5.2 bracket to valid hardware block multiples.

        The kernel only ever launches BLOCK = k x 1024 (8 sublanes x 128
        lanes), so raw element-count candidates that resolve to the SAME
        block are duplicates — measuring them would time one compiled shape
        twice and call the timer noise a tuning decision.  Candidates are
        therefore rounded to their effective block first and deduplicated;
        the chosen block *shape* is recorded in the plan entry
        (``PlanEntry.block_shape``)."""
        from repro.core.stage_exec import candidate_batches
        if n <= 0:
            return [1]
        seen: dict[int, int] = {}
        for c in candidate_batches(est, n):
            b = _effective_block(c, n)
            seen.setdefault(b, min(b, n))
        return sorted(set(seen.values()))

    def note_pinned(self, stage: Stage, ctx, entry, batch: int, n: int) -> None:
        entry.pin_block_shape(stage.id, (1, _effective_block(batch, n)))


def _eligible(stage: Stage, concrete: dict[tuple, Any]) -> bool:
    for node in stage.nodes:
        t = stage.out_types[node.id]
        if node.fn.sa.elementwise:
            continue
        if isinstance(t, st.ReduceSplit):
            continue
        return False
    for key, si in stage.inputs.items():
        v = concrete[key]
        if si.split_type.splittable:
            if not isinstance(si.split_type, st.ArraySplit):
                return False
            if si.split_type.axis != 0 or len(si.split_type.shape) != 1:
                return False
        else:
            if getattr(v, "shape", ()) not in ((), (1,)):
                return False
    # reductions must not feed later nodes inside this stage
    node_ids = {n.id for n in stage.nodes}
    for node in stage.nodes:
        if isinstance(stage.out_types[node.id], st.ReduceSplit):
            for other in stage.nodes:
                for v in other.bound.values():
                    if isinstance(v, NodeRef) and v.node_id == node.id:
                        return False
    return True


def _build_pallas_driver(stage: Stage, split_ckeys: list[tuple],
                         bcast_ckeys: list[tuple], esc_pos: list[int],
                         out_kinds: list[tuple[str, str]], out_dtypes: list,
                         batch: int, interpret: bool) -> Callable:
    from repro.kernels.split_pipeline import padded_layout, split_pipeline_call_2d

    plan = chain_plan(stage)
    reduce_keys = {("n", stage.pos[n.id]) for n in stage.nodes
                   if isinstance(stage.out_types[n.id], st.ReduceSplit)}

    def chain_fn(blocks, bcasts):
        env: dict[Any, Any] = dict(zip(split_ckeys, blocks))
        env.update(zip(bcast_ckeys, bcasts))
        reduce_src: dict[tuple, Any] = {}
        for fn, out_key, srcs, _raw in plan:
            kw = {}
            src = None
            for name, key, static in srcs:
                if key is None:
                    kw[name] = static
                    continue
                kw[name] = env[key]
                if src is None:
                    src = kw[name]
            env[out_key] = fn.fn(**kw)        # unmodified library fn
            if out_key in reduce_keys:
                # The kernel applies the masked reduction itself (padding must
                # be excluded), so hand it the PRE-reduction block.
                reduce_src[out_key] = src
        outs = []
        for p, (kind, _) in zip(esc_pos, out_kinds):
            outs.append(reduce_src[("n", p)] if kind == "reduce" else env[("n", p)])
        return outs

    def driver(donated: dict, rest: dict, bcast_vals, n: int):
        # Launch buffers arrive prebuilt in the padded (grid, BLOCK) layout
        # (position-keyed so donated and retained buffers reassemble in
        # split-key order); the true length ``n`` is a static argument —
        # the tail mask must never come from a stale closure.
        note_trace()
        bufs = {**rest, **donated}
        split2d = [bufs[i] for i in range(len(split_ckeys))]
        block, _n_pad, _grid = padded_layout(n, batch)
        return split_pipeline_call_2d(
            chain_fn, split2d, bcast_vals, out_kinds, out_dtypes, n, block,
            interpret=interpret)

    return jax.jit(driver, static_argnums=(3,), donate_argnums=(0,))


def _to_launch_layout(v: Any, n: int, block: int, stage: Stage, ck: tuple,
                      ctx) -> tuple[Any, bool]:
    """One split input as its ``(grid, BLOCK)`` launch buffer.

    Returns ``(buffer, fresh)`` — ``fresh`` means the buffer was assembled
    here (stack/pad copies) and may be donated without endangering anyone
    else's storage.  A handed-off ``ChunkStream`` stacks its chunk list
    straight into the layout (equal-grid fast path; ``rechunk`` for
    disagreeing grids) — ``materialize()`` is never called.

    Building the buffer EAGERLY (outside the pinned driver) costs a few
    extra dispatches per call, and is deliberate twice over: the driver's
    argument shape is identical whether a stream arrived or a whole array
    did (cross-evaluation arrival can flap call-to-call — inside-jit
    padding would retrace on every flap, breaking the warm zero-retrace
    invariant), and only an argument buffer can be DONATED (a padded
    intermediate built inside the jit has no donation story)."""
    from repro.kernels.split_pipeline import _round_up, pad_to_layout

    if not isinstance(v, ChunkStream):
        return pad_to_layout(v, n, block), _round_up(n, block) > n

    grid_ranges = batch_ranges(n, block)
    # scan→pallas: a carry-form stream whose batch IS the block passes its
    # (k, BLOCK) main buffer through untouched.
    if (v.stacked is not None and v._chunks is None
            and v.uniform_batch() == block
            and isinstance(v.stacked, jax.Array) and v.stacked.ndim == 2):
        if v.tail is None:
            return v.stacked, False
        pad = block - int(v.tail.shape[0])
        tail_row = jnp.pad(v.tail, (0, pad)).reshape(1, block)
        return jnp.concatenate([v.stacked, tail_row], axis=0), True

    chunks, ranges = v.chunks, v.ranges
    if ranges != grid_ranges:
        chunks, copied = v.split_type.rechunk(chunks, ranges, grid_ranges)
        note_materialized(copied, kind="rechunk",
                          where=f"stage {stage.id} input {ck}")
        ctx.stats["handoff_rechunks"] += 1
    sizes = [e - s for s, e in grid_ranges]
    ragged = sizes[-1] < block
    main = chunks[:-1] if ragged else chunks
    rows = []
    if main:
        rows.append(jnp.stack(main))
    if ragged:
        rows.append(jnp.pad(chunks[-1], (0, block - sizes[-1]))
                    .reshape(1, block))
    buf = rows[0] if len(rows) == 1 else jnp.concatenate(rows, axis=0)
    return buf, True


def try_execute_stage_pallas(stage: Stage, concrete: dict[tuple, Any], ctx,
                             executor: StageExecutor | None = None) -> bool:
    if not _eligible(stage, concrete):
        return False

    split_keys = [k for k, si in stage.inputs.items() if si.split_type.splittable]
    bcast_keys = [k for k, si in stage.inputs.items() if not si.split_type.splittable]
    if not split_keys:
        return False

    executor = executor or get_executor("pallas")
    n = effective_elements(ctx, stage_num_elements(stage, concrete, ctx.pedantic))
    if n == 0:
        return False                   # empty split: no grid to launch
    batch = executor.choose_batch(stage, concrete, ctx, n)
    block = _effective_block(batch, n)

    escape_ids = sorted(stage.escaping)
    esc_pos = [stage.pos[nid] for nid in escape_ids]
    out_kinds = []
    out_dtypes = []
    for nid in escape_ids:
        t = stage.out_types[nid]
        node = next(nd for nd in stage.nodes if nd.id == nid)
        if isinstance(t, st.ReduceSplit):
            out_kinds.append(("reduce", t.op_name))
        else:
            out_kinds.append(("concat", ""))
        out_dtypes.append(node.out_aval.dtype)

    interpret = jax.default_backend() != "tpu"
    entry = getattr(ctx, "_plan_entry", None)
    if entry is not None:
        # The block SHAPE this launch compiles to, persisted for warm starts
        # and EXPLAIN tooling (idempotent: no-op when already recorded).
        entry.pin_block_shape(stage.id, (1, block))

    # Structural donate set (shared rules with the fused/scan drivers): the
    # positions are part of the pinned variant key, so warm calls never flap.
    donate_cks = set(donatable_input_keys(stage, ctx))
    donate_pos = tuple(i for i, k in enumerate(split_keys)
                       if stage.ckey(k) in donate_cks)
    unsafe = undonatable_stream_keys(
        stage, concrete, ctx, tuple(donate_cks)) if donate_pos else set()

    driver = pinned_jit(
        stage, ctx, "pallas", (tuple(esc_pos), batch, interpret, donate_pos),
        lambda: _build_pallas_driver(
            stage, [stage.ckey(k) for k in split_keys],
            [stage.ckey(k) for k in bcast_keys], esc_pos,
            out_kinds, out_dtypes, batch, interpret))

    donated: dict[int, Any] = {}
    rest: dict[int, Any] = {}
    consumed_keys: set = set()
    for i, k in enumerate(split_keys):
        v = concrete[k]
        buf, fresh = _to_launch_layout(v, n, block, stage, stage.ckey(k), ctx)
        if i not in donate_pos:
            rest[i] = buf
            continue
        if fresh:
            donated[i] = buf           # our own assembly: donation is free
        elif stage.ckey(k) in unsafe or not isinstance(v, ChunkStream):
            # Observable stream pass-through, or a whole array whose padded
            # view may alias the producer's retained result: donate a copy.
            donated[i] = jnp.array(buf)
            ctx.stats["donation_copies"] += 1
        else:
            donated[i] = buf           # dead carry pass-through: real donation
            consumed_keys.add(stage.ckey(k))
    if donated:
        ctx.stats["donated_chunks"] += len(donated)

    outs = driver(donated, rest, [concrete[k] for k in bcast_keys], n)
    from repro.kernels.split_pipeline import unpad_outputs
    results = unpad_outputs(outs, out_kinds, n, block)
    mark_stream_consumed(stage, concrete, ctx, consumed_keys)
    for nid, res in zip(escape_ids, results):
        node = next(nd for nd in stage.nodes if nd.id == nid)
        node.result = res
    for node in stage.nodes:
        node.done = True
    ctx.stats["pallas_stages"] += 1
    return True
