"""The Mozart planner (paper §5.1): dataflow graph -> pipelined stages.

Two adjacent functions live in the same *stage* iff every value flowing
between them has the same split type (after generic inference).  A mismatch
forces the producer's outputs to be merged, the stage to close, and the
consumer to start a new stage whose inputs are re-split.

Generic inference "pushes known types along the edges" with a union-find
``TypeEnv``; anything still generic when a stage closes falls back to the
per-data-type default split type (paper §5.1).
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.core import split_types as st
from repro.core.graph import DataflowGraph, Node, NodeRef


def _value_key(v: Any) -> tuple:
    if isinstance(v, NodeRef):
        return ("node", v.node_id)
    return ("ext", id(v))


@dataclasses.dataclass
class StageInput:
    key: tuple
    value: Any                      # concrete value or NodeRef (resolved later)
    split_type: st.SplitType        # resolved, concrete


@dataclasses.dataclass
class Stage:
    id: int
    nodes: list[Node]
    inputs: dict[tuple, StageInput]
    out_types: dict[int, st.SplitType]      # node_id -> resolved output type
    escaping: set[int]                       # node ids whose output leaves the stage
    arg_types: dict[tuple[int, str], st.SplitType]  # (node, arg) resolved

    def __post_init__(self):
        # Position-based canonical env keys.  Runtime value keys — ("ext",
        # id(v)) and ("node", node_id) — are unique per *call*, so any jitted
        # driver whose argument env used them would see a fresh pytree
        # structure every evaluation and retrace.  Canonical keys depend only
        # on the stage's *shape*: input position and node position, which are
        # identical across every instantiation of the same plan template.
        # All executor chunk envs are keyed canonically via ``ckey``.
        self.canon: dict[tuple, tuple] = {}
        for i, key in enumerate(self.inputs):
            self.canon[key] = ("in", i)
        self.pos: dict[int, int] = {}        # node_id -> position in the stage
        for j, n in enumerate(self.nodes):
            self.pos[n.id] = j
            self.canon[("node", n.id)] = ("n", j)

    def ckey(self, key: tuple) -> tuple:
        """Canonical (position-based) form of a runtime env key."""
        return self.canon[key]

    def out_key(self, node: Node) -> tuple:
        return ("n", self.pos[node.id])

    def escape_positions(self) -> list[int]:
        """Stage-local positions of escaping nodes, in deterministic order."""
        return sorted(self.pos[nid] for nid in self.escaping)

    def internal(self, node: Node, argname: str) -> bool:
        v = node.bound.get(argname)
        return isinstance(v, NodeRef) and any(n.id == v.node_id for n in self.nodes)

    def flops_hint(self) -> float:
        """Arithmetic-intensity proxy: summed SA ``cost_hint`` over the chain.

        The annotation's per-call cost hint (relative to one elementwise op)
        feeds the executor cost model (``core/cost_model.py``) — a long chain
        of cheap ops is memory-bound, a short chain of expensive ones is not."""
        return sum(float(getattr(n.fn.sa, "cost_hint", 1.0)) for n in self.nodes)


def _count_of_type(t: Any) -> int | None:
    if isinstance(t, st.ArraySplit):
        return t.shape[t.axis] if t.shape else None
    if isinstance(t, st.PytreeSplit):
        return t.length
    return None


def _is_whole_array_source(node: Node) -> bool:
    """True when every non-static input is concretely broadcast ("_") but the
    output is splittable: the node computes on WHOLE arrays (e.g. Shallow
    Water's `roll`).  It must form its own stage — its output materializes
    and downstream stages re-split it — or chunked consumers would mix
    full-size values with chunks."""
    args = [t for name, t in node.arg_types.items()
            if name not in node.fn.sa.static]
    if not args or not all(isinstance(t, st.ScalarSplit) for t in args):
        return False
    return not isinstance(node.out_type, (st.ScalarSplit, st.ReduceSplit))


class _OpenStage:
    def __init__(self, sid: int):
        self.id = sid
        self.nodes: list[Node] = []
        self.env = st.TypeEnv()
        self.input_tvars: dict[tuple, Any] = {}    # key -> SplitType|GenericVar
        self.input_vals: dict[tuple, Any] = {}
        self.out_tvars: dict[int, Any] = {}
        self.count: int | None = None              # split element count
        self.closed = False                        # whole-array source stage

    def _candidate_count(self, node: Node, graph) -> int | None:
        """Element count this node's splittable inputs imply.  Generic args
        use the value's per-datatype default split (paper §5.1 fallback)."""
        for name, val in node.bound.items():
            if name in node.fn.sa.static:
                continue
            declared = self.env.resolve(node.arg_types[name])
            c = _count_of_type(declared)
            if c is not None:
                return c
            if isinstance(declared, st.GenericVar):
                aval = (graph.nodes[val.node_id].out_aval
                        if isinstance(val, NodeRef) else val)
                if aval is not None:
                    c = _count_of_type(st.default_split_type(aval))
                    if c is not None:
                        return c
        return None

    def try_place(self, node: Node, graph) -> bool:
        if _is_whole_array_source(node):
            if self.nodes:
                return False               # boundary: own stage
            self.closed = True             # and nothing joins after it
        # the per-stage driver loop iterates ONE chunk range: every
        # splittable value in a stage must agree on its element count
        cand = self._candidate_count(node, graph)
        if cand is not None and self.count is not None and cand != self.count:
            return False
        snap = self.env.snapshot()
        added_inputs: list[tuple] = []
        try:
            for name, val in node.bound.items():
                if name in node.fn.sa.static:
                    continue
                declared = node.arg_types[name]
                if isinstance(val, NodeRef) and val.node_id in self.out_tvars:
                    # intra-stage edge: source out type must equal dest arg type
                    self.env.unify(self.out_tvars[val.node_id], declared)
                else:
                    key = _value_key(val)
                    if key in self.input_tvars:
                        # same value used twice in one stage: one split only
                        self.env.unify(self.input_tvars[key], declared)
                    else:
                        self.input_tvars[key] = declared
                        self.input_vals[key] = val
                        added_inputs.append(key)
            self.out_tvars[node.id] = node.out_type
            self.nodes.append(node)
            node.stage_id = self.id
            if cand is not None and self.count is None:
                self.count = cand
            return True
        except st.UnificationError:
            self.env.restore(snap)
            for key in added_inputs:
                self.input_tvars.pop(key, None)
                self.input_vals.pop(key, None)
            return False


def _resolve(env: st.TypeEnv, t: Any, aval_like: Any) -> st.SplitType:
    r = env.resolve(t)
    if isinstance(r, st.GenericVar):
        r = (st.default_split_type(aval_like)
             if aval_like is not None else st.BROADCAST)
    # A generic unified across broadcasting operands of different shapes
    # (e.g. (1, n) vs (n, n)) must not be split with the larger operand's
    # geometry: shape-mismatched values are copied whole instead (the
    # paper's "_" semantics for values that are not actually split).
    if isinstance(r, st.ArraySplit) and aval_like is not None:
        shape = tuple(getattr(aval_like, "shape", ()) or ())
        if shape and shape != r.shape:
            return st.BROADCAST
    return r


#: process-global count of actual planner invocations.  The plan cache's
#: "second identical run performs zero planner calls" guarantee is asserted
#: against this counter (tests/test_stage_exec.py).
N_CALLS = 0


def simulate_stage_breaks(nodes: list[Node], graph: DataflowGraph,
                          max_stage_nodes: int | None = None
                          ) -> list[list[Node]]:
    """Dry-run the greedy grouping loop on a candidate node order and return
    the stage partition it would produce — WITHOUT counting as a planner call
    (``N_CALLS`` untouched) and without building ``Stage`` objects.

    Used by ``core/rewrite.py`` to score node orders before committing a
    reassociation: fewer breaks means fewer merge/re-split boundaries.
    ``try_place`` tags ``node.stage_id`` as a side effect; callers always
    re-plan (or re-simulate) afterwards, so the tags are transient.
    """
    groups: list[_OpenStage] = []
    cur: _OpenStage | None = None
    for node in nodes:
        full = (cur is not None and
                (cur.closed or (max_stage_nodes is not None
                                and len(cur.nodes) >= max_stage_nodes)))
        if cur is None or full or not cur.try_place(node, graph):
            cur = _OpenStage(len(groups))
            groups.append(cur)
            if not cur.try_place(node, graph):
                raise AssertionError(f"cannot place {node} in empty stage")
    return [g.nodes for g in groups]


def plan(nodes: list[Node], graph: DataflowGraph,
         max_stage_nodes: int | None = None) -> list[Stage]:
    """Greedy consecutive grouping in topological (= program) order.

    ``max_stage_nodes=1`` disables cross-function pipelining (each function
    still splits + parallelizes alone) — the paper's Table 4 "-pipe" ablation.
    """
    global N_CALLS
    N_CALLS += 1
    open_stages: list[_OpenStage] = []
    cur: _OpenStage | None = None
    for node in nodes:
        full = (cur is not None and
                (cur.closed or (max_stage_nodes is not None
                                and len(cur.nodes) >= max_stage_nodes)))
        if cur is None or full or not cur.try_place(node, graph):
            cur = _OpenStage(len(open_stages))
            open_stages.append(cur)
            ok = cur.try_place(node, graph)
            if not ok:  # single node must always fit a fresh stage
                raise AssertionError(f"cannot place {node} in empty stage")

    consumers = graph.consumers()
    stages: list[Stage] = []
    for s in open_stages:
        inputs: dict[tuple, StageInput] = {}
        for key, tvar in s.input_tvars.items():
            val = s.input_vals[key]
            if isinstance(val, NodeRef):
                aval = graph.nodes[val.node_id].out_aval
            else:
                aval = val          # default_split_type dispatches on type
            inputs[key] = StageInput(key, val, _resolve(s.env, tvar, aval))
        out_types: dict[int, st.SplitType] = {}
        escaping: set[int] = set()
        node_ids = {n.id for n in s.nodes}
        for n in s.nodes:
            out_types[n.id] = _resolve(s.env, s.out_tvars[n.id], n.out_aval)
            ext_consumer = any(c not in node_ids for c in consumers.get(n.id, []))
            if ext_consumer or n.future_alive():
                escaping.add(n.id)
        arg_types: dict[tuple[int, str], st.SplitType] = {}
        for n in s.nodes:
            for name in n.bound:
                if name in n.fn.sa.static:
                    continue
                v = n.bound[name]
                if isinstance(v, NodeRef):
                    aval = graph.nodes[v.node_id].out_aval
                else:
                    aval = v
                arg_types[(n.id, name)] = _resolve(s.env, n.arg_types[name], aval)
        stages.append(Stage(s.id, s.nodes, inputs, out_types, escaping, arg_types))
    return stages
