"""The Mozart runtime facade: contexts, configuration, evaluation.

A ``MozartContext`` owns a dataflow graph (libmozart), a planner, and an
executor configuration.  ``evaluate()`` converts pending annotated calls into
stages and runs them (paper Figure 2).  Contexts nest; ``mozart.session``
is the user-facing way to scope configuration:

    with mozart.session(executor="scan"):
        out = bs.black_scholes(price, strike, ...)   # lazy
        print(out.value)                             # forces evaluation
"""

from __future__ import annotations

import collections
import contextlib
import os
import threading
import weakref
from typing import Any

from repro import hardware
from repro.core import resilience
from repro.core import split_types as st
from repro.core.future import Future
from repro.core.graph import DataflowGraph, NodeRef
from repro.core.planner import plan
from repro.core.resilience import inject_faults  # noqa: F401  (mozart.inject_faults)
from repro.core.stage_exec import BoundaryCounters, counter_scope, get_executor


class MozartContext:
    def __init__(
        self,
        executor: str = "pipelined",
        chip: hardware.Chip = hardware.TARGET,
        mesh=None,
        data_axes: tuple[str, ...] = ("data",),
        lazy: bool = True,
        pedantic: bool = False,
        batch_elements: int | None = None,
        log: bool = False,
        inner_executor: str = "fused",
        pipeline: bool = True,
        plan_cache: bool = True,
        autotune: bool = True,
        plan_cache_path: str | None = None,
        handoff: bool = True,
        rewrite: bool = True,
    ):
        self.executor = executor
        self.chip = chip
        self.mesh = mesh
        self.data_axes = data_axes
        self.lazy = lazy
        self.pedantic = pedantic
        self.batch_elements = batch_elements
        self.log = log
        self.inner_executor = inner_executor    # per-shard strategy for "sharded"
        self.pipeline = pipeline                 # False: Table-4 "-pipe" ablation
        self.plan_cache = plan_cache             # reuse plans across evaluations
        self.autotune = autotune                 # measure+pin chunk sizes on cached plans
        self.handoff = handoff                   # cross-stage chunk handoff (core/handoff.py)
        self.rewrite = rewrite                   # static graph rewrite pass (core/rewrite.py)
        # Persist plans/tuned batches/executor choices across processes.  The
        # MOZART_PLAN_CACHE env var pre-warms every context (serving replicas
        # restart with pinned plans: zero planner calls, zero tuning runs).
        if plan_cache_path is None:
            plan_cache_path = os.environ.get("MOZART_PLAN_CACHE") or None
        self.plan_cache_path = plan_cache_path
        self.graph = DataflowGraph()
        self.stats: collections.Counter = collections.Counter()
        #: this context's scoped trace/boundary-traffic view — concurrent
        #: sessions never pollute each other's gates (stage_exec).
        self.counters = BoundaryCounters()
        self._plan_entry = None                  # active plan_cache.PlanEntry
        self._handoff = None                     # active handoff decisions
        self._batch_override: int | None = None  # set by the auto-tuner only
        self._n_cap: int | None = None           # set during sampled tuning only
        self._entry_keys: set = set()            # cache keys this context used
        self._last_rewrites: list = []           # RewriteRecords of the last plan
        if self.plan_cache_path:
            from repro.core.plan_cache import load_once
            load_once(self.plan_cache_path)

    # -- libmozart register() -------------------------------------------------
    def register_call(self, fn, bound: dict[str, Any]) -> Future:
        avals: dict[str, Any] = {}
        ctor_bound: dict[str, Any] = {}
        stored: dict[str, Any] = {}
        for name, v in bound.items():
            if isinstance(v, Future):
                node = v._node
                avals[name] = node.out_aval
                ctor_bound[name] = node.out_aval     # ctors may read .shape
                stored[name] = NodeRef(node.id)
            else:
                avals[name] = v
                ctor_bound[name] = v
                stored[name] = v

        # Dynamic-shape functions (and consumers of their outputs) cannot be
        # abstractly evaluated; they run un-jitted per chunk (paper: filters).
        if getattr(fn.sa, "dynamic", False) or any(a is None for a in avals.values()):
            out_aval = None
        else:
            out_aval = fn.abstract_eval(avals)
        arg_types, out_type = fn.construct_types(ctor_bound, avals, out_aval)
        node = self.graph.register(fn, stored, arg_types, out_type, out_aval)
        fut = Future(self, node)
        node.future_ref = weakref.ref(fut)
        self.stats["registered"] += 1
        return fut

    # -- libmozart evaluate() ---------------------------------------------------
    def evaluate(self) -> None:
        pending = self.graph.pending()
        if not pending:
            return
        from repro.core.plan_cache import lookup_or_plan
        stages, entry = lookup_or_plan(pending, self.graph, self)
        self.stats["evaluations"] += 1
        if self.log:
            for s in stages:
                names = ",".join(n.fn.name for n in s.nodes)
                print(f"[mozart] stage {s.id}: [{names}] inputs="
                      f"{[str(si.split_type) for si in s.inputs.values()]}")
        # Handoff decisions: replayed from the cache entry (zero analysis on
        # warm calls); uncacheable pipelines analyze fresh per evaluation.
        from repro.core.handoff import resolve_decisions
        ho = resolve_decisions(self, entry, stages)
        # Save/restore (not clear): a dynamic node forcing a Future of this
        # same session re-enters evaluate(), and the outer plan's entry must
        # survive the nested call.
        prev_entry, prev_ho = self._plan_entry, self._handoff
        self._plan_entry = entry
        self._handoff = ho
        try:
            # Dispatch PER STAGE: under ``executor="auto"`` each stage is
            # scored and routed independently (cost_model.AutoExecutor).
            # Trace/boundary events attribute to THIS context's counters
            # (plus the process-global aggregate) for the duration.
            # ``resilience.run_stage`` arms the degradation ladder: a failing
            # executor is quarantined and the stage completes on a lower rung.
            with counter_scope(self.counters):
                for s in stages:
                    resilience.run_stage(self.executor, s, self.graph, self)
        finally:
            self._plan_entry, self._handoff = prev_entry, prev_ho
        self.graph.prune()

    def last_plan(self):
        """Plan (without executing) — used by tests and EXPLAIN tooling."""
        return plan(self.graph.pending(), self.graph,
                    max_stage_nodes=None if self.pipeline else 1)


_tls = threading.local()


def _stack() -> list[MozartContext]:
    if not hasattr(_tls, "stack"):
        _tls.stack = [MozartContext()]      # paper behaviour: lazy by default
    return _tls.stack


def current_context() -> MozartContext | None:
    s = _stack()
    return s[-1] if s else None


def configure(**kwargs) -> MozartContext:
    """Reconfigure the innermost context (flushes pending work first).

    Plan-cache-aware: when a knob that is part of the plan-cache key changes
    (executor, chip, mesh/data_axes, pipeline), the entries THIS context has
    used are re-keyed (copied) to the new configuration so the next
    evaluation hits the cache instead of replanning — see
    ``plan_cache.rekey_config``.  Scoped to this context's own entries:
    other sessions and compiled Pipelines sharing the old configuration keep
    their entries and pinned executables untouched."""
    ctx = current_context()
    if ctx is None:
        if kwargs:
            raise AttributeError("no active Mozart context to configure")
        return ctx
    ctx.evaluate()
    from repro.core import plan_cache as _pc
    old_prefix = _pc.context_key_prefix(ctx)
    for k, v in kwargs.items():
        if not hasattr(ctx, k):
            raise AttributeError(f"unknown Mozart option {k!r}")
        setattr(ctx, k, v)
    new_prefix = _pc.context_key_prefix(ctx)
    if old_prefix != new_prefix and getattr(ctx, "plan_cache", True):
        ctx.stats["configure_rekeyed"] += _pc.rekey_config(
            old_prefix, new_prefix, only_keys=ctx._entry_keys)
    return ctx


@contextlib.contextmanager
def session(**kwargs):
    """Scope a Mozart configuration (paper-style usage).

    Implemented on top of the AOT pipeline API: a session is an anonymous
    :class:`repro.core.pipeline.Pipeline`'s ``scope()`` — the same context,
    evaluation flush and plan persistence drive both entry points."""
    from repro.core.pipeline import Pipeline
    with Pipeline(None, **kwargs).scope() as ctx:
        yield ctx


def pipeline(fn=None, **config):
    """AOT entry point: ``mozart.pipeline(fn, ...)`` with an explicit
    ``lower → compile → call`` lifecycle.  See ``repro.core.pipeline``."""
    from repro.core.pipeline import pipeline as _pipeline
    return _pipeline(fn, **config)


def evaluate() -> None:
    ctx = current_context()
    if ctx is not None:
        ctx.evaluate()


def verify(target=None, *args, **kwargs):
    """``mozart.verify()``: lint every registered split annotation, or
    ``mozart.verify(fn, *args, executor=...)``: trace one pipeline and run
    the dataflow analyzer over its plan.  Returns an
    ``analysis.Report``; see ``repro.core.analysis`` for the MZ codes."""
    from repro.core import analysis
    return analysis.verify(target, *args, **kwargs)
