"""The AOT pipeline API: ``mozart.pipeline`` — trace → plan → compile → call.

``mozart.session`` scopes *configuration* and evaluates whatever lazy graph
the enclosed code happens to build; every knob of the runtime is re-resolved
per evaluation.  For serving-shaped workloads the pipeline is fixed and the
per-call budget is tiny, so this module provides the ahead-of-time analogue
of ``jax.jit``'s ``lower``/``compile`` protocol over a whole Mozart program:

    p = mozart.pipeline(fn, executor="auto", plan_cache_path="plans.json")
    p.lower(x, y)        # build the dataflow graph once, resolve a PlanEntry
    p.compile()          # pin batches, executors AND compiled executables
    out = p(x, y)        # hot path: split -> drive pinned drivers -> merge

* ``lower(*args)`` traces ``fn`` lazily (nothing executes), fingerprints the
  captured graph and resolves its plan-cache entry — planning happens here,
  never on the hot path.
* ``compile()`` runs the pipeline on the lowered example until it reaches a
  fixed point: the chunk-size tuner has pinned, ``auto`` has measured and
  pinned per-stage executors, and every per-stage compiled executable (the
  fused/scan jitted drivers, Pallas launchers, ``shard_map`` closures) is
  built and pinned into the plan entry's executable table.  Executables are
  keyed by stage POSITION (``Stage.ckey``), not per-call node ids, so they
  are reused verbatim by later calls.
* ``__call__`` is the steady-state path: re-capture the (cheap, Python-level)
  graph, hit the plan cache, split inputs, drive the pinned executables and
  merge — zero planner calls and zero jit retraces, asserted via
  ``stage_exec.trace_count()`` deltas in ``last_call_stats["jit_traces"]``.

``mozart.session`` itself is reimplemented on top of this class: a session is
an anonymous Pipeline's ``scope()`` (see ``runtime.session``), so both entry
points share one lifecycle and one cache.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Callable

from repro.core.future import Future
from repro.core.runtime import MozartContext, _stack

#: compile() runs at most this many passes while converging to the pinned
#: steady state (plan -> measure/tune -> compile real shapes -> quiescent).
MAX_COMPILE_PASSES = 6

#: per-call counters that must all be zero for a call to count as "warm".
WARM_STATS = ("planner_calls", "autotuned_stages", "auto_measured_stages",
              "jit_traces")


def _force(out: Any) -> Any:
    """Materialize every Future in a (possibly nested) return value."""
    if isinstance(out, Future):
        return out.value
    if isinstance(out, (list, tuple)):
        forced = [_force(o) for o in out]
        if hasattr(out, "_fields"):              # namedtuple
            return type(out)(*forced)
        return type(out)(forced)
    if isinstance(out, dict):
        return {k: _force(v) for k, v in out.items()}
    return out


class Pipeline:
    """An ahead-of-time-compilable Mozart program (see module docstring)."""

    def __init__(self, fn: Callable | None, **config):
        self.fn = fn
        self.ctx = MozartContext(**config)
        self._lock = threading.RLock()
        self._example: tuple | None = None       # (args, kwargs) from lower()
        self._entry = None                       # resolved plan_cache.PlanEntry
        self._n_stages: int | None = None
        self.compiled = False
        #: stat deltas of the most recent ``__call__`` (includes
        #: ``jit_traces``, the stage_exec trace-counter delta).
        self.last_call_stats: dict[str, int] = {}

    # -- session compatibility ----------------------------------------------
    @contextlib.contextmanager
    def scope(self):
        """Enter this pipeline's context as the ambient Mozart scope.

        ``mozart.session(**cfg)`` is exactly ``Pipeline(None, **cfg).scope()``:
        annotated calls made inside register against this pipeline's context,
        evaluation is flushed at scope exit, and (when configured) plans are
        persisted."""
        ctx = self.ctx
        _stack().append(ctx)
        try:
            yield ctx
            ctx.evaluate()                       # flush at scope exit
            if ctx.plan_cache_path:
                from repro.core import plan_cache as _pc
                _pc.save(ctx.plan_cache_path)    # persist plans + decisions
        finally:
            _stack().pop()

    # -- AOT lifecycle -------------------------------------------------------
    def lower(self, *args, **kwargs) -> "Pipeline":
        """Trace ``fn`` into a dataflow graph and resolve its plan entry.

        Nothing executes: the captured nodes are planned (or matched against
        the plan cache) and then discarded.  The arguments become the example
        ``compile()`` specializes to."""
        self._require_fn()
        with self._lock:
            ctx = self.ctx
            _stack().append(ctx)
            try:
                out = self.fn(*args, **kwargs)
            finally:
                _stack().pop()
            pending = ctx.graph.pending()
            entry = None
            if pending:
                from repro.core.plan_cache import lookup_or_plan
                stages, entry = lookup_or_plan(pending, ctx.graph, ctx)
                self._n_stages = len(stages)
            # lower never executes: drop the traced nodes (their Futures die
            # with `out`) so they cannot leak into the next evaluation.
            for n in pending:
                n.done = True
            del out
            ctx.graph.prune()
            self._example = (args, kwargs)
            self._entry = entry
            return self

    def compile(self, *args, **kwargs) -> "Pipeline":
        """Drive the pipeline to its pinned steady state.

        Runs the lowered example repeatedly (bounded by
        ``MAX_COMPILE_PASSES``) until a pass performs zero planner calls,
        zero tuning/measurement runs and zero jit traces — at which point
        every chunk size, executor choice and compiled executable is pinned
        and subsequent ``__call__``s are pure split/drive/merge."""
        self._require_fn()
        if args or kwargs:
            self._example = (args, kwargs)
        if self._example is None:
            raise ValueError(
                "compile() needs example arguments: call p.lower(*args) "
                "first or pass them directly: p.compile(*args)")
        a, kw = self._example
        for _ in range(MAX_COMPILE_PASSES):
            self(*a, **kw)
            if all(self.last_call_stats.get(k, 0) == 0 for k in WARM_STATS):
                break
        else:
            import warnings
            warnings.warn(
                f"{self!r} did not reach the warm fixed point after "
                f"{MAX_COMPILE_PASSES} passes (last call: "
                f"{self.last_call_stats}); the pipeline is likely "
                "uncacheable (unfingerprintable values / plan_cache=False) "
                "and every call will replan", RuntimeWarning, stacklevel=2)
        if self.ctx.plan_cache_path:
            from repro.core import plan_cache as _pc
            _pc.save(self.ctx.plan_cache_path)
        self.compiled = True
        return self

    def __call__(self, *args, **kwargs):
        """Hot path: capture, cache-hit, split, drive pinned drivers, merge."""
        self._require_fn()
        from repro.core import stage_exec
        with self._lock:
            ctx = self.ctx
            before = dict(ctx.stats)
            traces_before = stage_exec.trace_count()
            _stack().append(ctx)
            try:
                out = self.fn(*args, **kwargs)
                ctx.evaluate()
            finally:
                _stack().pop()
            result = _force(out)
            ctx.graph.prune()
            delta = {k: v - before.get(k, 0)
                     for k, v in ctx.stats.items() if v != before.get(k, 0)}
            delta["jit_traces"] = stage_exec.trace_count() - traces_before
            self.last_call_stats = delta
            return result

    # -- introspection -------------------------------------------------------
    @property
    def plan_entry(self):
        """The resolved plan-cache entry (after ``lower``/first call)."""
        return self._entry if self._entry is not None else self.ctx._plan_entry

    @property
    def stats(self):
        """Cumulative context stats across every call of this pipeline."""
        return self.ctx.stats

    def warm(self) -> bool:
        """True when the most recent call ran at pinned steady state."""
        return bool(self.last_call_stats) and all(
            self.last_call_stats.get(k, 0) == 0 for k in WARM_STATS)

    def describe(self) -> str:
        e = self.plan_entry
        if e is None:
            return f"Pipeline({getattr(self.fn, '__name__', self.fn)}): not lowered"
        return (f"Pipeline({getattr(self.fn, '__name__', self.fn)}): "
                f"{len(e.stage_templates)} stage(s), "
                f"tuned_batch={dict(e.tuned_batch)}, "
                f"chosen_exec={dict(e.chosen_exec)}, "
                f"executables={sorted(e.exec_table())}")

    def _require_fn(self) -> None:
        if self.fn is None:
            raise TypeError(
                "this Pipeline wraps no function (session-scope pipeline); "
                "construct it as mozart.pipeline(fn, ...)")

    def __repr__(self) -> str:
        name = getattr(self.fn, "__name__", None) or "session"
        state = "compiled" if self.compiled else (
            "lowered" if self._example is not None else "fresh")
        return f"<mozart.Pipeline {name} [{state}]>"


def pipeline(fn: Callable | None = None, **config):
    """Build a :class:`Pipeline` over ``fn``; usable as a decorator.

        p = mozart.pipeline(my_fn, executor="auto")

        @mozart.pipeline(executor="scan", plan_cache_path="plans.json")
        def my_fn(x, y): ...

    ``config`` accepts every ``mozart.session`` knob (executor, chip, mesh,
    batch_elements, plan_cache_path, ...).
    """
    if fn is None:
        return lambda f: Pipeline(f, **config)
    return Pipeline(fn, **config)
