"""The AOT pipeline API: ``mozart.pipeline`` — trace → plan → compile → call.

``mozart.session`` scopes *configuration* and evaluates whatever lazy graph
the enclosed code happens to build; every knob of the runtime is re-resolved
per evaluation.  For serving-shaped workloads the pipeline is fixed and the
per-call budget is tiny, so this module provides the ahead-of-time analogue
of ``jax.jit``'s ``lower``/``compile`` protocol over a whole Mozart program:

    p = mozart.pipeline(fn, executor="auto", plan_cache_path="plans.json")
    p.lower(x, y)        # build the dataflow graph once, resolve a PlanEntry
    p.compile()          # pin batches, executors AND compiled executables
    out = p(x, y)        # hot path: split -> drive pinned drivers -> merge

* ``lower(*args)`` traces ``fn`` lazily (nothing executes), fingerprints the
  captured graph and resolves its plan-cache entry — planning happens here,
  never on the hot path.
* ``compile()`` runs the pipeline on the lowered example until it reaches a
  fixed point: the chunk-size tuner has pinned, ``auto`` has measured and
  pinned per-stage executors, and every per-stage compiled executable (the
  fused/scan jitted drivers, Pallas launchers, ``shard_map`` closures) is
  built and pinned into the plan entry's executable table.  Executables are
  keyed by stage POSITION (``Stage.ckey``), not per-call node ids, so they
  are reused verbatim by later calls.
* ``__call__`` is the steady-state path: re-capture the (cheap, Python-level)
  graph, hit the plan cache, split inputs, drive the pinned executables and
  merge — zero planner calls and zero jit retraces, asserted via
  ``stage_exec.trace_count()`` deltas in ``last_call_stats["jit_traces"]``.

``mozart.session`` itself is reimplemented on top of this class: a session is
an anonymous Pipeline's ``scope()`` (see ``runtime.session``), so both entry
points share one lifecycle and one cache.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable

import jax

from repro.core import resilience
from repro.core.future import Future
from repro.core.runtime import MozartContext, _stack

#: compile() runs at most this many passes while converging to the pinned
#: steady state (plan -> measure/tune -> compile real shapes -> quiescent).
MAX_COMPILE_PASSES = 6

#: per-call counters that must all be zero for a call to count as "warm".
WARM_STATS = ("planner_calls", "autotuned_stages", "auto_measured_stages",
              "jit_traces")


def _force(out: Any) -> Any:
    """Materialize every Future in a (possibly nested) return value."""
    if isinstance(out, Future):
        return out.value
    if isinstance(out, (list, tuple)):
        forced = [_force(o) for o in out]
        if hasattr(out, "_fields"):              # namedtuple
            return type(out)(*forced)
        return type(out)(forced)
    if isinstance(out, dict):
        return {k: _force(v) for k, v in out.items()}
    return out


#: sentinel: the fast path declined this call (shape/alias/value mismatch).
_NO_FAST = object()


@dataclasses.dataclass
class _FastReplay:
    """Retained capture for the bound-arguments fast path.

    When the wrapped fn is marked *arg-transparent* — its argument arrays
    flow into annotated calls unmodified and the captured graph's structure
    does not depend on argument values — re-capturing the graph and
    re-fingerprinting it per call buys nothing: the plan-cache hit is
    foregone, and the SAME node set is re-executed with this call's arrays
    rebound in place.  Built once after ``compile()``; any call whose
    argument treedef, array shapes/dtypes, alias pattern or non-array values
    diverge from the example falls back to the full capture path."""

    pending: list                        # retained (pinned) Node objects
    stages: list                         # their instantiated Stage objects
    entry: Any                           # resolved plan-cache entry (or None)
    handoff: Any                         # handoff decisions used at build
    out: Any                             # fn's return structure (holds Futures)
    treedef: Any                         # example (args, kwargs) treedef
    leaf_specs: list                     # per-leaf ("arr", shape, dtype) | ("val", v)
    alias_sig: tuple                     # first-occurrence index per array leaf
    node_bindings: list                  # (node index, argname, leaf slot)
    input_bindings: list                 # (stage index, input key, leaf slot)


def _leaf_spec(l: Any):
    if hasattr(l, "shape") and hasattr(l, "dtype"):
        return ("arr", tuple(l.shape), str(l.dtype))
    return ("val", l)


def _alias_sig(leaves: list) -> tuple:
    first: dict[int, int] = {}
    return tuple(first.setdefault(id(l), j) for j, l in enumerate(leaves)
                 if hasattr(l, "shape"))


class Pipeline:
    """An ahead-of-time-compilable Mozart program (see module docstring)."""

    def __init__(self, fn: Callable | None, **config):
        self.fn = fn
        #: user promise: argument arrays reach annotated calls unmodified and
        #: graph structure is value-independent -> warm calls may skip graph
        #: capture + fingerprinting entirely (the bound-arguments fast path).
        self.arg_transparent = bool(config.pop("arg_transparent", False))
        self.ctx = MozartContext(**config)
        self._lock = threading.RLock()
        self._example: tuple | None = None       # (args, kwargs) from lower()
        self._entry = None                       # resolved plan_cache.PlanEntry
        self._n_stages: int | None = None
        self._fast: _FastReplay | None = None
        self.compiled = False
        # stat deltas of the most recent ``__call__`` (includes
        # ``jit_traces``, the stage_exec trace-counter delta).  Private:
        # read through the ``last_call_stats`` property (snapshot under the
        # lock) or, for concurrent callers, atomically via
        # ``call_with_stats()``.
        self._last_call_stats: dict[str, int] = {}
        #: bucket label -> resolved PlanEntry, one per ``compile(bucket=...)``
        #: (serving: a (kind, batch, length) bucket per pinned shape).
        self._buckets: dict[tuple, Any] = {}

    # -- session compatibility ----------------------------------------------
    @contextlib.contextmanager
    def scope(self):
        """Enter this pipeline's context as the ambient Mozart scope.

        ``mozart.session(**cfg)`` is exactly ``Pipeline(None, **cfg).scope()``:
        annotated calls made inside register against this pipeline's context,
        evaluation is flushed at scope exit, and (when configured) plans are
        persisted."""
        ctx = self.ctx
        _stack().append(ctx)
        try:
            yield ctx
            ctx.evaluate()                       # flush at scope exit
            if ctx.plan_cache_path:
                from repro.core import plan_cache as _pc
                _pc.save(ctx.plan_cache_path)    # persist plans + decisions
        finally:
            _stack().pop()

    # -- AOT lifecycle -------------------------------------------------------
    def lower(self, *args, **kwargs) -> "Pipeline":
        """Trace ``fn`` into a dataflow graph and resolve its plan entry.

        Nothing executes: the captured nodes are planned (or matched against
        the plan cache) and then discarded.  The arguments become the example
        ``compile()`` specializes to."""
        self._require_fn()
        with self._lock:
            ctx = self.ctx
            _stack().append(ctx)
            try:
                ctx.stats["graph_captures"] += 1
                out = self.fn(*args, **kwargs)
            finally:
                _stack().pop()
            pending = ctx.graph.pending()
            entry = None
            if pending:
                from repro.core.plan_cache import lookup_or_plan
                stages, entry = lookup_or_plan(pending, ctx.graph, ctx)
                self._n_stages = len(stages)
            # lower never executes: drop the traced nodes (their Futures die
            # with `out`) so they cannot leak into the next evaluation.
            for n in pending:
                n.done = True
            del out
            ctx.graph.prune()
            self._example = (args, kwargs)
            self._entry = entry
            return self

    def compile(self, *args, bucket: tuple | None = None, **kwargs) -> "Pipeline":
        """Drive the pipeline to its pinned steady state.

        Runs the lowered example repeatedly (bounded by
        ``MAX_COMPILE_PASSES``) until a pass performs zero planner calls,
        zero tuning/measurement runs and zero jit traces — at which point
        every chunk size, executor choice and compiled executable is pinned
        and subsequent ``__call__``s are pure split/drive/merge.

        ``bucket`` labels the plan entry this example resolves to (e.g. a
        serving scheduler's ``("prefill", batch, length)`` shape bucket) and
        records it in ``self.buckets``.  One pipeline may pin many buckets:
        each distinct example shape fingerprints to its own plan entry, so
        ``compile(ex_a, bucket=A); compile(ex_b, bucket=B)`` leaves both
        executables pinned and every warm call replays whichever bucket the
        call's shapes match — no retrace when occupancy moves between
        buckets."""
        self._require_fn()
        if args or kwargs:
            self._example = (args, kwargs)
        if self._example is None:
            raise ValueError(
                "compile() needs example arguments: call p.lower(*args) "
                "first or pass them directly: p.compile(*args)")
        a, kw = self._example
        for _ in range(MAX_COMPILE_PASSES):
            self(*a, **kw)
            if all(self.last_call_stats.get(k, 0) == 0 for k in WARM_STATS):
                break
        else:
            import warnings
            warnings.warn(
                f"{self!r} did not reach the warm fixed point after "
                f"{MAX_COMPILE_PASSES} passes (last call: "
                f"{self.last_call_stats}); the pipeline is likely "
                "uncacheable (unfingerprintable values / plan_cache=False) "
                "and every call will replan", RuntimeWarning, stacklevel=2)
        if bucket is not None:
            # Resolve this example's plan entry (cache hit after the warm
            # loop above) and stamp the bucket label on it.
            self.lower(*a, **kw)
            entry = self._entry
            if entry is not None:
                with entry._lock:
                    entry.bucket = tuple(bucket)
            with self._lock:
                self._buckets[tuple(bucket)] = entry
        if self.ctx.plan_cache_path:
            from repro.core import plan_cache as _pc
            _pc.save(self.ctx.plan_cache_path)
        self.compiled = True
        return self

    def __call__(self, *args, **kwargs):
        """Hot path: capture, cache-hit, split, drive pinned drivers, merge.

        With ``arg_transparent=True`` and a completed ``compile()``, warm
        calls skip even the capture: the retained node set is re-executed
        with this call's arrays rebound (``_FastReplay``) — zero graph
        captures, zero fingerprints, zero planner calls, zero retraces."""
        self._require_fn()
        from repro.core import stage_exec
        with self._lock:
            ctx = self.ctx
            before = dict(ctx.stats)
            traces_before = stage_exec.trace_count()
            result = _NO_FAST
            if self._fast is not None:
                result = self._fast_call(args, kwargs)
            if result is _NO_FAST:
                _stack().append(ctx)
                try:
                    ctx.stats["graph_captures"] += 1
                    out = self.fn(*args, **kwargs)
                    if (self.arg_transparent and self.compiled
                            and self._fast is None):
                        result = self._build_fast(out, args, kwargs)
                    if result is _NO_FAST:
                        ctx.evaluate()
                        result = _force(out)
                finally:
                    _stack().pop()
                ctx.graph.prune()
            delta = {k: v - before.get(k, 0)
                     for k, v in ctx.stats.items() if v != before.get(k, 0)}
            delta["jit_traces"] = stage_exec.trace_count() - traces_before
            self._last_call_stats = delta
            return result

    def call_with_stats(self, *args, **kwargs):
        """``(result, stats_delta)`` for one call, atomically.

        Concurrent callers reading ``last_call_stats`` after ``__call__``
        can observe another call's delta; this holds the pipeline lock
        across call + read so each caller gets exactly its own delta (the
        serving scheduler's per-step retrace accounting relies on this)."""
        with self._lock:
            result = self(*args, **kwargs)
            return result, dict(self._last_call_stats)

    # -- bound-arguments fast path (arg_transparent, ROADMAP follow-up) ------
    def _build_fast(self, out, args, kwargs):
        """One instrumented execution that RETAINS the captured node set.

        Runs inside the capture scope.  Returns the forced result, or
        ``_NO_FAST`` when the pipeline's bindings cannot be proven
        re-executable (an argument array never reaches a node's bound
        arguments, or is bound to a static parameter) — in which case the
        caller falls through to the normal evaluate path and the fast path
        stays disabled."""
        from repro.core.graph import NodeRef
        from repro.core.plan_cache import lookup_or_plan
        from repro.core.stage_exec import get_executor

        ctx = self.ctx
        pending = ctx.graph.pending()
        if not pending:
            return _NO_FAST
        pending_ids = {n.id for n in pending}
        for n in pending:
            for v in n.bound.values():
                if isinstance(v, NodeRef) and v.node_id not in pending_ids:
                    # The fn forces evaluation internally (mozart.evaluate()/
                    # Future access): the retained set would reference DONE
                    # producers from the build call — pruned later (KeyError)
                    # or silently stale on replay.  Not replayable.
                    return _NO_FAST
        stages, entry = lookup_or_plan(pending, ctx.graph, ctx)
        # The static rewrite pass (inside lookup_or_plan) may have retired
        # nodes (dead-elimination, CSE) and reordered the rest; the retained
        # replay set is the REWRITTEN live graph — the stages reference it.
        live = ctx.graph.pending()
        if not live:
            return _NO_FAST              # everything rewritten away
        leaves, treedef = jax.tree_util.tree_flatten((args, kwargs))
        slot_of = {id(l): j for j, l in enumerate(leaves)}
        node_bindings, bound_ids = [], set()
        for idx, n in enumerate(live):
            for name, v in n.bound.items():
                if isinstance(v, NodeRef) or id(v) not in slot_of:
                    continue
                if name in n.fn.sa.static:
                    return _NO_FAST      # value baked into compiled plans
                node_bindings.append((idx, name, slot_of[id(v)]))
                bound_ids.add(id(v))
        for l in leaves:
            if hasattr(l, "shape") and id(l) not in bound_ids:
                return _NO_FAST          # array arg never reaches a node
        input_bindings = []
        for s_idx, s in enumerate(stages):
            for key, si in s.inputs.items():
                if not isinstance(si.value, NodeRef) and id(si.value) in slot_of:
                    input_bindings.append((s_idx, key, slot_of[id(si.value)]))
        from repro.core.handoff import resolve_decisions
        from repro.core.stage_exec import counter_scope
        ho = resolve_decisions(ctx, entry, stages)
        prev = (ctx._plan_entry, ctx._handoff)
        ctx._plan_entry, ctx._handoff = entry, ho
        try:
            with counter_scope(ctx.counters):
                for s in stages:
                    resilience.run_stage(ctx.executor, s, ctx.graph, ctx)
        finally:
            ctx._plan_entry, ctx._handoff = prev
        for n in live:
            n.pinned = True              # survive prune(): re-executed per call
        self._fast = _FastReplay(
            pending=live, stages=stages, entry=entry, handoff=ho, out=out,
            treedef=treedef, leaf_specs=[_leaf_spec(l) for l in leaves],
            alias_sig=_alias_sig(leaves), node_bindings=node_bindings,
            input_bindings=input_bindings)
        return _force(out)

    def _fast_call(self, args, kwargs):
        """Re-execute the retained node set with this call's arrays rebound.

        Validates treedef, per-leaf shapes/dtypes, the identity-alias
        pattern of array leaves and equality of non-array leaves against the
        build-time example; any divergence returns ``_NO_FAST`` (full
        capture handles the call, the retained replay stays valid)."""
        from repro.core.stage_exec import counter_scope, get_executor
        f = self._fast
        leaves, treedef = jax.tree_util.tree_flatten((args, kwargs))
        if treedef != f.treedef or _alias_sig(leaves) != f.alias_sig:
            return _NO_FAST
        for l, spec in zip(leaves, f.leaf_specs):
            if spec[0] == "arr":
                if (not hasattr(l, "shape")
                        or (tuple(l.shape), str(l.dtype)) != spec[1:]):
                    return _NO_FAST
            else:
                try:
                    if not bool(l == spec[1]):
                        return _NO_FAST  # non-array args are specialized
                except resilience.PROBE_ERRORS as e:
                    # incomparable leaf (ambiguous array truth, custom
                    # container): full capture handles the call
                    resilience.note_swallowed("fast_leaf_compare", e)
                    return _NO_FAST
        ctx = self.ctx
        for idx, name, slot in f.node_bindings:
            f.pending[idx].bound[name] = leaves[slot]
        for s_idx, key, slot in f.input_bindings:
            f.stages[s_idx].inputs[key].value = leaves[slot]
        for n in f.pending:
            n.result = None
            n.done = False
        prev = (ctx._plan_entry, ctx._handoff)
        ctx._plan_entry, ctx._handoff = f.entry, f.handoff
        try:
            with counter_scope(ctx.counters):
                for s in f.stages:
                    resilience.run_stage(ctx.executor, s, ctx.graph, ctx)
        finally:
            ctx._plan_entry, ctx._handoff = prev
        ctx.stats["fast_path_calls"] += 1
        return _force(f.out)

    # -- introspection -------------------------------------------------------
    @property
    def plan_entry(self):
        """The resolved plan-cache entry (after ``lower``/first call)."""
        return self._entry if self._entry is not None else self.ctx._plan_entry

    @property
    def last_call_stats(self) -> dict:
        """Snapshot of the most recent call's stat deltas (lock-consistent).

        Under concurrency this tells you about *some* recent call, not
        necessarily yours — use ``call_with_stats()`` to pair a call with
        its own delta."""
        with self._lock:
            return dict(self._last_call_stats)

    @last_call_stats.setter
    def last_call_stats(self, value: dict) -> None:
        with self._lock:
            self._last_call_stats = dict(value)

    @property
    def buckets(self) -> dict:
        """Bucket label -> pinned plan entry, from ``compile(bucket=...)``."""
        with self._lock:
            return dict(self._buckets)

    @property
    def stats(self):
        """Cumulative context stats across every call of this pipeline."""
        return self.ctx.stats

    def warm(self) -> bool:
        """True when the most recent call ran at pinned steady state."""
        stats = self.last_call_stats          # one lock-consistent snapshot
        return bool(stats) and all(stats.get(k, 0) == 0 for k in WARM_STATS)

    def describe(self) -> str:
        e = self.plan_entry
        if e is None:
            return f"Pipeline({getattr(self.fn, '__name__', self.fn)}): not lowered"
        return (f"Pipeline({getattr(self.fn, '__name__', self.fn)}): "
                f"{len(e.stage_templates)} stage(s), "
                f"tuned_batch={dict(e.tuned_batch)}, "
                f"chosen_exec={dict(e.chosen_exec)}, "
                f"executables={sorted(e.exec_table())}")

    def verify(self, *args, **kwargs):
        """Static analysis of this pipeline (never executes it): trace with
        the given example arguments — or the ones ``lower()`` saw — and run
        the Mozart dataflow analyzer (``repro.core.analysis``, MZ2xx codes)
        under this Pipeline's configuration.  Returns an
        ``analysis.Report``."""
        from repro.core import analysis
        self._require_fn()
        if not args and not kwargs and self._example is not None:
            args, kwargs = self._example
        c = self.ctx
        return analysis.verify_pipeline(
            lambda *a: self.fn(*a, **kwargs), *args,
            executor=c.executor, chip=c.chip, mesh=c.mesh,
            batch_elements=c.batch_elements, inner_executor=c.inner_executor,
            pipeline=c.pipeline, handoff=c.handoff, rewrite=c.rewrite)

    def _require_fn(self) -> None:
        if self.fn is None:
            raise TypeError(
                "this Pipeline wraps no function (session-scope pipeline); "
                "construct it as mozart.pipeline(fn, ...)")

    def __repr__(self) -> str:
        name = getattr(self.fn, "__name__", None) or "session"
        state = "compiled" if self.compiled else (
            "lowered" if self._example is not None else "fresh")
        return f"<mozart.Pipeline {name} [{state}]>"


def pipeline(fn: Callable | None = None, **config):
    """Build a :class:`Pipeline` over ``fn``; usable as a decorator.

        p = mozart.pipeline(my_fn, executor="auto")

        @mozart.pipeline(executor="scan", plan_cache_path="plans.json")
        def my_fn(x, y): ...

    ``config`` accepts every ``mozart.session`` knob (executor, chip, mesh,
    batch_elements, plan_cache_path, ...).
    """
    if fn is None:
        return lambda f: Pipeline(f, **config)
    return Pipeline(fn, **config)
