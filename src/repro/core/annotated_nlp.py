"""The "spaCy" integration (paper §7): NLP pipeline over token minibatches.

The paper's spaCy split type uses the library's own minibatch tokenizer to
split a corpus; any function over text pipelines/parallelizes through it.
Our analogue: a corpus is a (docs, max_len) padded token-id matrix + length
vector; ``CorpusSplit`` splits by documents (the minibatch dimension), and
the "library" ops are jit-compiled per-token taggers / feature extractors —
unmodified functions, SAs only (the paper integrated spaCy with 20 LoC;
ours is comparable).
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import split_types as st
from repro.core.annotation import annotate


class Corpus:
    """Padded token-id matrix (docs, max_len) + per-doc lengths."""

    def __init__(self, tokens, lengths):
        self.tokens = tokens          # (D, L) int32
        self.lengths = lengths        # (D,) int32

    @property
    def n_docs(self) -> int:
        return int(self.tokens.shape[0])

    def mozart_fingerprint(self) -> tuple:
        """Plan-cache identity: token matrix geometry, never values."""
        return ("corpus", tuple(self.tokens.shape), str(self.tokens.dtype),
                tuple(self.lengths.shape), str(self.lengths.dtype))


def _corpus_flatten(c: Corpus):
    return [c.tokens, c.lengths], None


jax.tree_util.register_pytree_node(
    Corpus, _corpus_flatten, lambda _, xs: Corpus(*xs))


class CorpusSplit(st.SplitType):
    """Split a corpus by documents (the paper's minibatch split)."""

    name = "CorpusSplit"

    def __init__(self, n_docs: int):
        super().__init__(int(n_docs))
        self.n_docs = int(n_docs)

    def info(self, value: Corpus) -> st.RuntimeInfo:
        eb = int(value.tokens.shape[1]) * 4 + 4
        return st.RuntimeInfo(num_elements=self.n_docs, elem_bytes=eb)

    def split(self, value: Corpus, start: int, end: int) -> Corpus:
        return Corpus(value.tokens[start:end], value.lengths[start:end])

    def merge(self, pieces: Sequence[Corpus]) -> Corpus:
        st._require_pieces(pieces, self.name)
        if len(pieces) == 1:
            return pieces[0]
        return Corpus(jnp.concatenate([p.tokens for p in pieces]),
                      jnp.concatenate([p.lengths for p in pieces]))


st.register_default_split(Corpus, lambda c: CorpusSplit(c.n_docs))


class CorpusRows(st.SplitSpec):
    def construct(self, value, bound, generics):
        if value is None:
            return st.UnknownSplit()
        n = value.n_docs if isinstance(value, Corpus) else int(
            jax.tree_util.tree_leaves(value)[0].shape[0])
        return CorpusSplit(n)


__all_ops__: dict[str, Any] = {}


def _reg(name, fn):
    __all_ops__[name] = fn
    globals()[name] = fn
    return fn


# -- the "library": unmodified jit-able NLP functions -------------------------

def _pos_tag(corpus: Corpus, emb, head):
    """Per-token classification with a preloaded model (emb (V,d), head (d,T))."""
    x = emb[corpus.tokens]                                 # (D, L, d)
    logits = jnp.einsum("dlk,kt->dlt", x, head)
    tags = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    mask = jnp.arange(corpus.tokens.shape[1])[None] < corpus.lengths[:, None]
    return jnp.where(mask, tags, -1)


_reg("pos_tag", annotate(_pos_tag, name="pos_tag",
                         corpus=CorpusRows(), emb=st._, head=st._,
                         ret=st.Along(0)))


def _token_counts(corpus: Corpus):
    """Corpus-level statistics: valid-token count (a reduction)."""
    mask = jnp.arange(corpus.tokens.shape[1])[None] < corpus.lengths[:, None]
    return jnp.sum(mask.astype(jnp.int32))


_reg("token_counts", annotate(_token_counts, name="token_counts",
                              corpus=CorpusRows(), ret=st.Reduce("add")))


def _normalize_case(corpus: Corpus, vocab_size: int):
    """Stub lemmatizer: fold the 'uppercase' half of the vocab down."""
    half = vocab_size // 2
    toks = jnp.where(corpus.tokens >= half, corpus.tokens - half, corpus.tokens)
    return Corpus(toks, corpus.lengths)


class _SameCorpus(st.SplitSpec):
    def construct(self, value, bound, generics):
        if "S" not in generics:
            generics["S"] = st.GenericVar("S")
        return generics["S"]


_reg("normalize_case", annotate(_normalize_case, name="normalize_case",
                                static=("vocab_size",),
                                corpus=_SameCorpus(), ret=_SameCorpus()))


def make_corpus(n_docs: int, max_len: int = 64, vocab: int = 1000,
                seed: int = 0) -> Corpus:
    r = np.random.RandomState(seed)
    lengths = r.randint(4, max_len, n_docs).astype(np.int32)
    toks = r.randint(0, vocab, (n_docs, max_len)).astype(np.int32)
    return Corpus(jnp.asarray(toks), jnp.asarray(lengths))


def __probe_examples__(n: int = 12) -> dict[str, Any]:
    """Tiny concrete inputs per op for the annotation contract checker."""
    vocab, d, tags = 50, 4, 5
    corpus = make_corpus(n, max_len=8, vocab=vocab, seed=0)
    r = np.random.RandomState(1)
    emb = jnp.asarray(r.standard_normal((vocab, d)).astype(np.float32))
    head = jnp.asarray(r.standard_normal((d, tags)).astype(np.float32))
    return {
        "pos_tag": {"corpus": corpus, "emb": emb, "head": head},
        "token_counts": {"corpus": corpus},
        "normalize_case": {"corpus": corpus, "vocab_size": vocab},
    }
