"""repro.core — Split Annotations (Mozart) in JAX.

The paper's primary contribution: split types + split annotations over
unmodified functions, lazy dataflow capture (libmozart), the stage planner,
and the pipelined/parallel executors (Mozart).

Public API:
    mozart.session / configure / evaluate      — runtime scope
    mozart.pipeline / Pipeline                 — AOT lower/compile/call
    splittable / annotate                      — attach SAs to functions
    split types & specs                        — Along, Broadcast(_), Generic,
                                                 Unknown, Reduce, Pytree, Custom
"""

from repro.core import runtime as mozart
from repro.core.analysis import CODES, Diagnostic, Report, verify
from repro.core.annotation import SA, AnnotatedFn, annotate, splittable
from repro.core.future import Future
from repro.core.pipeline import Pipeline
from repro.core.split_types import (
    BROADCAST,
    Along,
    ArraySplit,
    Broadcast,
    Concat,
    ConcatSplit,
    Custom,
    Generic,
    GenericVar,
    Pytree,
    PytreeSplit,
    Reduce,
    ReduceSplit,
    RuntimeInfo,
    ScalarSplit,
    SplitSpec,
    SplitType,
    TypeEnv,
    UnificationError,
    Unknown,
    UnknownSplit,
    default_split_type,
    _,
)
from repro.core.stage_exec import (
    ChunkStream,
    StageExecutor,
    available_executors,
    bytes_materialized,
    get_executor,
    register_executor,
)

__all__ = [
    "mozart", "SA", "AnnotatedFn", "annotate", "splittable", "Future", "Pipeline",
    "BROADCAST", "Along", "ArraySplit", "Broadcast", "Concat", "ConcatSplit",
    "Custom", "Generic", "GenericVar", "Pytree", "PytreeSplit", "Reduce",
    "ReduceSplit", "RuntimeInfo", "ScalarSplit", "SplitSpec", "SplitType",
    "TypeEnv", "UnificationError", "Unknown", "UnknownSplit",
    "default_split_type", "_",
    "ChunkStream", "StageExecutor", "available_executors", "bytes_materialized",
    "get_executor", "register_executor",
    "CODES", "Diagnostic", "Report", "verify",
]
