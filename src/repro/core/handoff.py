"""Cross-stage chunk handoff analysis (the merge→re-split eliminator).

The paper's central claim (§3–§5) is that cache-sized chunks pipelined
across library functions beat materializing every intermediate.  Within one
stage Mozart already delivers that; at every stage *boundary*, however, the
producer merges its partials into a full value and the consumer re-splits it
— an O(data) round trip per boundary.  This pass walks the planned stages
and decides, per producer→consumer edge, whether the consumer can ingest the
producer's chunk list directly:

* the producer's resolved output split type must ``can_handoff`` the
  consumer's resolved input split type (same concrete geometry and
  iteration axis — ``core/split_types.py``), and
* a node is left unmerged (:class:`~repro.core.stage_exec.ChunkStream`)
  only when EVERY in-plan consumer edge accepts the grid; values that any
  consumer must see whole (broadcast args, whole-array sources, axis
  changes) merge exactly as before.

Nodes with no in-plan consumer at all (pure pipeline outputs) also stream:
their merge happens lazily when the ``Future`` is observed, and not at all
if it never is.  Grids that disagree between producer and consumer convert
through ``SplitType.rechunk`` (integer-multiple regroup — at most one copy
instead of the merge+re-split two).

Fresh-output (``ConcatSplit``) producers hand off to concrete
``ArraySplit`` consumers on the same axis: piece sizes are unknowable here,
so the analysis records *permission* plus the conversion point
(``StageHandoff.convert_in``) and the runtime derives the concrete grid
from the chunk buffers (``stage_exec.adapt_stream``), merging instead when
they do not tile the consumer's geometry.

Donation points (``last_use``) are vetoed at plan time for in-plan
producers whose ``Future`` is alive during analysis — donating an
observable stream could only ever ship defensive copies, and a late merge
after a real donation is the ``stage_exec.DONATED_MERGE_ERROR`` failure
mode; the runtime raise stays as the backstop.

The analysis is pure and structural — a function of the stage templates
only — so its result is recorded on the plan-cache entry
(``PlanEntry.handoff``) and replayed by warm calls with zero analysis; it is
also persisted (``plan_cache.save/load``), so ``MOZART_PLAN_CACHE`` warm
starts stream from the first call.

Cross-*evaluation* edges (a pending stage consuming a ``done`` node from an
earlier ``evaluate()`` — the serve-decode shape) cannot be decided
structurally: the producer ran under a different plan, so the entry records
the ingest as *permitted* and ``stage_exec.resolve_stage_inputs`` re-checks
the concrete stream's grid at run time (an O(1) type comparison, not a
planner call).
"""

from __future__ import annotations

import dataclasses

from repro.core import split_types as st
from repro.core.graph import NodeRef
from repro.core.planner import Stage


@dataclasses.dataclass(frozen=True)
class StageHandoff:
    """Handoff decisions for one stage (positions, never node/value ids)."""

    #: stage-local node positions whose output stays a ChunkStream.
    stream_out: frozenset
    #: stage input positions permitted to ingest a producer's chunk list.
    stream_in: frozenset
    #: input positions where this stage is the LAST in-plan consumer of the
    #: handed-off stream — chunk buffers may be donated to the driver there
    #: (re-checked against ``future_alive`` at run time).
    last_use: frozenset
    #: input positions PERMITTED to convert a producer's stream onto the
    #: consumer's grid (the ConcatSplit→ArraySplit rule): in-plan edges
    #: whose producer type is ConcatSplit, plus cross-evaluation ingests
    #: into an ArraySplit consumer (whose producer type is unknowable
    #: here).  ``stage_exec.resolve_stage_inputs`` converts ONLY at these
    #: positions — the decision replays with zero analysis (persisted
    #: schema v3; v2 files migrate with this empty, correct because the
    #: rule postdates them and v2-era plans never streamed fresh outputs).
    convert_in: frozenset = frozenset()

    def to_json(self) -> dict:
        return {"stream_out": sorted(self.stream_out),
                "stream_in": sorted(self.stream_in),
                "last_use": sorted(self.last_use),
                "convert_in": sorted(self.convert_in)}

    @classmethod
    def from_json(cls, d: dict) -> "StageHandoff":
        return cls(stream_out=frozenset(int(p) for p in d["stream_out"]),
                   stream_in=frozenset(int(p) for p in d["stream_in"]),
                   last_use=frozenset(int(p) for p in d["last_use"]),
                   convert_in=frozenset(
                       int(p) for p in d.get("convert_in", ())))


def resolve_decisions(ctx, entry, stages: list[Stage]):
    """Handoff decisions for one evaluation of ``stages``.

    Replays the entry's recorded analysis when present; otherwise analyzes
    fresh and caches the result onto the entry (rekeyed or pre-analysis
    entries), so warm calls never re-derive it.  None when the context has
    handoff disabled.  The single policy point for ``runtime.evaluate`` and
    the Pipeline fast path."""
    if not getattr(ctx, "handoff", True):
        return None
    if entry is not None and entry.handoff is not None:
        return entry.handoff
    ho = analyze(stages)
    if entry is not None:
        entry.handoff = ho
    return ho


def _streamable_out(t: st.SplitType, stage_count: int | None) -> bool:
    """Concrete array-like grids stream; the chunk count of the output must
    ride the stage's iteration grid (guarded via the static shape).
    ConcatSplit (fresh-output) producers stream too: they emit exactly one
    piece per iterated range by construction, so the grid condition holds
    without a count."""
    if isinstance(t, st.ConcatSplit):
        return True
    if not isinstance(t, (st.ArraySplit, st.PytreeSplit)):
        return False
    info_count = t.shape[t.axis] if isinstance(t, st.ArraySplit) and t.shape \
        else (t.length if isinstance(t, st.PytreeSplit) else None)
    return stage_count is None or info_count == stage_count


def _stage_count(stage: Stage) -> int | None:
    for si in stage.inputs.values():
        t = si.split_type
        if isinstance(t, st.ArraySplit) and t.shape:
            return t.shape[t.axis]
        if isinstance(t, st.PytreeSplit):
            return t.length
    return None


def analyze(stages: list[Stage]) -> dict[int, StageHandoff]:
    """Per-stage handoff decisions for one planned evaluation.

    O(edges); runs once per plan-cache MISS (the result is stored on the
    entry) or once per evaluation for uncacheable pipelines.
    """
    # node id -> (producer stage, position) over this plan
    producer: dict[int, tuple[Stage, int]] = {}
    for s in stages:
        for n in s.nodes:
            producer[n.id] = (s, s.pos[n.id])

    # First pass: collect every in-plan edge and whether it accepts the grid.
    accepts: dict[int, list[bool]] = {}            # node id -> per-edge verdicts
    edges: dict[tuple[int, int], int] = {}         # (stage id, input pos) -> node id
    done_edges: dict[tuple[int, int], int] = {}    # cross-evaluation ingests
    convert_edges: set[tuple[int, int]] = set()    # ConcatSplit→ArraySplit
    for s in stages:
        for i, (key, si) in enumerate(s.inputs.items()):
            v = si.value
            if not isinstance(v, NodeRef):
                continue
            prod = producer.get(v.node_id)
            if prod is None:
                # Cross-evaluation edge: the producer already ran.  Permit the
                # ingest when the consumer's grid is a concrete array split;
                # the runtime re-checks the actual stream's type.  ArraySplit
                # consumers additionally permit a grid CONVERSION (the
                # producer's type is unknowable here — it may be a fresh-
                # output ConcatSplit stream from the prior evaluation).
                if isinstance(si.split_type, (st.ArraySplit, st.PytreeSplit)):
                    done_edges[(s.id, i)] = v.node_id
                    if isinstance(si.split_type, st.ArraySplit):
                        convert_edges.add((s.id, i))
                continue
            ps, _pos = prod
            if ps.id == s.id:
                continue                           # self-edge: internal value
            pt = ps.out_types[v.node_id]
            ok = (_streamable_out(pt, _stage_count(ps))
                  and pt.can_handoff(si.split_type)
                  and si.split_type.splittable)
            accepts.setdefault(v.node_id, []).append(ok)
            if ok:
                edges[(s.id, i)] = v.node_id
                if isinstance(pt, st.ConcatSplit):
                    convert_edges.add((s.id, i))

    # A node streams iff every in-plan consumer edge accepts its grid.  Pure
    # outputs (no in-plan consumer) stream too: merge only on observation.
    streamed: set[int] = set()
    for s in stages:
        for n in s.nodes:
            if n.id not in s.escaping:
                continue
            t = s.out_types[n.id]
            if not _streamable_out(t, _stage_count(s)):
                continue
            if all(accepts.get(n.id, [])):
                streamed.add(n.id)

    # Plan-time donation veto: an in-plan producer whose Future is alive at
    # analysis time is OBSERVABLE — donating its buffers could only ever be
    # satisfied with defensive copies, and a late merge after a real
    # donation is the ``stage_exec.DONATED_MERGE_ERROR`` failure mode.  Veto
    # the donation point here so the conflict cannot arise; the runtime
    # raise stays as the backstop.  Cross-evaluation (done-edge) producers
    # are not vetoed: their liveness legitimately varies call-to-call and
    # ``undonatable_stream_keys`` handles them with per-call copies.
    observable = {n.id for s in stages for n in s.nodes if n.future_alive()}

    # Last pending consumer of each handed-off value (the donation point).
    last_consumer: dict[int, tuple[int, int]] = {}
    for (sid, i), nid in list(edges.items()) + list(done_edges.items()):
        if nid in streamed or (sid, i) in done_edges:
            if nid in producer and nid in observable:
                continue                           # plan-time veto
            cur = last_consumer.get(nid)
            if cur is None or sid > cur[0]:
                last_consumer[nid] = (sid, i)

    out: dict[int, StageHandoff] = {}
    for s in stages:
        stream_out = frozenset(
            s.pos[n.id] for n in s.nodes if n.id in streamed)
        stream_in = frozenset(
            i for (sid, i), nid in edges.items()
            if sid == s.id and nid in streamed
        ) | frozenset(i for (sid, i) in done_edges if sid == s.id)
        last_use = frozenset(
            i for nid, (sid, i) in last_consumer.items() if sid == s.id)
        convert_in = frozenset(
            i for (sid, i) in convert_edges
            if sid == s.id and ((sid, i) in done_edges
                                or edges.get((sid, i)) in streamed))
        if stream_out or stream_in:
            out[s.id] = StageHandoff(stream_out, stream_in, last_use,
                                     convert_in)
    return out
