"""Cross-stage chunk handoff analysis (the merge→re-split eliminator).

The paper's central claim (§3–§5) is that cache-sized chunks pipelined
across library functions beat materializing every intermediate.  Within one
stage Mozart already delivers that; at every stage *boundary*, however, the
producer merges its partials into a full value and the consumer re-splits it
— an O(data) round trip per boundary.  This pass walks the planned stages
and decides, per producer→consumer edge, whether the consumer can ingest the
producer's chunk list directly:

* the producer's resolved output split type must ``can_handoff`` the
  consumer's resolved input split type (same concrete geometry and
  iteration axis — ``core/split_types.py``), and
* a node is left unmerged (:class:`~repro.core.stage_exec.ChunkStream`)
  only when EVERY in-plan consumer edge accepts the grid; values that any
  consumer must see whole (broadcast args, whole-array sources, axis
  changes) merge exactly as before.

Nodes with no in-plan consumer at all (pure pipeline outputs) also stream:
their merge happens lazily when the ``Future`` is observed, and not at all
if it never is.  Grids that disagree between producer and consumer convert
through ``SplitType.rechunk`` (integer-multiple regroup — at most one copy
instead of the merge+re-split two).

Fresh-output (``ConcatSplit``) producers hand off to concrete
``ArraySplit`` consumers on the same axis: piece sizes are unknowable here,
so the analysis records *permission* plus the conversion point
(``StageHandoff.convert_in``) and the runtime derives the concrete grid
from the chunk buffers (``stage_exec.adapt_stream``), merging instead when
they do not tile the consumer's geometry.

Donation points (``last_use``) are vetoed at plan time for in-plan
producers whose ``Future`` is alive during analysis — donating an
observable stream could only ever ship defensive copies, and a late merge
after a real donation is the ``stage_exec.DONATED_MERGE_ERROR`` failure
mode; the runtime raise stays as the backstop.

The analysis is pure and structural — a function of the stage templates
only — so its result is recorded on the plan-cache entry
(``PlanEntry.handoff``) and replayed by warm calls with zero analysis; it is
also persisted (``plan_cache.save/load``), so ``MOZART_PLAN_CACHE`` warm
starts stream from the first call.

Cross-*evaluation* edges (a pending stage consuming a ``done`` node from an
earlier ``evaluate()`` — the serve-decode shape) cannot be decided
structurally: the producer ran under a different plan, so the entry records
the ingest as *permitted* and ``stage_exec.resolve_stage_inputs`` re-checks
the concrete stream's grid at run time (an O(1) type comparison, not a
planner call).
"""

from __future__ import annotations

import dataclasses

from repro.core import split_types as st
from repro.core.graph import NodeRef
from repro.core.planner import Stage


@dataclasses.dataclass(frozen=True)
class StageHandoff:
    """Handoff decisions for one stage (positions, never node/value ids)."""

    #: stage-local node positions whose output stays a ChunkStream.
    stream_out: frozenset
    #: stage input positions permitted to ingest a producer's chunk list.
    stream_in: frozenset
    #: input positions where this stage is the LAST in-plan consumer of the
    #: handed-off stream — chunk buffers may be donated to the driver there
    #: (re-checked against ``future_alive`` at run time).
    last_use: frozenset
    #: input positions PERMITTED to convert a producer's stream onto the
    #: consumer's grid (the ConcatSplit→{ArraySplit,PytreeSplit} rules):
    #: in-plan edges whose producer type is ConcatSplit, plus
    #: cross-evaluation ingests into an ArraySplit/PytreeSplit consumer
    #: (whose producer type is unknowable here).
    #: ``stage_exec.resolve_stage_inputs`` converts ONLY at these
    #: positions — the decision replays with zero analysis (persisted
    #: schema v3; v2 files migrate with this empty, correct because the
    #: rule postdates them and v2-era plans never streamed fresh outputs).
    convert_in: frozenset = frozenset()
    #: input positions permitted to ingest a SHARDED-form stream (a
    #: device-resident global array) without gathering it — recorded only
    #: when the plan's executor can place per-shard buffers ("sharded" /
    #: "auto"); the runtime re-checks the concrete mesh and Sharding per
    #: call.  Persisted schema v4; v2/v3 files migrate with this empty
    #: (correct: sharded streams postdate them, so nothing ever produced
    #: one under those plans).
    shard_in: frozenset = frozenset()
    #: input positions that WOULD be ``last_use`` donation points but were
    #: vetoed at plan time because the producer's Future was alive during
    #: analysis.  Recorded so ``resolve_decisions`` can detect when the
    #: veto has gone stale (the producer stopped being observable on later
    #: calls) and re-analyze through the aging path.  Persisted schema v4.
    vetoed: frozenset = frozenset()

    def to_json(self) -> dict:
        return {"stream_out": sorted(self.stream_out),
                "stream_in": sorted(self.stream_in),
                "last_use": sorted(self.last_use),
                "convert_in": sorted(self.convert_in),
                "shard_in": sorted(self.shard_in),
                "vetoed": sorted(self.vetoed)}

    @classmethod
    def from_json(cls, d: dict) -> "StageHandoff":
        return cls(stream_out=frozenset(int(p) for p in d["stream_out"]),
                   stream_in=frozenset(int(p) for p in d["stream_in"]),
                   last_use=frozenset(int(p) for p in d["last_use"]),
                   convert_in=frozenset(
                       int(p) for p in d.get("convert_in", ())),
                   shard_in=frozenset(
                       int(p) for p in d.get("shard_in", ())),
                   vetoed=frozenset(
                       int(p) for p in d.get("vetoed", ())))


#: consecutive stale observations before a recorded handoff re-analyzes —
#: the same hysteresis discipline as ``cost_model.AutoExecutor``'s exec_meta
#: aging: one flap is noise (liveness legitimately varies call-to-call), a
#: persistent disagreement means the plan-time donation vetoes no longer
#: describe this workload.
STALE_THRESHOLD = 2


def _liveness_stale(ho_map: dict[int, "StageHandoff"],
                    stages: list[Stage]) -> bool:
    """Whether recorded donation decisions disagree with CURRENT liveness.

    Checks only in-plan producers: a ``vetoed`` position whose producer is
    now dead is paying ``donation_copies`` it no longer needs to; a
    ``last_use`` position whose producer is now alive ships defensive
    copies through ``undonatable_stream_keys``.  Cross-evaluation edges are
    skipped — their liveness varies per call by design and the runtime
    copy path handles them (re-analyzing cannot improve them)."""
    nodes = {n.id: n for s in stages for n in s.nodes}
    by_id = {s.id: s for s in stages}
    for sid, ho in ho_map.items():
        s = by_id.get(sid)
        if s is None or not (ho.vetoed or ho.last_use):
            continue
        for i, si in enumerate(s.inputs.values()):
            v = si.value
            if not isinstance(v, NodeRef):
                continue
            n = nodes.get(v.node_id)
            if n is None:
                continue                   # cross-evaluation edge
            if i in ho.vetoed and not n.future_alive():
                return True
            if i in ho.last_use and n.future_alive():
                return True
    return False


def decisions_fresh(ho_map: dict[int, "StageHandoff"],
                    stages: list[Stage]) -> bool:
    """Whether a recorded decision map still describes ``stages``' current
    Future liveness — the reuse guard for read-only consumers (the verifier's
    ``analyze_dataflow`` cached path), which must not re-derive decisions a
    plan entry already carries unless they have actually gone stale."""
    return not _liveness_stale(ho_map, stages)


def resolve_decisions(ctx, entry, stages: list[Stage]):
    """Handoff decisions for one evaluation of ``stages``.

    Replays the entry's recorded analysis when present; otherwise analyzes
    fresh and caches the result onto the entry (rekeyed or pre-analysis
    entries), so warm calls never re-derive it.  None when the context has
    handoff disabled.  The single policy point for ``runtime.evaluate`` and
    the Pipeline fast path.

    Recorded donation decisions AGE: when current Future liveness disagrees
    with the recorded ``vetoed``/``last_use`` sets for ``STALE_THRESHOLD``
    consecutive calls, the plan re-analyzes against this call's liveness
    (one retrace on the donate-set change, then warm again) — so a producer
    that stops being observed after the first call does not pay defensive
    ``donation_copies`` forever.  The periodic re-analysis tick
    (``MOZART_REANALYZE_EVERY``, ``plan_cache._maybe_reanalyze``) drives the
    same machinery from the other end: it clears ``entry.handoff`` outright,
    so the next call lands on the analyze-fresh path below and plan-time
    donation vetoes get revisited on schedule rather than only on observed
    staleness."""
    if not getattr(ctx, "handoff", True):
        return None
    if entry is not None and entry.handoff is not None:
        if _liveness_stale(entry.handoff, stages):
            entry.ho_age += 1
            if entry.ho_age >= STALE_THRESHOLD:
                with entry._lock:
                    entry.handoff = analyze(
                        stages, getattr(ctx, "executor", None))
                    entry.ho_age = 0
                ctx.stats["handoff_reanalyzed"] += 1
                from repro.core import plan_cache as _pc
                _pc._mark_dirty()
        else:
            entry.ho_age = 0
        return entry.handoff
    ho = analyze(stages, getattr(ctx, "executor", None))
    if entry is not None:
        entry.handoff = ho
    return ho


def _streamable_out(t: st.SplitType, stage_count: int | None) -> bool:
    """Concrete array-like grids stream; the chunk count of the output must
    ride the stage's iteration grid (guarded via the static shape).
    ConcatSplit (fresh-output) producers stream too: they emit exactly one
    piece per iterated range by construction, so the grid condition holds
    without a count."""
    if isinstance(t, st.ConcatSplit):
        return True
    if not isinstance(t, (st.ArraySplit, st.PytreeSplit)):
        return False
    info_count = t.shape[t.axis] if isinstance(t, st.ArraySplit) and t.shape \
        else (t.length if isinstance(t, st.PytreeSplit) else None)
    return stage_count is None or info_count == stage_count


def _stage_count(stage: Stage) -> int | None:
    for si in stage.inputs.values():
        t = si.split_type
        if isinstance(t, st.ArraySplit) and t.shape:
            return t.shape[t.axis]
        if isinstance(t, st.PytreeSplit):
            return t.length
    return None


def edge_fallback_reason(pt: st.SplitType, ct: st.SplitType,
                         stage_count: int | None = None) -> str | None:
    """Why a producer→consumer edge cannot stream, or None when it can.

    The exact conjunction ``analyze`` tests per edge, decomposed so callers
    that need to *explain* a merge+re-split fallback (the MZ203 diagnostic
    in ``core/analysis.py``, the runtime fallback events in
    ``stage_exec.resolve_stage_inputs``) report the failing conjunct
    instead of a bare verdict."""
    if not ct.splittable:
        return f"unsplittable consumer type ({type(ct).__name__})"
    if not _streamable_out(pt, stage_count):
        if isinstance(pt, (st.ArraySplit, st.PytreeSplit)):
            return ("producer chunk grid does not ride the stage's "
                    "iteration grid (extent {} vs stage count {})".format(
                        pt.shape[pt.axis] if isinstance(pt, st.ArraySplit)
                        else pt.length, stage_count))
        return f"non-streamable producer type ({type(pt).__name__})"
    if not pt.can_handoff(ct):
        pa = getattr(pt, "axis", None)
        ca = getattr(ct, "axis", None)
        if pa is not None and ca is not None and pa != ca:
            return f"axis mismatch (producer axis {pa}, consumer axis {ca})"
        return f"geometry mismatch ({pt} cannot hand off to {ct})"
    return None


def analyze(stages: list[Stage],
            executor: str | None = None) -> dict[int, StageHandoff]:
    """Per-stage handoff decisions for one planned evaluation.

    O(edges); runs once per plan-cache MISS (the result is stored on the
    entry) or once per evaluation for uncacheable pipelines.  ``executor``
    is the context's executor name: sharded-capable executors ("sharded",
    "auto") additionally record which stream ingests may accept a
    SHARDED-form stream (``StageHandoff.shard_in``) — the runtime
    re-checks the concrete mesh and Sharding per call.
    """
    # node id -> (producer stage, position) over this plan
    producer: dict[int, tuple[Stage, int]] = {}
    for s in stages:
        for n in s.nodes:
            producer[n.id] = (s, s.pos[n.id])

    # First pass: collect every in-plan edge and whether it accepts the grid.
    accepts: dict[int, list[bool]] = {}            # node id -> per-edge verdicts
    edges: dict[tuple[int, int], int] = {}         # (stage id, input pos) -> node id
    done_edges: dict[tuple[int, int], int] = {}    # cross-evaluation ingests
    convert_edges: set[tuple[int, int]] = set()    # ConcatSplit→ArraySplit
    for s in stages:
        for i, (_key, si) in enumerate(s.inputs.items()):
            v = si.value
            if not isinstance(v, NodeRef):
                continue
            prod = producer.get(v.node_id)
            if prod is None:
                # Cross-evaluation edge: the producer already ran.  Permit the
                # ingest when the consumer's grid is a concrete array split;
                # the runtime re-checks the actual stream's type.  ArraySplit
                # consumers additionally permit a grid CONVERSION (the
                # producer's type is unknowable here — it may be a fresh-
                # output ConcatSplit stream from the prior evaluation).
                if isinstance(si.split_type, (st.ArraySplit, st.PytreeSplit)):
                    done_edges[(s.id, i)] = v.node_id
                    convert_edges.add((s.id, i))
                continue
            ps, _pos = prod
            if ps.id == s.id:
                continue                           # self-edge: internal value
            pt = ps.out_types[v.node_id]
            ok = edge_fallback_reason(
                pt, si.split_type, _stage_count(ps)) is None
            accepts.setdefault(v.node_id, []).append(ok)
            if ok:
                edges[(s.id, i)] = v.node_id
                if isinstance(pt, st.ConcatSplit):
                    convert_edges.add((s.id, i))

    # A node streams iff every in-plan consumer edge accepts its grid.  Pure
    # outputs (no in-plan consumer) stream too: merge only on observation.
    streamed: set[int] = set()
    for s in stages:
        for n in s.nodes:
            if n.id not in s.escaping:
                continue
            t = s.out_types[n.id]
            if not _streamable_out(t, _stage_count(s)):
                continue
            if all(accepts.get(n.id, [])):
                streamed.add(n.id)

    # Plan-time donation veto: an in-plan producer whose Future is alive at
    # analysis time is OBSERVABLE — donating its buffers could only ever be
    # satisfied with defensive copies, and a late merge after a real
    # donation is the ``stage_exec.DONATED_MERGE_ERROR`` failure mode.  Veto
    # the donation point here so the conflict cannot arise; the runtime
    # raise stays as the backstop.  Vetoed positions are RECORDED (not
    # dropped) so ``resolve_decisions`` can age a veto out once the
    # producer stops being observed.  Cross-evaluation (done-edge)
    # producers are not vetoed: their liveness legitimately varies
    # call-to-call and ``undonatable_stream_keys`` handles them with
    # per-call copies.
    observable = {n.id for s in stages for n in s.nodes if n.future_alive()}

    # Last pending consumer of each handed-off value (the donation point),
    # plus whether that point is plan-time vetoed.
    last_consumer: dict[int, tuple[int, int, bool]] = {}
    for (sid, i), nid in list(edges.items()) + list(done_edges.items()):
        if nid in streamed or (sid, i) in done_edges:
            veto = nid in producer and nid in observable
            cur = last_consumer.get(nid)
            if cur is None or sid > cur[0]:
                last_consumer[nid] = (sid, i, veto)

    # Sharded-capable executors may pass SHARDED-form streams through any
    # permitted ingest; everything else must gather first (shard_in empty).
    shard_exec = executor in ("sharded", "auto")

    out: dict[int, StageHandoff] = {}
    for s in stages:
        stream_out = frozenset(
            s.pos[n.id] for n in s.nodes if n.id in streamed)
        stream_in = frozenset(
            i for (sid, i), nid in edges.items()
            if sid == s.id and nid in streamed
        ) | frozenset(i for (sid, i) in done_edges if sid == s.id)
        last_use = frozenset(
            i for nid, (sid, i, veto) in last_consumer.items()
            if sid == s.id and not veto)
        vetoed = frozenset(
            i for nid, (sid, i, veto) in last_consumer.items()
            if sid == s.id and veto)
        convert_in = frozenset(
            i for (sid, i) in convert_edges
            if sid == s.id and ((sid, i) in done_edges
                                or edges.get((sid, i)) in streamed))
        shard_in = stream_in if shard_exec else frozenset()
        if stream_out or stream_in:
            out[s.id] = StageHandoff(stream_out, stream_in, last_use,
                                     convert_in, shard_in, vetoed)
    return out
