"""Built-in Mozart executor strategies (paper §5.2) as ``StageExecutor``s.

Per stage: (1) discover runtime parameters — the batch size is chosen so one
batch of *every* live pipeline value fits in fast memory (L2 on the paper's
CPUs, VMEM on our TPU target), or taken from the plan cache's auto-tuner;
(2) split inputs and drive each batch through the whole function chain;
(3) merge partial results associatively.

Strategies registered here (see ``core/stage_exec.py`` for the registry):

* ``"eager"``      — no splitting: each function runs whole.  This is the
                     un-annotated library baseline.
* ``"pipelined"``  — paper-faithful: a Python driver loop calls each
                     *separately jit-compiled* (black-box) function on one
                     chunk at a time.
* ``"fused"``      — beyond-paper: the whole per-chunk chain is traced into
                     ONE jitted function (still driven chunk-by-chunk).
* ``"scan"``       — beyond-paper: equal-size chunks are stacked and the
                     fused chain is driven by ``lax.map`` so the chunk loop
                     itself compiles to a single streaming XLA loop.

``"sharded"`` (mesh scale-out) and ``"pallas"`` (TPU split-pipeline kernel)
live in ``core/sharded.py`` / ``core/pallas_exec.py``.

The jitted drivers built here are *capture-safe* (closed over ``chain_plan``
and canonical env keys, never over a Stage or concrete arrays) and pinned
into the plan cache via ``pinned_jit``: warm executions of a cached plan
reuse the same compiled executable — zero retraces (``note_trace``).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.graph import NodeRef
from repro.core.planner import Stage
from repro.core.stage_exec import (
    ChunkStream,
    PedanticError,
    StageExecutor,
    batch_ranges,
    chain_plan,
    chunk_env_for,
    effective_elements,
    finish_stage,
    get_executor,
    has_dynamic,
    note_materialized,
    note_trace,
    pinned_jit,
    register_executor,
    run_chain,
    run_plan,
    split_axis_of,
    stage_num_elements,
)

__all__ = [
    "PedanticError", "EagerExecutor", "PipelinedExecutor",
    "FusedExecutor", "ScanExecutor",
]


@register_executor("eager")
class EagerExecutor(StageExecutor):
    """The un-annotated library baseline: every function runs whole."""

    tunable = False
    stream_capable = False       # whole-value strategy: streams materialize

    def execute(self, stage: Stage, concrete: dict[tuple, Any], ctx) -> None:
        env = {stage.ckey(key): v for key, v in concrete.items()}
        run_chain(stage, env, jit_each=True)
        for node in stage.nodes:
            node.result = env[stage.out_key(node)]
            node.done = True
            ctx.stats["calls"] += 1


def _build_fused_driver(stage: Stage, esc: tuple[int, ...],
                        donate: tuple = ()) -> Callable:
    plan = chain_plan(stage)

    if donate:
        # Handed-off chunk buffers whose stream dies after this stage arrive
        # as a separate (donated) argument: XLA reuses the dead intermediate's
        # memory for this chunk's outputs instead of allocating fresh buffers.
        def fused_driver_donate(donated, env):
            note_trace()
            env = dict(env)
            env.update(donated)
            run_plan(plan, env)
            return {p: env[("n", p)] for p in esc}

        return jax.jit(fused_driver_donate, donate_argnums=(0,))

    def fused_driver(env):
        note_trace()
        run_plan(plan, env)
        return {p: env[("n", p)] for p in esc}

    return jax.jit(fused_driver)


class ChunkedExecutor(StageExecutor):
    """Shared Python-driver chunk loop; ``mode`` picks the per-chunk style.

    Chunk handoff: stream inputs (producer chunk lists) are iterated without
    re-slicing.  The loop itself never blocks between chunks — jax dispatch
    is asynchronous, so host-side split work for chunk *i+1* always overlaps
    device compute of chunk *i* — and chunk buffers that die here are
    donated to the fused driver (``_build_fused_driver``) so XLA reuses the
    dead intermediate's memory for this chunk's outputs."""

    tunable = True
    stream_capable = True
    mode = "pipelined"

    #: a producer grid whose chunks are up to this factor over the consumer's
    #: own batch estimate is adopted as-is: the §5.2 estimate deliberately
    #: leaves fast-memory headroom, and adopting the grid costs zero copies
    #: while re-gridding costs one per chunk.  Beyond it the stream is
    #: re-gridded to protect the fast-memory budget.
    GRID_SLACK = 2.0

    def _ingest_streams(self, stage: Stage, concrete: dict[tuple, Any], ctx,
                        n: int, batch: int):
        """Align every stream input onto ONE chunk grid.

        The producer's grid is adopted as-is when its chunks (approximately)
        fit this stage's fast-memory budget — finer grids always fit, and up
        to ``GRID_SLACK``x oversized chunks are tolerated; grids beyond that
        (or streams disagreeing with the adopted grid) convert via
        ``SplitType.rechunk`` — at most one copy, never the merge +
        re-split two."""
        streams = [(k, v) for k, v in concrete.items()
                   if isinstance(v, ChunkStream)]
        if not streams:
            return concrete, batch_ranges(n, batch)
        base = streams[0][1]
        grid = base.ranges
        ub = base.uniform_batch()
        if ub is not None and ub > batch * self.GRID_SLACK and n > 0:
            grid = batch_ranges(n, batch)
        out = dict(concrete)
        for k, v in streams:
            if v.ranges != grid:
                chunks, copied = v.split_type.rechunk(v.chunks, v.ranges, grid)
                out[k] = ChunkStream(chunks, grid, v.split_type, v.aval)
                note_materialized(copied)
                ctx.stats["handoff_rechunks"] += 1
        return out, grid

    def _donatable(self, stage: Stage, ctx) -> tuple:
        """Canonical env keys of inputs whose per-chunk buffers die here.

        STRUCTURAL only — a pure function of the handoff plan (this stage is
        the handed-off value's LAST in-plan consumer) and the stage template
        (NodeRef-sourced, splittable, some escaping output chunk can absorb
        the buffer) — so the pinned driver's variant key is identical on
        every call and the zero-retrace warm-call invariant holds.  Whether
        a producer is still observable is a *runtime* question answered per
        chunk in ``execute`` (an observable stream donates a defensive COPY,
        never its own buffers)."""
        plan = getattr(ctx, "_handoff", None)
        ho = plan.get(stage.id) if plan else None
        if ho is None or not ho.last_use:
            return ()

        def _sig(aval):
            return tuple((tuple(l.shape), str(l.dtype))
                         for l in jax.tree_util.tree_leaves(aval)
                         if hasattr(l, "shape"))

        # XLA can only reuse a donated buffer for an output of the same
        # shape/dtype: donate at most ONE chunk per matching escaping
        # output chunk (else jax warns about unusable donations).
        out_sigs: dict[tuple, int] = {}
        for n in stage.nodes:
            if (n.id in stage.escaping and n.out_aval is not None
                    and stage.out_types[n.id].splittable):
                sig = _sig(n.out_aval)
                out_sigs[sig] = out_sigs.get(sig, 0) + 1
        keys = []
        for i, (key, si) in enumerate(stage.inputs.items()):
            if not (i in ho.last_use and isinstance(si.value, NodeRef)
                    and si.split_type.splittable):
                continue
            node = ctx.graph.nodes.get(si.value.node_id)
            aval = node.out_aval if node is not None else None
            if aval is not None and out_sigs.get(_sig(aval), 0) > 0:
                out_sigs[_sig(aval)] -= 1
                keys.append(stage.ckey(key))
        return tuple(sorted(keys))

    def _undonatable_streams(self, stage: Stage, concrete: dict[tuple, Any],
                             ctx, donate: tuple) -> set:
        """Donate-marked keys whose ChunkStream may still be observed (the
        producer's Future is alive): their chunks are copied before donation
        so the stream's own buffers survive."""
        unsafe = set()
        for key, si in stage.inputs.items():
            ck = stage.ckey(key)
            if ck in donate and isinstance(concrete.get(key), ChunkStream):
                node = ctx.graph.nodes.get(si.value.node_id)
                if node is None or node.future_alive():
                    unsafe.add(ck)
        return unsafe

    def execute(self, stage: Stage, concrete: dict[tuple, Any], ctx) -> None:
        mode = self.mode
        if has_dynamic(stage):
            mode = "pipelined"           # dynamic-shape fns cannot be traced
        n = effective_elements(ctx, stage_num_elements(stage, concrete, ctx.pedantic))
        batch = self.choose_batch(stage, concrete, ctx, n)
        concrete, ranges = self._ingest_streams(stage, concrete, ctx, n, batch)
        ctx.stats["chunks"] += len(ranges)

        esc = tuple(stage.escape_positions())
        fused_fn: Callable | None = None
        donate: tuple = ()
        unsafe: set = set()
        if mode == "fused":
            # The donate key set is structural (plan-derived), so the pinned
            # driver variant is the same on every warm call — zero retraces.
            donate = self._donatable(stage, ctx)
            if donate:
                unsafe = self._undonatable_streams(stage, concrete, ctx, donate)
            fused_fn = pinned_jit(stage, ctx, "fused", (esc, donate),
                                  lambda: _build_fused_driver(stage, esc, donate))

        partials: dict[int, list[Any]] = {p: [] for p in esc}
        for i, (s, e) in enumerate(ranges):
            env = chunk_env_for(stage, concrete, s, e, ctx.pedantic,
                                chunk_index=i, force_slice=donate)
            if mode == "pipelined":
                run_chain(stage, env, jit_each=True)
                ctx.stats["calls"] += len(stage.nodes)
                outs = {p: env[("n", p)] for p in esc}
            else:
                if donate:
                    # Observable streams donate a defensive COPY — their own
                    # chunk buffers must survive a later Future.value.
                    donated = {}
                    for k in donate:
                        v = env.pop(k)
                        if k in unsafe:
                            v = jax.tree_util.tree_map(jnp.array, v)
                            ctx.stats["donation_copies"] += 1
                        donated[k] = v
                    outs = fused_fn(donated, env)
                    ctx.stats["donated_chunks"] += len(donated)
                else:
                    outs = fused_fn(env)
                ctx.stats["calls"] += 1
            for p, v in outs.items():
                partials[p].append(v)
            if ctx.log:
                print(f"[mozart] stage {stage.id} chunk [{s},{e}) done")
        for key, si in stage.inputs.items():
            ck = stage.ckey(key)
            v = concrete.get(key)
            if (ck in donate and ck not in unsafe and isinstance(v, ChunkStream)):
                v.consumed = True              # buffers are gone: mark both the
                orig = ctx.graph.nodes[si.value.node_id].result
                if isinstance(orig, ChunkStream):
                    orig.consumed = True       # original and rechunked aliases
        finish_stage(stage, partials, ranges, ctx)


@register_executor("pipelined")
class PipelinedExecutor(ChunkedExecutor):
    """Paper-faithful driver: separately jitted black-box calls per chunk."""

    mode = "pipelined"


@register_executor("fused")
class FusedExecutor(ChunkedExecutor):
    """Whole per-chunk chain traced into one jitted function."""

    mode = "fused"


def _build_scan_driver(stage: Stage, esc: tuple[int, ...],
                       split_axes: dict[tuple, int],
                       out_axes: dict[int, int | None]) -> Callable:
    plan = chain_plan(stage)

    def chain_fn(split_vals: dict, bcast_env: dict):
        env = dict(bcast_env)
        for key, v in split_vals.items():
            ax = split_axes[key]
            env[key] = jax.tree_util.tree_map(
                lambda l: jnp.moveaxis(l, 0, ax) if ax else l, v)
        run_plan(plan, env)
        outs = {}
        for p in esc:
            ax = out_axes[p]
            o = env[("n", p)]
            if ax is not None:
                o = jax.tree_util.tree_map(
                    lambda l: jnp.moveaxis(l, ax, 0) if ax else l, o)
            outs[p] = o
        return outs

    def driver(stacked_inputs: dict, bcast_env: dict):
        # Broadcast values ride along as a real jit argument (not a closure
        # capture): the pinned executable must not bake one call's scalars
        # into the compiled program.
        note_trace()
        return jax.lax.map(lambda sv: chain_fn(sv, bcast_env), stacked_inputs)

    return jax.jit(driver)


@register_executor("scan")
class ScanExecutor(StageExecutor):
    """Stack equal-size chunks and drive the fused chain with ``lax.map``.

    The chunk loop compiles into a single XLA while-loop whose body touches
    one fast-memory-sized batch at a time — the TPU-native rendering of the
    paper's driver loop.  The ragged tail chunk is handled separately.
    """

    tunable = True
    # Stacking wants one contiguous array (the reshape into (chunks, batch)
    # is free on a merged value but a real gather on a chunk list), so
    # stream inputs materialize on ingest rather than stream through.
    stream_capable = False

    def execute(self, stage: Stage, concrete: dict[tuple, Any], ctx) -> None:
        if has_dynamic(stage):
            return get_executor("pipelined").execute(stage, concrete, ctx)

        n = effective_elements(ctx, stage_num_elements(stage, concrete, ctx.pedantic))
        if n == 0:
            # Empty split: the stacked driver has no chunks to map over; the
            # fused driver runs one degenerate zero-size chunk instead.
            return get_executor("fused").execute(stage, concrete, ctx)
        batch = self.choose_batch(stage, concrete, ctx, n)
        n_main = (n // batch) * batch
        n_chunks = n_main // batch

        # Outputs whose split axis we know get stacked; everything else falls
        # back to the fused python driver.
        for nid in stage.escaping:
            if split_axis_of(stage.out_types[nid]) is None and stage.out_types[nid].splittable:
                return get_executor("fused").execute(stage, concrete, ctx)

        split_keys = [k for k, si in stage.inputs.items() if si.split_type.splittable]
        if not split_keys or any(
            split_axis_of(stage.inputs[k].split_type) is None for k in split_keys
        ):
            return get_executor("fused").execute(stage, concrete, ctx)

        def stacked(key):
            si = stage.inputs[key]
            ax = split_axis_of(si.split_type)
            v = concrete[key]

            def stack_leaf(leaf):
                lead = jnp.moveaxis(leaf, ax, 0) if ax else leaf
                main = lead[:n_main].reshape((n_chunks, batch) + lead.shape[1:])
                return main
            return jax.tree_util.tree_map(stack_leaf, v)

        stacked_inputs = {stage.ckey(key): stacked(key) for key in split_keys}
        bcast_env = {stage.ckey(k): concrete[k] for k, si in stage.inputs.items()
                     if not si.split_type.splittable}

        esc = tuple(stage.escape_positions())
        split_axes = {stage.ckey(k): split_axis_of(stage.inputs[k].split_type)
                      for k in split_keys}
        out_axes = {stage.pos[nid]: split_axis_of(stage.out_types[nid])
                    for nid in stage.escaping}
        driver = pinned_jit(
            stage, ctx, "scan", (esc, batch),
            lambda: _build_scan_driver(stage, esc, split_axes, out_axes))

        stacked_outs = driver(stacked_inputs, bcast_env) if n_chunks \
            else {p: None for p in esc}
        ctx.stats["chunks"] += n_chunks + (1 if n_main < n else 0)
        ctx.stats["calls"] += 1

        partials: dict[int, list[Any]] = {p: [] for p in esc}
        for nid in stage.escaping:
            p = stage.pos[nid]
            t = stage.out_types[nid]
            ax = split_axis_of(t)
            if n_chunks:
                so = stacked_outs[p]
                if ax is not None:
                    def unstack(l):
                        flat = l.reshape((n_chunks * batch,) + l.shape[2:])
                        return jnp.moveaxis(flat, 0, ax) if ax else flat
                    partials[p].append(jax.tree_util.tree_map(unstack, so))
                else:  # ReduceSplit etc.: merge over the stacked leading dim
                    pieces = [jax.tree_util.tree_map(lambda l: l[i], so)
                              for i in range(n_chunks)]
                    partials[p].extend(pieces)
        if n_main < n:  # ragged tail
            env = chunk_env_for(stage, concrete, n_main, n, ctx.pedantic)
            run_chain(stage, env, jit_each=False)
            for nid in stage.escaping:
                partials[stage.pos[nid]].append(env[("n", stage.pos[nid])])
        finish_stage(stage, partials, ctx=ctx)
