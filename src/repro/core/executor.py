"""Built-in Mozart executor strategies (paper §5.2) as ``StageExecutor``s.

Per stage: (1) discover runtime parameters — the batch size is chosen so one
batch of *every* live pipeline value fits in fast memory (L2 on the paper's
CPUs, VMEM on our TPU target), or taken from the plan cache's auto-tuner;
(2) split inputs and drive each batch through the whole function chain;
(3) merge partial results associatively.

Strategies registered here (see ``core/stage_exec.py`` for the registry):

* ``"eager"``      — no splitting: each function runs whole.  This is the
                     un-annotated library baseline.
* ``"pipelined"``  — paper-faithful: a Python driver loop calls each
                     *separately jit-compiled* (black-box) function on one
                     chunk at a time.
* ``"fused"``      — beyond-paper: the whole per-chunk chain is traced into
                     ONE jitted function (still driven chunk-by-chunk).
* ``"scan"``       — beyond-paper: equal-size chunks are stacked and the
                     fused chain is driven by ``lax.map`` so the chunk loop
                     itself compiles to a single streaming XLA loop.

``"sharded"`` (mesh scale-out) and ``"pallas"`` (TPU split-pipeline kernel)
live in ``core/sharded.py`` / ``core/pallas_exec.py``.

The jitted drivers built here are *capture-safe* (closed over ``chain_plan``
and canonical env keys, never over a Stage or concrete arrays) and pinned
into the plan cache via ``pinned_jit``: warm executions of a cached plan
reuse the same compiled executable — zero retraces (``note_trace``).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import resilience
from repro.core.graph import NodeRef
from repro.core.planner import Stage
from repro.core.stage_exec import (
    ChunkStream,
    PedanticError,
    StageExecutor,
    batch_ranges,
    chain_plan,
    chunk_env_for,
    donatable_input_keys,
    effective_elements,
    finish_stage,
    get_executor,
    has_dynamic,
    mark_stream_consumed,
    note_materialized,
    note_trace,
    pinned_jit,
    register_executor,
    run_chain,
    run_plan,
    split_axis_of,
    stage_num_elements,
    undonatable_stream_keys,
)

__all__ = [
    "PedanticError", "EagerExecutor", "PipelinedExecutor",
    "FusedExecutor", "ScanExecutor",
]


@register_executor("eager")
class EagerExecutor(StageExecutor):
    """The un-annotated library baseline: every function runs whole."""

    tunable = False
    stream_capable = False       # whole-value strategy: streams materialize

    def execute(self, stage: Stage, concrete: dict[tuple, Any], ctx) -> None:
        env = {stage.ckey(key): v for key, v in concrete.items()}
        run_chain(stage, env, jit_each=True)
        for node in stage.nodes:
            node.result = env[stage.out_key(node)]
            node.done = True
            ctx.stats["calls"] += 1


def _build_fused_driver(stage: Stage, esc: tuple[int, ...],
                        donate: tuple = ()) -> Callable:
    plan = chain_plan(stage)

    if donate:
        # Handed-off chunk buffers whose stream dies after this stage arrive
        # as a separate (donated) argument: XLA reuses the dead intermediate's
        # memory for this chunk's outputs instead of allocating fresh buffers.
        def fused_driver_donate(donated, env):
            note_trace()
            env = dict(env)
            env.update(donated)
            run_plan(plan, env)
            return {p: env[("n", p)] for p in esc}

        return jax.jit(fused_driver_donate, donate_argnums=(0,))

    def fused_driver(env):
        note_trace()
        run_plan(plan, env)
        return {p: env[("n", p)] for p in esc}

    return jax.jit(fused_driver)


class ChunkedExecutor(StageExecutor):
    """Shared Python-driver chunk loop; ``mode`` picks the per-chunk style.

    Chunk handoff: stream inputs (producer chunk lists) are iterated without
    re-slicing.  The loop itself never blocks between chunks — jax dispatch
    is asynchronous, so host-side split work for chunk *i+1* always overlaps
    device compute of chunk *i* — and chunk buffers that die here are
    donated to the fused driver (``_build_fused_driver``) so XLA reuses the
    dead intermediate's memory for this chunk's outputs."""

    tunable = True
    stream_capable = True
    mode = "pipelined"

    #: a producer grid whose chunks are up to this factor over the consumer's
    #: own batch estimate is adopted as-is: the §5.2 estimate deliberately
    #: leaves fast-memory headroom, and adopting the grid costs zero copies
    #: while re-gridding costs one per chunk.  Beyond it the stream is
    #: re-gridded to protect the fast-memory budget.
    GRID_SLACK = 2.0

    def _ingest_streams(self, stage: Stage, concrete: dict[tuple, Any], ctx,
                        n: int, batch: int):
        """Align every stream input onto ONE chunk grid.

        The producer's grid is adopted as-is when its chunks (approximately)
        fit this stage's fast-memory budget — finer grids always fit, and up
        to ``GRID_SLACK``x oversized chunks are tolerated; grids beyond that
        (or streams disagreeing with the adopted grid) convert via
        ``SplitType.rechunk`` — at most one copy, never the merge +
        re-split two."""
        streams = [(k, v) for k, v in concrete.items()
                   if isinstance(v, ChunkStream)]
        if not streams:
            return concrete, batch_ranges(n, batch)
        base = streams[0][1]
        grid = base.ranges
        ub = base.uniform_batch()
        if ub is not None and ub > batch * self.GRID_SLACK and n > 0:
            grid = batch_ranges(n, batch)
        if not grid:
            grid = batch_ranges(n, batch)  # zero-chunk stream: degenerate grid
        out = dict(concrete)
        for k, v in streams:
            if v.ranges != grid:
                chunks, copied = v.split_type.rechunk(v.chunks, v.ranges, grid)
                out[k] = ChunkStream(chunks, grid, v.split_type, v.aval)
                note_materialized(copied, kind="rechunk",
                                  where=f"stage {stage.id} input {stage.ckey(k)}")
                ctx.stats["handoff_rechunks"] += 1
        return out, grid

    def execute(self, stage: Stage, concrete: dict[tuple, Any], ctx) -> None:
        mode = self.mode
        if has_dynamic(stage):
            mode = "pipelined"           # dynamic-shape fns cannot be traced
        n = effective_elements(ctx, stage_num_elements(stage, concrete, ctx.pedantic))
        batch = self.choose_batch(stage, concrete, ctx, n)
        # Chunk-granular OOM policy (resilience leg 2): on resource
        # exhaustion, halve the batch and re-drive — bounded, and only while
        # no chunk buffer was REALLY donated (a freed buffer must never be
        # re-read; defensive copies are safe).  The surviving size is
        # re-pinned into the tuner state so warm calls start from it.
        halvings = 0
        while True:
            real_donated = (ctx.stats.get("donated_chunks", 0)
                            - ctx.stats.get("donation_copies", 0))
            try:
                self._drive(stage, concrete, ctx, mode, n, batch)
                break
            except resilience.PROBE_ERRORS as e:
                still_clean = real_donated == (
                    ctx.stats.get("donated_chunks", 0)
                    - ctx.stats.get("donation_copies", 0))
                if (not resilience.is_resource_exhausted(e) or batch <= 1
                        or halvings >= resilience.MAX_OOM_HALVINGS
                        or not still_clean):
                    raise
                halvings += 1
                batch = max(1, batch // 2)
                ctx.stats["chunk_oom_halvings"] += 1
                resilience.record_event(
                    "MZ403", f"stage {stage.id}: {type(e).__name__}, "
                             f"batch halved to {batch}")
        if halvings:
            entry = getattr(ctx, "_plan_entry", None)
            if entry is not None:
                entry.pin(stage.id, batch)   # survive into warm calls

    def _drive(self, stage: Stage, concrete: dict[tuple, Any], ctx,
               mode: str, n: int, batch: int) -> None:
        concrete, ranges = self._ingest_streams(stage, concrete, ctx, n, batch)
        ctx.stats["chunks"] += len(ranges)

        esc = tuple(stage.escape_positions())
        fused_fn: Callable | None = None
        donate: tuple = ()
        unsafe: set = set()
        if mode == "fused":
            # The donate key set is structural (plan-derived), so the pinned
            # driver variant is the same on every warm call — zero retraces.
            donate = donatable_input_keys(stage, ctx)
            if donate:
                unsafe = undonatable_stream_keys(stage, concrete, ctx, donate)
            fused_fn = pinned_jit(stage, ctx, "fused", (esc, donate),
                                  lambda: _build_fused_driver(stage, esc, donate))

        partials: dict[int, list[Any]] = {p: [] for p in esc}
        for i, (s, e) in enumerate(ranges):
            resilience.maybe_fail("chunk", f"stage {stage.id} chunk [{s},{e})")
            env = chunk_env_for(stage, concrete, s, e, ctx.pedantic,
                                chunk_index=i, force_slice=donate)
            if mode == "pipelined":
                run_chain(stage, env, jit_each=True)
                ctx.stats["calls"] += len(stage.nodes)
                outs = {p: env[("n", p)] for p in esc}
            else:
                if donate:
                    # Observable streams donate a defensive COPY — their own
                    # chunk buffers must survive a later Future.value.
                    donated = {}
                    for k in donate:
                        v = env.pop(k)
                        if k in unsafe:
                            v = jax.tree_util.tree_map(jnp.array, v)
                            ctx.stats["donation_copies"] += 1
                        donated[k] = v
                    outs = fused_fn(donated, env)
                    ctx.stats["donated_chunks"] += len(donated)
                else:
                    outs = fused_fn(env)
                ctx.stats["calls"] += 1
            for p, v in outs.items():
                partials[p].append(v)
            if ctx.log:
                print(f"[mozart] stage {stage.id} chunk [{s},{e}) done")
        mark_stream_consumed(stage, concrete, ctx, set(donate) - unsafe)
        finish_stage(stage, partials, ranges, ctx)


@register_executor("pipelined")
class PipelinedExecutor(ChunkedExecutor):
    """Paper-faithful driver: separately jitted black-box calls per chunk."""

    mode = "pipelined"


@register_executor("fused")
class FusedExecutor(ChunkedExecutor):
    """Whole per-chunk chain traced into one jitted function."""

    mode = "fused"


def _build_scan_driver(stage: Stage, esc: tuple[int, ...],
                       split_axes: dict[tuple, int],
                       out_axes: dict[int, int | None],
                       donate: tuple = ()) -> Callable:
    plan = chain_plan(stage)

    def chain_fn(split_vals: dict, bcast_env: dict):
        env = dict(bcast_env)
        for key, v in split_vals.items():
            ax = split_axes[key]
            env[key] = jax.tree_util.tree_map(
                lambda l: jnp.moveaxis(l, 0, ax) if ax else l, v)
        run_plan(plan, env)
        outs = {}
        for p in esc:
            ax = out_axes[p]
            o = env[("n", p)]
            if ax is not None:
                o = jax.tree_util.tree_map(
                    lambda l: jnp.moveaxis(l, ax, 0) if ax else l, o)
            outs[p] = o
        return outs

    if donate:
        # Stacked carry buffers that die at this stage arrive as a separate
        # donated argument: XLA reuses the dead (n_chunks, batch, …) buffer
        # for this stage's stacked outputs instead of allocating fresh ones —
        # the scan-driver rendering of the fused driver's chunk donation.
        def driver_donate(donated: dict, stacked_inputs: dict, bcast_env: dict):
            note_trace()
            stacked_inputs = dict(stacked_inputs)
            stacked_inputs.update(donated)
            return jax.lax.map(lambda sv: chain_fn(sv, bcast_env),
                               stacked_inputs)

        return jax.jit(driver_donate, donate_argnums=(0,))

    def driver(stacked_inputs: dict, bcast_env: dict):
        # Broadcast values ride along as a real jit argument (not a closure
        # capture): the pinned executable must not bake one call's scalars
        # into the compiled program.
        note_trace()
        return jax.lax.map(lambda sv: chain_fn(sv, bcast_env), stacked_inputs)

    return jax.jit(driver)


@register_executor("scan")
class ScanExecutor(StageExecutor):
    """Stack equal-size chunks and drive the fused chain with ``lax.map``.

    The chunk loop compiles into a single XLA while-loop whose body touches
    one fast-memory-sized batch at a time — the TPU-native rendering of the
    paper's driver loop.  The ragged tail chunk is handled separately.

    Chunk handoff: an incoming ``ChunkStream`` is stacked DIRECTLY into the
    driver's carry layout — the producer's own stacked carry passes through
    untouched when the grids agree (scan→scan is zero-copy), a chunk list
    stacks in one gather (equal-grid fast path), and disagreeing grids
    convert through ``SplitType.rechunk`` first — ``materialize()`` is never
    called on ingest.  Streamed outputs keep the carry layout
    (``ChunkStream.from_stacked``), and dying stacked inputs are donated to
    the driver under the same structural (plan-derived) donate-key rules as
    the fused driver, so pinned variants never flap and warm calls stay
    zero-retrace.
    """

    tunable = True
    stream_capable = True

    #: same grid-adoption slack as the chunk-loop drivers: a producer grid
    #: whose chunks are at most this factor over the §5.2 estimate is
    #: adopted as the scan batch (zero copies); beyond it the stream is
    #: re-gridded to protect the fast-memory budget.
    GRID_SLACK = 2.0

    def _ingest_streams(self, stage: Stage, concrete: dict[tuple, Any], ctx,
                        n: int, batch: int) -> tuple[dict[tuple, Any], int]:
        """Align stream inputs onto ONE regular grid; returns the batch.

        The scan layout needs equal-size main chunks + one ragged tail,
        which is exactly the shape of a ``batch_ranges`` grid: a stream
        whose grid already is one (within ``GRID_SLACK`` of the estimate)
        fixes the batch; anything else rechunks — at most one copy."""
        streams = [(k, v) for k, v in concrete.items()
                   if isinstance(v, ChunkStream)]
        if not streams or n <= 0:
            return concrete, batch
        base = streams[0][1]
        ub = base.uniform_batch()
        if (ub and ub <= batch * self.GRID_SLACK
                and base.ranges == batch_ranges(n, ub)):
            batch = ub                     # adopt the producer's grid as-is
        grid = batch_ranges(n, batch)
        out = dict(concrete)
        for k, v in streams:
            if v.ranges != grid:
                chunks, copied = v.split_type.rechunk(v.chunks, v.ranges, grid)
                out[k] = ChunkStream(chunks, grid, v.split_type, v.aval)
                note_materialized(copied, kind="rechunk",
                                  where=f"stage {stage.id} input {stage.ckey(k)}")
                ctx.stats["handoff_rechunks"] += 1
        return out, batch

    def execute(self, stage: Stage, concrete: dict[tuple, Any], ctx) -> None:
        if has_dynamic(stage):
            return get_executor("pipelined").execute(stage, concrete, ctx)

        n = effective_elements(ctx, stage_num_elements(stage, concrete, ctx.pedantic))
        if n == 0:
            # Empty split: the stacked driver has no chunks to map over; the
            # fused driver runs one degenerate zero-size chunk instead (and
            # handles any zero-element stream input itself).
            return get_executor("fused").execute(stage, concrete, ctx)
        batch = self.choose_batch(stage, concrete, ctx, n)
        concrete, batch = self._ingest_streams(stage, concrete, ctx, n, batch)
        n_main = (n // batch) * batch
        n_chunks = n_main // batch

        # Outputs whose split axis we know get stacked; everything else falls
        # back to the fused python driver.
        for nid in stage.escaping:
            if split_axis_of(stage.out_types[nid]) is None and stage.out_types[nid].splittable:
                return get_executor("fused").execute(stage, concrete, ctx)

        split_keys = [k for k, si in stage.inputs.items() if si.split_type.splittable]
        if not split_keys or any(
            split_axis_of(stage.inputs[k].split_type) is None for k in split_keys
        ):
            return get_executor("fused").execute(stage, concrete, ctx)

        fresh_stacked: set[tuple] = set()    # ckeys whose stacked buffer is ours

        def stacked(key):
            si = stage.inputs[key]
            ax = split_axis_of(si.split_type)
            v = concrete[key]
            if isinstance(v, ChunkStream):
                if (v.stacked is not None and v._chunks is None
                        and v.uniform_batch() == batch):
                    # scan→scan: the producer's carry layout IS this stage's
                    # stacked input — zero copies, zero dispatches.
                    return v.stacked
                # Equal-grid fast path: stack the chunk list straight into
                # the carry layout (one gather — the merge+reshape round
                # trip is gone).
                fresh_stacked.add(stage.ckey(key))
                main = [jax.tree_util.tree_map(
                            lambda l: jnp.moveaxis(l, ax, 0) if ax else l,
                            v.chunk(i))
                        for i in range(n_chunks)]
                if not main:
                    return None
                return jax.tree_util.tree_map(
                    lambda *ls: jnp.stack(ls), *main)

            def stack_leaf(leaf):
                lead = jnp.moveaxis(leaf, ax, 0) if ax else leaf
                main = lead[:n_main].reshape((n_chunks, batch) + lead.shape[1:])
                return main
            return jax.tree_util.tree_map(stack_leaf, v)

        stacked_inputs = {stage.ckey(key): stacked(key) for key in split_keys}
        bcast_env = {stage.ckey(k): concrete[k] for k, si in stage.inputs.items()
                     if not si.split_type.splittable}

        esc = tuple(stage.escape_positions())
        split_axes = {stage.ckey(k): split_axis_of(stage.inputs[k].split_type)
                      for k in split_keys}
        out_axes = {stage.pos[nid]: split_axis_of(stage.out_types[nid])
                    for nid in stage.escaping}

        # Donation: structural key set shared with the fused driver.  The
        # donated value is always the STACKED buffer; whether it may be the
        # stream's own storage is a runtime question (a fresh stack we built
        # is always safe; a passed-through carry or a plain reshaped array
        # donates a defensive copy unless provably dead).
        donate = tuple(k for k in donatable_input_keys(stage, ctx)
                       if k in stacked_inputs) if n_chunks else ()
        unsafe = undonatable_stream_keys(stage, concrete, ctx, donate) \
            if donate else set()
        driver = pinned_jit(
            stage, ctx, "scan", (esc, batch, donate),
            lambda: _build_scan_driver(stage, esc, split_axes, out_axes,
                                       donate))

        consumed_keys: tuple = ()
        if n_chunks:
            resilience.maybe_fail("chunk", f"stage {stage.id} scan driver")
            if donate:
                key_of = {stage.ckey(k): k for k in stage.inputs}
                donated = {}
                for ck in donate:
                    val = stacked_inputs.pop(ck)
                    if ck in fresh_stacked:
                        # Our own stack: the stream's chunk buffers survive
                        # regardless — donate without copying or consuming.
                        donated[ck] = val
                    elif (ck in unsafe or not isinstance(
                            concrete.get(key_of[ck]), ChunkStream)):
                        # Observable carry pass-through, or a plain array
                        # whose reshape may alias the producer's retained
                        # result: donate a defensive copy.
                        donated[ck] = jax.tree_util.tree_map(jnp.array, val)
                        ctx.stats["donation_copies"] += 1
                    else:
                        donated[ck] = val        # dead carry: real donation
                        consumed_keys += (ck,)
                stacked_outs = driver(donated, stacked_inputs, bcast_env)
                ctx.stats["donated_chunks"] += len(donated)
            else:
                stacked_outs = driver(stacked_inputs, bcast_env)
        else:
            stacked_outs = {p: None for p in esc}
        ctx.stats["chunks"] += n_chunks + (1 if n_main < n else 0)
        ctx.stats["calls"] += 1

        # Which outputs stay in carry form (the handoff plan's decision).
        plan_ho = getattr(ctx, "_handoff", None)
        ho = plan_ho.get(stage.id) if plan_ho else None
        ranges = batch_ranges(n, batch)

        tail_env = None
        if n_main < n:  # ragged tail
            tail_env = chunk_env_for(stage, concrete, n_main, n, ctx.pedantic,
                                     chunk_index=n_chunks)
            run_chain(stage, tail_env, jit_each=False)

        partials: dict[int, list[Any]] = {}
        for nid in stage.escaping:
            p = stage.pos[nid]
            t = stage.out_types[nid]
            ax = split_axis_of(t)
            node = next(nd for nd in stage.nodes if nd.id == nid)
            tail_piece = tail_env[("n", p)] if tail_env is not None else None
            if (ho is not None and p in ho.stream_out and ax is not None
                    and n_chunks and len(ranges) > 1):
                # Streamed output: keep the driver's carry layout — a scan
                # consumer ingests it with zero copies, a chunk-loop consumer
                # derives the chunk list lazily, and observation merges
                # lazily via Future.value.
                node.result = ChunkStream.from_stacked(
                    stacked_outs[p], tail_piece, ranges, t, node.out_aval)
                node.done = True
                ctx.stats["streamed_outputs"] += 1
                continue
            pieces: list[Any] = []
            if n_chunks:
                so = stacked_outs[p]
                if ax is not None:
                    def unstack(l):
                        flat = l.reshape((n_chunks * batch,) + l.shape[2:])
                        return jnp.moveaxis(flat, 0, ax) if ax else flat
                    pieces.append(jax.tree_util.tree_map(unstack, so))
                else:  # ReduceSplit etc.: merge over the stacked leading dim
                    pieces.extend(jax.tree_util.tree_map(lambda l: l[i], so)
                                  for i in range(n_chunks))
            if tail_piece is not None:
                pieces.append(tail_piece)
            partials[p] = pieces
        mark_stream_consumed(stage, concrete, ctx, consumed_keys)
        finish_stage(stage, partials, ctx=ctx)
