"""The Mozart execution engine (paper §5.2).

Per stage: (1) discover runtime parameters — the batch size is chosen so one
batch of *every* live pipeline value fits in fast memory (L2 on the paper's
CPUs, VMEM on our TPU target); (2) split inputs and drive each batch through
the whole function chain; (3) merge partial results associatively.

Executor strategies (``MozartContext.executor``):

* ``"eager"``      — no splitting: each function runs whole.  This is the
                     un-annotated library baseline.
* ``"pipelined"``  — paper-faithful: a Python driver loop calls each
                     *separately jit-compiled* (black-box) function on one
                     chunk at a time.
* ``"fused"``      — beyond-paper: the whole per-chunk chain is traced into
                     ONE jitted function (still driven chunk-by-chunk).
* ``"scan"``       — beyond-paper: equal-size chunks are stacked and the
                     fused chain is driven by ``lax.map`` so the chunk loop
                     itself compiles to a single streaming XLA loop.
* ``"sharded"``    — splits become mesh shards (see ``core/sharded.py``).
* ``"pallas"``     — elementwise stages lower onto the split-pipeline TPU
                     kernel (see ``core/pallas_exec.py``).
"""

from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import hardware
from repro.core import split_types as st
from repro.core.graph import DataflowGraph, Node, NodeRef
from repro.core.planner import Stage, _value_key


class PedanticError(RuntimeError):
    pass


# ---------------------------------------------------------------------------
# Runtime parameter discovery (paper §5.2 step 1)
# ---------------------------------------------------------------------------


def stage_num_elements(stage: Stage, concrete: dict[tuple, Any], pedantic: bool) -> int:
    counts = set()
    for key, si in stage.inputs.items():
        if not si.split_type.splittable:
            continue
        info = si.split_type.info(concrete[key])
        if info is not None:
            counts.add(info.num_elements)
    if len(counts) > 1:
        raise PedanticError(f"stage {stage.id}: inputs disagree on element count: {counts}")
    return counts.pop() if counts else 1


def stage_elem_bytes(stage: Stage, concrete: dict[tuple, Any], n: int) -> int:
    """Σ sizeof(element) over live pipeline values (inputs + outputs)."""
    total = 0
    for key, si in stage.inputs.items():
        if not si.split_type.splittable:
            continue
        info = si.split_type.info(concrete[key])
        if info is not None:
            total += info.elem_bytes
    for node in stage.nodes:
        t = stage.out_types[node.id]
        if t.splittable and node.out_aval is not None:
            leaves = jax.tree_util.tree_leaves(node.out_aval)
            nb = sum(st.nbytes_of(l) for l in leaves)
            total += max(nb // max(n, 1), 1)
    return total


def batch_ranges(n: int, batch: int) -> list[tuple[int, int]]:
    return [(s, min(s + batch, n)) for s in range(0, n, batch)]


# ---------------------------------------------------------------------------
# Per-chunk chain driving
# ---------------------------------------------------------------------------


def _chunk_env_for(stage: Stage, concrete: dict[tuple, Any], s: int, e: int,
                   pedantic: bool) -> dict[tuple, Any]:
    env: dict[tuple, Any] = {}
    for key, si in stage.inputs.items():
        v = concrete[key]
        if si.split_type.splittable:
            piece = si.split_type.split(v, s, e)
            if pedantic and hasattr(piece, "shape") and 0 in piece.shape:
                raise PedanticError(f"empty split for {key} range [{s},{e})")
            env[key] = piece
        else:
            env[key] = v                      # "_" values: pointer copy
    return env


def _node_kwargs(node: Node, stage: Stage, env: dict[tuple, Any]) -> dict[str, Any]:
    kw: dict[str, Any] = {}
    for name, v in node.bound.items():
        if name in node.fn.sa.static:
            kw[name] = v
        elif isinstance(v, NodeRef) and ("node", v.node_id) in env:
            kw[name] = env[("node", v.node_id)]
        else:
            kw[name] = env[_value_key(v)]
    return kw


def run_chain(stage: Stage, env: dict[tuple, Any], jit_each: bool) -> dict[int, Any]:
    """Drive one chunk through every function of the stage in order."""
    outs: dict[int, Any] = {}
    for node in stage.nodes:
        kw = _node_kwargs(node, stage, env)
        if getattr(node.fn.sa, "dynamic", False) or node.out_aval is None:
            res = node.fn.call_raw(kw)
        elif jit_each:
            res = node.fn.jitted(**kw)        # black-box library call
        else:
            res = node.fn.fn(**kw)            # traced into enclosing jit
        env[("node", node.id)] = res
        outs[node.id] = res
    return outs


# ---------------------------------------------------------------------------
# Executors
# ---------------------------------------------------------------------------


def _has_dynamic(stage: Stage) -> bool:
    return any(
        getattr(n.fn.sa, "dynamic", False) or n.out_aval is None
        for n in stage.nodes
    )


def execute_stage(stage: Stage, graph: DataflowGraph, ctx) -> None:
    concrete = {key: graph.resolve(si.value) for key, si in stage.inputs.items()}
    executor = ctx.executor

    if executor == "eager":
        _execute_eager(stage, concrete, ctx)
    elif executor == "sharded":
        from repro.core.sharded import execute_stage_sharded
        execute_stage_sharded(stage, concrete, ctx)
    elif executor == "pallas":
        from repro.core.pallas_exec import try_execute_stage_pallas
        if not try_execute_stage_pallas(stage, concrete, ctx):
            _execute_chunked(stage, concrete, ctx, mode="fused")
    elif executor in ("pipelined", "fused"):
        mode = executor
        if _has_dynamic(stage):
            mode = "pipelined"           # dynamic-shape fns cannot be traced
        _execute_chunked(stage, concrete, ctx, mode=mode)
    elif executor == "scan":
        if _has_dynamic(stage):
            _execute_chunked(stage, concrete, ctx, mode="pipelined")
        else:
            _execute_scan(stage, concrete, ctx)
    else:
        raise ValueError(f"unknown executor {executor!r}")

    ctx.stats["stages"] += 1
    for node in stage.nodes:
        node.done = True


def _finish(stage: Stage, partials: dict[int, list[Any]]) -> None:
    for node in stage.nodes:
        if node.id in partials:
            node.result = stage.out_types[node.id].merge(partials[node.id])
        node.done = True


def _execute_eager(stage: Stage, concrete: dict[tuple, Any], ctx) -> None:
    env = dict(concrete)
    for node in stage.nodes:
        kw = _node_kwargs(node, stage, env)
        if getattr(node.fn.sa, "dynamic", False) or node.out_aval is None:
            res = node.fn.call_raw(kw)
        else:
            res = node.fn.jitted(**kw)
        env[("node", node.id)] = res
        node.result = res
        node.done = True
        ctx.stats["calls"] += 1


def _execute_chunked(stage: Stage, concrete: dict[tuple, Any], ctx,
                     mode: str) -> None:
    n = stage_num_elements(stage, concrete, ctx.pedantic)
    elem_bytes = stage_elem_bytes(stage, concrete, n)
    batch = ctx.batch_elements or hardware.mozart_batch_elements(elem_bytes, ctx.chip)
    batch = min(batch, n)
    ranges = batch_ranges(n, batch)
    ctx.stats["chunks"] += len(ranges)

    fused_fn: Callable | None = None
    if mode == "fused":
        def fused_fn_impl(env):
            run_chain(stage, env, jit_each=False)
            return {nid: env[("node", nid)] for nid in stage.escaping}
        fused_fn = jax.jit(fused_fn_impl)

    partials: dict[int, list[Any]] = {nid: [] for nid in stage.escaping}
    for (s, e) in ranges:
        env = _chunk_env_for(stage, concrete, s, e, ctx.pedantic)
        if mode == "pipelined":
            run_chain(stage, env, jit_each=True)
            ctx.stats["calls"] += len(stage.nodes)
            outs = {nid: env[("node", nid)] for nid in stage.escaping}
        else:
            outs = fused_fn(env)
            ctx.stats["calls"] += 1
        for nid, v in outs.items():
            partials[nid].append(v)
        if ctx.log:
            print(f"[mozart] stage {stage.id} chunk [{s},{e}) done")
    _finish(stage, partials)


def _split_axis_of(t: st.SplitType) -> int | None:
    if isinstance(t, st.ArraySplit):
        return t.axis
    if isinstance(t, st.PytreeSplit):
        return t.axis
    return None


def _execute_scan(stage: Stage, concrete: dict[tuple, Any], ctx) -> None:
    """Stack equal-size chunks and drive the fused chain with ``lax.map``.

    The chunk loop compiles into a single XLA while-loop whose body touches
    one fast-memory-sized batch at a time — the TPU-native rendering of the
    paper's driver loop.  The ragged tail chunk is handled separately.
    """
    n = stage_num_elements(stage, concrete, ctx.pedantic)
    elem_bytes = stage_elem_bytes(stage, concrete, n)
    batch = ctx.batch_elements or hardware.mozart_batch_elements(elem_bytes, ctx.chip)
    batch = min(batch, n)
    n_main = (n // batch) * batch
    n_chunks = n_main // batch

    # Outputs whose split axis we know get stacked; everything else falls
    # back to the fused python driver.
    for nid in stage.escaping:
        if _split_axis_of(stage.out_types[nid]) is None and stage.out_types[nid].splittable:
            return _execute_chunked(stage, concrete, ctx, mode="fused")

    split_keys = [k for k, si in stage.inputs.items() if si.split_type.splittable]
    if not split_keys or any(
        _split_axis_of(stage.inputs[k].split_type) is None for k in split_keys
    ):
        return _execute_chunked(stage, concrete, ctx, mode="fused")

    def stacked(key):
        si = stage.inputs[key]
        ax = _split_axis_of(si.split_type)
        v = concrete[key]

        def stack_leaf(leaf):
            lead = jnp.moveaxis(leaf, ax, 0) if ax else leaf
            main = lead[:n_main].reshape((n_chunks, batch) + lead.shape[1:])
            return main
        return jax.tree_util.tree_map(stack_leaf, v)

    stacked_inputs = {key: stacked(key) for key in split_keys}
    bcast_inputs = {k: concrete[k] for k, si in stage.inputs.items()
                    if not si.split_type.splittable}

    def chain_fn(split_vals: dict):
        env = dict(bcast_inputs)
        for key, v in split_vals.items():
            ax = _split_axis_of(stage.inputs[key].split_type)
            env[key] = jax.tree_util.tree_map(
                lambda l: jnp.moveaxis(l, 0, ax) if ax else l, v)
        run_chain(stage, env, jit_each=False)
        outs = {}
        for nid in stage.escaping:
            ax = _split_axis_of(stage.out_types[nid])
            o = env[("node", nid)]
            if ax is not None:
                o = jax.tree_util.tree_map(lambda l: jnp.moveaxis(l, ax, 0) if ax else l, o)
            outs[nid] = o
        return outs

    @jax.jit
    def driver(stacked_inputs):
        return jax.lax.map(chain_fn, stacked_inputs)

    stacked_outs = driver(stacked_inputs) if n_chunks else {nid: None for nid in stage.escaping}
    ctx.stats["chunks"] += n_chunks + (1 if n_main < n else 0)
    ctx.stats["calls"] += 1

    partials: dict[int, list[Any]] = {nid: [] for nid in stage.escaping}
    for nid in stage.escaping:
        t = stage.out_types[nid]
        ax = _split_axis_of(t)
        if n_chunks:
            so = stacked_outs[nid]
            if ax is not None:
                def unstack(l):
                    flat = l.reshape((n_chunks * batch,) + l.shape[2:])
                    return jnp.moveaxis(flat, 0, ax) if ax else flat
                partials[nid].append(jax.tree_util.tree_map(unstack, so))
            else:  # ReduceSplit etc.: merge over the stacked leading dim
                pieces = [jax.tree_util.tree_map(lambda l: l[i], so) for i in range(n_chunks)]
                partials[nid].extend(pieces)
    if n_main < n:  # ragged tail
        env = _chunk_env_for(stage, concrete, n_main, n, ctx.pedantic)
        run_chain(stage, env, jit_each=False)
        for nid in stage.escaping:
            partials[nid].append(env[("node", nid)])
    _finish(stage, partials)
