"""The "ImageMagick" integration (paper §7): row-split image operators.

Images are (H, W, 3) float32 arrays in [0,1].  The split type is the
paper's MagickWand row split: pieces are horizontal bands (crops), and the
merge stacks bands back together — which is exactly ``ArraySplit(axis=0)``.

Like the paper we leave boundary-coupled operators (``blur``) un-annotated:
a blur over a band differs from a blur over the full image at band edges,
violating the SA condition F(a) = Merge(F(a1), F(a2), ...) (§3.4 / §7.1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import split_types as st
from repro.core.annotation import annotate

__all_ops__: dict[str, object] = {}


def _reg(name, fn):
    __all_ops__[name] = fn
    globals()[name] = fn
    return fn


def _rgb_to_hsv(img):
    r, g, b = img[..., 0], img[..., 1], img[..., 2]
    mx = jnp.max(img, axis=-1)
    mn = jnp.min(img, axis=-1)
    d = mx - mn
    safe = jnp.where(d == 0, 1.0, d)
    h = jnp.where(
        mx == r, (g - b) / safe % 6.0,
        jnp.where(mx == g, (b - r) / safe + 2.0, (r - g) / safe + 4.0),
    )
    h = jnp.where(d == 0, 0.0, h) / 6.0
    s = jnp.where(mx == 0, 0.0, d / jnp.where(mx == 0, 1.0, mx))
    return jnp.stack([h, s, mx], axis=-1)


def _hsv_to_rgb(hsv):
    h, s, v = hsv[..., 0] * 6.0, hsv[..., 1], hsv[..., 2]
    i = jnp.floor(h)
    f = h - i
    p = v * (1 - s)
    q = v * (1 - f * s)
    t = v * (1 - (1 - f) * s)
    i = i.astype(jnp.int32) % 6
    r = jnp.select([i == 0, i == 1, i == 2, i == 3, i == 4, i == 5], [v, q, p, p, t, v])
    g = jnp.select([i == 0, i == 1, i == 2, i == 3, i == 4, i == 5], [t, v, v, q, p, p])
    b = jnp.select([i == 0, i == 1, i == 2, i == 3, i == 4, i == 5], [p, p, t, v, v, q])
    return jnp.stack([r, g, b], axis=-1)


# -- annotated operators (all row-splittable) --------------------------------

def _colortone(img, color, level, negate):
    """Blend a solid color weighted by (optionally negated) luminance."""
    lum = (img[..., 0] * 0.299 + img[..., 1] * 0.587 + img[..., 2] * 0.114)
    mask = 1.0 - lum if negate else lum
    alpha = (mask * level)[..., None]
    c = jnp.asarray(color, img.dtype)
    return jnp.clip(img * (1 - alpha) + c * alpha, 0.0, 1.0)


_reg("colortone", annotate(
    _colortone, name="colortone", static=("color", "level", "negate"),
    img=st.Generic("S"), ret=st.Generic("S")))


def _gamma(img, g):
    return jnp.clip(jnp.power(jnp.maximum(img, 1e-6), 1.0 / g), 0.0, 1.0)


_reg("gamma", annotate(_gamma, name="gamma", img=st.Generic("S"),
                       g=st._, ret=st.Generic("S")))


def _modulate(img, brightness, saturation, hue):
    """ImageMagick -modulate (percentages, 100 = unchanged)."""
    hsv = _rgb_to_hsv(img)
    h = (hsv[..., 0] + (hue - 100.0) / 200.0) % 1.0
    s = jnp.clip(hsv[..., 1] * (saturation / 100.0), 0.0, 1.0)
    v = jnp.clip(hsv[..., 2] * (brightness / 100.0), 0.0, 1.0)
    return _hsv_to_rgb(jnp.stack([h, s, v], axis=-1))


_reg("modulate", annotate(
    _modulate, name="modulate",
    img=st.Generic("S"), brightness=st._, saturation=st._, hue=st._,
    ret=st.Generic("S")))


def _contrast(img, amount):
    """Sigmoidal-ish contrast about mid-gray."""
    return jnp.clip(0.5 + (img - 0.5) * amount, 0.0, 1.0)


_reg("contrast", annotate(_contrast, name="contrast", img=st.Generic("S"),
                          amount=st._, ret=st.Generic("S")))


def _level(img, black, white):
    return jnp.clip((img - black) / jnp.maximum(white - black, 1e-6), 0.0, 1.0)


_reg("level", annotate(_level, name="level", img=st.Generic("S"),
                       black=st._, white=st._, ret=st.Generic("S")))


def _screen_blend(img, other):
    return 1.0 - (1.0 - img) * (1.0 - other)


_reg("screen_blend", annotate(
    _screen_blend, name="screen_blend",
    img=st.Generic("S"), other=st.Generic("S"), ret=st.Generic("S")))


def _brightness_histogram(img):
    lum = (img[..., 0] * 0.299 + img[..., 1] * 0.587 + img[..., 2] * 0.114)
    return jnp.histogram(lum, bins=16, range=(0.0, 1.0))[0]


_reg("brightness_histogram", annotate(
    _brightness_histogram, name="brightness_histogram",
    img=st.Generic("S"), ret=st.Reduce("add")))


# -- deliberately UN-annotated: boundary-coupled (paper §7.1) ------------------

def blur(img, radius: int = 2):
    """Box blur.  NOT annotatable: band edges differ from full-image edges."""
    k = 2 * radius + 1
    kern = jnp.ones((k, k, 1, 1), img.dtype) / (k * k)
    x = img[None].transpose(0, 3, 1, 2).reshape(-1, 1, *img.shape[:2])
    out = jax.lax.conv_general_dilated(
        x, kern.transpose(2, 3, 0, 1), (1, 1), "SAME")
    return out.reshape(3, *img.shape[:2]).transpose(1, 2, 0)


def __probe_examples__(n: int = 12) -> dict[str, object]:
    """Tiny concrete inputs per op for the annotation contract checker."""
    img = (jnp.arange(n * 5 * 3, dtype=jnp.float32).reshape(n, 5, 3)
           / float(n * 5 * 3))
    return {
        "colortone": {"img": img, "color": (0.2, 0.3, 0.5), "level": 0.5,
                      "negate": True},
        "gamma": {"img": img, "g": 2.2},
        "modulate": {"img": img, "brightness": 120.0, "saturation": 80.0,
                     "hue": 110.0},
        "contrast": {"img": img, "amount": 1.5},
        "level": {"img": img, "black": 0.1, "white": 0.9},
        "screen_blend": {"img": img, "other": 1.0 - img},
        "brightness_histogram": {"img": img},
    }
