"""Split types and the splitting API (paper §3).

A *split type* is a parameterized type ``N<V0..Vn>``: two split types are
equal iff their names and parameters are equal.  Equal split types mean two
values are split the same way and corresponding pieces may be passed to a
function together (pipelined).  Annotators bridge the abstraction to code by
implementing the splitting API: ``constructor`` (function args -> params),
``split`` (value, [start,end) -> piece), ``merge`` (pieces -> value,
associative) and ``info`` (element count / element byte width).

This module provides the split-type algebra plus the concrete split types
used by our library integrations:

* ``ArraySplit``    — split a jnp array along one axis (NumPy/MKL analogue).
* ``ScalarSplit``   — the paper's missing type "_": broadcast, never split.
* ``ReduceSplit``   — partial results merged by an associative reduction.
* ``ConcatSplit``   — alias family for merge-by-concatenation of new outputs.
* ``UnknownSplit``  — the unique ``unknown`` type (filters etc.).
* ``GenericVar``    — an SA-local generic (``S``), resolved by unification.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def _require_pieces(pieces: Sequence[Any], type_name: str) -> None:
    """Degenerate-merge guard (lint code MZ109): ``merge([])`` has no
    identity element for concat/fold merges, so every split type must fail
    it with one clear error instead of whatever its library backend throws
    (``tree_map`` with zero trees, ``pieces[0]`` IndexError, …)."""
    if not len(pieces):
        raise ValueError(
            f"{type_name}.merge requires at least one piece (merge of an "
            "empty chunk list has no identity element)")


@dataclasses.dataclass(frozen=True)
class RuntimeInfo:
    """Relayed to Mozart by ``info`` (paper Table 1) to size batches."""

    num_elements: int      # how many splittable elements the value contains
    elem_bytes: int        # bytes occupied by ONE element (a slice)


class SplitType:
    """Base class. Identity = (name, params); paper §3.2."""

    #: human-readable type name; parameters complete the identity.
    name: str = "SplitType"

    def __init__(self, *params: Any):
        self.params = tuple(params)

    # -- type identity ----------------------------------------------------
    def key(self) -> tuple:
        return (self.name, self.params)

    def __eq__(self, other: object) -> bool:
        return isinstance(other, SplitType) and self.key() == other.key()

    def __hash__(self) -> int:
        return hash(self.key())

    def __repr__(self) -> str:
        ps = ", ".join(repr(p) for p in self.params)
        return f"{self.name}<{ps}>"

    # -- splitting API (paper Table 1) ------------------------------------
    @property
    def splittable(self) -> bool:
        """False for broadcast-like types that are copied, not split."""
        return True

    def info(self, value: Any) -> RuntimeInfo | None:
        raise NotImplementedError

    def split(self, value: Any, start: int, end: int) -> Any:
        raise NotImplementedError

    def merge(self, pieces: Sequence[Any]) -> Any:
        raise NotImplementedError

    # -- cross-stage chunk handoff (core/handoff.py) -----------------------
    def can_handoff(self, consumer: "SplitType") -> bool:
        """True when pieces of a value split by ``self`` may be ingested
        directly by a consumer whose input split type is ``consumer`` —
        i.e. corresponding chunks of the producer's grid ARE what the
        consumer's ``split`` would have produced, so the merge→re-split
        round trip at the stage boundary can be skipped entirely."""
        return False

    def rechunk(self, chunks: Sequence[Any],
                src_ranges: Sequence[tuple[int, int]],
                dst_ranges: Sequence[tuple[int, int]]) -> tuple[list[Any], int]:
        """Regroup a chunk list from one grid onto another.

        Converts pieces laid out on ``src_ranges`` to pieces on
        ``dst_ranges`` (both sorted, covering the same [0, n) extent) using
        only ``split``/``merge`` in chunk-local coordinates — a destination
        chunk aligned with a single source chunk is passed through by
        reference (zero copy); spanning or sub-slicing chunks pay a partial
        copy.  Returns ``(new_chunks, bytes_copied)`` so callers can account
        the partial materialization (``stage_exec.bytes_materialized``).
        Grids that are integer multiples of each other regroup with at most
        one copy of the data; a full merge + re-split always pays two.
        """
        out: list[Any] = []
        copied = 0
        si = 0
        for ds, de in dst_ranges:
            parts: list[Any] = []
            while si < len(src_ranges) and src_ranges[si][1] <= ds:
                si += 1
            j = si
            aligned = j < len(src_ranges) and src_ranges[j] == (ds, de)
            while j < len(src_ranges) and src_ranges[j][0] < de:
                ss, se = src_ranges[j]
                lo, hi = max(ds, ss), min(de, se)
                c = chunks[j]
                if lo == ss and hi == se:
                    parts.append(c)
                else:                      # partial overlap: chunk-local slice
                    parts.append(self.split(c, lo - ss, hi - ss))
                j += 1
            if aligned:
                piece = parts[0]           # exact alignment: pass through
            elif not parts:
                # Degenerate zero-element destination range (empty grids,
                # zero-size fresh pieces): carve an empty slice out of any
                # source chunk instead of crashing merge([]).
                if not chunks:
                    raise ValueError(
                        "rechunk: no source chunks to carve an empty piece "
                        "from (zero-chunk stream reached rechunk)")
                piece = self.split(chunks[0], 0, 0)
            else:
                piece = self.merge(parts) if len(parts) > 1 else parts[0]
                copied += sum(nbytes_of(l) for l in
                              jax.tree_util.tree_leaves(piece))
            out.append(piece)
        return out, copied


class ScalarSplit(SplitType):
    """The paper's "_" type: the value is copied to every pipeline."""

    name = "_"

    @property
    def splittable(self) -> bool:
        return False

    def info(self, value: Any) -> None:
        return None                      # does not constrain batch counts

    def split(self, value: Any, start: int, end: int) -> Any:
        return value                     # pointer copy in the paper

    def merge(self, pieces: Sequence[Any]) -> Any:
        _require_pieces(pieces, self.name)
        return pieces[-1]


#: canonical broadcast instance — all ScalarSplit() compare equal anyway.
BROADCAST = ScalarSplit()


def _elem_bytes_along(aval_like: Any, axis: int) -> int:
    shape = tuple(aval_like.shape)
    dt = jnp.dtype(aval_like.dtype)
    total = math.prod(shape) * dt.itemsize if shape else dt.itemsize
    n = shape[axis] if shape else 1
    return max(total // max(n, 1), 1)


class ArraySplit(SplitType):
    """Split an N-d array along one axis into regularly sized pieces.

    Parameters are ``(shape, axis)`` — mirroring the paper's
    ``MatrixSplit<rows, cols, axis>``; equality therefore requires both the
    same dimensions AND the same iteration axis (paper §3.1's normalize-
    rows-then-columns example maps to ArraySplit((r,c),0) != ArraySplit((r,c),1)).
    """

    name = "ArraySplit"

    def __init__(self, shape: Sequence[int], axis: int = 0):
        shape = tuple(int(s) for s in shape)
        axis = int(axis)
        if not -len(shape) <= axis < len(shape) if shape else axis != 0:
            raise ValueError(f"axis {axis} out of bounds for shape {shape}")
        if shape:
            axis %= len(shape)
        super().__init__(shape, axis)
        self.shape = shape
        self.axis = axis

    def info(self, value: Any) -> RuntimeInfo:
        return RuntimeInfo(
            num_elements=self.shape[self.axis] if self.shape else 1,
            elem_bytes=_elem_bytes_along(value, self.axis) if self.shape else jnp.dtype(value.dtype).itemsize,
        )

    def split(self, value: Any, start: int, end: int) -> Any:
        return jax.lax.slice_in_dim(value, start, end, axis=self.axis)

    def merge(self, pieces: Sequence[Any]) -> Any:
        _require_pieces(pieces, self.name)
        if len(pieces) == 1:
            return pieces[0]
        return jnp.concatenate(list(pieces), axis=self.axis)

    def can_handoff(self, consumer: "SplitType") -> bool:
        # Identical geometry AND iteration axis: chunk i of the producer's
        # grid is exactly what the consumer's split(v, s, e) would yield.
        return isinstance(consumer, ArraySplit) and consumer.key() == self.key()


class ReduceSplit(SplitType):
    """Output-only split type for reductions (paper Ex. 5).

    Pieces are partial results; ``merge`` combines them with an associative
    operator.  The ``op_name`` participates in type identity so that, e.g.,
    partial sums are never pipelined into a consumer expecting partial maxes.
    """

    name = "ReduceSplit"

    _OPS: dict[str, Callable[[Any, Any], Any]] = {
        "add": lambda a, b: a + b,
        "mul": lambda a, b: a * b,
        "max": jnp.maximum,
        "min": jnp.minimum,
    }

    def __init__(self, op_name: str, extra: tuple = ()):  # extra e.g. axis
        if op_name not in self._OPS:
            raise ValueError(f"unknown reduce op {op_name!r}")
        super().__init__(op_name, tuple(extra))
        self.op_name = op_name

    @property
    def splittable(self) -> bool:
        return False                     # you cannot re-split a partial

    def info(self, value: Any) -> None:
        return None

    def split(self, value: Any, start: int, end: int) -> Any:
        raise TypeError("ReduceSplit values are partial results; merge first")

    def merge(self, pieces: Sequence[Any]) -> Any:
        _require_pieces(pieces, self.name)
        op = self._OPS[self.op_name]
        out = pieces[0]
        for p in pieces[1:]:
            out = op(out, p)
        return out


class ConcatSplit(SplitType):
    """Output-only split type whose merge is concatenation (paper Ex. 4).

    For functions that *produce* fresh data per piece (one output row per
    input chunk, encoded blocks, per-batch records): pieces are new values
    whose total element count is unknowable before the merge, so the value
    cannot be re-split — but unlike ``unknown`` the type is *shared* by
    every producer with the same ``tag``, so equal-tagged outputs may be
    pipelined together.  Identity: ``(tag, axis)``.
    """

    name = "ConcatSplit"

    def __init__(self, tag: str = "", axis: int = 0):
        super().__init__(str(tag), int(axis))
        self.tag = str(tag)
        self.axis = int(axis)

    @property
    def splittable(self) -> bool:
        return False                     # piece boundaries vanish at merge

    def info(self, value: Any) -> None:
        return None

    def split(self, value: Any, start: int, end: int) -> Any:
        raise TypeError("ConcatSplit values are fresh outputs; merge first")

    def merge(self, pieces: Sequence[Any]) -> Any:
        _require_pieces(pieces, self.name)
        if len(pieces) == 1:
            return pieces[0]
        return jax.tree_util.tree_map(
            lambda *ls: jnp.concatenate(ls, axis=self.axis), *pieces
        )

    def can_handoff(self, consumer: "SplitType") -> bool:
        # ConcatSplit→ArraySplit: fresh pieces merge by concatenation along
        # ``axis``; a consumer iterating the SAME axis of a concrete array
        # grid can ingest them directly — the pieces laid end to end ARE a
        # chunk grid for it.  ConcatSplit→PytreeSplit: the same rule holds
        # per LEAF — every leaf of every piece must span the same extent of
        # the iteration axis, decided from the concrete buffers.  Piece
        # sizes are unknowable before execution, so both are only
        # *permission*: the runtime derives the concrete grid from the
        # chunk buffers (``stage_exec.adapt_stream``) and falls back to a
        # merge when they do not tile the consumer's geometry.
        return ((isinstance(consumer, ArraySplit) and bool(consumer.shape)
                 and consumer.axis == self.axis)
                or (isinstance(consumer, PytreeSplit)
                    and consumer.axis == self.axis))


_unknown_uid = itertools.count()


class UnknownSplit(SplitType):
    """The paper's ``unknown``: a *unique* split type per instantiation.

    Uniqueness prevents pipelining two independently-filtered values
    together, while generics may still bind to an unknown value (a generic
    consumer accepts pieces split in whatever way the producer emitted).
    Merging concatenates along ``axis`` (the producer's iteration axis).
    """

    name = "unknown"

    def __init__(self, axis: int = 0, _uid: int | None = None):
        uid = next(_unknown_uid) if _uid is None else _uid
        super().__init__(uid)
        self.axis = axis
        self.uid = uid

    def info(self, value: Any) -> None:
        return None                      # element count is unknowable

    def split(self, value: Any, start: int, end: int) -> Any:
        raise TypeError("unknown-typed values cannot be re-split without a merge")

    def merge(self, pieces: Sequence[Any]) -> Any:
        _require_pieces(pieces, self.name)
        if len(pieces) == 1:
            return pieces[0]
        return jnp.concatenate(list(pieces), axis=self.axis)


class PytreeSplit(SplitType):
    """Split every array leaf of a pytree along ``axis`` in lockstep.

    Used for optimizer states / (param, m, v) bundles so the whole training
    update pipelines as one stage.  Identity params: (treedef repr, leading
    sizes, axis).
    """

    name = "PytreeSplit"

    def __init__(self, treedef_repr: str, length: int, axis: int = 0):
        super().__init__(treedef_repr, int(length), int(axis))
        self.length = int(length)
        self.axis = int(axis)

    def info(self, value: Any) -> RuntimeInfo:
        leaves = jax.tree_util.tree_leaves(value)
        per_elem = sum(_elem_bytes_along(l, self.axis) for l in leaves)
        return RuntimeInfo(num_elements=self.length, elem_bytes=per_elem)

    def split(self, value: Any, start: int, end: int) -> Any:
        return jax.tree_util.tree_map(
            lambda l: jax.lax.slice_in_dim(l, start, end, axis=self.axis), value
        )

    def merge(self, pieces: Sequence[Any]) -> Any:
        _require_pieces(pieces, self.name)
        if len(pieces) == 1:
            return pieces[0]
        return jax.tree_util.tree_map(
            lambda *ls: jnp.concatenate(ls, axis=self.axis), *pieces
        )

    def can_handoff(self, consumer: "SplitType") -> bool:
        return isinstance(consumer, PytreeSplit) and consumer.key() == self.key()


# ---------------------------------------------------------------------------
# Type variables & unification (generics + inference, paper §3.2/§5.1)
# ---------------------------------------------------------------------------


class GenericVar:
    """An SA-local generic (``S``).  Fresh per function *call*."""

    __slots__ = ("label", "uid")
    _uids = itertools.count()

    def __init__(self, label: str):
        self.label = label
        self.uid = next(GenericVar._uids)

    def __repr__(self) -> str:
        return f"?{self.label}{self.uid}"


class UnificationError(Exception):
    pass


class TypeEnv:
    """Union-find over GenericVars with concrete SplitType bindings.

    Implements the paper's "push known types along the edges of the graph"
    inference (§5.1).  Unknown split types are concrete-but-unique, so a var
    may bind to one, while two distinct unknowns never unify.
    """

    def __init__(self) -> None:
        self._parent: dict[int, GenericVar] = {}
        self._binding: dict[int, SplitType] = {}

    def _find(self, v: GenericVar) -> GenericVar:
        p = self._parent.get(v.uid)
        if p is None or p.uid == v.uid:
            return v
        root = self._find(p)
        self._parent[v.uid] = root
        return root

    def resolve(self, t: "SplitType | GenericVar") -> "SplitType | GenericVar":
        if isinstance(t, GenericVar):
            root = self._find(t)
            return self._binding.get(root.uid, root)
        return t

    def unify(self, a: "SplitType | GenericVar", b: "SplitType | GenericVar") -> None:
        a, b = self.resolve(a), self.resolve(b)
        if isinstance(a, GenericVar) and isinstance(b, GenericVar):
            if a.uid != b.uid:
                self._parent[a.uid] = b
            return
        if isinstance(a, GenericVar):
            self._binding[a.uid] = b
            return
        if isinstance(b, GenericVar):
            self._binding[b.uid] = a
            return
        if a != b:
            raise UnificationError(f"split types differ: {a} vs {b}")

    def snapshot(self) -> tuple:
        return (dict(self._parent), dict(self._binding))

    def restore(self, snap: tuple) -> None:
        self._parent, self._binding = dict(snap[0]), dict(snap[1])


# ---------------------------------------------------------------------------
# Split SPECS — what annotators write inside an SA.  A spec is the split-type
# *constructor* (paper §3.2): at call time it maps the bound function
# arguments to a concrete split type (or a generic var / broadcast).
# ---------------------------------------------------------------------------


class SplitSpec:
    def construct(self, value: Any, bound: dict[str, Any], generics: dict[str, GenericVar]):
        raise NotImplementedError


class Along(SplitSpec):
    """ArraySplit along ``axis``; the constructor reads the value's shape.

    ``axis`` may also be the *name* of a function argument (runtime value),
    mirroring the paper's ``MatrixSplit(m, axis)`` constructor.
    """

    def __init__(self, axis: int | str = 0):
        self.axis = axis

    def construct(self, value, bound, generics):
        if value is None:            # downstream of a dynamic-shape op
            return UnknownSplit()
        axis = bound[self.axis] if isinstance(self.axis, str) else self.axis
        shape = tuple(value.shape)
        if not shape:
            return BROADCAST
        return ArraySplit(shape, int(axis))


class Broadcast(SplitSpec):
    def construct(self, value, bound, generics):
        return BROADCAST


#: annotators may write ``_`` like the paper.
_ = Broadcast()


class Generic(SplitSpec):
    def __init__(self, label: str = "S"):
        self.label = label

    def construct(self, value, bound, generics):
        if self.label not in generics:
            generics[self.label] = GenericVar(self.label)
        return generics[self.label]


class Unknown(SplitSpec):
    def __init__(self, axis: int = 0):
        self.axis = axis

    def construct(self, value, bound, generics):
        return UnknownSplit(axis=self.axis)


class Reduce(SplitSpec):
    def __init__(self, op_name: str, extra: tuple = ()):
        self.op_name = op_name
        self.extra = extra

    def construct(self, value, bound, generics):
        return ReduceSplit(self.op_name, self.extra)


class Concat(SplitSpec):
    """Spec form of ``ConcatSplit`` for annotators (see class docstring)."""

    def __init__(self, tag: str = "", axis: int = 0):
        self.tag = tag
        self.axis = axis

    def construct(self, value, bound, generics):
        return ConcatSplit(self.tag, self.axis)


class Custom(SplitSpec):
    """Escape hatch: an arbitrary constructor ``(value, bound_args) -> SplitType``."""

    def __init__(self, fn: Callable[[Any, dict[str, Any]], SplitType]):
        self.fn = fn

    def construct(self, value, bound, generics):
        return self.fn(value, bound)


class Pytree(SplitSpec):
    """PytreeSplit along ``axis`` of every leaf, lockstep across leaves.

    Every leaf must carry the SAME extent along ``axis`` — a PytreeSplit
    split slices all leaves in lockstep, so a value whose leaves disagree
    (lint code MZ103: the declared length would misdescribe some leaf)
    falls back to BROADCAST and is seen whole, the same conservative
    fallback ``planner._resolve`` uses for shape-mismatched arrays."""

    def __init__(self, axis: int = 0):
        self.axis = axis

    def construct(self, value, bound, generics):
        leaves, treedef = jax.tree_util.tree_flatten(value)
        if not leaves:
            return BROADCAST
        extents = set()
        for leaf in leaves:
            shape = tuple(getattr(leaf, "shape", ()))
            if len(shape) <= self.axis:
                return BROADCAST
            extents.add(int(shape[self.axis]))
        if len(extents) != 1:
            return BROADCAST
        return PytreeSplit(str(treedef), extents.pop(), self.axis)


#: per-data-type default split constructors (paper §5.1: "annotators provide
#: a default split type constructor per data type").
_DEFAULT_SPLITS: list[tuple[type, Callable[[Any], "SplitType"]]] = []


def register_default_split(cls: type, ctor: Callable[[Any], "SplitType"]) -> None:
    _DEFAULT_SPLITS.append((cls, ctor))


def default_split_type(value: Any) -> SplitType:
    """Paper §5.1 fallback: per-data-type default when inference fails."""
    for cls, ctor in _DEFAULT_SPLITS:
        if isinstance(value, cls):
            return ctor(value)
    shape = tuple(getattr(value, "shape", ()))
    if not shape:
        return BROADCAST
    return ArraySplit(shape, 0)


def aval_of(x: Any) -> jax.ShapeDtypeStruct:
    if isinstance(x, jax.ShapeDtypeStruct):
        return x
    arr = jnp.asarray(x) if not hasattr(x, "shape") else x
    return jax.ShapeDtypeStruct(tuple(arr.shape), jnp.dtype(arr.dtype))


def nbytes_of(x: Any) -> int:
    aval = aval_of(x)
    return math.prod(aval.shape or (1,)) * jnp.dtype(aval.dtype).itemsize
