"""Dataflow graph capture — the libmozart client library (paper §4).

Annotated calls are recorded as ``Node``s in a ``DataflowGraph`` instead of
executing.  Each node stores the *bound* arguments with lazy values replaced
by ``NodeRef``s (so that intermediate ``Future`` handles can die, which is
how Mozart learns that a value never escapes its pipeline stage and need not
be merged/materialized).  Evaluation is forced when arbitrary code touches a
``Future`` — the JAX analogue of the paper's memory-protection trick.
"""

from __future__ import annotations

import dataclasses
import weakref
from typing import Any

import jax

from repro.core import split_types as st


@dataclasses.dataclass(frozen=True)
class NodeRef:
    """Reference to the output of an earlier node in the same graph."""

    node_id: int


class Node:
    __slots__ = (
        "id", "fn", "bound", "arg_types", "out_type", "out_aval",
        "result", "done", "future_ref", "stage_id", "pinned", "alias_refs",
    )

    def __init__(self, node_id: int, fn, bound: dict[str, Any],
                 arg_types: dict[str, Any], out_type, out_aval):
        self.id = node_id
        self.fn = fn                     # AnnotatedFn
        self.bound = bound               # name -> value | NodeRef
        self.arg_types = arg_types       # name -> SplitType | GenericVar
        self.out_type = out_type         # SplitType | GenericVar
        self.out_aval = out_aval         # pytree of ShapeDtypeStruct
        self.result: Any = None
        self.done = False
        self.future_ref: weakref.ref | None = None
        self.stage_id: int | None = None
        # Pinned nodes survive prune(): the Pipeline bound-arguments fast
        # path re-executes a retained node set per call instead of
        # re-capturing the graph (core/pipeline.py).
        self.pinned = False
        # Futures of nodes CSE-merged into this one (core/rewrite.py): while
        # any of them is alive, this node's output is observable.
        self.alias_refs: list[weakref.ref] = []

    def future_alive(self) -> bool:
        if self.future_ref is not None and self.future_ref() is not None:
            return True
        return any(r() is not None for r in self.alias_refs)

    def deps(self) -> list[int]:
        out = []
        for v in self.bound.values():
            if isinstance(v, NodeRef):
                out.append(v.node_id)
        return out

    def __repr__(self) -> str:
        return f"Node#{self.id}({self.fn.name})"


class DataflowGraph:
    """Pending (not yet executed) annotated calls, in program order.

    Program order is a valid topological order: a ``Future`` can only refer
    to an already-registered node.
    """

    def __init__(self) -> None:
        self.nodes: dict[int, Node] = {}
        self._next_id = 0

    def register(self, fn, bound, arg_types, out_type, out_aval) -> Node:
        node = Node(self._next_id, fn, bound, arg_types, out_type, out_aval)
        self.nodes[node.id] = node
        self._next_id += 1
        return node

    def pending(self) -> list[Node]:
        return [n for n in self.nodes.values() if not n.done]

    def consumers(self) -> dict[int, list[int]]:
        out: dict[int, list[int]] = {nid: [] for nid in self.nodes}
        for n in self.nodes.values():
            for d in n.deps():
                if d in out:            # producer may already be pruned
                    out[d].append(n.id)
        return out

    def prune(self) -> None:
        """Drop executed nodes whose results can no longer be observed."""
        cons = self.consumers()
        dead = [
            nid for nid, n in self.nodes.items()
            if n.done and not n.pinned and not n.future_alive()
            and all(self.nodes[c].done for c in cons[nid])
        ]
        for nid in dead:
            del self.nodes[nid]

    def resolve(self, value: Any) -> Any:
        """NodeRef -> materialized result (must be done)."""
        if isinstance(value, NodeRef):
            node = self.nodes[value.node_id]
            if not node.done:
                raise RuntimeError(f"{node} consumed before evaluation")
            return node.result
        return value
