"""The "NumPy / Intel MKL" integration (paper §7, Listing 2).

SAs over jnp vector math.  Mirrors the paper's MKL integration: the
*library* functions are the jit-compiled jnp ops (hand-optimized black
boxes from Mozart's point of view), and the annotator supplies only split
types.  Exactly like the paper we generate most SAs from a table because
functions with matching signatures share an annotation shape.

Usage:
    from repro.core import annotated_numpy as anp
    with mozart.session(executor="scan") as ctx:
        d1 = anp.log1p(x); d2 = anp.add(d1, y); ...
        result = d2.value
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import jax.scipy.special

from repro.core import split_types as st
from repro.core.annotation import AnnotatedFn, SA, annotate, splittable
from repro.core.future import register_operator

__all_ops__: dict[str, AnnotatedFn] = {}


def _reg(name: str, fn: AnnotatedFn) -> AnnotatedFn:
    __all_ops__[name] = fn
    globals()[name] = fn
    return fn


# -- unary elementwise:  (S) -> S  ------------------------------------------
_UNARY = {
    "exp": jnp.exp, "log": jnp.log, "log1p": jnp.log1p, "sqrt": jnp.sqrt,
    "erf": jax.scipy.special.erf, "negative": jnp.negative, "abs": jnp.abs,
    "sin": jnp.sin, "cos": jnp.cos, "tanh": jnp.tanh, "sign": jnp.sign,
    "arcsin": jnp.arcsin, "arccos": jnp.arccos, "expm1": jnp.expm1,
    "square": jnp.square, "reciprocal": jnp.reciprocal, "floor": jnp.floor,
    "isnan": jnp.isnan, "logical_not": jnp.logical_not,
}

for _name, _fn in _UNARY.items():
    def _mk(f):
        def op(x):
            return f(x)
        return op
    _reg(_name, annotate(_mk(_fn), name=_name, elementwise=True,
                         x=st.Generic("S"), ret=st.Generic("S")))


# -- binary elementwise:  (S, S) -> S  (scalar operands broadcast) ----------
_BINARY = {
    "add": jnp.add, "subtract": jnp.subtract, "multiply": jnp.multiply,
    "divide": jnp.divide, "power": jnp.power, "maximum": jnp.maximum,
    "minimum": jnp.minimum, "greater": jnp.greater, "less": jnp.less,
    "equal": jnp.equal, "logical_and": jnp.logical_and,
    "logical_or": jnp.logical_or, "atan2": jnp.arctan2, "mod": jnp.mod,
}


class _BinarySpec(st.SplitSpec):
    """Generic S for array operands, broadcast for scalar operands."""

    def construct(self, value, bound, generics):
        # ``value is None`` = upstream dynamic-shape output: still an array.
        if value is not None and not getattr(value, "shape", ()):
            return st.BROADCAST
        if "S" not in generics:
            generics["S"] = st.GenericVar("S")
        return generics["S"]


for _name, _fn in _BINARY.items():
    def _mkb(f):
        def op(x, y):
            return f(x, y)
        return op
    _reg(_name, annotate(_mkb(_fn), name=_name, elementwise=True,
                         x=_BinarySpec(), y=_BinarySpec(), ret=st.Generic("S")))


# -- ternary ------------------------------------------------------------------
def _where(c, x, y):
    return jnp.where(c, x, y)


_reg("where", annotate(_where, name="where", elementwise=True,
                       c=_BinarySpec(), x=_BinarySpec(), y=_BinarySpec(),
                       ret=st.Generic("S")))


# -- reductions: (ArraySplit over axis) -> ReduceSplit --------------------------
# One split type per reduction merge op, exactly like the paper's NumPy
# integration ("we implemented split types for each reduction operator ...
# these only required merge functions").
def _make_reduction(name: str, red: Callable, merge_op: str):
    def op(x):
        return red(x)
    return _reg(name, annotate(op, name=name, x=st.Generic("S"),
                               ret=st.Reduce(merge_op)))


_make_reduction("sum", jnp.sum, "add")
_make_reduction("max", jnp.max, "max")
_make_reduction("min", jnp.min, "min")
_make_reduction("prod", jnp.prod, "mul")


def _sum_axis(x, axis):
    return jnp.sum(x, axis=axis)


class _AxisReduceRet(st.SplitSpec):
    """sum(m, axis): reducing the split axis yields partials (ReduceSplit);
    reducing another axis keeps the row split (ArraySplit over axis 0)."""

    def construct(self, value, bound, generics):
        axis = bound["axis"]
        if axis == 0:
            return st.ReduceSplit("add")
        return st.ArraySplit(tuple(value.shape), 0)


_reg("sum_axis", annotate(_sum_axis, name="sum_axis", static=("axis",),
                          x=st.Along(0), ret=_AxisReduceRet()))


# -- shape-changing ops: unknown split types (paper Ex. 4) --------------------
def _compress(mask, x):
    # NOTE: dynamic output shape -> not jit-able; Mozart runs it raw per chunk.
    import numpy as np
    mask = np.asarray(mask)
    xx = np.asarray(x)
    return jnp.asarray(xx[mask])


_compress_ann = annotate(_compress, name="compress",
                         mask=st.Generic("S"), x=st.Generic("S"), ret=st.Unknown())
_compress_ann.sa.dynamic = True
_compress_ann.sa.selective = "x"     # row-subset of x: pushdown-eligible
_reg("compress", _compress_ann)


# -- matrix ops (MKL L2 BLAS analogue) ----------------------------------------
def _matvec(m, v):
    return m @ v


_reg("matvec", annotate(_matvec, name="matvec",
                        m=st.Along(0), v=st._, ret=st.Along(0)))


def _matmul(a, b):
    return a @ b


# A @ B splits by rows of A; B is broadcast (the paper's matrix-panel split).
_reg("matmul", annotate(_matmul, name="matmul",
                        a=st.Along(0), b=st._, ret=st.Along(0)))


# -- axis-parameterized normalize (paper §3.1 example) -------------------------
def _normalize_axis(m, axis):
    mean = jnp.mean(m, axis=axis, keepdims=True)
    sd = jnp.std(m, axis=axis, keepdims=True) + 1e-9
    return (m - mean) / sd


class _MatrixSplitCtor(st.SplitSpec):
    """MatrixSplit(m, axis): split along the axis NOT being normalized."""

    def construct(self, value, bound, generics):
        axis = int(bound["axis"])
        split_axis = 1 - axis           # normalizing rows => split rows apart
        return st.ArraySplit(tuple(value.shape), split_axis)


_reg("normalize_axis", annotate(
    _normalize_axis, name="normalize_axis", static=("axis",),
    m=_MatrixSplitCtor(), ret=_MatrixSplitCtor()))


# -- operator table for Future dunders ---------------------------------------
for _op in ("add", "subtract", "multiply", "divide", "power", "negative"):
    register_operator(_op, __all_ops__[_op])


def __probe_examples__(n: int = 12) -> dict[str, Any]:
    """Tiny concrete inputs per op for the annotation contract checker
    (``core/analysis.py``): every value is chosen inside the op's domain
    (arcsin/log need (0,1)) so the MZ108 whole-vs-merged comparison tests
    the SA, not numerical edge cases.  Values may be a kwargs dict or a
    list of them (one check per variant)."""
    x = jnp.linspace(0.1, 0.9, n, dtype=jnp.float32)
    y = jnp.linspace(0.2, 1.1, n, dtype=jnp.float32)
    m = (jnp.arange(n * 4, dtype=jnp.float32).reshape(n, 4) + 1.0) / (n * 4)
    ex: dict[str, Any] = {name: {"x": x} for name in _UNARY}
    ex.update({name: {"x": x, "y": y} for name in _BINARY})
    ex["where"] = {"c": x > 0.5, "x": x, "y": y}
    ex.update({name: {"x": x} for name in ("sum", "max", "min", "prod")})
    ex["sum_axis"] = [{"x": m, "axis": 0}, {"x": m, "axis": 1}]
    ex["compress"] = {"mask": x > 0.4, "x": x}
    ex["matvec"] = {"m": m, "v": jnp.linspace(0.1, 1.0, 4, dtype=jnp.float32)}
    ex["matmul"] = {"a": m,
                    "b": jnp.linspace(0.1, 1.2, 12, dtype=jnp.float32).reshape(4, 3)}
    ex["normalize_axis"] = [{"m": m, "axis": 0}, {"m": m, "axis": 1}]
    return ex
