"""Runtime-wide failure domains: fault injection, degradation, retries.

The paper's promise is that Mozart optimizes *unmodified* library functions
while "respecting each function's correctness constraints" — which must
include the constraint of returning a correct answer when something breaks.
An intrusive IR (Weld) controls failure semantics inside the IR; an
annotation-based runtime proves instead that it can DEGRADE: fall down the
executor ladder, retry at chunk granularity, and shed serving load, without
ever returning a wrong result.  This module is the one place that policy
lives; the boundaries it guards call in from ``stage_exec``, ``executor``,
``cost_model``, ``plan_cache``, ``pipeline`` and ``serving``.

Three legs:

1. **Deterministic fault injection.**  ``MOZART_FAULTS=<spec>`` (or
   ``mozart.inject_faults(spec)`` as a context manager) arms failures at
   named boundaries — ``split``, ``chunk`` (drive), ``merge``, ``ingest``
   (handoff), ``compile`` (executor driver build), ``persist`` (plan-cache
   save), ``serve_step`` (batcher step).  Each armed spec fires a bounded
   number of times and then disarms, so every recovery path is testable and
   CI-gated with *exact* reproducibility: same spec, same crossing order,
   same failures.  Fired faults (and every recovery action) are recorded as
   MZ4xx events in the ``core/analysis.py`` vocabulary.

2. **Graceful degradation.**  ``run_stage`` is the stage-dispatch wrapper:
   when an executor raises a recoverable error at compile or drive time it
   demotes along ``DEGRADE_ORDER`` (pallas → scan/fused → pipelined →
   eager) until the stage completes, quarantines the broken choice in the
   plan entry (persisted — warm calls and restarted processes skip it) and
   ages the quarantine so the executor is eventually retried.  Chunk-loop
   resource exhaustion is handled below the ladder: ``core/executor.py``
   halves the chunk batch with bounded retries and re-pins the surviving
   size into the tuner state.

3. **Shared error taxonomy.**  ``TRANSIENT_ERRORS`` / ``PROBE_ERRORS``
   replace the runtime's bare ``except Exception`` swallows: probe/measure
   sites catch exactly the classes a library call can legitimately raise
   for "unavailable here" (never programming errors), and every swallow is
   counted (``stats["swallowed_errors"]``) so it is observable.  The
   seed-era ``repro.runtime.fault`` helpers (``with_retries``,
   ``StepTimer``, ``run_with_restarts``) live here now, on the same
   taxonomy and backoff policy; ``repro.runtime.fault`` re-exports them.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import logging
import os
import threading
import time
from typing import Any, Callable

log = logging.getLogger("repro.resilience")

__all__ = [
    "BOUNDARIES", "DEGRADE_ORDER", "FaultPlan", "FaultSpec", "InjectedFault",
    "InjectedResourceExhausted", "PROBE_ERRORS", "QUARANTINE_TTL", "StepFailure",
    "StepTimer", "FaultConfig", "TRANSIENT_ERRORS", "clear_events", "events",
    "inject_faults", "is_resource_exhausted", "maybe_fail", "note_swallowed",
    "record_event", "run_stage", "run_with_restarts", "stats", "with_retries",
]


# ---------------------------------------------------------------------------
# Error taxonomy
# ---------------------------------------------------------------------------


class StepFailure(RuntimeError):
    """A training/serving step failed after exhausting its retries."""


class InjectedFault(RuntimeError):
    """A deterministic fault armed by a :class:`FaultPlan` fired."""


class InjectedResourceExhausted(InjectedFault):
    """Injected stand-in for an XLA RESOURCE_EXHAUSTED / host MemoryError."""


#: errors a *retry* can plausibly fix: infrastructure/runtime failures
#: (XLA's XlaRuntimeError is a RuntimeError subclass), host I/O, memory
#: pressure.  ``TimeoutError``/``ConnectionError`` are OSError subclasses.
#: Programming errors (NameError, AttributeError, AssertionError) and
#: control-flow exceptions (KeyboardInterrupt, SystemExit) are deliberately
#: NOT here — retrying those hides bugs.
TRANSIENT_ERRORS: tuple = (RuntimeError, OSError, MemoryError)

#: errors a *probe* of one candidate/path may legitimately raise for "not
#: available on this input" — the transient classes plus the shape/dtype
#: rejections a library call makes before doing any work.  This is the
#: narrow replacement for the runtime's former bare ``except Exception``
#: swallows (tuner samples, cost-model measurement, fast-path equality,
#: best-effort device syncs).
PROBE_ERRORS: tuple = TRANSIENT_ERRORS + (
    ValueError, TypeError, ArithmeticError, NotImplementedError)


def is_resource_exhausted(e: BaseException) -> bool:
    """Whether ``e`` is memory pressure (halve the chunk batch and retry)
    rather than a generic failure (demote down the executor ladder)."""
    if isinstance(e, (MemoryError, InjectedResourceExhausted)):
        return True
    msg = str(e)
    return "RESOURCE_EXHAUSTED" in msg or "Out of memory" in msg


#: process-global resilience counters (benchmarks and tests read these;
#: per-session counts additionally land in ``ctx.stats``).
stats: collections.Counter = collections.Counter()

_stats_lock = threading.Lock()


def note_swallowed(where: str, e: BaseException, ctx=None) -> None:
    """Count a deliberately swallowed transient error so it is observable
    (the satellite fix for the former invisible ``except Exception`` sites)."""
    with _stats_lock:
        stats["swallowed_errors"] += 1
        stats[f"swallowed:{where}"] += 1
    if ctx is not None:
        ctx.stats["swallowed_errors"] += 1
    record_event("MZ406", f"{where}: {type(e).__name__}: {e}",
                 severity="info")


# ---------------------------------------------------------------------------
# Event log (MZ4xx records)
# ---------------------------------------------------------------------------

_EVENT_CAP = 512
_events: collections.deque = collections.deque(maxlen=_EVENT_CAP)


def record_event(code: str, where: str, severity: str = "warning") -> None:
    """Append one MZ4xx record (code, where) to the bounded process log and
    bump its counter.  Records become ``analysis.Diagnostic``s on demand
    (``events()``) — this path must not import the verifier."""
    with _stats_lock:
        stats[code] += 1
    _events.append((code, severity, where))


def events() -> list:
    """The recorded MZ4xx events as ``analysis.Diagnostic``s (most recent
    last)."""
    from repro.core.analysis import CODES, Diagnostic
    return [Diagnostic(code, sev, where, CODES.get(code, code))
            for code, sev, where in list(_events)]


def clear_events() -> None:
    """Reset the event log and the resilience counters (tests)."""
    _events.clear()
    with _stats_lock:
        stats.clear()


# ---------------------------------------------------------------------------
# Leg 1: deterministic fault injection
# ---------------------------------------------------------------------------

#: the named boundaries ``maybe_fail`` guards, in pipeline order.
BOUNDARIES = ("split", "chunk", "merge", "ingest", "compile", "persist",
              "serve_step")


@dataclasses.dataclass
class FaultSpec:
    """One armed failure: fire ``count`` times at ``boundary`` crossings
    whose ``where`` string contains ``match`` (empty = every crossing),
    after skipping the first ``after`` matching crossings."""

    boundary: str
    kind: str = "fail"                   # "fail" | "oom"
    count: int = 1
    match: str = ""
    after: int = 0

    def __post_init__(self) -> None:
        if self.boundary not in BOUNDARIES:
            raise ValueError(
                f"unknown fault boundary {self.boundary!r}; "
                f"known: {BOUNDARIES}")
        if self.kind not in ("fail", "oom"):
            raise ValueError(f"unknown fault kind {self.kind!r}")


class FaultPlan:
    """A set of armed :class:`FaultSpec`s with deterministic firing order.

    Firing is a pure function of the sequence of boundary crossings: each
    spec skips its first ``after`` matching crossings, then fires on the
    next ``count`` and disarms.  No randomness — the registry is seedable
    only in the sense that the *spec* decides everything, so a failing CI
    run reproduces exactly from its ``MOZART_FAULTS`` value."""

    def __init__(self, specs: list[FaultSpec]):
        self.specs = list(specs)
        self.fired: list[tuple[str, str]] = []      # (boundary, where)
        self._lock = threading.Lock()

    def check(self, boundary: str, where: str) -> None:
        armed = None
        with self._lock:
            for spec in self.specs:
                if spec.boundary != boundary or spec.count <= 0:
                    continue
                if spec.match and spec.match not in where:
                    continue
                if spec.after > 0:
                    spec.after -= 1
                    continue
                spec.count -= 1
                armed = spec
                self.fired.append((boundary, where))
                break
        if armed is None:
            return
        record_event("MZ401", f"{boundary} @ {where} (kind={armed.kind})")
        if armed.kind == "oom":
            raise InjectedResourceExhausted(
                f"injected RESOURCE_EXHAUSTED at {boundary} ({where})")
        raise InjectedFault(f"injected fault at {boundary} ({where})")

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse a ``MOZART_FAULTS`` spec string.

        Comma-separated entries ``boundary[:kind[:count[:match]]]``, e.g.
        ``compile:fail:1`` (first driver build fails),
        ``chunk:oom:2`` (first two chunk drives hit injected OOM),
        ``merge:fail:1:stage 0`` (first merge whose location names stage 0).
        An entry may append ``+N`` to the count to skip N crossings first:
        ``chunk:fail:1+3`` fires on the 4th crossing only."""
        specs = []
        for raw in spec.split(","):
            raw = raw.strip()
            if not raw:
                continue
            parts = raw.split(":", 3)
            boundary = parts[0]
            kind = parts[1] if len(parts) > 1 and parts[1] else "fail"
            count_s = parts[2] if len(parts) > 2 and parts[2] else "1"
            match = parts[3] if len(parts) > 3 else ""
            after = 0
            if "+" in count_s:
                count_s, after_s = count_s.split("+", 1)
                after = int(after_s)
            specs.append(FaultSpec(boundary, kind, int(count_s or 1),
                                   match, after))
        return cls(specs)


_active_plan: FaultPlan | None = None
_env_spec_seen: str | None = None


def _plan() -> FaultPlan | None:
    """The active plan: an explicit ``inject_faults`` install wins; else the
    ``MOZART_FAULTS`` env var (parsed once per distinct value, so a spent
    plan stays spent — deterministic counts, not per-read re-arming)."""
    global _active_plan, _env_spec_seen
    if _active_plan is not None:
        return _active_plan
    spec = os.environ.get("MOZART_FAULTS", "")
    if not spec:
        return None
    if spec != _env_spec_seen:
        _env_spec_seen = spec
        _active_plan = FaultPlan.parse(spec)
    return _active_plan


@contextlib.contextmanager
def inject_faults(spec: "str | FaultPlan"):
    """``mozart.inject_faults("chunk:oom:1")``: arm a fault plan for the
    duration of the ``with`` block; yields the plan so callers can inspect
    ``plan.fired`` afterwards.  Nesting replaces (the inner plan wins) and
    restores on exit."""
    global _active_plan
    plan = FaultPlan.parse(spec) if isinstance(spec, str) else spec
    prev = _active_plan
    _active_plan = plan
    try:
        yield plan
    finally:
        _active_plan = prev


def clear_faults() -> None:
    """Disarm everything, including an env-armed plan (tests)."""
    global _active_plan, _env_spec_seen
    _active_plan = None
    _env_spec_seen = os.environ.get("MOZART_FAULTS", "")


def maybe_fail(boundary: str, where: str = "") -> None:
    """The instrumented-boundary hook: a no-op (one global read) unless a
    plan is armed for ``boundary``."""
    plan = _plan()
    if plan is not None:
        plan.check(boundary, where)


# ---------------------------------------------------------------------------
# Leg 2: the executor degradation ladder
# ---------------------------------------------------------------------------

#: demotion order: on failure of an executor, the ladder continues from the
#: position after it — progressively fewer moving parts, ending at the
#: un-annotated library baseline which cannot be demoted further.  (Distinct
#: from ``cost_model.CANDIDATE_ORDER``, which is a *preference* order for
#: scoring; this is a *simplification* order for recovery.)
DEGRADE_ORDER = ("pallas", "sharded", "scan", "fused", "pipelined", "eager")

#: warm calls a quarantined executor sits out before it is retried — the
#: aging that keeps one transient compile failure from banning a strategy
#: forever.  Override per process with ``MOZART_QUARANTINE_TTL``.
QUARANTINE_TTL = int(os.environ.get("MOZART_QUARANTINE_TTL", "32"))


def demotion_ladder(name: str) -> list[str]:
    """Executors to try, in order, after ``name`` failed.  Unknown names
    (custom registrations, "auto") restart the ladder from the top minus
    the failed name; known names continue strictly downward."""
    if name in DEGRADE_ORDER:
        i = DEGRADE_ORDER.index(name)
        return list(DEGRADE_ORDER[i + 1:])
    return [n for n in DEGRADE_ORDER if n != name]


def _stage_retry_safe(ctx) -> bool:
    """A failed stage execution may be re-driven only if it has not already
    really donated chunk buffers to a driver (re-reading a donated chunk
    returns freed memory).  Donation marks are applied post-loop
    (``mark_stream_consumed``), so mid-loop failures leave streams intact —
    but a *successful* donate-then-fail-later sequence inside one attempt is
    detected via the per-attempt donation counter snapshot the caller
    takes."""
    return True   # the per-attempt check lives in run_stage via stats deltas


def run_stage(name: str, stage, graph, ctx, _tick: bool = True) -> None:
    """Dispatch one stage with the degradation ladder armed.

    The stage-dispatch sites (``runtime.evaluate``, the Pipeline build/fast
    paths, ``AutoExecutor``'s delegate) call this instead of
    ``get_executor(name).run``.  On a recoverable failure the stage is
    re-driven by the next executor down ``DEGRADE_ORDER``; the broken
    choice is quarantined in the plan entry (persisted — warm calls skip
    it) with TTL aging so it is eventually retried.  Unrecoverable errors
    (programming errors, sanitizer trips) propagate unchanged."""
    from repro.core.stage_exec import get_executor

    entry = getattr(ctx, "_plan_entry", None)
    blocked: set = set()
    if entry is not None:
        blocked = (entry.tick_quarantine(stage.id, QUARANTINE_TTL)
                   if _tick else entry.quarantined_execs(stage.id))

    first = name
    if name in blocked:
        # The requested executor is quarantined for this stage: skip straight
        # to the first healthy rung below it (counted, evented).
        for alt in demotion_ladder(name):
            if alt not in blocked:
                first = alt
                break
        ctx.stats["exec_quarantine_skips"] += 1
        record_event("MZ404", f"stage {stage.id}: {name} quarantined, "
                              f"dispatching {first}", severity="info")

    donated_before = ctx.stats.get("donated_chunks", 0)
    try:
        get_executor(first).run(stage, graph, ctx)
        return
    except PROBE_ERRORS as e:
        if first == "auto":
            # AutoExecutor's own delegate dispatch already runs this ladder
            # (with the pinned choice quarantined); an error escaping it
            # means every rung failed — re-laddering here would only repeat
            # the walk.
            raise
        last = e
        if not _recoverable(e, ctx, donated_before):
            raise

    failed = first
    for alt in demotion_ladder(first):
        if alt in blocked:
            continue
        if entry is not None:
            entry.quarantine_exec(stage.id, failed)
            record_event("MZ404", f"stage {stage.id}: quarantined {failed} "
                                  f"({type(last).__name__}: {last})")
        ctx.stats["exec_demotions"] += 1
        ctx.stats[f"exec_demoted_to_{alt}"] += 1
        record_event("MZ402", f"stage {stage.id}: {failed} -> {alt} "
                              f"({type(last).__name__})")
        log.warning("stage %s: executor %s failed (%s); demoting to %s",
                    stage.id, failed, last, alt)
        donated_before = ctx.stats.get("donated_chunks", 0)
        try:
            get_executor(alt).run(stage, graph, ctx)
            return
        except PROBE_ERRORS as e:
            last = e
            if not _recoverable(e, ctx, donated_before):
                raise
            failed = alt
    raise last


def _recoverable(e: BaseException, ctx, donated_before: int) -> bool:
    """Whether a failed stage attempt may be re-driven by another executor.

    Sanitizer trips are invariant violations, never demoted around; and an
    attempt that already really donated chunk buffers must not be re-driven
    (the donated chunks are freed — re-reading them is undefined)."""
    from repro.core.stage_exec import SanitizerError
    if isinstance(e, SanitizerError):
        return False
    if ctx.stats.get("donated_chunks", 0) != donated_before:
        return False
    return True


# ---------------------------------------------------------------------------
# Leg 2b: chunk-granular OOM policy (used by core/executor.py)
# ---------------------------------------------------------------------------

#: bounded halvings of the chunk batch on resource exhaustion before the
#: failure propagates (to the ladder, which demotes executors).
MAX_OOM_HALVINGS = 4


# ---------------------------------------------------------------------------
# Leg 3 helpers + absorbed seed-era fault tolerance (runtime/fault.py)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FaultConfig:
    max_retries_per_step: int = 2
    max_restarts: int = 3
    #: straggler watchdog: a step slower than median * factor is flagged
    straggler_factor: float = 3.0
    straggler_window: int = 20
    min_steps_for_baseline: int = 5
    #: base sleep between retries; attempt ``i`` backs off ``base * 2**i``
    backoff_s: float = 0.0


class StepTimer:
    """Rolling per-step wall-clock stats + straggler flagging.

    On a real fleet ``on_straggler`` triggers re-slicing or pod eviction; on
    this container it logs — the control flow is identical and unit-tested
    (tests/test_resilience.py), only the actuator differs."""

    def __init__(self, cfg: FaultConfig,
                 on_straggler: Callable[[int, float, float], None] | None = None):
        self.cfg = cfg
        self.times: list[float] = []
        self.stragglers: list[int] = []
        self.on_straggler = on_straggler

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler vs the rolling median."""
        window = self.times[-self.cfg.straggler_window:]
        is_straggler = False
        if len(window) >= self.cfg.min_steps_for_baseline:
            med = sorted(window)[len(window) // 2]
            if seconds > med * self.cfg.straggler_factor:
                is_straggler = True
                self.stragglers.append(step)
                with _stats_lock:
                    stats["stragglers"] += 1
                log.warning("step %d took %.3fs (median %.3fs): straggler",
                            step, seconds, med)
                if self.on_straggler:
                    self.on_straggler(step, seconds, med)
        self.times.append(seconds)
        return is_straggler


def with_retries(fn: Callable[[], Any], *, retries: int,
                 on_retry: Callable[[int, Exception], None] | None = None,
                 backoff_s: float = 0.0) -> Any:
    """Run ``fn``; retry the shared transient classes with exponential
    backoff (the paper-world analogue of a preempted host re-issuing a
    step).  Non-transient errors propagate immediately."""
    last: Exception | None = None
    for attempt in range(retries + 1):
        try:
            return fn()
        except TRANSIENT_ERRORS as e:
            last = e
            with _stats_lock:
                stats["step_retries"] += 1
            log.warning("step attempt %d failed: %s", attempt, e)
            if on_retry:
                on_retry(attempt, e)
            if backoff_s and attempt < retries:
                time.sleep(backoff_s * (2 ** attempt))
    raise StepFailure(f"exhausted {retries} retries") from last


def run_with_restarts(
    make_state: Callable[[int | None], tuple[Any, int]],
    run_from: Callable[[Any, int], Any],
    *,
    fault_cfg: FaultConfig,
    latest_step: Callable[[], int | None],
):
    """Full restart loop: build state (fresh or from the latest checkpoint),
    run; on a transient failure rebuild from the newest complete checkpoint
    and continue.  Returns the final result of ``run_from``.

    make_state(step|None) -> (state, start_step)
    run_from(state, start_step) -> result       (raises on fatal error)
    """
    restarts = 0
    while True:
        ckpt = latest_step()
        state, start = make_state(ckpt)
        try:
            return run_from(state, start)
        except TRANSIENT_ERRORS as e:       # restart boundary
            restarts += 1
            with _stats_lock:
                stats["restarts"] += 1
            log.error("run crashed at restart %d: %s", restarts, e)
            if restarts > fault_cfg.max_restarts:
                raise
            time.sleep(min(fault_cfg.backoff_s * (2 ** restarts), 2.0)
                       if fault_cfg.backoff_s else 0.1)
