"""Plan cache + auto-tuner state: skip the planner for repeated pipelines.

The paper's Mozart re-plans every ``evaluate()``.  Weld-style lazy systems
show that the cross-call win comes from *caching* the materialized plan: the
second execution of an identical pipeline should touch neither the planner
nor the split-type unifier.  This module provides that cache.

**Key.**  A pipeline is identified by a structural fingerprint of the
pending dataflow graph: per node, the annotated function's identity, the
aliasing pattern of its arguments (which argument is which external value /
which earlier node), static argument values, the *constructed* split types
(with SA-local generics normalized and ``unknown`` uids erased), and the
shapes/dtypes of every external input and abstract output.  Context knobs
that change planning or batch sizing (``executor``, ``chip``, ``pipeline``)
are part of the key; concrete array *values* are not — calling the same
pipeline on fresh data of the same shape is a hit.

**Template.**  A hit does not reuse ``Stage`` objects (they reference the
prior call's nodes); it re-instantiates them from a symbolic template that
names values by (node position, argument name).  Escaping-output sets are
recomputed per instantiation because they depend on which ``Future`` handles
are still alive *this* call.

**Auto-tuner.**  Each cache entry owns ``tuned_batch``: on the first
execution of a cached plan, ``StageExecutor._tune`` measures 2–3 candidate
chunk sizes around the §5.2 VMEM-derived estimate (a bounded *sample* of
chunks per candidate, extrapolated) and pins the fastest here; later hits
reuse the pinned size via ``StageExecutor.choose_batch``.  Under
``executor="auto"`` the entry additionally owns ``chosen_exec`` (the pinned
per-stage executor) and ``exec_timings`` (measured seconds per candidate
executor) — the cost model's measured feedback (``core/cost_model.py``).

**Persistence.**  ``save(path)`` / ``load(path)`` serialize fingerprints,
stage templates, tuned batches and chosen executors to a versioned JSON file
so a restarted process replays pinned plans with zero planner calls and zero
tuning executions.  A schema-version + chip guard rejects stale or
cross-chip files (cold planning, never a crash); saves write through a temp
file + fsync + atomic rename under an advisory file lock, merging the
on-disk entries first, so concurrent sessions can neither corrupt the file
nor drop each other's entries.  Entries
whose split types cannot round-trip structurally are skipped.  Rehydrated
entries carry function *names* instead of live objects; the first lookup
match binds the current process's ``AnnotatedFn`` identities.

Values that cannot be fingerprinted (no shape/dtype, no
``mozart_fingerprint()`` hook) make a pipeline *uncacheable* — it is planned
from scratch every time, which is always correct, merely slower.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import json
import os
import threading
from typing import Any

import jax

from repro import hardware
from repro.core import split_types as st
from repro.core.graph import DataflowGraph, Node, NodeRef
from repro.core.planner import Stage, StageInput, _value_key, plan

_MAX_ENTRIES = 256

#: serialized file format version; bump on any layout change.
#: v2: handoff decisions, pallas block shapes, auto exec_meta shape buckets.
#: v3: ``convert_in`` on handoff records (ConcatSplit→ArraySplit edges).
#: v4: ``shard_in`` (sharded-form stream ingests) and ``vetoed`` (recorded
#:     donation vetoes, for the staleness aging path) on handoff records.
#: v5: ``bucket`` — the serving-scheduler bucket label a pinned entry was
#:     compiled for (``Pipeline.compile(bucket=...)``).
#: v6: ``quarantined`` — per-stage executor quarantine ages (resilience
#:     degradation ladder), persisted so a restarted process keeps skipping
#:     a strategy that crashed its predecessor until the quarantine ages out.
#: v7: ``rewrites`` — the MZ5xx rewrite-justification records of the static
#:     graph rewrite pass (``core/rewrite.py``) that produced this entry's
#:     (rewritten) graph, persisted so warm-started processes can report why
#:     the replayed plan differs from the captured program.
SCHEMA_VERSION = 7

#: older schemas the loader can migrate forward in place.  v2 files differ
#: from v3/v4 only by the absence of ``convert_in`` on handoff records, and
#: v3 from v4 by the absence of ``shard_in``/``vetoed`` — all of which
#: default to empty, correct for every pre-bump plan (the rules did not
#: exist, so no recorded decision could have used them; an empty ``vetoed``
#: merely means the aging path has nothing to reconsider until the first
#: re-analysis).  v4 files lack only ``bucket``, which defaults to None
#: (unlabelled) — correct for every pre-serving plan.  v5 files lack only
#: ``quarantined``, which defaults to empty — correct for every pre-resilience
#: plan (nothing had been observed to fail, so nothing is quarantined).
#: v6 files lack only ``rewrites``, which defaults to empty — correct for
#: every pre-rewrite plan: the pass postdates them, and any graph the pass
#: *would* rewrite fingerprints to a different key than the unrewritten one,
#: so a v6 entry can only ever be hit by a capture the pass left alone.
_MIGRATABLE_SCHEMAS = (2, 3, 4, 5, 6)

#: process-global cache statistics (benchmarks report these).
stats: collections.Counter = collections.Counter()

_lock = threading.Lock()
_entries: "collections.OrderedDict[tuple, PlanEntry]" = collections.OrderedDict()
_loaded_paths: set[str] = set()

#: In-process side table of PINNED COMPILED EXECUTABLES (jitted fused/scan
#: drivers, shard_map closures, Pallas kernel launchers), keyed by the same
#: persisted fingerprint as the entries.  Executables cannot be serialized,
#: so they live here rather than on ``PlanEntry``: a process that warm-starts
#: from ``MOZART_PLAN_CACHE`` rehydrates the entry from disk, compiles each
#: stage executable exactly once on its first execution, and then replays it
#: for the life of the process.  Populated by ``stage_exec.pinned_jit``.
_exec_tables: dict[tuple, dict] = {}

#: monotone version of the persistable state; ``save`` skips the disk write
#: when the target file already reflects the current version (steady-state
#: serving sessions save on every exit — almost all are no-ops).
_mutations = 0
_saved_versions: dict[str, int] = {}


def _mark_dirty() -> None:
    global _mutations
    _mutations += 1


def clear() -> None:
    """Drop every cached plan and reset the global counters (tests).  Pinned
    executables go too: ``clear()`` simulates a full process restart."""
    with _lock:
        _entries.clear()
        _exec_tables.clear()
        stats.clear()
        _loaded_paths.clear()
        _mark_dirty()


def cache_info() -> dict[str, int]:
    with _lock:
        return {"entries": len(_entries), **stats}


def entries() -> list["PlanEntry"]:
    with _lock:
        return list(_entries.values())


def tuned_batches() -> dict[tuple[int, int], int]:
    """(entry uid, stage_id) -> pinned chunk size (diagnostics).  Stage ids
    restart at 0 per plan, so the stable per-entry uid (not the LRU position,
    which reshuffles on every hit) keeps pipelines distinct."""
    out: dict[tuple[int, int], int] = {}
    for e in entries():
        for sid, batch in dict(e.tuned_batch).items():
            out[(e.uid, sid)] = batch
    return out


# ---------------------------------------------------------------------------
# Fingerprinting
# ---------------------------------------------------------------------------


def value_fingerprint(v: Any, with_value: bool = False) -> tuple | None:
    """Shape/dtype-level identity of an external value; None = uncacheable.

    Numeric scalars are keyed by *type only* unless ``with_value`` (static
    arguments): a pipeline driven with a changing rate/step scalar must still
    hit the cache — any plan-relevant effect of the value already shows up in
    the constructed split types and output avals, which the key captures, and
    instantiation rebinds the current call's values.  Custom containers
    (tables, corpora) opt in via a ``mozart_fingerprint()`` method returning
    a hashable tuple of their leaves' shapes/dtypes.
    """
    hook = getattr(v, "mozart_fingerprint", None)
    if callable(hook):
        return hook()
    if isinstance(v, (bool, int, float, complex)):
        return ("py", type(v).__name__, v) if with_value else ("py", type(v).__name__)
    if isinstance(v, (str, bytes, type(None))):
        return ("py", type(v).__name__, v)
    if hasattr(v, "shape") and hasattr(v, "dtype"):
        return ("arr", tuple(v.shape), str(v.dtype))
    if isinstance(v, (tuple, list)):
        parts = tuple(value_fingerprint(x, with_value) for x in v)
        if any(p is None for p in parts):
            return None
        return ("seq", type(v).__name__, parts)
    if isinstance(v, dict):
        items = []
        for k in sorted(v, key=repr):
            p = value_fingerprint(v[k], with_value)
            if p is None:
                return None
            items.append((repr(k), p))
        return ("map", tuple(items))
    return None


def _aval_fingerprint(aval: Any) -> tuple | None:
    if aval is None:
        return ("dynamic",)
    leaves, treedef = jax.tree_util.tree_flatten(aval)
    leaf_fps = []
    for l in leaves:
        shape = getattr(l, "shape", None)
        dtype = getattr(l, "dtype", None)
        if shape is None or dtype is None:
            return None
        leaf_fps.append((tuple(shape), str(dtype)))
    return (str(treedef), tuple(leaf_fps))


def _type_fingerprint(t: Any, varmap: dict[int, int]) -> tuple | None:
    if isinstance(t, st.GenericVar):
        return ("var", varmap.setdefault(t.uid, len(varmap)))
    if isinstance(t, st.UnknownSplit):
        return ("unknown", t.axis)       # uid erased: unknowns are structural here
    if not isinstance(t, st.SplitType):
        return None
    try:
        hash(t.params)
    except TypeError:
        return None
    return ("T", t.name, t.params)


def fingerprint(pending: list[Node], graph: DataflowGraph, ctx) -> tuple | None:
    """Structural key of the pending graph, or None if uncacheable."""
    pos = {n.id: i for i, n in enumerate(pending)}
    ext_index: dict[int, int] = {}       # id(value) -> alias slot
    done_index: dict[int, int] = {}      # done node_id -> alias slot
    node_fps = []
    for n in pending:
        varmap: dict[int, int] = {}      # generics are fresh per call/node
        arg_fps = []
        for name, v in n.bound.items():
            if name in n.fn.sa.static:
                f = value_fingerprint(v, with_value=True)   # baked into jit
                if f is None:
                    return None
                arg_fps.append(("static", name, f))
            elif isinstance(v, NodeRef):
                if v.node_id in pos:
                    arg_fps.append(("ref", name, pos[v.node_id]))
                else:
                    src = graph.nodes.get(v.node_id)
                    f = _aval_fingerprint(src.out_aval) if src is not None else None
                    if f is None:
                        return None
                    slot = done_index.setdefault(v.node_id, len(done_index))
                    arg_fps.append(("done", name, slot, f))
            else:
                f = value_fingerprint(v)
                if f is None:
                    return None
                # alias slot: add(x, x) and add(x, y) must key differently
                slot = ext_index.setdefault(id(v), len(ext_index))
                arg_fps.append(("ext", name, slot, f))
        type_fps = []
        for name in n.bound:
            if name in n.fn.sa.static:
                continue
            f = _type_fingerprint(n.arg_types[name], varmap)
            if f is None:
                return None
            type_fps.append((name, f))
        out_fp = _type_fingerprint(n.out_type, varmap)
        if out_fp is None:
            return None
        aval_fp = _aval_fingerprint(n.out_aval)
        if aval_fp is None:
            return None
        node_fps.append((n.fn.name, tuple(arg_fps), tuple(type_fps), out_fp, aval_fp))
    return context_key_prefix(ctx) + (tuple(node_fps),)


def context_key_prefix(ctx) -> tuple:
    """The context-knob part of every fingerprint: the planning/executor
    configuration a plan was cached under.  Mesh geometry is included: under
    "auto" a pinned `sharded` choice (or a batch tuned for one mesh extent)
    must never replay in a session with a different mesh — or none at all.
    The ``handoff`` flag is included because recorded handoff decisions only
    apply under the configuration they were analyzed for.  ``configure()``
    uses this prefix to re-key entries when knobs change mid-session
    (``rekey_config``)."""
    mesh_fp = None
    if ctx.mesh is not None:
        mesh_fp = tuple((str(a), int(ctx.mesh.shape[a])) for a in ctx.data_axes)
    return (ctx.executor, ctx.chip.name, bool(ctx.pipeline), mesh_fp,
            bool(getattr(ctx, "handoff", True)))


_PREFIX_LEN = 5

#: prefix component indices (kept in sync with ``context_key_prefix``).
_P_EXEC, _P_CHIP, _P_PIPE, _P_MESH, _P_HANDOFF = range(_PREFIX_LEN)


def rekey_config(old_prefix: tuple, new_prefix: tuple,
                 only_keys: set | None = None) -> int:
    """Migrate cached plans across a mid-session ``configure()`` knob change.

    Entries keyed under ``old_prefix`` would never be hit by the reconfigured
    context again — without this, a knob change silently replans from
    scratch while fresh entries accumulate beside the stale ones.  Stage
    *templates* are executor-independent (the planner keys only off the
    ``pipeline`` flag), so each matching entry is COPIED to ``new_prefix``.
    Executor-AGNOSTIC measured state migrates with it: tuned chunk sizes,
    their trial history and pinned Pallas block shapes were measured by
    re-running the library functions on this chip/mesh and stay valid when
    only the executor (or handoff) knob changed; they are dropped when the
    chip or mesh changed (measured on different hardware).  Executor-
    SELECTION state (``chosen_exec``/``exec_timings``) never migrates — it
    is what the knob change invalidates.  Handoff decisions are structural
    (a function of the templates) but EXECUTOR-SCOPED since ``shard_in``
    (sharded-form ingests are only safe under a shard-capable executor), so
    they migrate only when the executor knob did not change; otherwise the
    copy re-analyzes on first use (``handoff.resolve_decisions`` — zero
    planner calls, O(edges)).  The originals stay
    in place: other sessions and compiled ``Pipeline``s may still be
    executing under the old configuration, and popping their entry (or its
    pinned executables) would break their zero-retrace guarantee mid-flight.
    A ``pipeline`` flag change alters plan structure itself, so nothing is
    copied (the new config plans fresh).  ``only_keys`` scopes the copy to
    the entries the configuring context actually used.  Returns the number
    of entries re-keyed."""
    if old_prefix == new_prefix:
        return 0
    structural = old_prefix[_P_PIPE] != new_prefix[_P_PIPE]
    same_hw = (old_prefix[_P_CHIP] == new_prefix[_P_CHIP]
               and old_prefix[_P_MESH] == new_prefix[_P_MESH])
    moved = 0
    with _lock:
        for key in [k for k in _entries if k[:_PREFIX_LEN] == old_prefix]:
            if only_keys is not None and key not in only_keys:
                continue
            if structural:
                stats["rekey_skipped_structural"] += 1
                continue
            new_key = new_prefix + key[_PREFIX_LEN:]
            if new_key in _entries:
                continue                             # existing entry wins
            e = _entries[key]
            stats["rekeyed"] += 1
            copy = PlanEntry(
                key=new_key, stage_templates=e.stage_templates,
                fns=e.fns, fn_names=e.fn_names, loaded=e.loaded,
                handoff=(e.handoff
                         if old_prefix[_P_EXEC] == new_prefix[_P_EXEC]
                         else None))
            if same_hw:
                with e._lock:
                    copy.tuned_batch = dict(e.tuned_batch)
                    copy.trials = {k: list(v) for k, v in e.trials.items()}
                    copy.block_shape = dict(e.block_shape)
                    # Quarantines are observations of this hardware crashing
                    # a strategy — they follow the tuned state, not the knob.
                    copy.quarantined = {k: dict(v)
                                        for k, v in e.quarantined.items()}
                stats["rekey_migrated_tuned"] += len(copy.tuned_batch)
            _entries[new_key] = copy
            moved += 1
        _mark_dirty()
    return moved


# ---------------------------------------------------------------------------
# Plan templates
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _StageTemplate:
    positions: list[int]                             # indices into pending list
    inputs: list[tuple[tuple, st.SplitType]]          # (desc, resolved split type)
    out_types: dict[int, st.SplitType]                # position -> resolved type
    arg_types: dict[tuple[int, str], st.SplitType]    # (position, argname) -> type


_entry_uids = iter(range(1 << 62))


@dataclasses.dataclass
class PlanEntry:
    key: tuple
    stage_templates: list[_StageTemplate]
    fns: tuple | None                                # per-node AnnotatedFn identity
    fn_names: tuple = ()                             # per-node fn names (persistable)
    uid: int = dataclasses.field(default_factory=lambda: next(_entry_uids))
    tuned_batch: dict[int, int] = dataclasses.field(default_factory=dict)
    trials: dict[int, list[tuple[int, float]]] = dataclasses.field(default_factory=dict)
    #: executor="auto": pinned per-stage executor name (cost_model feedback).
    chosen_exec: dict[int, str] = dataclasses.field(default_factory=dict)
    #: executor="auto": measured seconds per (stage, candidate executor).
    exec_timings: dict[int, dict[str, float]] = dataclasses.field(default_factory=dict)
    #: executor="auto": shape context a pinned choice was measured at
    #: (element count + log2 bucket) — the re-measurement aging policy
    #: compares warm-call shapes against it (``cost_model``).
    exec_meta: dict[int, dict] = dataclasses.field(default_factory=dict)
    #: pallas: pinned (sublane, lane-multiple) block shape per stage — the
    #: tuner rounds candidates to valid block multiples and records the
    #: winner here (``pallas_exec.PallasExecutor``).
    block_shape: dict[int, tuple] = dataclasses.field(default_factory=dict)
    #: cross-stage chunk handoff decisions (``handoff.analyze``), keyed by
    #: stage id; None = not analyzed (handoff disabled / pre-analysis entry).
    handoff: dict | None = None
    #: consecutive calls whose Future liveness disagreed with the recorded
    #: donation decisions; at ``handoff.STALE_THRESHOLD`` the decisions
    #: re-analyze (``handoff.resolve_decisions``).  Runtime-only — never
    #: persisted: a warm-started process re-observes staleness from zero.
    ho_age: int = 0
    #: serving-scheduler bucket label this entry was pinned for
    #: (``Pipeline.compile(bucket=...)``); None = not bucket-labelled.  Purely
    #: descriptive — lookup is still by structural fingerprint — but persisted
    #: so a warm-started server can report which (batch, length) buckets its
    #: plan file covers before replaying them.
    bucket: tuple | None = None
    #: resilience: per-stage quarantined executors, ``{stage_id: {name: age}}``.
    #: A name lands here when that executor failed at compile or drive time
    #: and the stage completed via the degradation ladder; warm calls skip
    #: quarantined names.  ``age`` counts stage dispatches since quarantine —
    #: at ``resilience.QUARANTINE_TTL`` the name is dropped and retried
    #: (one transient crash must not ban a strategy forever).  Persisted, so
    #: a restarted process does not re-crash on a known-bad pin.
    quarantined: dict[int, dict[str, int]] = dataclasses.field(default_factory=dict)
    #: MZ5xx rewrite-justification records (``RewriteRecord.to_json()`` dicts)
    #: of the static rewrite pass that produced this entry's graph, including
    #: MZ505 declines.  Persisted (schema v7): a warm-started process replays
    #: the rewritten graph and can still report why it looks the way it does.
    rewrites: list = dataclasses.field(default_factory=list)
    #: warm hits since the last periodic re-analysis tick
    #: (``MOZART_REANALYZE_EVERY``).  Runtime-only, never persisted.
    evals_since_reanalysis: int = 0
    #: stage ids whose pinned executor choice the next dispatch must re-check
    #: against the cost model's drift test (set by the re-analysis tick,
    #: consumed by ``cost_model.AutoExecutor``).  Runtime-only.
    recheck_stages: set = dataclasses.field(default_factory=set)
    hits: int = 0
    loaded: bool = False                             # rehydrated from disk
    _lock: threading.Lock = dataclasses.field(default_factory=threading.Lock)
    _tuning: set = dataclasses.field(default_factory=set)

    def matches(self, pending: list[Node]) -> bool:
        """Guard against hash collisions / interpreter id() reuse: the cached
        plan applies only if every node still calls the same function object.
        Rehydrated entries (``fns is None``) have no live objects yet — they
        match on function names (the key already pins the full structure) and
        the caller binds identities on the first hit via ``bind_fns``."""
        if self.fns is None:
            return len(pending) == len(self.fn_names) and all(
                n.fn.name == name for n, name in zip(pending, self.fn_names)
            )
        return len(pending) == len(self.fns) and all(
            n.fn is f for n, f in zip(pending, self.fns)
        )

    def bind_fns(self, pending: list[Node]) -> None:
        with self._lock:
            if self.fns is None:
                self.fns = tuple(n.fn for n in pending)

    def try_claim_tuning(self, stage_id: int) -> bool:
        """Exactly one session tunes a stage; racers run with the estimate."""
        with self._lock:
            if stage_id in self.tuned_batch or stage_id in self._tuning:
                return False
            self._tuning.add(stage_id)
            return True

    def release_tuning(self, stage_id: int) -> None:
        with self._lock:
            self._tuning.discard(stage_id)

    def pin(self, stage_id: int, batch: int) -> None:
        with self._lock:
            self.tuned_batch[stage_id] = int(batch)
            self._tuning.discard(stage_id)
        _mark_dirty()

    def record_trial(self, stage_id: int, batch: int, seconds: float) -> None:
        with self._lock:
            self.trials.setdefault(stage_id, []).append((int(batch), seconds))

    # -- executor auto-selection state (cost model feedback) ----------------
    def try_claim_exec(self, stage_id: int) -> bool:
        """Exactly one session measures executors for a stage."""
        with self._lock:
            if stage_id in self.chosen_exec or ("exec", stage_id) in self._tuning:
                return False
            self._tuning.add(("exec", stage_id))
            return True

    def release_exec(self, stage_id: int) -> None:
        with self._lock:
            self._tuning.discard(("exec", stage_id))

    def pin_exec(self, stage_id: int, name: str, n: int | None = None) -> None:
        with self._lock:
            self.chosen_exec[stage_id] = str(name)
            if n is not None:
                self.exec_meta[stage_id] = {
                    "n": int(n), "bucket": int(n).bit_length()}
            self._tuning.discard(("exec", stage_id))
        _mark_dirty()

    def unpin_exec(self, stage_id: int) -> None:
        """Age out a pinned executor choice (shape-drift re-measurement)."""
        with self._lock:
            self.chosen_exec.pop(stage_id, None)
            self.exec_meta.pop(stage_id, None)
        _mark_dirty()

    def pin_block_shape(self, stage_id: int, shape: tuple) -> None:
        shape = tuple(int(x) for x in shape)
        with self._lock:
            if self.block_shape.get(stage_id) == shape:
                return                   # idempotent: no save-dirtying spam
            self.block_shape[stage_id] = shape
        _mark_dirty()

    def record_exec_timing(self, stage_id: int, name: str, seconds: float) -> None:
        """Fresh measurements overwrite whatever was recorded (or poisoned)."""
        with self._lock:
            self.exec_timings.setdefault(stage_id, {})[str(name)] = float(seconds)
        _mark_dirty()

    # -- executor quarantine (resilience degradation ladder) -----------------
    def quarantine_exec(self, stage_id: int, name: str) -> None:
        """Ban ``name`` for this stage until the quarantine ages out."""
        with self._lock:
            self.quarantined.setdefault(int(stage_id), {})[str(name)] = 0
        _mark_dirty()

    def quarantined_execs(self, stage_id: int) -> set:
        """The currently banned executor names for a stage (read-only)."""
        with self._lock:
            return set(self.quarantined.get(int(stage_id), ()))

    def tick_quarantine(self, stage_id: int, ttl: int) -> set:
        """Age this stage's quarantines by one dispatch; names reaching
        ``ttl`` are dropped (eligible again).  Returns the still-banned set.
        Called once per stage dispatch (``resilience.run_stage``)."""
        with self._lock:
            ages = self.quarantined.get(int(stage_id))
            if not ages:
                return set()
            expired = []
            for name in ages:
                ages[name] += 1
                if ages[name] >= ttl:
                    expired.append(name)
            for name in expired:
                del ages[name]
            if not ages:
                del self.quarantined[int(stage_id)]
            alive = set(ages or ())
        if expired:
            _mark_dirty()
        return alive

    # -- pinned compiled executables (in-process, keyed by fingerprint) ------
    def exec_table(self) -> dict:
        """The entry's compiled-executable table (see ``_exec_tables``).

        Keyed by ``(stage position, kind, *geometry)`` — never by per-call
        node ids — so every instantiation of this template resolves to the
        same jitted callable and warm calls never retrace."""
        t = _exec_tables.get(self.key)
        if t is None:
            with _lock:
                t = _exec_tables.setdefault(self.key, {})
        return t


def _make_templates(stages: list[Stage], pending: list[Node]) -> list[_StageTemplate] | None:
    pos = {n.id: i for i, n in enumerate(pending)}
    templates = []
    for s in stages:
        inputs: list[tuple[tuple, st.SplitType]] = []
        for key, si in s.inputs.items():
            v = si.value
            if isinstance(v, NodeRef) and v.node_id in pos:
                desc: tuple = ("node", pos[v.node_id])
            else:
                # name the value symbolically: "arg <name> of node <position>"
                desc = ()
                for n in s.nodes:
                    for name, bv in n.bound.items():
                        if name not in n.fn.sa.static and _value_key(bv) == key:
                            desc = ("arg", pos[n.id], name)
                            break
                    if desc:
                        break
                if not desc:
                    return None          # value not reachable from bound args
            inputs.append((desc, si.split_type))
        templates.append(_StageTemplate(
            positions=[pos[n.id] for n in s.nodes],
            inputs=inputs,
            out_types={pos[nid]: t for nid, t in s.out_types.items()},
            arg_types={(pos[nid], name): t for (nid, name), t in s.arg_types.items()},
        ))
    return templates


def _instantiate(entry: PlanEntry, pending: list[Node],
                 graph: DataflowGraph) -> list[Stage]:
    consumers = graph.consumers()
    stages: list[Stage] = []
    for sid, tm in enumerate(entry.stage_templates):
        nodes = [pending[p] for p in tm.positions]
        node_ids = {n.id for n in nodes}
        inputs: dict[tuple, StageInput] = {}
        for desc, t in tm.inputs:
            if desc[0] == "node":
                val: Any = NodeRef(pending[desc[1]].id)
            else:
                val = pending[desc[1]].bound[desc[2]]
            key = _value_key(val)
            inputs[key] = StageInput(key, val, t)
        out_types = {pending[p].id: t for p, t in tm.out_types.items()}
        # Escaping outputs depend on which Futures are alive *this* call.
        escaping: set[int] = set()
        for n in nodes:
            ext = any(c not in node_ids for c in consumers.get(n.id, []))
            if ext or n.future_alive():
                escaping.add(n.id)
            n.stage_id = sid
        arg_types = {(pending[p].id, name): t
                     for (p, name), t in tm.arg_types.items()}
        stages.append(Stage(sid, nodes, inputs, out_types, escaping, arg_types))
    return stages


# ---------------------------------------------------------------------------
# Lookup
# ---------------------------------------------------------------------------


def lookup_or_plan(pending: list[Node], graph: DataflowGraph,
                   ctx) -> tuple[list[Stage], PlanEntry | None]:
    """Return (stages, cache entry or None).  Counts live in ``ctx.stats``:
    ``planner_calls`` increments only when the planner actually runs.

    The static rewrite pass (``core/rewrite.py``) runs FIRST, so everything
    downstream — fingerprint, planner, handoff analysis, templates — sees the
    rewritten graph.  The rewrite is cheap, deterministic pure Python: warm
    calls re-run it per capture and land on the rewritten graph's cache key,
    replaying the optimized plan with zero planner calls and zero retraces."""
    from repro.core import rewrite as rewrite_mod
    rw = rewrite_mod.apply(pending, graph, ctx)
    pending = rw.pending
    ctx._last_rewrites = rw.records
    max_nodes = None if ctx.pipeline else 1
    if not pending:                      # the rewriter eliminated every node
        return [], None
    if not getattr(ctx, "plan_cache", True):
        ctx.stats["planner_calls"] += 1
        return plan(pending, graph, max_stage_nodes=max_nodes), None

    key = fingerprint(pending, graph, ctx)
    if key is None:
        with _lock:
            stats["uncacheable"] += 1
        ctx.stats["plan_cache_uncacheable"] += 1
        ctx.stats["planner_calls"] += 1
        return plan(pending, graph, max_stage_nodes=max_nodes), None

    with _lock:
        entry = _entries.get(key)
        hit = entry is not None and entry.matches(pending)
        if hit:
            _entries.move_to_end(key)
            entry.hits += 1
            stats["hits"] += 1
            if entry.loaded:
                stats["warm_hits"] += 1
        else:
            stats["misses"] += 1
    if hit:
        if entry.fns is None:
            entry.bind_fns(pending)      # rehydrated entry: bind live identities
        ctx.stats["plan_cache_hits"] += 1
        _note_entry_key(ctx, key)        # configure() rekeys only owned entries
        _maybe_reanalyze(ctx, entry, rw.records)
        # O(graph) template instantiation happens outside the global lock so
        # concurrent sessions on different pipelines don't serialize here.
        return _instantiate(entry, pending, graph), entry
    ctx.stats["plan_cache_misses"] += 1
    ctx.stats["planner_calls"] += 1
    stages = plan(pending, graph, max_stage_nodes=max_nodes)
    templates = _make_templates(stages, pending)
    if templates is None:
        with _lock:
            stats["uncacheable"] += 1
        return stages, None
    # Handoff analysis is structural: run it once at plan time and record the
    # decisions on the entry so warm calls replay them with zero analysis.
    ho = None
    if getattr(ctx, "handoff", True):
        from repro.core import handoff as _ho
        ho = _ho.analyze(stages, getattr(ctx, "executor", None))
    with _lock:
        existing = _entries.get(key)
        if existing is not None and existing.matches(pending):
            entry = existing        # concurrent miss: keep the winner's tuner state
        else:
            entry = PlanEntry(key=key, stage_templates=templates,
                              fns=tuple(n.fn for n in pending),
                              fn_names=tuple(n.fn.name for n in pending),
                              handoff=ho,
                              rewrites=[r.to_json() for r in rw.records])
            _entries[key] = entry
            _mark_dirty()
            while len(_entries) > _MAX_ENTRIES:
                evicted, _ = _entries.popitem(last=False)
                _exec_tables.pop(evicted, None)
    _note_entry_key(ctx, key)
    return stages, entry


def peek(pending: list[Node], graph: DataflowGraph, ctx) -> PlanEntry | None:
    """Read-only lookup of the entry an UNREWRITTEN pending graph maps to —
    no rewrite pass, no hit counters, no LRU reshuffle, no planning.  Used by
    the verifier (``analysis.verify_pipeline``) to reuse recorded handoff
    decisions instead of re-analyzing per ``verify()`` call; it only hits
    when the rewrite pass left this graph alone, which is exactly when the
    entry's decisions describe the verifier's (unrewritten) plan."""
    if not getattr(ctx, "plan_cache", True):
        return None
    key = fingerprint(pending, graph, ctx)
    if key is None:
        return None
    with _lock:
        entry = _entries.get(key)
    if entry is not None and entry.matches(pending):
        return entry
    return None


def _maybe_reanalyze(ctx, entry: PlanEntry, records: list) -> None:
    """Periodic re-analysis tick (``MOZART_REANALYZE_EVERY``, 0/unset = off).

    First-plan conclusions age: a donation vetoed because a Future happened
    to be alive at plan time, an executor pinned at one shape, a rewrite
    declined when the cost inputs looked different.  Every N warm hits this
    drops the entry's resolved handoff decisions (``resolve_decisions``
    re-analyzes on next use — vetoed donations get reconsidered), flags every
    stage for a pinned-executor drift re-check (``cost_model.AutoExecutor``),
    and refreshes the persisted rewrite records from this capture's pass (a
    formerly declined rewrite that now applies replaces its MZ505 record)."""
    try:
        every = int(os.environ.get("MOZART_REANALYZE_EVERY", "0") or 0)
    except ValueError:
        every = 0
    if every <= 0:
        return
    with entry._lock:
        entry.evals_since_reanalysis += 1
        if entry.evals_since_reanalysis < every:
            return
        entry.evals_since_reanalysis = 0
        entry.handoff = None             # resolve_decisions re-analyzes
        entry.ho_age = 0
        entry.rewrites = [r.to_json() for r in records]
        entry.recheck_stages = set(range(len(entry.stage_templates)))
    with _lock:
        stats["reanalysis_ticks"] += 1
    ctx.stats["reanalysis_ticks"] += 1
    _mark_dirty()


def _note_entry_key(ctx, key: tuple) -> None:
    """Record that ``ctx`` used the entry at ``key`` (scopes ``configure()``
    re-keying).  Bounded: when the set outgrows the cache capacity, drop the
    keys whose entries the LRU has already evicted."""
    ctx._entry_keys.add(key)
    if len(ctx._entry_keys) > _MAX_ENTRIES:
        with _lock:
            ctx._entry_keys &= set(_entries)


# ---------------------------------------------------------------------------
# Persistence (save / load)
# ---------------------------------------------------------------------------
#
# Fingerprint keys are nested tuples of JSON scalars; tuples are encoded as
# ``{"t": [...]}`` (fingerprints never contain raw dicts — ``value_fingerprint``
# normalizes mappings into ("map", ...) tuples), bytes/complex get their own
# markers.  Split types are encoded as (class name, params) and rebuilt via
# ``cls(*params)``; a save-time round-trip self-test skips any entry whose
# types do not reconstruct equal (e.g. ``UnknownSplit``, whose identity is a
# process-local uid).


def _enc(o: Any) -> Any:
    if isinstance(o, tuple):
        return {"t": [_enc(x) for x in o]}
    if isinstance(o, bytes):
        return {"b": o.hex()}
    if isinstance(o, complex):
        return {"c": [o.real, o.imag]}
    if o is None or isinstance(o, (str, int, float, bool)):
        return o
    raise TypeError(f"unpersistable fingerprint element {type(o).__name__}")


def _dec(o: Any) -> Any:
    if isinstance(o, dict):
        if "t" in o:
            return tuple(_dec(x) for x in o["t"])
        if "b" in o:
            return bytes.fromhex(o["b"])
        if "c" in o:
            return complex(o["c"][0], o["c"][1])
        raise ValueError(f"unknown marker {sorted(o)}")
    if isinstance(o, list):
        return tuple(_dec(x) for x in o)
    return o


def _split_type_classes() -> dict[str, type]:
    out: dict[str, type] = {}
    work = [st.SplitType]
    while work:
        cls = work.pop()
        out[cls.__name__] = cls
        work.extend(cls.__subclasses__())
    return out


def _type_enc(t: st.SplitType) -> dict:
    rebuilt = type(t)(*t.params)       # raises / differs => entry is skipped
    if rebuilt != t:
        raise TypeError(f"{type(t).__name__} does not round-trip from params")
    return {"cls": type(t).__name__, "params": _enc(t.params)}


def _type_dec(d: dict, classes: dict[str, type]) -> st.SplitType:
    return classes[d["cls"]](*_dec(d["params"]))


def _entry_enc(e: PlanEntry) -> dict:
    with e._lock:                      # consistent snapshot vs concurrent pins
        tuned = dict(e.tuned_batch)
        chosen = dict(e.chosen_exec)
        timings = {k: dict(v) for k, v in e.exec_timings.items()}
        meta = {k: dict(v) for k, v in e.exec_meta.items()}
        blocks = dict(e.block_shape)
        quarantined = {k: dict(v) for k, v in e.quarantined.items()}
        rewrites = [dict(r) for r in e.rewrites]
    return {
        "key": _enc(e.key),
        "fn_names": list(e.fn_names),
        "rewrites": rewrites,
        "bucket": None if e.bucket is None else _enc(tuple(e.bucket)),
        "quarantined": {str(k): v for k, v in quarantined.items()},
        "tuned_batch": {str(k): v for k, v in tuned.items()},
        "chosen_exec": {str(k): v for k, v in chosen.items()},
        "exec_timings": {str(k): v for k, v in timings.items()},
        "exec_meta": {str(k): v for k, v in meta.items()},
        "block_shape": {str(k): list(v) for k, v in blocks.items()},
        "handoff": None if e.handoff is None else {
            str(sid): ho.to_json() for sid, ho in e.handoff.items()},
        "templates": [
            {
                "positions": tm.positions,
                "inputs": [[_enc(desc), _type_enc(t)] for desc, t in tm.inputs],
                "out_types": {str(p): _type_enc(t) for p, t in tm.out_types.items()},
                "arg_types": [[p, name, _type_enc(t)]
                              for (p, name), t in tm.arg_types.items()],
            }
            for tm in e.stage_templates
        ],
    }


def _entry_dec(d: dict, classes: dict[str, type]) -> PlanEntry:
    templates = [
        _StageTemplate(
            positions=[int(p) for p in tm["positions"]],
            inputs=[(_dec(desc), _type_dec(t, classes))
                    for desc, t in tm["inputs"]],
            out_types={int(p): _type_dec(t, classes)
                       for p, t in tm["out_types"].items()},
            arg_types={(int(p), name): _type_dec(t, classes)
                       for p, name, t in tm["arg_types"]},
        )
        for tm in d["templates"]
    ]
    from repro.core.handoff import StageHandoff
    raw_ho = d.get("handoff")
    return PlanEntry(
        key=_dec(d["key"]),
        stage_templates=templates,
        fns=None,
        fn_names=tuple(d["fn_names"]),
        tuned_batch={int(k): int(v) for k, v in d["tuned_batch"].items()},
        chosen_exec={int(k): str(v) for k, v in d["chosen_exec"].items()},
        exec_timings={int(k): {str(n): float(s) for n, s in v.items()}
                      for k, v in d["exec_timings"].items()},
        exec_meta={int(k): {str(n): int(s) for n, s in v.items()}
                   for k, v in d.get("exec_meta", {}).items()},
        block_shape={int(k): tuple(int(x) for x in v)
                     for k, v in d.get("block_shape", {}).items()},
        handoff=None if raw_ho is None else {
            int(sid): StageHandoff.from_json(ho) for sid, ho in raw_ho.items()},
        bucket=None if d.get("bucket") is None else tuple(_dec(d["bucket"])),
        quarantined={int(k): {str(n): int(a) for n, a in v.items()}
                     for k, v in d.get("quarantined", {}).items()},
        rewrites=[dict(r) for r in d.get("rewrites", [])],
        loaded=True,
    )


@contextlib.contextmanager
def _file_lock(path: str):
    """Advisory exclusive lock on a ``<path>.lock`` sidecar, so processes
    sharing one ``MOZART_PLAN_CACHE`` serialize their read-merge-write saves.
    Best-effort: platforms without ``fcntl`` (or locked-down filesystems)
    fall through unlocked — the write is still atomic, only the cross-process
    merge can then race (last writer wins, same as before the lock)."""
    try:
        import fcntl
        lf = open(f"{path}.lock", "a+")
    except (ImportError, OSError):
        yield
        return
    try:
        fcntl.flock(lf.fileno(), fcntl.LOCK_EX)
        yield
    finally:
        try:
            fcntl.flock(lf.fileno(), fcntl.LOCK_UN)
        finally:
            lf.close()


def save(path: str, force: bool = False) -> int:
    """Serialize every persistable cached plan to ``path``; returns the entry
    count written (0 when the file is already current — steady-state session
    exits are no-ops).

    Crash- and concurrency-hardened: the payload is fsynced before the atomic
    rename (a host crash can lose the save, never corrupt the file), and the
    whole save runs read-merge-write under an advisory ``<path>.lock`` — the
    current file is merged into the live cache first (live entries win), so
    two processes sharing ``MOZART_PLAN_CACHE`` cannot lose each other's
    entries."""
    from repro.core import resilience
    ap = os.path.abspath(path)
    with _lock:
        if (not force and _saved_versions.get(ap) == _mutations
                and os.path.exists(path)):
            stats["persist_save_noop"] += 1
            return 0
    with _file_lock(path):
        if os.path.exists(path):
            _load(path)                  # merge concurrent sessions' entries
        with _lock:
            version = _mutations         # taken BEFORE the snapshot
            snapshot = list(_entries.values())
        encoded = []
        for e in snapshot:
            try:
                encoded.append(_entry_enc(e))
            except (TypeError, ValueError):
                stats["persist_skipped"] += 1
        payload = {
            "schema": SCHEMA_VERSION,
            "chip": hardware.TARGET.name,
            "entries": encoded,
        }
        resilience.maybe_fail("persist", where=ap)
        tmp = f"{path}.tmp.{os.getpid()}.{threading.get_ident()}"
        with open(tmp, "w") as f:
            json.dump(payload, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    with _lock:
        _saved_versions[ap] = version
    stats["persist_saved"] += len(encoded)
    return len(encoded)


def load(path: str) -> int:
    """Merge persisted plans into the live cache; returns entries loaded.

    Rejects (returns 0, never raises) on: missing/corrupt file, schema
    version mismatch, cross-chip file.  Live entries win over loaded ones —
    a loaded plan never clobbers in-process tuner state.  Split-type classes
    unknown to this process (library integration not imported yet) skip only
    the entries that need them."""
    return _load(path)[0]


def _load(path: str) -> tuple[int, int]:
    """(entries loaded, entries left unresolved by missing split-type classes)."""
    try:
        with open(path) as f:
            payload = json.load(f)
        schema = payload["schema"]
        chip = payload["chip"]
        raw_entries = payload["entries"]
    except FileNotFoundError:
        stats["persist_missing"] += 1    # normal cold start, not an error
        return 0, 0
    except (OSError, ValueError, KeyError, TypeError):
        stats["persist_corrupt"] += 1
        return 0, 0
    if not isinstance(raw_entries, list):
        # Well-formed JSON, wrong shape ("entries" not a list): corrupt all
        # the same — the per-entry loop below must never raise.
        stats["persist_corrupt"] += 1
        return 0, 0
    if schema != SCHEMA_VERSION:
        if schema in _MIGRATABLE_SCHEMAS:
            stats[f"persist_migrated_v{schema}"] += 1
        else:
            stats["persist_rejected_schema"] += 1
            return 0, 0
    if chip != hardware.TARGET.name:
        stats["persist_rejected_chip"] += 1
        return 0, 0
    classes = _split_type_classes()
    loaded = 0
    unresolved = 0
    for d in raw_entries:
        try:
            names = {tm_t["cls"] for tm in d["templates"]
                     for tm_t in _template_types(tm)}
            if not names <= classes.keys():
                # A library integration (e.g. annotated_table) isn't imported
                # yet, so its split-type classes don't exist in this process.
                # Not a corrupt entry: load_once retries it later.
                unresolved += 1
                stats["persist_unresolved"] += 1
                continue
            e = _entry_dec(d, classes)
        except (KeyError, ValueError, TypeError):
            stats["persist_skipped"] += 1
            continue
        with _lock:
            if e.key not in _entries:
                _entries[e.key] = e
                loaded += 1
                while len(_entries) > _MAX_ENTRIES:
                    evicted, _ = _entries.popitem(last=False)
                    _exec_tables.pop(evicted, None)
    stats["persist_loaded"] += loaded
    if loaded:
        _mark_dirty()
    return loaded, unresolved


def _template_types(tm: dict):
    yield from (t for _, t in tm["inputs"])
    yield from tm["out_types"].values()
    yield from (t for _, _, t in tm["arg_types"])


def load_once(path: str) -> int:
    """Load ``path`` at most once per process (session/env-var hook).

    If entries were left unresolved because their split-type classes are not
    imported yet, the path stays retryable: the next context creation loads
    again (already-merged keys are skipped), picking up entries whose
    integrations have been imported in the meantime."""
    ap = os.path.abspath(path)
    with _lock:
        if ap in _loaded_paths:
            return 0
        _loaded_paths.add(ap)
    loaded, unresolved = _load(path)
    if unresolved:                      # retry once the classes exist
        with _lock:
            _loaded_paths.discard(ap)
    return loaded
