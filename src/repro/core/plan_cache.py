"""Plan cache + auto-tuner state: skip the planner for repeated pipelines.

The paper's Mozart re-plans every ``evaluate()``.  Weld-style lazy systems
show that the cross-call win comes from *caching* the materialized plan: the
second execution of an identical pipeline should touch neither the planner
nor the split-type unifier.  This module provides that cache.

**Key.**  A pipeline is identified by a structural fingerprint of the
pending dataflow graph: per node, the annotated function's identity, the
aliasing pattern of its arguments (which argument is which external value /
which earlier node), static argument values, the *constructed* split types
(with SA-local generics normalized and ``unknown`` uids erased), and the
shapes/dtypes of every external input and abstract output.  Context knobs
that change planning or batch sizing (``executor``, ``chip``, ``pipeline``)
are part of the key; concrete array *values* are not — calling the same
pipeline on fresh data of the same shape is a hit.

**Template.**  A hit does not reuse ``Stage`` objects (they reference the
prior call's nodes); it re-instantiates them from a symbolic template that
names values by (node position, argument name).  Escaping-output sets are
recomputed per instantiation because they depend on which ``Future`` handles
are still alive *this* call.

**Auto-tuner.**  Each cache entry owns ``tuned_batch``: on the first
execution of a cached plan, ``StageExecutor._tune`` measures 2–3 candidate
chunk sizes around the §5.2 VMEM-derived estimate and pins the fastest here;
later hits reuse the pinned size via ``StageExecutor.choose_batch``.

Values that cannot be fingerprinted (no shape/dtype, no
``mozart_fingerprint()`` hook) make a pipeline *uncacheable* — it is planned
from scratch every time, which is always correct, merely slower.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any

import jax

from repro.core import split_types as st
from repro.core.graph import DataflowGraph, Node, NodeRef
from repro.core.planner import Stage, StageInput, _value_key, plan

_MAX_ENTRIES = 256

#: process-global cache statistics (benchmarks report these).
stats: collections.Counter = collections.Counter()

_lock = threading.Lock()
_entries: "collections.OrderedDict[tuple, PlanEntry]" = collections.OrderedDict()


def clear() -> None:
    """Drop every cached plan and reset the global counters (tests)."""
    with _lock:
        _entries.clear()
        stats.clear()


def cache_info() -> dict[str, int]:
    with _lock:
        return {"entries": len(_entries), **stats}


def entries() -> list["PlanEntry"]:
    with _lock:
        return list(_entries.values())


def tuned_batches() -> dict[tuple[int, int], int]:
    """(entry uid, stage_id) -> pinned chunk size (diagnostics).  Stage ids
    restart at 0 per plan, so the stable per-entry uid (not the LRU position,
    which reshuffles on every hit) keeps pipelines distinct."""
    out: dict[tuple[int, int], int] = {}
    for e in entries():
        for sid, batch in dict(e.tuned_batch).items():
            out[(e.uid, sid)] = batch
    return out


# ---------------------------------------------------------------------------
# Fingerprinting
# ---------------------------------------------------------------------------


def value_fingerprint(v: Any, with_value: bool = False) -> tuple | None:
    """Shape/dtype-level identity of an external value; None = uncacheable.

    Numeric scalars are keyed by *type only* unless ``with_value`` (static
    arguments): a pipeline driven with a changing rate/step scalar must still
    hit the cache — any plan-relevant effect of the value already shows up in
    the constructed split types and output avals, which the key captures, and
    instantiation rebinds the current call's values.  Custom containers
    (tables, corpora) opt in via a ``mozart_fingerprint()`` method returning
    a hashable tuple of their leaves' shapes/dtypes.
    """
    hook = getattr(v, "mozart_fingerprint", None)
    if callable(hook):
        return hook()
    if isinstance(v, (bool, int, float, complex)):
        return ("py", type(v).__name__, v) if with_value else ("py", type(v).__name__)
    if isinstance(v, (str, bytes, type(None))):
        return ("py", type(v).__name__, v)
    if hasattr(v, "shape") and hasattr(v, "dtype"):
        return ("arr", tuple(v.shape), str(v.dtype))
    if isinstance(v, (tuple, list)):
        parts = tuple(value_fingerprint(x, with_value) for x in v)
        if any(p is None for p in parts):
            return None
        return ("seq", type(v).__name__, parts)
    if isinstance(v, dict):
        items = []
        for k in sorted(v, key=repr):
            p = value_fingerprint(v[k], with_value)
            if p is None:
                return None
            items.append((repr(k), p))
        return ("map", tuple(items))
    return None


def _aval_fingerprint(aval: Any) -> tuple | None:
    if aval is None:
        return ("dynamic",)
    leaves, treedef = jax.tree_util.tree_flatten(aval)
    leaf_fps = []
    for l in leaves:
        shape = getattr(l, "shape", None)
        dtype = getattr(l, "dtype", None)
        if shape is None or dtype is None:
            return None
        leaf_fps.append((tuple(shape), str(dtype)))
    return (str(treedef), tuple(leaf_fps))


def _type_fingerprint(t: Any, varmap: dict[int, int]) -> tuple | None:
    if isinstance(t, st.GenericVar):
        return ("var", varmap.setdefault(t.uid, len(varmap)))
    if isinstance(t, st.UnknownSplit):
        return ("unknown", t.axis)       # uid erased: unknowns are structural here
    if not isinstance(t, st.SplitType):
        return None
    try:
        hash(t.params)
    except TypeError:
        return None
    return ("T", t.name, t.params)


def fingerprint(pending: list[Node], graph: DataflowGraph, ctx) -> tuple | None:
    """Structural key of the pending graph, or None if uncacheable."""
    pos = {n.id: i for i, n in enumerate(pending)}
    ext_index: dict[int, int] = {}       # id(value) -> alias slot
    done_index: dict[int, int] = {}      # done node_id -> alias slot
    node_fps = []
    for n in pending:
        varmap: dict[int, int] = {}      # generics are fresh per call/node
        arg_fps = []
        for name, v in n.bound.items():
            if name in n.fn.sa.static:
                f = value_fingerprint(v, with_value=True)   # baked into jit
                if f is None:
                    return None
                arg_fps.append(("static", name, f))
            elif isinstance(v, NodeRef):
                if v.node_id in pos:
                    arg_fps.append(("ref", name, pos[v.node_id]))
                else:
                    src = graph.nodes.get(v.node_id)
                    f = _aval_fingerprint(src.out_aval) if src is not None else None
                    if f is None:
                        return None
                    slot = done_index.setdefault(v.node_id, len(done_index))
                    arg_fps.append(("done", name, slot, f))
            else:
                f = value_fingerprint(v)
                if f is None:
                    return None
                # alias slot: add(x, x) and add(x, y) must key differently
                slot = ext_index.setdefault(id(v), len(ext_index))
                arg_fps.append(("ext", name, slot, f))
        type_fps = []
        for name in n.bound:
            if name in n.fn.sa.static:
                continue
            f = _type_fingerprint(n.arg_types[name], varmap)
            if f is None:
                return None
            type_fps.append((name, f))
        out_fp = _type_fingerprint(n.out_type, varmap)
        if out_fp is None:
            return None
        aval_fp = _aval_fingerprint(n.out_aval)
        if aval_fp is None:
            return None
        node_fps.append((n.fn.name, tuple(arg_fps), tuple(type_fps), out_fp, aval_fp))
    return (ctx.executor, ctx.chip.name, bool(ctx.pipeline), tuple(node_fps))


# ---------------------------------------------------------------------------
# Plan templates
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _StageTemplate:
    positions: list[int]                             # indices into pending list
    inputs: list[tuple[tuple, st.SplitType]]          # (desc, resolved split type)
    out_types: dict[int, st.SplitType]                # position -> resolved type
    arg_types: dict[tuple[int, str], st.SplitType]    # (position, argname) -> type


_entry_uids = iter(range(1 << 62))


@dataclasses.dataclass
class PlanEntry:
    key: tuple
    stage_templates: list[_StageTemplate]
    fns: tuple                                       # per-node AnnotatedFn identity
    uid: int = dataclasses.field(default_factory=lambda: next(_entry_uids))
    tuned_batch: dict[int, int] = dataclasses.field(default_factory=dict)
    trials: dict[int, list[tuple[int, float]]] = dataclasses.field(default_factory=dict)
    hits: int = 0
    _lock: threading.Lock = dataclasses.field(default_factory=threading.Lock)
    _tuning: set = dataclasses.field(default_factory=set)

    def matches(self, pending: list[Node]) -> bool:
        """Guard against hash collisions / interpreter id() reuse: the cached
        plan applies only if every node still calls the same function object."""
        return len(pending) == len(self.fns) and all(
            n.fn is f for n, f in zip(pending, self.fns)
        )

    def try_claim_tuning(self, stage_id: int) -> bool:
        """Exactly one session tunes a stage; racers run with the estimate."""
        with self._lock:
            if stage_id in self.tuned_batch or stage_id in self._tuning:
                return False
            self._tuning.add(stage_id)
            return True

    def release_tuning(self, stage_id: int) -> None:
        with self._lock:
            self._tuning.discard(stage_id)

    def pin(self, stage_id: int, batch: int) -> None:
        with self._lock:
            self.tuned_batch[stage_id] = int(batch)
            self._tuning.discard(stage_id)

    def record_trial(self, stage_id: int, batch: int, seconds: float) -> None:
        with self._lock:
            self.trials.setdefault(stage_id, []).append((int(batch), seconds))


def _make_templates(stages: list[Stage], pending: list[Node]) -> list[_StageTemplate] | None:
    pos = {n.id: i for i, n in enumerate(pending)}
    templates = []
    for s in stages:
        inputs: list[tuple[tuple, st.SplitType]] = []
        for key, si in s.inputs.items():
            v = si.value
            if isinstance(v, NodeRef) and v.node_id in pos:
                desc: tuple = ("node", pos[v.node_id])
            else:
                # name the value symbolically: "arg <name> of node <position>"
                desc = ()
                for n in s.nodes:
                    for name, bv in n.bound.items():
                        if name not in n.fn.sa.static and _value_key(bv) == key:
                            desc = ("arg", pos[n.id], name)
                            break
                    if desc:
                        break
                if not desc:
                    return None          # value not reachable from bound args
            inputs.append((desc, si.split_type))
        templates.append(_StageTemplate(
            positions=[pos[n.id] for n in s.nodes],
            inputs=inputs,
            out_types={pos[nid]: t for nid, t in s.out_types.items()},
            arg_types={(pos[nid], name): t for (nid, name), t in s.arg_types.items()},
        ))
    return templates


def _instantiate(entry: PlanEntry, pending: list[Node],
                 graph: DataflowGraph) -> list[Stage]:
    consumers = graph.consumers()
    stages: list[Stage] = []
    for sid, tm in enumerate(entry.stage_templates):
        nodes = [pending[p] for p in tm.positions]
        node_ids = {n.id for n in nodes}
        inputs: dict[tuple, StageInput] = {}
        for desc, t in tm.inputs:
            if desc[0] == "node":
                val: Any = NodeRef(pending[desc[1]].id)
            else:
                val = pending[desc[1]].bound[desc[2]]
            key = _value_key(val)
            inputs[key] = StageInput(key, val, t)
        out_types = {pending[p].id: t for p, t in tm.out_types.items()}
        # Escaping outputs depend on which Futures are alive *this* call.
        escaping: set[int] = set()
        for n in nodes:
            ext = any(c not in node_ids for c in consumers.get(n.id, []))
            if ext or n.future_alive():
                escaping.add(n.id)
            n.stage_id = sid
        arg_types = {(pending[p].id, name): t
                     for (p, name), t in tm.arg_types.items()}
        stages.append(Stage(sid, nodes, inputs, out_types, escaping, arg_types))
    return stages


# ---------------------------------------------------------------------------
# Lookup
# ---------------------------------------------------------------------------


def lookup_or_plan(pending: list[Node], graph: DataflowGraph,
                   ctx) -> tuple[list[Stage], PlanEntry | None]:
    """Return (stages, cache entry or None).  Counts live in ``ctx.stats``:
    ``planner_calls`` increments only when the planner actually runs."""
    max_nodes = None if ctx.pipeline else 1
    if not getattr(ctx, "plan_cache", True):
        ctx.stats["planner_calls"] += 1
        return plan(pending, graph, max_stage_nodes=max_nodes), None

    key = fingerprint(pending, graph, ctx)
    if key is None:
        with _lock:
            stats["uncacheable"] += 1
        ctx.stats["plan_cache_uncacheable"] += 1
        ctx.stats["planner_calls"] += 1
        return plan(pending, graph, max_stage_nodes=max_nodes), None

    with _lock:
        entry = _entries.get(key)
        hit = entry is not None and entry.matches(pending)
        if hit:
            _entries.move_to_end(key)
            entry.hits += 1
            stats["hits"] += 1
        else:
            stats["misses"] += 1
    if hit:
        ctx.stats["plan_cache_hits"] += 1
        # O(graph) template instantiation happens outside the global lock so
        # concurrent sessions on different pipelines don't serialize here.
        return _instantiate(entry, pending, graph), entry
    ctx.stats["plan_cache_misses"] += 1
    ctx.stats["planner_calls"] += 1
    stages = plan(pending, graph, max_stage_nodes=max_nodes)
    templates = _make_templates(stages, pending)
    if templates is None:
        with _lock:
            stats["uncacheable"] += 1
        return stages, None
    with _lock:
        existing = _entries.get(key)
        if existing is not None and existing.matches(pending):
            entry = existing        # concurrent miss: keep the winner's tuner state
        else:
            entry = PlanEntry(key=key, stage_templates=templates,
                              fns=tuple(n.fn for n in pending))
            _entries[key] = entry
            while len(_entries) > _MAX_ENTRIES:
                _entries.popitem(last=False)
    return stages, entry
