"""Split annotations over unmodified functions (paper §3, Listing 3).

``@splittable`` attaches an SA to a function *without changing its body*:

    @splittable(x=Along(0), y=Along(0), ret=Along(0))
    def vadd(x, y): return x + y                     # the "library" fn

    @splittable(m=Custom(matrix_ctor), axis=_, ret=Reduce("add"), static=("axis",))
    def sum_reduce(m, axis): ...

The decorated function behaves as follows:

* called with JAX tracers (i.e. from inside someone else's ``jit``) — the
  original function runs directly; Mozart stays out of the way;
* called under a lazy Mozart context — the call is *registered* in the
  dataflow graph and a ``Future`` is returned (libmozart ``register()``);
* called eagerly (``lazy=False``) — the jitted original runs immediately,
  which is exactly "the library without Mozart" (our baseline).
"""

from __future__ import annotations

import functools
import inspect
import weakref
from typing import Any, Callable, Sequence

import jax

from repro.core import split_types as st
from repro.core.future import Future
from repro.core.graph import NodeRef

#: every live AnnotatedFn, for the contract checker (``core/analysis.py``):
#: module-level annotated APIs register themselves at decoration time, so a
#: full-repo sweep needs no per-module enumeration.  Weak so short-lived
#: test/bench annotations do not accumulate.
_REGISTERED_FNS: "weakref.WeakSet[AnnotatedFn]" = weakref.WeakSet()


def registered_fns() -> list["AnnotatedFn"]:
    """All live AnnotatedFns, deterministically ordered."""
    return sorted(_REGISTERED_FNS,
                  key=lambda f: (getattr(f.fn, "__module__", "") or "",
                                 f.name))


class SA:
    """A split annotation: split specs per argument + return + metadata."""

    def __init__(
        self,
        arg_specs: dict[str, st.SplitSpec],
        ret_spec: st.SplitSpec,
        static: Sequence[str] = (),
        elementwise: bool = False,
        mut: Sequence[str] = (),
        cost_hint: float = 1.0,
    ):
        self.arg_specs = arg_specs
        self.ret_spec = ret_spec
        self.static = tuple(static)
        self.elementwise = elementwise      # hint: stage may lower to Pallas
        self.mut = tuple(mut)               # donation hint (JAX is pure)
        self.cost_hint = cost_hint
        #: name of the data argument a SELECTIVE op filters (row-subset
        #: semantics: output rows are a subset of that argument's rows, other
        #: arguments are selectors).  Set ad hoc by integrations — like the
        #: ``dynamic`` flag — e.g. ``compress`` ("x") and ``filter_rows``
        #: ("t").  The static rewrite pass (core/rewrite.py) uses it to prove
        #: filter-before-map commutation for the MZ503 pushdown.
        self.selective: str | None = None


class AnnotatedFn:
    """A library function wrapped (not modified) by its SA."""

    def __init__(self, fn: Callable, sa: SA, name: str | None = None):
        functools.update_wrapper(self, fn)
        self.fn = fn
        self.sa = sa
        self.name = name or getattr(fn, "__name__", "fn")
        self.signature = inspect.signature(fn)
        self._jitted: Callable | None = None
        self._aval_cache: dict[tuple, Any] = {}
        _REGISTERED_FNS.add(self)

    # -- plain execution ----------------------------------------------------
    @property
    def jitted(self) -> Callable:
        if self._jitted is None:
            from repro.core.stage_exec import note_trace

            inner = self.fn

            @functools.wraps(inner)
            def counted(*args, **kwargs):
                # Python body only runs while jax is TRACING; compiled-cache
                # hits never execute it — the counter counts (re)traces.
                note_trace()
                return inner(*args, **kwargs)

            self._jitted = jax.jit(counted, static_argnames=self.sa.static or None)
        return self._jitted

    def call_eager(self, bound: dict[str, Any]) -> Any:
        return self.jitted(**bound)

    def call_raw(self, bound: dict[str, Any]) -> Any:
        return self.fn(**bound)

    # -- laziness -----------------------------------------------------------
    def __call__(self, *args, **kwargs):
        from repro.core.runtime import current_context

        b = self.signature.bind(*args, **kwargs)
        b.apply_defaults()
        bound = dict(b.arguments)

        # Inside someone else's trace: step aside entirely.
        if any(isinstance(v, jax.core.Tracer) for v in bound.values()):
            return self.fn(**bound)

        ctx = current_context()
        if ctx is None or not ctx.lazy:
            return self.call_eager(self._force_all(bound))
        return ctx.register_call(self, bound)

    @staticmethod
    def _force_all(bound: dict[str, Any]) -> dict[str, Any]:
        return {
            k: (v.value if isinstance(v, Future) else v) for k, v in bound.items()
        }

    def __repr__(self) -> str:
        return f"AnnotatedFn({self.name})"

    # -- SA machinery ---------------------------------------------------------
    def _aval_key(self, bound_avals: dict[str, Any]) -> tuple | None:
        """Hashable identity of one abstract call, or None (uncacheable).

        Statics are keyed by value (they are closed over the traced
        function); everything else by pytree structure + leaf shapes/dtypes
        only — ``jax.eval_shape`` never observes non-static values, so two
        calls with equal keys have equal output avals."""
        parts = []
        for name, v in bound_avals.items():
            if name in self.sa.static:
                try:
                    hash(v)
                except TypeError:
                    return None
                parts.append((name, "static", v))
                continue
            leaves, treedef = jax.tree_util.tree_flatten(v)
            leaf_ids = []
            for l in leaves:
                shape = getattr(l, "shape", None)
                dtype = getattr(l, "dtype", None)
                if shape is None or dtype is None:
                    leaf_ids.append(("py", type(l).__name__))
                else:
                    leaf_ids.append((tuple(shape), str(dtype)))
            parts.append((name, str(treedef), tuple(leaf_ids)))
        return tuple(parts)

    def abstract_eval(self, bound_avals: dict[str, Any]) -> Any:
        """Output aval via jax.eval_shape, statics closed over.

        Cached per aval structure: re-registering the same call shape (every
        warm ``mozart.pipeline`` call re-captures its graph) must not re-pay
        a whole-function abstract trace — for model-sized functions that
        trace IS the per-call cost."""
        key = self._aval_key(bound_avals)
        if key is not None:
            hit = self._aval_cache.get(key)
            if hit is not None:
                return hit

        statics = {k: bound_avals[k] for k in self.sa.static}
        arrs = {k: v for k, v in bound_avals.items() if k not in self.sa.static}

        def f(**kw):
            return self.fn(**kw, **statics)

        out = jax.eval_shape(f, **arrs)
        if key is not None:
            if len(self._aval_cache) > 128:      # runaway-shape backstop
                self._aval_cache.clear()
            self._aval_cache[key] = out
        return out

    def construct_types(self, bound: dict[str, Any], avals: dict[str, Any], out_aval):
        """Run every split-type constructor for one call (paper §3.2)."""
        generics: dict[str, st.GenericVar] = {}
        ctor_args = dict(bound)          # constructors may read runtime args
        arg_types: dict[str, Any] = {}
        for name in bound:
            spec = self.sa.arg_specs.get(name, st._)
            arg_types[name] = spec.construct(avals[name], ctor_args, generics)
        out_type = self.sa.ret_spec.construct(out_aval, ctor_args, generics)
        return arg_types, out_type


def splittable(
    ret: st.SplitSpec | None = None,
    static: Sequence[str] = (),
    elementwise: bool = False,
    mut: Sequence[str] = (),
    name: str | None = None,
    **arg_specs: st.SplitSpec,
) -> Callable[[Callable], AnnotatedFn]:
    """Attach a split annotation to an unmodified function.

    ``ret`` defaults to a fresh SA-local generic if any argument uses a
    generic, else to ``Along(0)``-style inference is NOT attempted — the
    annotator should be explicit; we default to ``Unknown()`` which is always
    safe (it merely prevents pipelining downstream).
    """
    if ret is None:
        ret = st.Unknown()

    def deco(fn: Callable) -> AnnotatedFn:
        sa = SA(dict(arg_specs), ret, static=static, elementwise=elementwise, mut=mut)
        return AnnotatedFn(fn, sa, name=name)

    return deco


def annotate(fn: Callable, *, ret: st.SplitSpec | None = None,
             static: Sequence[str] = (), elementwise: bool = False,
             name: str | None = None, **arg_specs: st.SplitSpec) -> AnnotatedFn:
    """Annotate a function you do not own (third-party annotator workflow)."""
    return splittable(ret=ret, static=static, elementwise=elementwise,
                      name=name or getattr(fn, "__name__", "fn"), **arg_specs)(fn)
