"""The Mozart verifier: annotation linter + dataflow analyzer (plan time).

Split annotations are *claims*: ``merge(split(v)) == v``, ``F(a) ==
merge(F(a1..ak))``, "these two grids may hand off chunks directly".  The
paper trusts the annotator (§3.4); this module checks the claims instead
of trusting them, in three layers:

1. **Contract checker** — every registered split type and AnnotatedFn is
   probed with tiny concrete inputs (``jax.eval_shape`` for avals, real
   numpy/jnp values for the laws themselves) against the algebraic laws a
   correct SA must satisfy (MZ1xx codes).
2. **Dataflow analyzer** — a lowered pipeline's stage plan + handoff
   decisions are re-examined for dead stages, donation hazards, and
   handoff fallbacks *with reasons* (MZ2xx codes).
3. **Boundary sanitizer** — runtime poison/tiling/counter checks in
   ``stage_exec`` behind ``MOZART_SANITIZE=1`` (MZ3xx codes; the codes are
   defined here, the checks live at the boundaries they guard).

Diagnostics are structured (code, severity, subject, message) so tests pin
codes, not prose.  ``repro.launch.lint`` is the CLI; ``mozart.verify(...)``
is the API.  Laws are *data* (``CONTRACT_LAWS``): the property-test suite
(tests/test_analysis.py) iterates the same list the linter runs, so a new
law is automatically both linted and unit-tested.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Callable, Iterable, Sequence

import jax
import numpy as np

from repro.core import handoff as handoff_mod
from repro.core import split_types as st
from repro.core import stage_exec
from repro.core.graph import DataflowGraph, NodeRef
from repro.core.planner import Stage, plan

# ---------------------------------------------------------------------------
# Diagnostics
# ---------------------------------------------------------------------------

#: Stable diagnostic codes.  MZ1xx = annotation contract, MZ2xx = pipeline
#: dataflow, MZ3xx = runtime boundary sanitizer (MOZART_SANITIZE=1),
#: MZ4xx = resilience events (core/resilience.py: faults, demotion,
#: quarantine, serving failure domains), MZ5xx = static graph rewrites
#: (core/rewrite.py: applied rewrites and justified declines).
CODES: dict[str, str] = {
    "MZ101": "split followed by merge does not reproduce the value",
    "MZ102": "merge is not associative",
    "MZ103": "info() extents are inconsistent with split() slicing",
    "MZ104": "ReduceSplit merge disagrees with its declared combiner",
    "MZ105": "can_handoff granted for a grid the consumer cannot ingest",
    "MZ106": "rechunk exceeded the at-most-one-copy bound or corrupted data",
    "MZ107": "split type does not round-trip through its params",
    "MZ108": "annotated function violates the SA condition F(a) = merge(F(a1..ak))",
    "MZ109": "degenerate merges misbehave (empty / singleton / zero-size pieces)",
    "MZ110": "registered architecture config failed to construct",
    "MZ201": "dead stage: output has no consumer and no live Future",
    "MZ202": "donation hazard: donation point whose producer Future is live",
    "MZ203": "handoff fallback: edge materializes instead of streaming",
    "MZ204": "unsplittable arguments force whole-value execution",
    "MZ205": "plan-cache entry can never replay under its guards",
    "MZ301": "use-after-donate: donated chunk buffers were observed",
    "MZ302": "stream ranges do not tile the value's extent",
    "MZ303": "scoped boundary counters disagree with the global tallies",
    "MZ401": "fault fired at an instrumented boundary (injected or real)",
    "MZ402": "executor demoted down the degradation ladder",
    "MZ403": "chunk batch halved after resource exhaustion and re-pinned",
    "MZ404": "executor quarantined in the plan entry (aging until retry)",
    "MZ405": "serving step failed; affected requests failed, driver survived",
    "MZ406": "transient error swallowed at a probe site (counted, not hidden)",
    "MZ501": "dead stage eliminated by the rewrite pass",
    "MZ502": "common subexpression shared: duplicate call collapsed",
    "MZ503": "selective stage pushed ahead of an elementwise map",
    "MZ504": "stage chain reassociated into fewer stages for splitting",
    "MZ505": "rewrite declined with reason",
}

_SEV_ORDER = {"error": 0, "warning": 1, "info": 2}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One structured finding.  ``code`` is stable; prose is not."""

    code: str
    severity: str                  # "error" | "warning" | "info"
    subject: str                   # what was checked (type, op, stage edge)
    message: str
    where: str = ""                # optional extra location (grid, law name)

    def __str__(self) -> str:
        loc = f" [{self.where}]" if self.where else ""
        return f"{self.code} {self.severity}: {self.subject}: {self.message}{loc}"


@dataclasses.dataclass
class Report:
    """A batch of diagnostics plus how many subjects were checked."""

    diagnostics: list[Diagnostic] = dataclasses.field(default_factory=list)
    checked: int = 0

    @property
    def errors(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "error"]

    @property
    def warnings(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "warning"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def codes(self) -> set[str]:
        return {d.code for d in self.diagnostics}

    def extend(self, more: "Report | Iterable[Diagnostic]") -> "Report":
        if isinstance(more, Report):
            self.diagnostics.extend(more.diagnostics)
            self.checked += more.checked
        else:
            self.diagnostics.extend(more)
        return self

    def render(self, verbose: bool = False) -> str:
        lines = []
        shown = sorted(
            self.diagnostics,
            key=lambda d: (_SEV_ORDER.get(d.severity, 3), d.code, d.subject))
        for d in shown:
            if d.severity == "info" and not verbose:
                continue
            lines.append(str(d))
        hidden = len(self.diagnostics) - len(lines)
        tail = f" ({hidden} info notes hidden; -v shows them)" if hidden else ""
        lines.append(
            f"{len(self.errors)} error(s), {len(self.warnings)} warning(s) "
            f"across {self.checked} checked subject(s){tail}")
        return "\n".join(lines)

    def to_json(self) -> dict:
        return {
            "checked": self.checked,
            "ok": self.ok,
            "diagnostics": [dataclasses.asdict(d) for d in self.diagnostics],
        }


# ---------------------------------------------------------------------------
# Small helpers shared by the laws
# ---------------------------------------------------------------------------


def _grid(n: int, k: int) -> list[tuple[int, int]]:
    """k contiguous ranges tiling [0, n) (last one ragged)."""
    k = max(min(int(k), int(n)), 1)
    b = -(-n // k)
    return [(s, min(s + b, n)) for s in range(0, n, b)]


def _tree_allclose(a: Any, b: Any, rtol: float = 1e-4, atol: float = 1e-5) -> bool:
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    if str(ta) != str(tb) or len(la) != len(lb):
        return False
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        if x.shape != y.shape:
            return False
        if x.dtype == bool or np.issubdtype(x.dtype, np.integer):
            if not np.array_equal(x, y):
                return False
        elif not np.allclose(x, y, rtol=rtol, atol=atol):
            return False
    return True


def _nbytes(value: Any) -> int:
    return sum(st.nbytes_of(l) for l in jax.tree_util.tree_leaves(value))


def _callable_name(fn: Any) -> str:
    return getattr(fn, "name", None) or getattr(fn, "__name__", repr(fn))


# ---------------------------------------------------------------------------
# Probes: one concrete exercise of one split type
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Probe:
    """Concrete material for checking one split type against the laws.

    ``value`` + ``extent_of`` drive the split-based laws (MZ101/103/105/
    106); ``pieces`` drive the merge-only laws (MZ102/104/109) for output-
    only types whose values cannot be split.  ``reference`` is the
    independently-known merge of ``pieces`` (for ConcatSplit-family grid
    checks).  ``supports_split`` overrides ``split_type.splittable`` for
    types like ``unknown`` that report splittable but raise from split().
    """

    name: str
    split_type: st.SplitType
    value: Any = None
    pieces: list | None = None
    reference: Any = None
    extent_of: Callable[[Any], int] | None = None
    consumers: tuple = ()
    supports_split: bool | None = None
    expect_unique: bool = False    # identity is unique-per-instance by design

    @property
    def can_split(self) -> bool:
        if self.supports_split is not None:
            return self.supports_split
        return bool(self.split_type.splittable) and self.value is not None

    def extent(self) -> int | None:
        if self.value is None or self.extent_of is None:
            return None
        return int(self.extent_of(self.value))


def builtin_probes(n: int = 12) -> list[Probe]:
    """Probes for every split type the repo ships (core + integrations)."""
    import jax.numpy as jnp

    from repro.core import annotated_nlp as nlp
    from repro.core import annotated_table as tbl

    n = max(int(n), 8)
    probes: list[Probe] = []

    m0 = jnp.arange(n * 3, dtype=jnp.float32).reshape(n, 3) / (n * 3)
    m1 = jnp.arange(3 * n, dtype=jnp.float32).reshape(3, n) / (3 * n)
    probes.append(Probe(
        "ArraySplit/axis0", st.ArraySplit((n, 3), 0), value=m0,
        extent_of=lambda v: int(v.shape[0]),
        consumers=(st.ArraySplit((n, 3), 0), st.ArraySplit((n, 3), 1))))
    probes.append(Probe(
        "ArraySplit/axis1", st.ArraySplit((3, n), 1), value=m1,
        extent_of=lambda v: int(v.shape[1]),
        consumers=(st.ArraySplit((3, n), 1),)))

    scalar = jnp.float32(1.5)
    probes.append(Probe("ScalarSplit", st.BROADCAST,
                        pieces=[scalar, scalar, scalar], reference=scalar))

    r = np.random.RandomState(0)
    partials = [jnp.asarray(r.uniform(0.5, 2.0, (3,)).astype(np.float32))
                for _ in range(4)]
    for op in ("add", "mul", "max", "min"):
        probes.append(Probe(f"ReduceSplit/{op}", st.ReduceSplit(op),
                            pieces=list(partials)))

    rows = [3, 1, n - 4]
    fresh = []
    s0 = 0
    for k in rows:
        fresh.append(jnp.arange(s0, s0 + k * 2, dtype=jnp.float32).reshape(k, 2))
        s0 += k * 2
    probes.append(Probe(
        "ConcatSplit", st.ConcatSplit("t", 0), pieces=list(fresh),
        reference=jnp.concatenate(fresh, axis=0),
        consumers=(st.ArraySplit((n, 2), 0), st.ArraySplit((n, 2), 1))))

    probes.append(Probe(
        "UnknownSplit", st.UnknownSplit(), pieces=list(fresh),
        reference=jnp.concatenate(fresh, axis=0),
        supports_split=False, expect_unique=True))

    tree = {"w": m0, "b": jnp.linspace(0.0, 1.0, n, dtype=jnp.float32)}
    treedef = jax.tree_util.tree_structure(tree)
    probes.append(Probe(
        "PytreeSplit", st.PytreeSplit(str(treedef), n, 0), value=tree,
        extent_of=lambda v: int(jax.tree_util.tree_leaves(v)[0].shape[0]),
        consumers=(st.PytreeSplit(str(treedef), n, 0),)))

    table = tbl.Table({
        "k": jnp.asarray(np.arange(n) % 3, jnp.int32),
        "v": jnp.linspace(0.5, 2.0, n, dtype=jnp.float32)})
    probes.append(Probe(
        "TableSplit", tbl.TableSplit(n), value=table,
        extent_of=lambda v: v.nrows))
    probes.append(Probe(
        "TableUnknown", tbl.TableUnknown(),
        pieces=[tbl.TableSplit(n).split(table, s, e) for s, e in _grid(n, 3)],
        supports_split=False, expect_unique=True))
    for op in ("sum", "count", "mean", "max", "min"):
        gparts = [tbl._group_reduce_partial(
            tbl.TableSplit(n).split(table, s, e), "k", "v", op)
            for s, e in _grid(n, 3)]
        probes.append(Probe(f"GroupSplit/{op}", tbl.GroupSplit(op, "k", "v"),
                            pieces=gparts))

    corpus = nlp.make_corpus(n, max_len=8, vocab=50, seed=0)
    probes.append(Probe(
        "CorpusSplit", nlp.CorpusSplit(n), value=corpus,
        extent_of=lambda v: v.n_docs))
    return probes


# ---------------------------------------------------------------------------
# Contract laws (MZ1xx).  Each law: Probe -> list[Diagnostic].
# ---------------------------------------------------------------------------


def _law_split_merge_identity(p: Probe) -> list[Diagnostic]:
    """MZ101: merge(split(v, grid)) must reproduce v for every grid."""
    ext = p.extent()
    if not p.can_split or ext is None or ext < 2:
        return []
    t = p.split_type
    for k in (2, 3, ext):
        ranges = _grid(ext, k)
        merged = t.merge([t.split(p.value, s, e) for s, e in ranges])
        if not _tree_allclose(merged, p.value):
            return [Diagnostic("MZ101", "error", p.name, CODES["MZ101"],
                               where=f"grid={ranges}")]
    return []


def _law_merge_associative(p: Probe) -> list[Diagnostic]:
    """MZ102: merge(a,b,c) == merge(merge(a,b),c) == merge(a,merge(b,c))."""
    t = p.split_type
    pieces = p.pieces
    if pieces is None:
        ext = p.extent()
        if not p.can_split or ext is None or ext < 3:
            return []
        pieces = [t.split(p.value, s, e) for s, e in _grid(ext, 3)]
    if len(pieces) < 3:
        return []
    flat = t.merge(list(pieces))
    left = t.merge([t.merge(list(pieces[:2]))] + list(pieces[2:]))
    right = t.merge([pieces[0], t.merge(list(pieces[1:]))])
    if not (_tree_allclose(flat, left) and _tree_allclose(flat, right)):
        return [Diagnostic("MZ102", "error", p.name, CODES["MZ102"])]
    return []


def _law_info_consistent(p: Probe) -> list[Diagnostic]:
    """MZ103: info().num_elements matches the value; split ranges slice it."""
    ext = p.extent()
    if p.value is None or ext is None:
        return []
    t = p.split_type
    info = t.info(p.value)
    if info is None:
        return []
    diags = []
    if int(info.num_elements) != ext:
        diags.append(Diagnostic(
            "MZ103", "error", p.name,
            f"info().num_elements = {info.num_elements} but the value has "
            f"{ext} elements"))
    if int(info.elem_bytes) < 1:
        diags.append(Diagnostic(
            "MZ103", "error", p.name,
            f"info().elem_bytes = {info.elem_bytes} (must be >= 1)"))
    if p.can_split and not diags:
        for s, e in _grid(ext, 3):
            got = int(p.extent_of(t.split(p.value, s, e)))
            if got != e - s:
                diags.append(Diagnostic(
                    "MZ103", "error", p.name,
                    f"split(v, {s}, {e}) has {got} elements, expected {e - s}"))
                break
    return diags


def _law_reduce_combiner(p: Probe) -> list[Diagnostic]:
    """MZ104: a ReduceSplit's merge must equal its declared combiner fold."""
    t = p.split_type
    if not isinstance(t, st.ReduceSplit) or not p.pieces:
        return []
    np_ops = {"add": np.add, "mul": np.multiply,
              "max": np.maximum, "min": np.minimum}
    ref_op = np_ops.get(t.op_name)
    if ref_op is None:
        return [Diagnostic("MZ104", "error", p.name,
                           f"op_name {t.op_name!r} has no reference combiner")]
    expect = np.asarray(p.pieces[0])
    for piece in p.pieces[1:]:
        expect = ref_op(expect, np.asarray(piece))
    got = t.merge(list(p.pieces))
    if not _tree_allclose(got, expect):
        return [Diagnostic(
            "MZ104", "error", p.name,
            f"merge disagrees with an independent {t.op_name!r} fold")]
    return []


def _law_handoff_grid(p: Probe) -> list[Diagnostic]:
    """MZ105: a granted handoff must mean producer chunks ARE the
    consumer's split outputs (splittable types), or that concrete fresh
    pieces that tile the extent are re-wrapped correctly (ConcatSplit)."""
    t = p.split_type
    diags: list[Diagnostic] = []
    for ct in p.consumers:
        if not t.can_handoff(ct):
            continue
        ext = p.extent()
        if p.can_split and ext is not None:
            for s, e in _grid(ext, 3):
                if not _tree_allclose(t.split(p.value, s, e),
                                      ct.split(p.value, s, e)):
                    diags.append(Diagnostic(
                        "MZ105", "error", p.name,
                        f"chunk [{s}:{e}) under {t} is not what {ct} "
                        "would have split out", where=f"consumer={ct}"))
                    break
        elif p.pieces is not None and p.reference is not None:
            ranges = [(i, i + 1) for i in range(len(p.pieces))]
            aval = jax.tree_util.tree_map(st.aval_of, p.reference)
            stream = stage_exec.ChunkStream(list(p.pieces), ranges, t, aval)
            adapted = stage_exec.adapt_stream(stream, ct)
            if adapted is None:
                if isinstance(ct, st.ArraySplit) and ct.shape and \
                        sum(int(np.asarray(c).shape[ct.axis] if
                                np.asarray(c).ndim > ct.axis else -1)
                            for c in p.pieces) == ct.shape[ct.axis]:
                    diags.append(Diagnostic(
                        "MZ105", "error", p.name,
                        "pieces tile the consumer extent but the granted "
                        "conversion was refused", where=f"consumer={ct}"))
                continue
            for (s, e), chunk in zip(adapted.ranges, adapted.chunks):
                if not _tree_allclose(chunk, ct.split(p.reference, s, e)):
                    diags.append(Diagnostic(
                        "MZ105", "error", p.name,
                        f"adapted chunk [{s}:{e}) differs from "
                        f"{ct}.split of the merged value",
                        where=f"consumer={ct}"))
                    break
    return diags


def _law_rechunk_single_copy(p: Probe) -> list[Diagnostic]:
    """MZ106: rechunk preserves data and copies at most the value once;
    an aligned (identical-grid) rechunk must be zero-copy."""
    ext = p.extent()
    if not p.can_split or ext is None or ext < 4:
        return []
    t = p.split_type
    total = _nbytes(p.value)
    src = _grid(ext, 4)
    chunks = [t.split(p.value, s, e) for s, e in src]
    diags: list[Diagnostic] = []
    for k in (2, 8, ext):
        dst = _grid(ext, k)
        new_chunks, copied = t.rechunk(chunks, src, dst)
        if copied > total:
            diags.append(Diagnostic(
                "MZ106", "error", p.name,
                f"rechunk {len(src)}->{len(dst)} copied {copied} bytes "
                f"(> one copy of the {total}-byte value)"))
        if not _tree_allclose(t.merge(new_chunks), p.value):
            diags.append(Diagnostic(
                "MZ106", "error", p.name,
                f"rechunk {len(src)}->{len(dst)} corrupted the data"))
        for (s, e), c in zip(dst, new_chunks):
            if int(p.extent_of(c)) != e - s:
                diags.append(Diagnostic(
                    "MZ106", "error", p.name,
                    f"rechunked chunk [{s}:{e}) has "
                    f"{int(p.extent_of(c))} elements"))
                break
        if diags:
            return diags
    _, copied = t.rechunk(chunks, src, src)
    if copied != 0:
        diags.append(Diagnostic(
            "MZ106", "error", p.name,
            f"aligned rechunk copied {copied} bytes (must pass through)"))
    return diags


def _law_params_round_trip(p: Probe) -> list[Diagnostic]:
    """MZ107: type(t)(*t.params) must rebuild an equal type — the plan
    cache persists types this way.  unknown-family types are unique per
    instance BY DESIGN, so their non-round-trip is an info note (the cache
    skips them via the same check)."""
    t = p.split_type
    sev = "info" if p.expect_unique else "error"
    try:
        rebuilt = type(t)(*t.params)
    except Exception as e:  # noqa: BLE001 - any ctor failure is the finding
        return [Diagnostic("MZ107", sev, p.name,
                           f"reconstructing from params raised "
                           f"{type(e).__name__}: {e}")]
    if rebuilt != t:
        msg = ("unique-per-instance identity does not persist (expected for "
               "unknown-family types; the plan cache skips these entries)"
               if p.expect_unique else
               f"type(t)(*t.params) rebuilt {rebuilt}, not {t}")
        return [Diagnostic("MZ107", sev, p.name, msg)]
    return []


def _law_degenerate_merge(p: Probe) -> list[Diagnostic]:
    """MZ109: merge([]) raises a clear ValueError; merge([x]) is identity;
    zero-size pieces are merge-neutral."""
    t = p.split_type
    diags: list[Diagnostic] = []
    try:
        t.merge([])
    except ValueError:
        pass
    except Exception as e:  # noqa: BLE001 - the obscure raise IS the finding
        diags.append(Diagnostic(
            "MZ109", "error", p.name,
            f"merge([]) raised {type(e).__name__} instead of a clear "
            "ValueError"))
    else:
        diags.append(Diagnostic(
            "MZ109", "warning", p.name,
            "merge([]) silently returned a value; an empty chunk list has "
            "no identity element for this type"))
    base = None
    if p.pieces:
        base = p.pieces[0]
    elif p.can_split and p.extent():
        base = t.split(p.value, 0, p.extent())
    if base is not None and not _tree_allclose(t.merge([base]), base):
        diags.append(Diagnostic(
            "MZ109", "error", p.name, "merge([x]) is not the identity"))
    ext = p.extent()
    if p.can_split and ext is not None and ext >= 2:
        k = ext // 2
        pieces = [t.split(p.value, 0, k), t.split(p.value, k, k),
                  t.split(p.value, k, ext)]
        if not _tree_allclose(t.merge(pieces), p.value):
            diags.append(Diagnostic(
                "MZ109", "error", p.name,
                "a zero-size piece in the chunk list changed the merge"))
    return diags


@dataclasses.dataclass(frozen=True)
class ContractLaw:
    code: str
    name: str
    check: Callable[[Probe], list[Diagnostic]]


#: The single source of truth for the MZ1xx laws.  The linter sweeps these
#: over every probe; tests/test_analysis.py parameterizes over the same list.
CONTRACT_LAWS: tuple[ContractLaw, ...] = (
    ContractLaw("MZ101", "split_merge_identity", _law_split_merge_identity),
    ContractLaw("MZ102", "merge_associative", _law_merge_associative),
    ContractLaw("MZ103", "info_consistent", _law_info_consistent),
    ContractLaw("MZ104", "reduce_combiner", _law_reduce_combiner),
    ContractLaw("MZ105", "handoff_grid", _law_handoff_grid),
    ContractLaw("MZ106", "rechunk_single_copy", _law_rechunk_single_copy),
    ContractLaw("MZ107", "params_round_trip", _law_params_round_trip),
    ContractLaw("MZ109", "degenerate_merge", _law_degenerate_merge),
)


def check_split_type(probe: Probe,
                     laws: Sequence[ContractLaw] = CONTRACT_LAWS
                     ) -> list[Diagnostic]:
    """Run every contract law against one probe."""
    diags: list[Diagnostic] = []
    for law in laws:
        try:
            diags.extend(law.check(probe))
        except Exception as e:  # noqa: BLE001 - a crashing law is a finding
            diags.append(Diagnostic(
                law.code, "error", probe.name,
                f"law {law.name!r} crashed: {type(e).__name__}: {e}"))
    return diags


def check_split_types(probes: Sequence[Probe] | None = None, n: int = 12
                      ) -> Report:
    rep = Report()
    for probe in (probes if probes is not None else builtin_probes(n)):
        rep.diagnostics.extend(check_split_type(probe))
        rep.checked += 1
    return rep


# ---------------------------------------------------------------------------
# The SA condition itself (MZ108): F(a) == merge(F(a1..ak))
# ---------------------------------------------------------------------------


def _resolve_call_types(fn, bound: dict[str, Any]):
    """Concrete (arg_types, out_type) for one call, generics resolved the
    way the planner would: each generic binds to the default split type of
    the first value it sees (paper §5.1 inference, collapsed to one call)."""
    dynamic = bool(getattr(fn.sa, "dynamic", False))
    out_aval = None if dynamic else fn.abstract_eval(bound)
    arg_types, out_type = fn.construct_types(bound, bound, out_aval)
    env = st.TypeEnv()
    resolved: dict[str, Any] = {}
    for name, v in bound.items():
        t = arg_types[name]
        if isinstance(t, st.GenericVar):
            c = env.resolve(t)
            if isinstance(c, st.GenericVar):
                env.unify(t, st.default_split_type(v))
            t = env.resolve(t)
        resolved[name] = t
    if isinstance(out_type, st.GenericVar):
        out_type = env.resolve(out_type)
        if isinstance(out_type, st.GenericVar):
            out_type = (st.default_split_type(out_aval)
                        if out_aval is not None else st.UnknownSplit())
    return resolved, out_type, out_aval


def check_annotated_fn(fn, kwargs: dict[str, Any], chunks: int = 3,
                       subject: str | None = None) -> list[Diagnostic]:
    """MZ108: run the function whole and chunked; the merged chunked
    outputs must equal the whole-value output (the SA condition, §3.4)."""
    subject = subject or _callable_name(fn)
    b = fn.signature.bind(**kwargs)
    b.apply_defaults()
    bound = dict(b.arguments)
    try:
        resolved, out_type, _ = _resolve_call_types(fn, bound)
    except Exception as e:  # noqa: BLE001 - ctor crashes are findings too
        return [Diagnostic("MZ108", "error", subject,
                           f"split-type construction crashed: "
                           f"{type(e).__name__}: {e}")]
    counts: dict[str, int] = {}
    for name, v in bound.items():
        t = resolved[name]
        if not (isinstance(t, st.SplitType) and t.splittable):
            continue
        info = t.info(v)
        if info is None:
            continue
        counts[name] = int(info.num_elements)
    if not counts:
        return []                      # nothing splittable: whole-value SA
    if len(set(counts.values())) != 1:
        return [Diagnostic(
            "MZ103", "error", subject,
            f"splittable arguments disagree on element count: {counts}")]
    n = next(iter(counts.values()))
    if n < 2:
        return []
    full = fn.call_raw(bound)
    pieces_out = []
    for s, e in _grid(n, min(chunks, n)):
        piece_bound = {
            name: (resolved[name].split(v, s, e) if name in counts else v)
            for name, v in bound.items()}
        pieces_out.append(fn.call_raw(piece_bound))
    try:
        merged = out_type.merge(pieces_out)
    except Exception as e:  # noqa: BLE001 - merge crash = broken annotation
        return [Diagnostic(
            "MZ108", "error", subject,
            f"merging per-chunk outputs under {out_type} raised "
            f"{type(e).__name__}: {e}")]
    if not _tree_allclose(full, merged):
        return [Diagnostic(
            "MZ108", "error", subject,
            f"F(a) != {out_type}.merge(F(a1..a{len(pieces_out)})) — the "
            "annotation claims a split this function does not satisfy")]
    return []


_INTEGRATION_MODULES = (
    "repro.core.annotated_numpy",
    "repro.core.annotated_image",
    "repro.core.annotated_nlp",
    "repro.core.annotated_table",
)


def check_annotated_ops(n: int = 12) -> Report:
    """Sweep the SA condition over every integration's annotated ops,
    using each module's ``__probe_examples__`` inputs."""
    import importlib

    rep = Report()
    for modname in _INTEGRATION_MODULES:
        mod = importlib.import_module(modname)
        examples = getattr(mod, "__probe_examples__", lambda n=12: {})(n)
        short = modname.rsplit(".", 1)[-1].replace("annotated_", "")
        for opname in sorted(getattr(mod, "__all_ops__", {})):
            fn = mod.__all_ops__[opname]
            ex = examples.get(opname)
            if ex is None:
                rep.diagnostics.append(Diagnostic(
                    "MZ108", "warning", f"{short}.{opname}",
                    "no probe example; the SA condition is unchecked"))
                continue
            for kwargs in (ex if isinstance(ex, list) else [ex]):
                rep.diagnostics.extend(check_annotated_fn(
                    fn, kwargs, subject=f"{short}.{opname}"))
                rep.checked += 1
    return rep


# ---------------------------------------------------------------------------
# Dataflow analyzer (MZ2xx)
# ---------------------------------------------------------------------------


def _executor_stream_capable(executor: str | None) -> bool | None:
    if not executor or executor == "auto":
        return True
    try:
        return bool(stage_exec.get_executor(executor).stream_capable)
    except Exception:  # noqa: BLE001 - unknown executor: no judgement
        return None


def analyze_dataflow(stages: Sequence[Stage], graph: DataflowGraph,
                     ho_map: dict[int, Any] | None,
                     executor: str | None = None) -> Report:
    """Re-examine a lowered plan: dead stages (MZ201), donation hazards
    (MZ202), handoff fallbacks with reasons (MZ203), whole-value stages
    (MZ204)."""
    rep = Report()
    ho_map = ho_map or {}
    cons = graph.consumers()
    producer: dict[int, Stage] = {}
    for s in stages:
        for node in s.nodes:
            producer[node.id] = s
    stream_cap = _executor_stream_capable(executor)
    if stream_cap is False:
        rep.diagnostics.append(Diagnostic(
            "MZ203", "info", f"executor {executor!r}",
            "executor cannot ingest chunk streams; every cross-stage edge "
            "materializes"))
    for s in stages:
        rep.checked += 1
        for node in s.nodes:
            if not cons.get(node.id) and not node.future_alive():
                rep.diagnostics.append(Diagnostic(
                    "MZ201", "warning",
                    f"stage {s.id} node {node.fn.name}#{node.id}",
                    CODES["MZ201"]))
        ho = ho_map.get(s.id)
        stream_in = ho.stream_in if ho else frozenset()
        last_use = ho.last_use if ho else frozenset()
        vetoed = getattr(ho, "vetoed", frozenset()) if ho else frozenset()
        inputs = list(s.inputs.items())

        chunkable = any(
            getattr(si.split_type, "splittable", False)
            and not isinstance(si.split_type, st.ScalarSplit)
            for _, si in inputs)
        if not chunkable and inputs:
            types = sorted({type(si.split_type).__name__ for _, si in inputs})
            rep.diagnostics.append(Diagnostic(
                "MZ204", "info", f"stage {s.id}",
                f"no splittable input ({', '.join(types)}); the stage runs "
                "whole-value"))

        for i, (key, si) in enumerate(inputs):
            v = si.value
            if not isinstance(v, NodeRef):
                continue
            ps = producer.get(v.node_id)
            if ps is None or ps.id == s.id:
                continue
            edge = f"stage {ps.id}->stage {s.id} input {s.ckey(key)}"
            if i in stream_in and stream_cap is not False:
                pass                   # streams: nothing to report
            else:
                pt = ps.out_types.get(v.node_id)
                reason = None
                if pt is not None:
                    reason = handoff_mod.edge_fallback_reason(
                        pt, si.split_type, handoff_mod._stage_count(ps))
                if i in stream_in:     # plan said stream; executor cannot
                    reason = f"stream-incapable executor ({executor})"
                elif reason is None:
                    reason = ("a sibling consumer of the same value rejected "
                              "the grid, forcing one merge for all consumers")
                sev = "warning" if "axis mismatch" in reason else "info"
                rep.diagnostics.append(Diagnostic(
                    "MZ203", sev, edge, f"handoff fallback: {reason}"))
            if i in last_use:
                node = graph.nodes.get(v.node_id)
                if node is not None and node.future_alive():
                    rep.diagnostics.append(Diagnostic(
                        "MZ202", "error", edge,
                        "donation point but the producer's Future is live — "
                        "use-after-donate is reachable (handoff.analyze "
                        "should have vetoed this edge)"))
            if i in vetoed:
                node = graph.nodes.get(v.node_id)
                if node is not None and not node.future_alive():
                    rep.diagnostics.append(Diagnostic(
                        "MZ202", "info", edge,
                        "stale donation veto: the producer's Future is gone; "
                        "the edge pays defensive copies until re-analysis"))
    return rep


def verify_pipeline(fn: Callable, *args, **config) -> Report:
    """Trace ``fn`` under a throwaway lazy context, plan it, and run the
    dataflow analyzer over the resulting stages.  Never executes the
    pipeline and never mutates the plan cache (a read-only ``peek`` reuses
    recorded handoff decisions when the entry already carries fresh ones —
    re-deriving them per ``verify()`` call was pure waste).  The MZ2xx
    analysis always runs over the UNREWRITTEN plan — the verifier reports on
    the program as written (a dead stage must still surface as MZ201) — and
    the static rewrite pass then runs dry on the throwaway graph to report
    what it *would* do as MZ5xx info diagnostics."""
    from repro.core import plan_cache as _pc
    from repro.core import rewrite as rewrite_mod
    from repro.core import runtime

    config.setdefault("executor", "auto")
    ctx = runtime.MozartContext(**config)
    stack = runtime._stack()
    stack.append(ctx)
    try:
        out = fn(*args)
    finally:
        stack.pop()
    pending = ctx.graph.pending()
    if not pending:
        rep = Report(checked=1)
        rep.diagnostics.append(Diagnostic(
            "MZ201", "warning", _callable_name(fn),
            "pipeline registered no annotated calls; nothing to analyze"))
        return rep
    stages = plan(pending, ctx.graph,
                  max_stage_nodes=None if ctx.pipeline else 1)
    ho = None
    if getattr(ctx, "handoff", True):
        entry = _pc.peek(pending, ctx.graph, ctx)
        if (entry is not None and entry.handoff is not None
                and handoff_mod.decisions_fresh(entry.handoff, stages)):
            ho = entry.handoff
            with _pc._lock:
                _pc.stats["verify_handoff_reused"] += 1
        else:
            ho = handoff_mod.analyze(stages, ctx.executor)
    rep = analyze_dataflow(stages, ctx.graph, ho, executor=ctx.executor)
    if getattr(ctx, "rewrite", True):
        rw = rewrite_mod.apply(pending, ctx.graph, ctx)
        rep.extend(rewrite_mod.records_to_diagnostics(rw.records))
    del out                            # keep Futures alive through analysis
    return rep


def rewrite_report(fn: Callable, *args, **config) -> Report:
    """Dry-run the static rewrite pass (``core/rewrite.py``) over one traced
    pipeline and report every MZ5xx rewrite it would apply (or decline),
    with cost-model deltas, without executing anything or mutating any plan
    cache.  Backs ``repro.launch.lint --rewrite-report``."""
    from repro.core import rewrite as rewrite_mod
    from repro.core import runtime

    config.setdefault("executor", "auto")
    config.setdefault("plan_cache", False)
    ctx = runtime.MozartContext(**config)
    stack = runtime._stack()
    stack.append(ctx)
    try:
        out = fn(*args)
    finally:
        stack.pop()
    pending = ctx.graph.pending()
    rep = Report(checked=1)
    if not pending:
        return rep
    rw = rewrite_mod.apply(pending, ctx.graph, ctx)
    rep.extend(rewrite_mod.records_to_diagnostics(rw.records))
    del out                            # keep Futures alive through the pass
    return rep


# ---------------------------------------------------------------------------
# Plan-cache guard analysis (MZ205)
# ---------------------------------------------------------------------------


def check_plan_cache(path: str | None = None) -> Report:
    """Flag live or persisted plan-cache entries whose key guards can never
    match on this host (wrong executor / chip / schema): they occupy cache
    slots but never replay."""
    from repro import hardware
    from repro.core import plan_cache as pc

    rep = Report()
    avail = set(stage_exec.available_executors())
    chip = hardware.TARGET.name
    with pc._lock:
        keys = list(pc._entries.keys())
    for key in keys:
        rep.checked += 1
        if len(key) < pc._PREFIX_LEN:
            continue
        subject = f"plan entry executor={key[pc._P_EXEC]!r}"
        if key[pc._P_EXEC] not in avail:
            rep.diagnostics.append(Diagnostic(
                "MZ205", "error", subject,
                f"executor {key[pc._P_EXEC]!r} is not registered on this "
                f"host (available: {sorted(avail)}); the entry never replays"))
        if key[pc._P_CHIP] != chip:
            rep.diagnostics.append(Diagnostic(
                "MZ205", "warning", subject,
                f"chip guard {key[pc._P_CHIP]!r} != current target {chip!r}; "
                "the entry never replays here"))
    if path is None:
        path = os.environ.get("MOZART_PLAN_CACHE") or None
    if path and os.path.exists(path):
        rep.checked += 1
        try:
            with open(path, encoding="utf-8") as f:
                blob = json.load(f)
        except (OSError, ValueError) as e:
            rep.diagnostics.append(Diagnostic(
                "MZ205", "warning", path,
                f"persisted plan cache unreadable ({type(e).__name__}); "
                "load() rejects it (stats['persist_corrupt']) and replans"))
            return rep
        schema = blob.get("schema")
        if schema != pc.SCHEMA_VERSION and schema not in pc._MIGRATABLE_SCHEMAS:
            rep.diagnostics.append(Diagnostic(
                "MZ205", "error", path,
                f"schema {schema!r} is neither current ({pc.SCHEMA_VERSION}) "
                f"nor migratable {pc._MIGRATABLE_SCHEMAS}; the file never "
                "loads"))
        if blob.get("chip") != chip:
            rep.diagnostics.append(Diagnostic(
                "MZ205", "warning", path,
                f"file chip {blob.get('chip')!r} != current target {chip!r}; "
                "the file never loads here"))
    return rep


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def verify(target: Callable | None = None, *args, n: int = 12,
           plan_cache_path: str | None = None, **config) -> Report:
    """``mozart.verify()``: lint every registered annotation (no target),
    or trace + analyze one pipeline (``mozart.verify(fn, *args)``)."""
    if target is None:
        rep = Report()
        rep.extend(check_split_types(n=n))
        rep.extend(check_annotated_ops(n=n))
        rep.extend(check_plan_cache(plan_cache_path))
        return rep
    if not callable(target):
        raise TypeError(
            f"verify() target must be a callable pipeline, got {target!r}")
    return verify_pipeline(target, *args, **config)
