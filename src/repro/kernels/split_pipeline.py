"""Split-pipeline kernel: a Mozart stage as ONE VMEM-tiled Pallas kernel.

This is the paper's core mechanism adapted to the TPU memory hierarchy.
On CPU, Mozart keeps a chunk of every pipeline value resident in L2 while a
driver loop calls each black-box function on it.  On TPU the analogous fast
memory is VMEM: this kernel streams `(1, BLOCK)` tiles of every input from
HBM into VMEM (double-buffered by the Pallas pipeline machinery), applies the
*entire* stage chain while the tile is resident, and writes only the stage's
escaping outputs back to HBM.  Intermediates never touch HBM at all — a
strictly stronger guarantee than the CPU version (which still writes
chunk-sized intermediates to cache-resident buffers).

The stage chain is supplied as a traceable ``chain_fn`` built by
``repro.core.pallas_exec`` from the planned stage, so ANY elementwise-
annotated library function participates without modification.

Layout: 1-D logical arrays are padded to a multiple of ``block_elems`` and
viewed as ``(G, BLOCK)``; the grid walks G. BLOCK is a multiple of 1024
(8 sublanes x 128 lanes) for hardware alignment.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Reduction identities per merge op (used to mask tail padding).
REDUCE_IDENTITY = {
    "add": 0.0,
    "mul": 1.0,
    "max": -jnp.inf,
    "min": jnp.inf,
}

LANES = 128
SUBLANES = 8
MIN_BLOCK = LANES * SUBLANES     # 1024


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pipeline_kernel(
    n_split: int,
    n_bcast: int,
    out_kinds: Sequence[tuple[str, str]],   # ("concat", _) | ("reduce", op)
    chain_fn: Callable,
    n_total: int,
    block: int,
    *refs,
):
    split_refs = refs[:n_split]
    bcast_refs = refs[n_split:n_split + n_bcast]
    out_refs = refs[n_split + n_bcast:]

    i = pl.program_id(0)
    blocks = [r[...] for r in split_refs]                 # (1, BLOCK) in VMEM
    bcasts = [r[0, 0] for r in bcast_refs]                # scalars

    outs = chain_fn(blocks, bcasts)                       # whole stage in VMEM

    # Tail-padding mask for reductions.
    idx = i * block + jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
    mask = idx < n_total

    for (kind, op), o_ref, val in zip(out_kinds, out_refs, outs):
        if kind == "concat":
            o_ref[...] = val.astype(o_ref.dtype)
        else:
            ident = jnp.asarray(REDUCE_IDENTITY[op], val.dtype)
            masked = jnp.where(mask, val, ident)
            if op == "add":
                part = jnp.sum(masked)
            elif op == "mul":
                part = jnp.prod(masked)
            elif op == "max":
                part = jnp.max(masked)
            else:
                part = jnp.min(masked)
            o_ref[0, 0] = part.astype(o_ref.dtype)


def padded_layout(n: int, block_elems: int) -> tuple[int, int, int]:
    """(block, n_pad, grid) the kernel will launch for ``n`` elements."""
    block = max(MIN_BLOCK, _round_up(min(block_elems, max(n, 1)), MIN_BLOCK))
    n_pad = _round_up(n, block)
    return block, n_pad, n_pad // block


def pad_to_layout(x: jax.Array, n: int, block: int) -> jax.Array:
    """View a 1-D logical array as the kernel's ``(grid, block)`` layout."""
    n_pad = _round_up(n, block)
    return jnp.pad(x, (0, n_pad - n)).reshape(n_pad // block, block)


def split_pipeline_call_2d(
    chain_fn: Callable,
    split2d: Sequence[jax.Array],
    bcast_inputs: Sequence[Any],
    out_kinds: Sequence[tuple[str, str]],
    out_dtypes: Sequence[Any],
    n: int,
    block: int,
    interpret: bool = True,
):
    """Padded-layout entry point: launch on prebuilt ``(grid, block)`` buffers.

    Returns the kernel's PADDED outputs — ``(grid, block)`` for concat
    outputs, ``(grid, 1)`` reduce partials — leaving the unpad/combine to the
    caller (``unpad_outputs``).  Splitting the lifecycle this way lets the
    caller build the launch buffers however it likes (pad a whole array,
    stack a handed-off chunk list) and DONATE them to a jitted wrapper: a
    donated ``(grid, block)`` input can back a same-shaped padded output,
    which the old whole-launch entry point could never line up.
    """
    grid = int(split2d[0].shape[0])
    bcast2d = [jnp.asarray(b, jnp.result_type(b)).reshape(1, 1)
               for b in bcast_inputs]

    in_specs = (
        [pl.BlockSpec((1, block), lambda i: (i, 0)) for _ in split2d]
        + [pl.BlockSpec((1, 1), lambda i: (0, 0)) for _ in bcast2d]
    )
    out_specs = []
    out_shapes = []
    for (kind, _), dt in zip(out_kinds, out_dtypes):
        if kind == "concat":
            out_specs.append(pl.BlockSpec((1, block), lambda i: (i, 0)))
            out_shapes.append(jax.ShapeDtypeStruct((grid, block), dt))
        else:
            out_specs.append(pl.BlockSpec((1, 1), lambda i: (i, 0)))
            out_shapes.append(jax.ShapeDtypeStruct((grid, 1), dt))

    kernel = functools.partial(
        _pipeline_kernel, len(split2d), len(bcast2d), tuple(out_kinds),
        chain_fn, n, block,
    )
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(*list(split2d), *bcast2d)


def unpad_outputs(outs, out_kinds: Sequence[tuple[str, str]], n: int,
                  block: int):
    """Strip the padded layout off kernel outputs and combine reductions."""
    n_pad = _round_up(n, block)
    results = []
    for (kind, op), o in zip(out_kinds, outs):
        if kind == "concat":
            results.append(o.reshape(n_pad)[:n])
        else:
            flat = o.reshape(o.shape[0])
            if op == "add":
                results.append(jnp.sum(flat))
            elif op == "mul":
                results.append(jnp.prod(flat))
            elif op == "max":
                results.append(jnp.max(flat))
            else:
                results.append(jnp.min(flat))
    return results


def split_pipeline_call(
    chain_fn: Callable,
    split_inputs: Sequence[jax.Array],
    bcast_inputs: Sequence[Any],
    out_kinds: Sequence[tuple[str, str]],
    out_dtypes: Sequence[Any],
    block_elems: int,
    interpret: bool = True,
):
    """Run a Mozart stage as one Pallas kernel (whole-launch convenience).

    chain_fn(blocks, bcasts) -> list of escaping outputs (block-shaped for
    concat outputs, scalar for reduce outputs).
    """
    n = int(split_inputs[0].shape[0])
    block, _n_pad, _grid = padded_layout(n, block_elems)
    split2d = [pad_to_layout(x, n, block) for x in split_inputs]
    outs = split_pipeline_call_2d(
        chain_fn, split2d, bcast_inputs, out_kinds, out_dtypes, n, block,
        interpret=interpret)
    return unpad_outputs(outs, out_kinds, n, block)
