"""jit'd public wrappers for the Pallas kernels.

On non-TPU backends every wrapper transparently runs the kernel in
interpret mode (Python-level execution of the kernel body) so the whole
framework is testable on CPU while the lowering targets TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.fused_adamw import fused_adamw as _adamw
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.split_pipeline import split_pipeline_call as _split_pipeline


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None,
                    block_q=256, block_k=256, interpret=None):
    return _flash(q, k, v, causal=causal, window=window,
                  block_q=block_q, block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("b1", "b2", "eps", "wd", "block", "interpret"))
def fused_adamw(p, g, m, v, *, lr, b1, b2, eps, wd, step,
                grad_scale=1.0, block=64 * 1024, interpret=None):
    return _adamw(p, g, m, v, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd,
                  step=step, grad_scale=grad_scale, block=block,
                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("eps", "row_block", "interpret"))
def rmsnorm(x, w, *, eps=1e-6, row_block=256, interpret=None):
    return _rmsnorm(x, w, eps=eps, row_block=row_block, interpret=interpret)


split_pipeline = _split_pipeline
