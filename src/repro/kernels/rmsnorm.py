"""Fused RMSNorm Pallas kernel.

One grid step normalizes a block of rows: the row-reduction (mean square),
rsqrt, and scale all happen on a VMEM-resident (ROWS, d) tile, so x is read
once from HBM instead of three times (square-reduce, normalize, scale).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 256


def _rmsnorm_kernel(eps: float, x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(
    x: jax.Array,          # (..., d)
    w: jax.Array,          # (d,)
    *,
    eps: float = 1e-6,
    row_block: int = ROW_BLOCK,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    import math
    orig_shape = x.shape
    d = x.shape[-1]
    n = math.prod(x.shape[:-1]) if x.ndim > 1 else 1
    x2 = x.reshape(n, d)
    rb = min(row_block, n)
    n_pad = ((n + rb - 1) // rb) * rb
    x2 = jnp.pad(x2, ((0, n_pad - n), (0, 0)))
    grid = n_pad // rb

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((rb, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d), x.dtype),
        interpret=interpret,
    )(x2, w.reshape(1, d))
    return out[:n].reshape(orig_shape)
