"""Fused AdamW update as a VMEM-tiled Pallas kernel.

The optimizer update is the paper's motivating workload shape transplanted
into training: a chain of ~10 elementwise vector ops over multi-GB arrays
(grad clip/scale, moment updates, bias correction, weight decay, parameter
step).  Un-fused, each op round-trips parameters through HBM exactly like
the un-annotated MKL Black Scholes; fused, every tile is read once.

Layout: the flat parameter vector is viewed as (G, BLOCK); one grid step
updates one tile of p/m/v in place (aliased outputs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 64 * 1024    # 64K elements * 4 values * 4B = 1 MiB of VMEM per step


def _adamw_kernel(wd: float, eps: float,
                  p_ref, g_ref, m_ref, v_ref, sc_ref,
                  po_ref, mo_ref, vo_ref):
    # sc: (1, 8) scalar row: lr, b1, b2, c1, c2, gscale, _, _
    lr = sc_ref[0, 0]
    b1 = sc_ref[0, 1]
    b2 = sc_ref[0, 2]
    c1 = sc_ref[0, 3]          # 1/(1-b1^t)
    c2 = sc_ref[0, 4]          # 1/(1-b2^t)
    gscale = sc_ref[0, 5]      # global-norm clip factor

    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32) * gscale
    m = m_ref[...]
    v = v_ref[...]

    m = b1 * m + (1.0 - b1) * g
    v = b2 * v + (1.0 - b2) * g * g
    mhat = m * c1
    vhat = v * c2
    update = mhat / (jnp.sqrt(vhat) + eps) + wd * p
    po_ref[...] = (p - lr * update).astype(po_ref.dtype)
    mo_ref[...] = m
    vo_ref[...] = v


def fused_adamw(
    p: jax.Array,        # (N,) any float dtype
    g: jax.Array,        # (N,) same length
    m: jax.Array,        # (N,) f32
    v: jax.Array,        # (N,) f32
    *,
    lr: jax.Array,
    b1: float,
    b2: float,
    eps: float,
    wd: float,
    step: jax.Array,     # 1-based step count
    grad_scale: jax.Array | float = 1.0,
    block: int = BLOCK,
    interpret: bool | None = None,
):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = p.shape[0]
    block = min(block, max(((n + 1023) // 1024) * 1024, 1024))
    n_pad = ((n + block - 1) // block) * block
    grid = n_pad // block

    def pad(x, dt):
        return jnp.pad(x.astype(dt), (0, n_pad - n)).reshape(grid, block)

    c1 = 1.0 / (1.0 - b1 ** step.astype(jnp.float32))
    c2 = 1.0 / (1.0 - b2 ** step.astype(jnp.float32))
    sc = jnp.stack([
        jnp.asarray(lr, jnp.float32), jnp.asarray(b1, jnp.float32),
        jnp.asarray(b2, jnp.float32), c1.astype(jnp.float32),
        c2.astype(jnp.float32), jnp.asarray(grad_scale, jnp.float32),
        jnp.float32(0), jnp.float32(0),
    ]).reshape(1, 8)

    kernel = functools.partial(_adamw_kernel, float(wd), float(eps))
    po, mo, vo = pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((grid, block), p.dtype),
            jax.ShapeDtypeStruct((grid, block), jnp.float32),
            jax.ShapeDtypeStruct((grid, block), jnp.float32),
        ],
        interpret=interpret,
    )(pad(p, p.dtype), pad(g, g.dtype), pad(m, jnp.float32),
      pad(v, jnp.float32), sc)

    unpad = lambda x: x.reshape(n_pad)[:n]
    return unpad(po), unpad(mo), unpad(vo)
