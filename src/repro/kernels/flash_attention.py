"""Blocked (flash) attention Pallas kernel for the TPU MXU.

Grid = (batch*q_heads, Sq/BLOCK_Q, Skv/BLOCK_K); the last axis is the
sequential ("arbitrary") dimension, so the (m, l, acc) online-softmax state
lives in VMEM scratch across kv steps of one (bh, iq) tile.  Supports GQA
(kv head = q head // group), causal masking, and sliding-window (local)
attention — the assigned architectures need all three.

Block shapes are (BLOCK_Q, HEAD_DIM) / (BLOCK_K, HEAD_DIM): HEAD_DIM of the
assigned archs is 64..256, a multiple of the 128-lane register width in all
but the 64-d case, which Pallas pads transparently.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across versions.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 256
NEG_INF = -1e30


def _attn_kernel(
    causal: bool,
    window: int | None,
    sm_scale: float,
    block_q: int,
    block_k: int,
    q_ref, k_ref, v_ref,          # inputs
    o_ref,                        # output
    m_scr, l_scr, acc_scr,        # scratch
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)              # (block_q, d)
    k = k_ref[0, 0].astype(jnp.float32)              # (block_k, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * sm_scale   # (block_q, block_k)

    q_ids = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_ids = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask = mask & (q_ids >= k_ids)
    if window is not None:
        mask = mask & (k_ids > q_ids - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                            # (block_q, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                         # (block_q, block_k)
    # fully-masked rows: exp(NEG_INF - NEG_INF) = 1 would poison l; zero them
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / safe).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,                  # (B, Hq, Sq, D)
    k: jax.Array,                  # (B, Hkv, Sk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    sm_scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool | None = None,
) -> jax.Array:
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    if sm_scale is None:
        sm_scale = D ** -0.5
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)

    grid = (B * Hq, Sq // block_q, Sk // block_k)

    def q_map(bh, iq, ik):
        return (bh // Hq, bh % Hq, iq, 0)

    def kv_map(bh, iq, ik):
        return (bh // Hq, (bh % Hq) // group, ik, 0)

    kernel = functools.partial(
        _attn_kernel, causal, window, sm_scale, block_q, block_k)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda bh, iq, ik: q_map(bh, iq, ik)),
            pl.BlockSpec((1, 1, block_k, D), lambda bh, iq, ik: kv_map(bh, iq, ik)),
            pl.BlockSpec((1, 1, block_k, D), lambda bh, iq, ik: kv_map(bh, iq, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda bh, iq, ik: q_map(bh, iq, ik)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
