"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""

from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: int | None = None,
    sm_scale: float | None = None,
) -> jax.Array:
    """Dense softmax attention with GQA / causal / sliding-window masks."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    group = Hq // Hkv
    if sm_scale is None:
        sm_scale = D ** -0.5
    kq = jnp.repeat(k, group, axis=1)
    vq = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kq.astype(jnp.float32)) * sm_scale
    q_ids = jnp.arange(Sq)[:, None]
    k_ids = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (q_ids >= k_ids)
    if window is not None:
        mask = mask & (k_ids > q_ids - window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no visible keys: softmax of all -1e30 is uniform garbage; zero
    p = jnp.where(mask[None, None].any(-1, keepdims=True), p, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vq.astype(jnp.float32)).astype(q.dtype)


def adamw_ref(p, g, m, v, *, lr, b1, b2, eps, wd, step, grad_scale=1.0):
    pf = p.astype(jnp.float32)
    gf = g.astype(jnp.float32) * grad_scale
    m = b1 * m + (1 - b1) * gf
    v = b2 * v + (1 - b2) * gf * gf
    c1 = 1.0 / (1.0 - b1 ** step.astype(jnp.float32))
    c2 = 1.0 / (1.0 - b2 ** step.astype(jnp.float32))
    update = (m * c1) / (jnp.sqrt(v * c2) + eps) + wd * pf
    return (pf - lr * update).astype(p.dtype), m, v


def rmsnorm_ref(x, w, *, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * w.astype(jnp.float32)).astype(x.dtype)


def split_pipeline_ref(
    chain_fn: Callable,
    split_inputs: Sequence[jax.Array],
    bcast_inputs: Sequence,
    out_kinds: Sequence[tuple[str, str]],
):
    """Oracle for the split-pipeline kernel: run the chain on FULL arrays.

    chain_fn sees (1, n)-shaped "blocks" so the same callable works for both
    the kernel and the oracle.
    """
    n = split_inputs[0].shape[0]
    blocks = [x.reshape(1, n) for x in split_inputs]
    outs = chain_fn(blocks, list(bcast_inputs))
    results = []
    for (kind, op), o in zip(out_kinds, outs):
        if kind == "concat":
            results.append(o.reshape(n))
        else:
            red = {"add": jnp.sum, "mul": jnp.prod,
                   "max": jnp.max, "min": jnp.min}[op]
            results.append(red(o))
    return results
