"""Sharded, atomic, async checkpointing with elastic restore.

Layout (mesh-independent — the logical arrays are saved whole so a restart
may use a different device count / mesh):

    <dir>/step_<N>/
        arrays.npz          flat {path: np.ndarray} of params + opt state
        meta.json           step, arch, config name, pytree manifest
        _COMPLETE           commit marker (atomicity: written LAST)

* save is atomic: writes to ``step_<N>.tmp`` then renames;
* ``async_save`` runs in a daemon thread (overlaps the next train steps) —
  ``wait()`` joins before the process exits;
* ``latest_step`` ignores uncommitted (crashed mid-write) checkpoints;
* ``restore`` re-shards onto whatever mesh/shardings the caller provides
  (elastic restart on a different topology).
"""

from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat: dict[str, np.ndarray] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(directory: str | Path, step: int, tree: Any,
         meta: dict | None = None) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(tree)
    np.savez(tmp / "arrays.npz", **flat)
    (tmp / "meta.json").write_text(json.dumps(
        {"step": step, "keys": sorted(flat), **(meta or {})}, indent=2))
    (tmp / "_COMPLETE").write_text(str(time.time()))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


class AsyncCheckpointer:
    """Fire-and-forget saves on a background thread (one in flight)."""

    def __init__(self, directory: str | Path, keep_last: int = 3):
        self.directory = Path(directory)
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None
        self.saved: list[int] = []

    def save_async(self, step: int, tree: Any, meta: dict | None = None):
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)   # device -> host

        def run():
            save(self.directory, step, host_tree, meta)
            self.saved.append(step)
            self._gc()

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(all_steps(self.directory))
        for s in steps[:-self.keep_last]:
            shutil.rmtree(self.directory / f"step_{s:08d}", ignore_errors=True)


def all_steps(directory: str | Path) -> list[int]:
    directory = Path(directory)
    if not directory.exists():
        return []
    out = []
    for p in directory.iterdir():
        if p.name.startswith("step_") and not p.name.endswith(".tmp") \
                and (p / "_COMPLETE").exists():
            out.append(int(p.name.split("_")[1]))
    return sorted(out)


def latest_step(directory: str | Path) -> int | None:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str | Path, step: int, target_tree: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``target_tree`` (avals or arrays),
    placing each leaf with ``shardings`` if given (elastic re-shard)."""
    path = Path(directory) / f"step_{step:08d}"
    if not (path / "_COMPLETE").exists():
        raise FileNotFoundError(f"checkpoint {path} is incomplete")
    data = np.load(path / "arrays.npz")
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves_p))
    out = []
    for (pth, leaf), shard in zip(leaves_p, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in pth)
        arr = data[key]
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def load_meta(directory: str | Path, step: int) -> dict:
    return json.loads(
        (Path(directory) / f"step_{step:08d}" / "meta.json").read_text())
