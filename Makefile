# Repro build/test entry points.  PYTHONPATH is exported so every target can
# be run straight from a fresh checkout: `make test`.

PYTHON ?= python
export PYTHONPATH := src

.PHONY: test test-slow bench-smoke bench lint help

help:
	@echo "test        tier-1: fast, dependency-light suite (pytest -m 'not slow')"
	@echo "test-slow   full suite including @slow (multi-device subprocesses, train loops)"
	@echo "bench-smoke executor-parity + plan-cache smoke; exits nonzero on mismatch"
	@echo "bench       full benchmark harness at --quick sizes"
	@echo "lint        Mozart annotation verifier (zero MZ errors) + ruff if installed"

# Annotation verifier gate: split-type laws, SA condition over every
# annotated op, example-pipeline dataflow analysis, config registry — zero
# MZ errors or nonzero exit.  The ruff leg is best-effort: it runs only
# where ruff is installed (CI installs it; the pinned local env may not).
lint:
	$(PYTHON) -m repro.launch.lint
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src; \
	else \
		echo "ruff not installed; skipping style check"; \
	fi

test:
	$(PYTHON) -m pytest -x -q

test-slow:
	$(PYTHON) -m pytest -q -m "slow or not slow"

# Optional: JSON=path dumps the recorded rows (CI uploads this artifact).
bench-smoke:
	$(PYTHON) -m benchmarks.run --smoke $(if $(JSON),--json $(JSON))

bench:
	$(PYTHON) -m benchmarks.run --quick
