"""Static graph rewrite pass (core/rewrite.py).

Differential guarantee: the rewritten graph is observationally identical
to the unrewritten one — checked for every rewrite kind across every
registered executor, including empty, odd-remainder and aliased inputs.
Unit coverage: each MZ5xx record fires (and declines) for the documented
reason, CSE never merges calls that could differ (property-tested), warm
calls replay the rewritten graph from the schema-v7 plan cache with zero
planner calls and zero retraces, v6 cache files migrate forward, and the
``MOZART_REANALYZE_EVERY`` tick revisits stale decisions.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analysis, mozart, plan_cache
from repro.core import annotated_numpy as anp
from repro.core.stage_exec import available_executors, trace_count

from repro.testing import given, hst, settings  # hypothesis-optional

EXECUTORS = sorted(available_executors())


def _kw(executor, **extra):
    kw = {"batch_elements": 32, "autotune": False, **extra}
    if executor == "sharded":
        kw["mesh"] = jax.make_mesh((1,), ("data",))
    return kw


def _chain(x, mask):
    """One dead call, one CSE pair, one pushdown opportunity."""
    anp.exp(x)                       # dead: its Future dies immediately
    b1 = anp.exp(x)
    b2 = anp.exp(x)                  # CSE duplicate of b1
    s = anp.add(b1, b2)
    m = anp.multiply(x, 3.0)
    f = anp.compress(mask, m)        # pushdown: m itself is unobserved
    return s, f


# ---------------------------------------------------------------------------
# Differential: rewritten == unrewritten on every executor
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("executor", EXECUTORS)
@pytest.mark.parametrize("n", [0, 7, 257])
def test_rewrite_parity_all_executors(executor, n):
    r = np.random.RandomState(n + 3)
    x = jnp.asarray(r.rand(n) + 0.5, jnp.float32)
    mask = jnp.asarray(np.arange(n) % 2 == 0)
    outs = {}
    for on in (True, False):
        plan_cache.clear()
        with mozart.session(executor=executor, rewrite=on,
                            **_kw(executor)) as ctx:
            s, f = _chain(x, mask)
            outs[on] = (np.asarray(s.value), np.asarray(f.value))
        if on:
            assert ctx.stats.get("rewrites_applied", 0) >= 1
    for i, (g, w) in enumerate(zip(outs[True], outs[False])):
        assert g.shape == w.shape and g.dtype == w.dtype, (executor, n, i)
        np.testing.assert_allclose(g, w, rtol=2e-5, atol=1e-6,
                                   err_msg=f"{executor} n={n} output {i}")


@pytest.mark.parametrize("executor", EXECUTORS)
def test_rewrite_parity_aliased_inputs(executor):
    """The same Future feeding several args of several ops must survive CSE
    (the merged node inherits every alias's liveness)."""
    x = jnp.linspace(0.2, 1.4, 33, dtype=jnp.float32)

    def aliased(x):
        a = anp.exp(x)
        b = anp.add(a, a)            # same future twice in one call
        c = anp.add(a, a)            # CSE duplicate of b
        return anp.multiply(b, c), a

    outs = {}
    for on in (True, False):
        plan_cache.clear()
        with mozart.session(executor=executor, rewrite=on,
                            **_kw(executor)):
            p, a = aliased(x)
            outs[on] = (np.asarray(p.value), np.asarray(a.value))
    for g, w in zip(outs[True], outs[False]):
        np.testing.assert_allclose(g, w, rtol=2e-5, atol=1e-6,
                                   err_msg=executor)


# ---------------------------------------------------------------------------
# MZ501: dead elimination
# ---------------------------------------------------------------------------


def test_dead_elimination_cascades():
    """Retiring a dead consumer must also retire producers that only it
    needed — and the eliminated work never executes."""
    x = jnp.linspace(0.1, 1.0, 64, dtype=jnp.float32)
    dm = jnp.ones((8, 64), jnp.float32)

    def f(x):
        a = anp.exp(x)
        anp.matvec(dm, a)            # dead; sole consumer of ``a``
        return anp.multiply(x, 2.0)

    with mozart.session(executor="fused", autotune=False) as ctx:
        got = np.asarray(f(x).value)
    codes = [r.code for r in ctx._last_rewrites]
    assert codes.count("MZ501") == 2          # matvec AND the cascaded exp
    assert ctx.stats["calls"] == 1            # only the multiply ran
    np.testing.assert_allclose(got, np.asarray(x) * 2.0, rtol=1e-6)
    (entry,) = plan_cache.entries()
    assert [r["code"] for r in entry.rewrites].count("MZ501") == 2


def test_live_future_is_never_dead():
    x = jnp.linspace(0.1, 1.0, 16, dtype=jnp.float32)
    with mozart.session(executor="fused") as ctx:
        a = anp.exp(x)               # held by this frame: live
        s = anp.add(a, 1.0)
        _ = np.asarray(s.value)
        assert not any(r.code == "MZ501" for r in ctx._last_rewrites)
        np.testing.assert_allclose(np.asarray(a.value), np.exp(np.asarray(x)),
                                   rtol=1e-6)


# ---------------------------------------------------------------------------
# MZ502: common-subexpression sharing
# ---------------------------------------------------------------------------


def _cse_merged(scalar_a, scalar_b) -> bool:
    """True iff multiply(x, a) and multiply(x, b) collapsed into one call."""
    plan_cache.clear()
    x = jnp.linspace(0.1, 1.0, 16, dtype=jnp.float32)
    with mozart.session(executor="fused") as ctx:
        a = anp.multiply(x, scalar_a)
        b = anp.multiply(x, scalar_b)
        s = anp.add(a, b)
        want = np.asarray(x) * scalar_a + np.asarray(x) * scalar_b
        np.testing.assert_allclose(np.asarray(s.value), want, rtol=2e-5,
                                   atol=1e-6)
    return any(r.code == "MZ502" for r in ctx._last_rewrites)


def test_cse_merges_identical_calls_only_once_executed():
    x = jnp.linspace(0.1, 1.0, 48, dtype=jnp.float32)

    def f(x):
        return anp.add(anp.exp(x), anp.exp(x))

    with mozart.session(executor="fused", autotune=False) as ctx:
        got = np.asarray(f(x).value)
    assert any(r.code == "MZ502" for r in ctx._last_rewrites)
    (entry,) = plan_cache.entries()
    planned = sum(len(t.positions) for t in entry.stage_templates)
    assert planned == 2                       # one exp + the add, not 3
    np.testing.assert_allclose(got, 2 * np.exp(np.asarray(x)), rtol=2e-5)


def test_cse_respects_captured_scalars_and_types():
    assert _cse_merged(2.0, 2.0)
    assert not _cse_merged(2.0, 3.0)
    assert not _cse_merged(2, 2.0)            # int vs float: distinct calls


@given(a=hst.floats(-2, 2, allow_nan=False),
       b=hst.floats(-2, 2, allow_nan=False),
       same=hst.booleans())
@settings(max_examples=10, deadline=None)
def test_cse_property_never_merges_distinct_scalars(a, b, same):
    """CSE merges two calls iff their captured scalars are equal (same type,
    same value) — it never collapses calls that could differ.  Runs under
    hypothesis when installed, as a deterministic seeded loop otherwise."""
    if same:
        b = a
    assert _cse_merged(a, b) == ((type(a), a) == (type(b), b))


# ---------------------------------------------------------------------------
# MZ503 / MZ505: pushdown and its declines
# ---------------------------------------------------------------------------


def test_pushdown_hoists_filter_ahead_of_map():
    n = 64
    x = jnp.linspace(0.1, 1.0, n, dtype=jnp.float32)
    mask = jnp.asarray(np.arange(n) % 2 == 0)

    def f(x, mask):
        m = anp.multiply(x, 3.0)     # elementwise map, output unobserved
        return anp.compress(mask, m)

    with mozart.session(executor="fused", autotune=False) as ctx:
        got = np.asarray(f(x, mask).value)
    recs = [r for r in ctx._last_rewrites if r.code == "MZ503"]
    assert len(recs) == 1 and recs[0].saved_s > 0.0
    want = (np.asarray(x) * 3.0)[np.asarray(mask)]
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)


def test_pushdown_declines_when_map_output_is_observed():
    n = 32
    x = jnp.linspace(0.1, 1.0, n, dtype=jnp.float32)
    mask = jnp.asarray(np.arange(n) % 2 == 0)
    with mozart.session(executor="fused") as ctx:
        m = anp.multiply(x, 3.0)     # live Future: hoist would skip elements
        fl = anp.compress(mask, m)
        _ = np.asarray(fl.value)
        codes = [r.code for r in ctx._last_rewrites]
        assert "MZ503" not in codes
        assert "MZ505" in codes
        np.testing.assert_allclose(np.asarray(m.value), np.asarray(x) * 3.0,
                                   rtol=2e-5)


def test_reduce_past_map_declined_with_reason():
    x = jnp.linspace(0.1, 1.0, 32, dtype=jnp.float32)

    def f(x):
        return anp.sum(anp.exp(x))

    with mozart.session(executor="fused") as ctx:
        got = float(np.asarray(f(x).value))
    declines = [r for r in ctx._last_rewrites if r.code == "MZ505"]
    assert any("distributivity" in r.detail for r in declines)
    np.testing.assert_allclose(got, float(np.sum(np.exp(np.asarray(x)))),
                               rtol=2e-5)


# ---------------------------------------------------------------------------
# MZ504: splitting-friendly reassociation
# ---------------------------------------------------------------------------


def test_reassociation_clusters_interleaved_chains():
    x8 = jnp.linspace(0.1, 1.0, 8, dtype=jnp.float32)
    y12 = jnp.linspace(0.2, 1.2, 12, dtype=jnp.float32)

    def interleaved(x, y):
        a1 = anp.exp(x)
        c1 = anp.exp(y)              # different extent: breaks the stage
        a2 = anp.multiply(a1, 2.0)
        c2 = anp.multiply(c1, 2.0)
        return a2, c2

    stages = {}
    outs = {}
    for on in (True, False):
        plan_cache.clear()
        with mozart.session(executor="fused", rewrite=on,
                            autotune=False) as ctx:
            a2, c2 = interleaved(x8, y12)
            outs[on] = (np.asarray(a2.value), np.asarray(c2.value))
            stages[on] = ctx.stats["stages"]
            if on:
                assert any(r.code == "MZ504" for r in ctx._last_rewrites)
    assert stages[True] < stages[False]
    for g, w in zip(outs[True], outs[False]):
        np.testing.assert_allclose(g, w, rtol=2e-5)


# ---------------------------------------------------------------------------
# Persistence: schema v7 round-trip, v6 migration, warm replay
# ---------------------------------------------------------------------------


def _simple(x):
    anp.exp(x)                       # dead
    b1 = anp.exp(x)
    b2 = anp.exp(x)                  # CSE pair
    return anp.add(b1, b2)


def test_rewrites_roundtrip_schema_v7(tmp_path):
    x = jnp.linspace(0.1, 1.0, 64, dtype=jnp.float32)
    with mozart.session(executor="fused", autotune=False):
        _ = np.asarray(_simple(x).value)
    path = str(tmp_path / "plans.json")
    assert plan_cache.save(path) == 1
    payload = json.load(open(path))
    assert payload["schema"] == plan_cache.SCHEMA_VERSION == 7
    plan_cache.clear()
    assert plan_cache.load(path) == 1
    (entry,) = plan_cache.entries()
    codes = [r["code"] for r in entry.rewrites]
    assert "MZ501" in codes and "MZ502" in codes


def test_schema_v6_file_migrates_forward(tmp_path):
    x = jnp.linspace(0.1, 1.0, 64, dtype=jnp.float32)
    with mozart.session(executor="fused", autotune=False):
        _ = np.asarray(_simple(x).value)
    path = str(tmp_path / "plans.json")
    assert plan_cache.save(path) == 1
    payload = json.load(open(path))
    payload["schema"] = 6
    for e in payload["entries"]:
        e.pop("rewrites", None)      # v6 entries never carried rewrites
    json.dump(payload, open(path, "w"))
    plan_cache.clear()
    assert plan_cache.load(path) == 1
    (entry,) = plan_cache.entries()
    assert entry.rewrites == []


def test_warm_replay_zero_planner_calls_zero_retraces():
    n = 96
    x = jnp.linspace(0.1, 1.0, n, dtype=jnp.float32)
    mask = jnp.asarray(np.arange(n) % 2 == 0)

    def run():
        with mozart.session(executor="fused", autotune=False) as ctx:
            s, f = _chain(x, mask)
            return (np.asarray(s.value), np.asarray(f.value)), ctx

    want, _ = run()                  # miss: rewrite + plan + compile
    run()                            # first hit
    t0 = trace_count()
    got, ctx = run()                 # warm: replay the rewritten graph
    assert ctx.stats.get("planner_calls", 0) == 0
    assert trace_count() - t0 == 0
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-6)
    (entry,) = plan_cache.entries()
    codes = {r["code"] for r in entry.rewrites}
    assert {"MZ501", "MZ502", "MZ503"} <= codes


# ---------------------------------------------------------------------------
# Periodic re-analysis (MOZART_REANALYZE_EVERY)
# ---------------------------------------------------------------------------


def test_periodic_reanalysis_ticks_and_refreshes(monkeypatch):
    monkeypatch.setenv("MOZART_REANALYZE_EVERY", "2")
    x = jnp.linspace(0.1, 1.0, 64, dtype=jnp.float32)

    def run():
        with mozart.session(executor="fused", autotune=False) as ctx:
            _ = np.asarray(_simple(x).value)
        return ctx

    run()                            # miss
    run()                            # hit 1
    ctx = run()                      # hit 2: the tick fires
    assert plan_cache.stats["reanalysis_ticks"] >= 1
    assert any(c.stats.get("reanalysis_ticks") for c in [ctx]) or \
        plan_cache.stats["reanalysis_ticks"] >= 1
    (entry,) = plan_cache.entries()
    # the tick re-derives rewrite records rather than trusting first-plan
    # conclusions forever: they are still the current ones
    assert {r["code"] for r in entry.rewrites} >= {"MZ501", "MZ502"}
    out = run()                      # next eval re-analyzes cleanly
    assert np.isfinite(plan_cache.stats["reanalysis_ticks"])
    assert out.stats["planner_calls"] == 0


def test_reanalysis_env_off_by_default(monkeypatch):
    monkeypatch.delenv("MOZART_REANALYZE_EVERY", raising=False)
    x = jnp.linspace(0.1, 1.0, 64, dtype=jnp.float32)
    for _ in range(3):
        with mozart.session(executor="fused", autotune=False):
            _ = np.asarray(_simple(x).value)
    assert plan_cache.stats.get("reanalysis_ticks", 0) == 0


# ---------------------------------------------------------------------------
# verify(): MZ5xx dry-run + recorded-handoff reuse (read-only)
# ---------------------------------------------------------------------------


def test_verify_reports_rewrites_without_mutating_cache():
    x = jnp.linspace(0.1, 1.0, 32, dtype=jnp.float32)

    def f(x):
        return _simple(x)

    rep = analysis.verify_pipeline(f, x, executor="fused")
    codes = {d.code for d in rep.diagnostics}
    assert "MZ501" in codes and "MZ502" in codes    # the dry-run reports
    assert "MZ201" in codes                          # on the UNREWRITTEN plan
    assert plan_cache.cache_info()["entries"] == 0   # peek never writes


def test_verify_reuses_recorded_handoff():
    """Regression: verify() re-derived handoff decisions the plan entry
    already carried — now it peeks and reuses them when fresh (MZ205's
    read-only guard still holds: no entry is created or promoted)."""
    x = jnp.linspace(0.1, 1.0, 64, dtype=jnp.float32)

    def f(x):
        return anp.add(anp.exp(x), 1.0)

    with mozart.session(executor="fused"):
        _ = np.asarray(f(x).value)
        _ = np.asarray(f(x).value)
    entries_before = [e.uid for e in plan_cache.entries()]
    base = plan_cache.stats.get("verify_handoff_reused", 0)
    rep = analysis.verify_pipeline(f, x, executor="fused")
    assert rep.ok
    assert plan_cache.stats["verify_handoff_reused"] == base + 1
    assert [e.uid for e in plan_cache.entries()] == entries_before


def test_lint_rewrite_report_cli_runs_clean():
    from repro.launch import lint

    assert lint.main(["--rewrite-report"]) == 0
