"""Per-architecture smoke tests: REDUCED config of the same family runs one
forward/train step on CPU; output shapes checked, no NaNs (full configs are
exercised only via the dry-run)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.models import lm
from repro.models import transformer as tfm
from repro.models.config import param_count


def make_smoke_batch(cfg, key, B=2, S=16):
    batch = {"tokens": jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)}
    if cfg.encdec:
        batch["enc_embeds"] = jax.random.normal(key, (B, 12, cfg.d_model),
                                                cfg.dtype)
    elif cfg.family == "vlm":
        batch["input_embeds"] = jax.random.normal(key, (B, S + 1, cfg.d_model),
                                                  cfg.dtype)
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S + 1)[None, None], (3, B, S + 1)).astype(jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch).with_runtime(dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    params = tfm.init_model(key, cfg)
    batch = make_smoke_batch(cfg, key)

    kw, labels, _ = lm.make_batch_views(batch, cfg)
    logits, aux = tfm.forward_train(params, cfg, **kw)
    B, S = labels.shape
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch

    # one SGD-flavoured train step (full optimizer tested elsewhere)
    loss, grads = jax.value_and_grad(lm.loss_fn)(params, batch, cfg)
    assert np.isfinite(float(loss)), arch
    new_params = jax.tree_util.tree_map(lambda p, g: p - 1e-3 * g.astype(p.dtype),
                                        params, grads)
    loss2 = lm.loss_fn(new_params, batch, cfg)
    assert np.isfinite(float(loss2)), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_constructs(arch):
    """FULL configs must build (no arrays allocated) and match the brief."""
    cfg = get_config(arch)
    brief = {
        "olmoe-1b-7b": dict(n_layers=16, d_model=2048, d_ff=1024, vocab_size=50304),
        "deepseek-moe-16b": dict(n_layers=28, d_model=2048, d_ff=1408, vocab_size=102400),
        "seamless-m4t-large-v2": dict(n_layers=24, d_model=1024, d_ff=8192, vocab_size=256206),
        "gemma-7b": dict(n_layers=28, d_model=3072, d_ff=24576, vocab_size=256000),
        "gemma3-4b": dict(n_layers=34, d_model=2560, d_ff=10240, vocab_size=262144),
        "internlm2-20b": dict(n_layers=48, d_model=6144, d_ff=16384, vocab_size=92544),
        "granite-34b": dict(n_layers=88, d_model=6144, d_ff=24576, vocab_size=49152),
        "hymba-1.5b": dict(n_layers=32, d_model=1600, d_ff=5504, vocab_size=32001),
        "qwen2-vl-2b": dict(n_layers=28, d_model=1536, d_ff=8960, vocab_size=151936),
        "rwkv6-1.6b": dict(n_layers=24, d_model=2048, d_ff=7168, vocab_size=65536),
    }[arch]
    for k, v in brief.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)

    heads = {
        "olmoe-1b-7b": (16, 16), "deepseek-moe-16b": (16, 16),
        "seamless-m4t-large-v2": (16, 16), "gemma-7b": (16, 16),
        "gemma3-4b": (8, 4), "internlm2-20b": (48, 8), "granite-34b": (48, 1),
        "hymba-1.5b": (25, 5), "qwen2-vl-2b": (12, 2),
    }
    if arch in heads:
        assert (cfg.attn.n_heads, cfg.attn.n_kv_heads) == heads[arch]
    else:
        assert cfg.attn is None                  # rwkv6 is attention-free

    n = param_count(cfg)
    expected_range = {
        "olmoe-1b-7b": (5e9, 9e9),               # 7B total params
        "deepseek-moe-16b": (13e9, 20e9),
        "seamless-m4t-large-v2": (1.2e9, 3e9),
        "gemma-7b": (7e9, 10e9),
        "gemma3-4b": (3e9, 6e9),
        "internlm2-20b": (17e9, 23e9),
        "granite-34b": (30e9, 40e9),
        "hymba-1.5b": (1e9, 2.2e9),
        "qwen2-vl-2b": (1.2e9, 2.5e9),
        "rwkv6-1.6b": (1.2e9, 2.2e9),
    }[arch]
    assert expected_range[0] < n < expected_range[1], (arch, n)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode(arch):
    cfg = get_smoke_config(arch).with_runtime(dtype=jnp.float32)
    if cfg.encdec:
        pytest.skip("enc-dec decode covered in test_models enc path")
    if cfg.family == "vlm":
        pytest.skip("vlm decode requires embeds pipeline; covered via specs")
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    out = lm.greedy_generate(params, cfg, prompt, 4, max_len=16)
    assert out.shape == (2, 4)
    assert np.all(np.asarray(out) >= 0)


def test_shape_applicability_table():
    from repro.configs.shapes import SHAPES, applicable
    runs_500k = {a for a in ARCH_IDS
                 if applicable(get_config(a), SHAPES["long_500k"])[0]}
    assert runs_500k == {"gemma3-4b", "hymba-1.5b", "rwkv6-1.6b"}
    for a in ARCH_IDS:                      # all archs decode
        assert applicable(get_config(a), SHAPES["decode_32k"])[0]
