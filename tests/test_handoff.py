"""Cross-stage chunk handoff: the merge→re-split eliminator.

Covers: the SplitType ``can_handoff``/``rechunk`` protocol; differential
parity (handoff on vs off) across every registered executor and across
ElementSplit/ReduceSplit/broadcast/axis-mismatch edges with empty and
odd-size inputs; boundary-traffic accounting (``stage_exec.
bytes_materialized`` — interior boundaries drop to zero under handoff);
chunk-buffer donation safety; and a ``MOZART_PLAN_CACHE`` round trip
asserting recorded handoff decisions replay in a fresh process with zero
planner calls.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mozart, plan_cache, stage_exec
from repro.core import annotated_numpy as anp
from repro.core import split_types as st
from repro.core.stage_exec import ChunkStream, available_executors


def _ranges(n, b):
    return [(s, min(s + b, n)) for s in range(0, n, b)]


# ---------------------------------------------------------------------------
# The SplitType handoff protocol
# ---------------------------------------------------------------------------


class TestCanHandoff:
    def test_array_split_same_grid(self):
        a = st.ArraySplit((100,), 0)
        assert a.can_handoff(st.ArraySplit((100,), 0))

    def test_array_split_axis_mismatch(self):
        assert not st.ArraySplit((8, 8), 0).can_handoff(st.ArraySplit((8, 8), 1))

    def test_array_split_shape_mismatch(self):
        assert not st.ArraySplit((100,), 0).can_handoff(st.ArraySplit((99,), 0))

    def test_non_splittable_consumers_refuse(self):
        a = st.ArraySplit((100,), 0)
        assert not a.can_handoff(st.BROADCAST)
        assert not a.can_handoff(st.ReduceSplit("add"))
        assert not a.can_handoff(st.ConcatSplit("t", 0))

    def test_non_array_producers_refuse(self):
        c = st.ArraySplit((100,), 0)
        assert not st.BROADCAST.can_handoff(c)
        assert not st.ReduceSplit("add").can_handoff(c)
        assert not st.UnknownSplit().can_handoff(c)

    def test_pytree_split(self):
        p = st.PytreeSplit("td", 10, 0)
        assert p.can_handoff(st.PytreeSplit("td", 10, 0))
        assert not p.can_handoff(st.PytreeSplit("td", 11, 0))
        assert not p.can_handoff(st.ArraySplit((10,), 0))


class TestRechunk:
    def _chunks(self, t, x, grid):
        return [t.split(x, s, e) for s, e in grid]

    @pytest.mark.parametrize("src_b,dst_b", [(4, 4), (4, 8), (8, 4), (10, 4), (4, 10)])
    def test_round_trips_any_aligned_grids(self, src_b, dst_b):
        n = 20
        t = st.ArraySplit((n,), 0)
        x = jnp.arange(n, dtype=jnp.float32)
        out, copied = t.rechunk(self._chunks(t, x, _ranges(n, src_b)),
                                _ranges(n, src_b), _ranges(n, dst_b))
        assert len(out) == len(_ranges(n, dst_b))
        np.testing.assert_array_equal(np.asarray(t.merge(out)), np.asarray(x))
        if src_b == dst_b:
            assert copied == 0          # identical grids: pure pass-through
        else:
            assert copied > 0

    def test_identity_passthrough_by_reference(self):
        n, b = 16, 4
        t = st.ArraySplit((n,), 0)
        chunks = self._chunks(t, jnp.arange(n, dtype=jnp.float32), _ranges(n, b))
        out, copied = t.rechunk(chunks, _ranges(n, b), _ranges(n, b))
        assert copied == 0
        assert all(o is c for o, c in zip(out, chunks))

    def test_coarsen_costs_at_most_one_copy(self):
        n, src_b, dst_b = 64, 8, 16
        t = st.ArraySplit((n,), 0)
        x = jnp.arange(n, dtype=jnp.float32)
        out, copied = t.rechunk(self._chunks(t, x, _ranges(n, src_b)),
                                _ranges(n, src_b), _ranges(n, dst_b))
        assert copied == int(x.nbytes)  # one copy — merge+re-split pays two
        np.testing.assert_array_equal(np.asarray(t.merge(out)), np.asarray(x))

    def test_pytree_split_rechunk(self):
        n = 12
        leaves = {"a": jnp.arange(n, dtype=jnp.float32),
                  "b": jnp.ones((n, 2), jnp.float32)}
        t = st.PytreeSplit("td", n, 0)
        out, copied = t.rechunk([t.split(leaves, s, e) for s, e in _ranges(n, 3)],
                                _ranges(n, 3), _ranges(n, 6))
        merged = t.merge(out)
        np.testing.assert_array_equal(np.asarray(merged["a"]),
                                      np.asarray(leaves["a"]))
        assert copied > 0


# ---------------------------------------------------------------------------
# Differential: handoff on == handoff off, everywhere
# ---------------------------------------------------------------------------


def _eval_chain(x, evals=3):
    """Multi-evaluation elementwise chain: every evaluation boundary is a
    producer→consumer edge with identical ArraySplit grids (the serve-decode
    shape — exactly where the merge→re-split round trip used to live)."""
    cur = x
    for _ in range(evals):
        cur = anp.multiply(anp.add(cur, 1.0), 0.5)
        mozart.evaluate()
    return cur


def _reduce_edge(x):
    """ElementSplit stage → ReduceSplit output → broadcast into the next
    evaluation: the boundary must merge (partials), never stream."""
    s = anp.sum(anp.exp(x))
    mozart.evaluate()
    return anp.multiply(x, s)


def _axis_mismatch(m):
    """Row-split then column-split: boundary with INCOMPATIBLE grids."""
    a = anp.normalize_axis(m, axis=1)
    mozart.evaluate()
    return anp.normalize_axis(a, axis=0)


SURFACES = {
    "element_chain": (lambda: jnp.linspace(0., 1., 10_000, dtype=jnp.float32),
                      _eval_chain),
    "reduce_edge": (lambda: jnp.linspace(0., 1., 10_000, dtype=jnp.float32),
                    _reduce_edge),
    "axis_mismatch": (lambda: jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32),
                      _axis_mismatch),
    "empty": (lambda: jnp.zeros((0,), jnp.float32), _eval_chain),
    "odd_size": (lambda: jnp.linspace(0., 1., 17, dtype=jnp.float32),
                 lambda x: _eval_chain(x, evals=2)),
}


@pytest.mark.parametrize("surface", sorted(SURFACES))
@pytest.mark.parametrize("executor", sorted(available_executors()))
def test_differential_handoff_on_off(executor, surface):
    make, fn = SURFACES[surface]
    if executor == "sharded" and surface in ("empty", "odd_size", "axis_mismatch"):
        pytest.skip("sharded requires mesh-divisible element counts")
    kwargs = {"batch_elements": 2048 if surface != "odd_size" else 4}
    if executor == "sharded":
        kwargs["mesh"] = jax.make_mesh((1,), ("data",))
    outs = {}
    for handoff in (True, False):
        plan_cache.clear()
        with mozart.session(executor=executor, handoff=handoff, **kwargs) as ctx:
            out = np.asarray(fn(make()))
        outs[handoff] = (out, dict(ctx.stats))
    np.testing.assert_allclose(outs[True][0], outs[False][0],
                               rtol=2e-5, atol=1e-6)
    # handoff=False must never stream or ingest
    assert outs[False][1].get("streamed_outputs", 0) == 0
    assert outs[False][1].get("stream_ingests", 0) == 0


def test_pytree_split_streams_end_to_end():
    """PytreeSplit outputs hand off like arrays: a chained pytree pipeline
    (optimizer-state shape) streams across evaluation boundaries, and batch
    sizing reads the stream's AVAL (the stream object is not a pytree)."""
    from repro.core import splittable
    from repro.core import split_types as _st

    @splittable(s=_st.Pytree(0), ret=_st.Pytree(0))
    def tree_step(s):
        return {"p": s["p"] * 0.5 + 1.0, "m": s["m"] + s["p"][:, None]}

    n = 4096
    state = {"p": jnp.arange(n, dtype=jnp.float32),
             "m": jnp.ones((n, 2), jnp.float32)}
    outs = {}
    for handoff in (True, False):
        plan_cache.clear()
        with mozart.session(executor="fused", batch_elements=512,
                            handoff=handoff) as ctx:
            cur = state
            for _ in range(3):
                cur = tree_step(cur)
                mozart.evaluate()
            outs[handoff] = (jax.tree_util.tree_map(np.asarray, cur.value),
                             dict(ctx.stats))
    assert outs[True][1].get("streamed_outputs", 0) == 3
    assert outs[True][1].get("stream_ingests", 0) == 2
    for k in ("p", "m"):
        np.testing.assert_allclose(outs[True][0][k], outs[False][0][k],
                                   rtol=1e-6)


def test_auto_executor_stream_stats_not_double_counted():
    """AutoExecutor resolves once for scoring and the delegate resolves
    again for execution — only the delegate's resolve may tally.  Delegates
    are pinned to the stream-capable `fused` so the streams actually exist
    (auto's own measured pick on this host is `eager`, which never chunks)."""
    n = 20_000
    x = jnp.linspace(0., 1., n, dtype=jnp.float32)
    plan_cache.clear()

    def once():
        with mozart.session(executor="auto", batch_elements=4096) as ctx:
            out = np.asarray(_eval_chain(x))
        return out, ctx

    out1, _ = once()
    for e in plan_cache.entries():      # pin every stage to the fused driver
        for tm_id in range(len(e.stage_templates)):
            e.pin_exec(tm_id, "fused")
    out2, ctx = once()                  # warm: auto replays the pins
    np.testing.assert_allclose(out1, out2, rtol=1e-6)
    assert ctx.stats["auto_pinned_replays"] == 3
    assert ctx.stats["streamed_outputs"] == 3
    # 2 interior edges: exactly ONE ingest event per edge, no double tally
    assert ctx.stats.get("stream_ingests", 0) == 2
    assert ctx.stats.get("stream_materialized", 0) == 0


# ---------------------------------------------------------------------------
# Boundary traffic: interior boundaries drop to zero
# ---------------------------------------------------------------------------


class TestBoundaryTraffic:
    N, BATCH = 50_000, 8192

    def _run(self, handoff, observe=True):
        def once():
            with mozart.session(executor="fused", batch_elements=self.BATCH,
                                handoff=handoff) as ctx:
                cur = _eval_chain(jnp.linspace(0., 1., self.N, dtype=jnp.float32))
                out = np.asarray(cur) if observe else None
            return out, ctx
        plan_cache.clear()
        once(); once()                   # plan, then warm the cache
        before = stage_exec.bytes_materialized()
        out, ctx = once()
        return out, ctx, stage_exec.bytes_materialized() - before

    def test_interior_boundaries_zero_bytes(self):
        final_bytes = self.N * 4
        _, ctx, on_bytes = self._run(handoff=True)
        assert on_bytes == final_bytes   # ONLY the observed output merged
        assert ctx.stats["streamed_outputs"] == 3
        assert ctx.stats["stream_ingests"] == 2
        _, _, off_bytes = self._run(handoff=False)
        # merge-everything pays ≥ (3 merges + 2 re-splits) x n bytes
        assert off_bytes >= 5 * final_bytes

    def test_unobserved_output_never_materializes(self):
        _, ctx, on_bytes = self._run(handoff=True, observe=False)
        assert on_bytes == 0             # nothing observed: zero merges total

    def test_zero_planner_calls_on_warm_handoff(self):
        _, ctx, _ = self._run(handoff=True)
        assert ctx.stats["planner_calls"] == 0
        assert ctx.stats.get("plan_cache_hits", 0) >= 3

    def test_pipe_ablation_streams_interior(self):
        """pipeline=False (Table-4 "-pipe") makes every op its own stage;
        handoff then removes the per-boundary round trips the ablation used
        to pay INSIDE one evaluation."""
        x = jnp.linspace(0., 1., self.N, dtype=jnp.float32)

        def once(handoff):
            with mozart.session(executor="fused", batch_elements=self.BATCH,
                                pipeline=False, handoff=handoff) as ctx:
                out = np.asarray(anp.multiply(anp.exp(anp.add(x, 1.0)), 0.5))
            return out, ctx
        plan_cache.clear()
        once(True); once(True)
        before = stage_exec.bytes_materialized()
        on_out, ctx = once(True)
        on_bytes = stage_exec.bytes_materialized() - before
        assert ctx.stats["streamed_outputs"] >= 2
        assert on_bytes == self.N * 4
        plan_cache.clear()
        once(False); once(False)
        before = stage_exec.bytes_materialized()
        off_out, _ = once(False)
        assert stage_exec.bytes_materialized() - before >= 5 * self.N * 4
        np.testing.assert_allclose(on_out, off_out, rtol=2e-5)

    def test_incapable_executor_materializes_on_ingest(self):
        """A stream handed to a whole-value executor merges on ingest —
        correct, merely the old cost."""
        x = jnp.linspace(0., 1., self.N, dtype=jnp.float32)
        plan_cache.clear()
        with mozart.session(executor="fused", batch_elements=self.BATCH) as ctx:
            a = anp.multiply(anp.add(x, 1.0), 0.5)
            mozart.evaluate()            # `a` streams (pure output, fused)
            assert isinstance(ctx.graph.nodes[a._node.id].result, ChunkStream)
            mozart.configure(executor="scan")
            out = np.asarray(anp.exp(a))
        assert ctx.stats["stream_materialized"] >= 1
        want = np.exp((np.linspace(0., 1., self.N, dtype=np.float32) + 1) * 0.5)
        np.testing.assert_allclose(out, want, rtol=2e-5)


# ---------------------------------------------------------------------------
# Donation safety
# ---------------------------------------------------------------------------


class TestDonation:
    def test_alive_future_donates_copies_only(self):
        """A stream whose producer Future is still observable must keep its
        own buffers — the driver gets defensive COPIES to donate, and
        observing the producer after consumption still works."""
        n, b = 20_000, 4096
        x = jnp.linspace(0., 1., n, dtype=jnp.float32)
        plan_cache.clear()
        for _ in range(3):
            with mozart.session(executor="fused", batch_elements=b) as ctx:
                a = anp.multiply(anp.add(x, 1.0), 0.5)
                mozart.evaluate()
                out = np.asarray(anp.exp(a))     # consumes a's stream
                a_val = np.asarray(a)            # a observed AFTER consumption
            if ctx.stats.get("donated_chunks", 0):
                assert ctx.stats["donation_copies"] == ctx.stats["donated_chunks"]
        want_a = (np.linspace(0., 1., n, dtype=np.float32) + 1) * 0.5
        np.testing.assert_allclose(a_val, want_a, rtol=2e-5)
        np.testing.assert_allclose(out, np.exp(want_a), rtol=2e-5)

    def test_liveness_flap_does_not_retrace(self):
        """The donate key set is structural: whether the producer's Future
        happens to be alive on a given call must not change the pinned
        driver variant (zero retraces on warm calls either way)."""
        n, b = 20_000, 4096
        x = jnp.linspace(0., 1., n, dtype=jnp.float32)
        plan_cache.clear()

        def once(hold):
            with mozart.session(executor="fused", batch_elements=b) as ctx:
                a = anp.multiply(anp.add(x, 1.0), 0.5)
                mozart.evaluate()
                e = anp.exp(a)                   # registered; holds a NodeRef
                if not hold:
                    del a                        # Future dies pre-consumption
                out = np.asarray(e)
                if hold:
                    _ = np.asarray(a)            # observe AFTER consumption
            return out, ctx

        once(True); once(True)                   # plan + warm the cache
        before = stage_exec.trace_count()
        o1, c1 = once(True)                      # producer observable: copies
        o2, c2 = once(False)                     # producer dead: real donation
        o3, _ = once(True)
        assert stage_exec.trace_count() == before
        assert c1.stats["exec_builds"] == 0 and c2.stats["exec_builds"] == 0
        assert c1.stats.get("donation_copies", 0) > 0
        assert c2.stats.get("donation_copies", 0) == 0
        assert c2.stats.get("donated_chunks", 0) > 0
        np.testing.assert_allclose(o1, o2, rtol=1e-6)
        np.testing.assert_allclose(o1, o3, rtol=1e-6)

    def test_dead_future_donates_and_stays_correct(self):
        n, b = 20_000, 4096
        x = jnp.linspace(0., 1., n, dtype=jnp.float32)
        plan_cache.clear()

        def once():
            with mozart.session(executor="fused", batch_elements=b) as ctx:
                cur = _eval_chain(x)
                out = np.asarray(cur)
            return out, ctx
        once(); once()
        out, ctx = once()
        assert ctx.stats["donated_chunks"] > 0
        want = np.asarray(x)
        for _ in range(3):
            want = (want + 1.0) * 0.5
        np.testing.assert_allclose(out, want, rtol=2e-5)


# ---------------------------------------------------------------------------
# Handoff decisions replay from MOZART_PLAN_CACHE with zero planner calls
# ---------------------------------------------------------------------------

_PRELUDE = """
import json, sys
import jax.numpy as jnp
import numpy as np
from repro.core import mozart, plan_cache, stage_exec
from repro.core import annotated_numpy as anp

x = jnp.linspace(0.0, 1.0, 30_000, dtype=jnp.float32)

def run():
    with mozart.session(executor="fused", batch_elements=4096) as ctx:
        cur = x
        for _ in range(3):
            cur = anp.multiply(anp.add(cur, 1.0), 0.5)
            mozart.evaluate()
        out = np.asarray(cur)
    return out, ctx
"""

_PROC_A = _PRELUDE + """
run(); run()
out, ctx = run()
print(json.dumps({"sum": float(out.sum()),
                  "streamed": ctx.stats["streamed_outputs"],
                  "ingests": ctx.stats["stream_ingests"]}))
"""

_PROC_B = _PRELUDE + """
b0 = stage_exec.bytes_materialized()
out, ctx = run()
print(json.dumps({"sum": float(out.sum()),
                  "streamed": ctx.stats["streamed_outputs"],
                  "ingests": ctx.stats["stream_ingests"],
                  "planner_calls": ctx.stats["planner_calls"],
                  "bytes": stage_exec.bytes_materialized() - b0,
                  "pc": dict(plan_cache.stats)}))
"""


def _run_subprocess(code, path):
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    existing = env.get("PYTHONPATH")
    env["PYTHONPATH"] = src + (os.pathsep + existing if existing else "")
    env["MOZART_PLAN_CACHE"] = path
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_handoff_decisions_replay_from_persisted_cache(tmp_path):
    """Process A records handoff decisions in its persisted plans; a FRESH
    process B replays them — zero planner calls, streams from call one, and
    interior boundary bytes already zero."""
    path = str(tmp_path / "plans.json")
    a = _run_subprocess(_PROC_A, path)
    assert a["streamed"] == 3 and a["ingests"] == 2
    assert os.path.exists(path)

    b = _run_subprocess(_PROC_B, path)
    assert b["pc"].get("persist_loaded", 0) >= 1
    assert b["planner_calls"] == 0            # decisions replayed, not re-derived
    assert b["streamed"] == 3 and b["ingests"] == 2
    assert b["bytes"] == 30_000 * 4           # final observed output only
    assert np.isclose(a["sum"], b["sum"], rtol=1e-6)
