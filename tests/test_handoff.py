"""Cross-stage chunk handoff: the merge→re-split eliminator.

Covers: the SplitType ``can_handoff``/``rechunk`` protocol (including the
misaligned-grid property test and the ConcatSplit→ArraySplit rule);
differential parity (handoff on vs off) across every registered executor
and across ElementSplit/ReduceSplit/broadcast/axis-mismatch edges with
empty and odd-size inputs; ``scan``/``pallas`` stream ingest (carry-layout
stacking, padded-launch-buffer stacking, zero interior bytes, zero warm
retraces); interior-vs-terminal boundary-byte accounting; zero-chunk
stream hardening; chunk-buffer donation safety (plan-time veto of
observable producers + the pinned runtime backstop); and
``MOZART_PLAN_CACHE`` round trips asserting recorded decisions — including
ConcatSplit conversions and migrated v2/v3 files — replay in a fresh
process with zero planner calls.  Also: per-context counter scoping
(``ctx.counters`` sees only its own session's traffic), the
ConcatSplit→PytreeSplit per-leaf conversion rule, and donation-veto aging
(stale plan-time vetoes re-analyze after ``handoff.STALE_THRESHOLD``
consecutive disagreements with observed liveness).
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mozart, plan_cache, stage_exec
from repro.core import annotated_numpy as anp
from repro.core import split_types as st
from repro.core.stage_exec import ChunkStream, available_executors


def _ranges(n, b):
    return [(s, min(s + b, n)) for s in range(0, n, b)]


# ---------------------------------------------------------------------------
# The SplitType handoff protocol
# ---------------------------------------------------------------------------


class TestCanHandoff:
    def test_array_split_same_grid(self):
        a = st.ArraySplit((100,), 0)
        assert a.can_handoff(st.ArraySplit((100,), 0))

    def test_array_split_axis_mismatch(self):
        assert not st.ArraySplit((8, 8), 0).can_handoff(st.ArraySplit((8, 8), 1))

    def test_array_split_shape_mismatch(self):
        assert not st.ArraySplit((100,), 0).can_handoff(st.ArraySplit((99,), 0))

    def test_non_splittable_consumers_refuse(self):
        a = st.ArraySplit((100,), 0)
        assert not a.can_handoff(st.BROADCAST)
        assert not a.can_handoff(st.ReduceSplit("add"))
        assert not a.can_handoff(st.ConcatSplit("t", 0))

    def test_non_array_producers_refuse(self):
        c = st.ArraySplit((100,), 0)
        assert not st.BROADCAST.can_handoff(c)
        assert not st.ReduceSplit("add").can_handoff(c)
        assert not st.UnknownSplit().can_handoff(c)

    def test_pytree_split(self):
        p = st.PytreeSplit("td", 10, 0)
        assert p.can_handoff(st.PytreeSplit("td", 10, 0))
        assert not p.can_handoff(st.PytreeSplit("td", 11, 0))
        assert not p.can_handoff(st.ArraySplit((10,), 0))


class TestRechunk:
    def _chunks(self, t, x, grid):
        return [t.split(x, s, e) for s, e in grid]

    @pytest.mark.parametrize("src_b,dst_b", [(4, 4), (4, 8), (8, 4), (10, 4), (4, 10)])
    def test_round_trips_any_aligned_grids(self, src_b, dst_b):
        n = 20
        t = st.ArraySplit((n,), 0)
        x = jnp.arange(n, dtype=jnp.float32)
        out, copied = t.rechunk(self._chunks(t, x, _ranges(n, src_b)),
                                _ranges(n, src_b), _ranges(n, dst_b))
        assert len(out) == len(_ranges(n, dst_b))
        np.testing.assert_array_equal(np.asarray(t.merge(out)), np.asarray(x))
        if src_b == dst_b:
            assert copied == 0          # identical grids: pure pass-through
        else:
            assert copied > 0

    def test_identity_passthrough_by_reference(self):
        n, b = 16, 4
        t = st.ArraySplit((n,), 0)
        chunks = self._chunks(t, jnp.arange(n, dtype=jnp.float32), _ranges(n, b))
        out, copied = t.rechunk(chunks, _ranges(n, b), _ranges(n, b))
        assert copied == 0
        assert all(o is c for o, c in zip(out, chunks))

    def test_coarsen_costs_at_most_one_copy(self):
        n, src_b, dst_b = 64, 8, 16
        t = st.ArraySplit((n,), 0)
        x = jnp.arange(n, dtype=jnp.float32)
        out, copied = t.rechunk(self._chunks(t, x, _ranges(n, src_b)),
                                _ranges(n, src_b), _ranges(n, dst_b))
        assert copied == int(x.nbytes)  # one copy — merge+re-split pays two
        np.testing.assert_array_equal(np.asarray(t.merge(out)), np.asarray(x))

    def test_pytree_split_rechunk(self):
        n = 12
        leaves = {"a": jnp.arange(n, dtype=jnp.float32),
                  "b": jnp.ones((n, 2), jnp.float32)}
        t = st.PytreeSplit("td", n, 0)
        out, copied = t.rechunk([t.split(leaves, s, e) for s, e in _ranges(n, 3)],
                                _ranges(n, 3), _ranges(n, 6))
        merged = t.merge(out)
        np.testing.assert_array_equal(np.asarray(merged["a"]),
                                      np.asarray(leaves["a"]))
        assert copied > 0


# ---------------------------------------------------------------------------
# Differential: handoff on == handoff off, everywhere
# ---------------------------------------------------------------------------


def _eval_chain(x, evals=3):
    """Multi-evaluation elementwise chain: every evaluation boundary is a
    producer→consumer edge with identical ArraySplit grids (the serve-decode
    shape — exactly where the merge→re-split round trip used to live)."""
    cur = x
    for _ in range(evals):
        cur = anp.multiply(anp.add(cur, 1.0), 0.5)
        mozart.evaluate()
    return cur


def _reduce_edge(x):
    """ElementSplit stage → ReduceSplit output → broadcast into the next
    evaluation: the boundary must merge (partials), never stream."""
    s = anp.sum(anp.exp(x))
    mozart.evaluate()
    return anp.multiply(x, s)


def _axis_mismatch(m):
    """Row-split then column-split: boundary with INCOMPATIBLE grids."""
    a = anp.normalize_axis(m, axis=1)
    mozart.evaluate()
    return anp.normalize_axis(a, axis=0)


SURFACES = {
    "element_chain": (lambda: jnp.linspace(0., 1., 10_000, dtype=jnp.float32),
                      _eval_chain),
    "reduce_edge": (lambda: jnp.linspace(0., 1., 10_000, dtype=jnp.float32),
                    _reduce_edge),
    "axis_mismatch": (lambda: jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32),
                      _axis_mismatch),
    "empty": (lambda: jnp.zeros((0,), jnp.float32), _eval_chain),
    "odd_size": (lambda: jnp.linspace(0., 1., 17, dtype=jnp.float32),
                 lambda x: _eval_chain(x, evals=2)),
}


@pytest.mark.parametrize("surface", sorted(SURFACES))
@pytest.mark.parametrize("executor", sorted(available_executors()))
def test_differential_handoff_on_off(executor, surface):
    make, fn = SURFACES[surface]
    if executor == "sharded" and surface in ("empty", "odd_size", "axis_mismatch"):
        pytest.skip("sharded requires mesh-divisible element counts")
    kwargs = {"batch_elements": 2048 if surface != "odd_size" else 4}
    if executor == "sharded":
        kwargs["mesh"] = jax.make_mesh((1,), ("data",))
    outs = {}
    for handoff in (True, False):
        plan_cache.clear()
        with mozart.session(executor=executor, handoff=handoff, **kwargs) as ctx:
            out = np.asarray(fn(make()))
        outs[handoff] = (out, dict(ctx.stats))
    np.testing.assert_allclose(outs[True][0], outs[False][0],
                               rtol=2e-5, atol=1e-6)
    # handoff=False must never stream or ingest
    assert outs[False][1].get("streamed_outputs", 0) == 0
    assert outs[False][1].get("stream_ingests", 0) == 0


def test_pytree_split_streams_end_to_end():
    """PytreeSplit outputs hand off like arrays: a chained pytree pipeline
    (optimizer-state shape) streams across evaluation boundaries, and batch
    sizing reads the stream's AVAL (the stream object is not a pytree)."""
    from repro.core import splittable
    from repro.core import split_types as _st

    @splittable(s=_st.Pytree(0), ret=_st.Pytree(0))
    def tree_step(s):
        return {"p": s["p"] * 0.5 + 1.0, "m": s["m"] + s["p"][:, None]}

    n = 4096
    state = {"p": jnp.arange(n, dtype=jnp.float32),
             "m": jnp.ones((n, 2), jnp.float32)}
    outs = {}
    for handoff in (True, False):
        plan_cache.clear()
        with mozart.session(executor="fused", batch_elements=512,
                            handoff=handoff) as ctx:
            cur = state
            for _ in range(3):
                cur = tree_step(cur)
                mozart.evaluate()
            outs[handoff] = (jax.tree_util.tree_map(np.asarray, cur.value),
                             dict(ctx.stats))
    assert outs[True][1].get("streamed_outputs", 0) == 3
    assert outs[True][1].get("stream_ingests", 0) == 2
    for k in ("p", "m"):
        np.testing.assert_allclose(outs[True][0][k], outs[False][0][k],
                                   rtol=1e-6)


def test_auto_executor_stream_stats_not_double_counted():
    """AutoExecutor resolves once for scoring and the delegate resolves
    again for execution — only the delegate's resolve may tally.  Delegates
    are pinned to the stream-capable `fused` so the streams actually exist
    (auto's own measured pick on this host is `eager`, which never chunks)."""
    n = 20_000
    x = jnp.linspace(0., 1., n, dtype=jnp.float32)
    plan_cache.clear()

    def once():
        with mozart.session(executor="auto", batch_elements=4096) as ctx:
            out = np.asarray(_eval_chain(x))
        return out, ctx

    out1, _ = once()
    for e in plan_cache.entries():      # pin every stage to the fused driver
        for tm_id in range(len(e.stage_templates)):
            e.pin_exec(tm_id, "fused")
    out2, ctx = once()                  # warm: auto replays the pins
    np.testing.assert_allclose(out1, out2, rtol=1e-6)
    assert ctx.stats["auto_pinned_replays"] == 3
    assert ctx.stats["streamed_outputs"] == 3
    # 2 interior edges: exactly ONE ingest event per edge, no double tally
    assert ctx.stats.get("stream_ingests", 0) == 2
    assert ctx.stats.get("stream_materialized", 0) == 0


# ---------------------------------------------------------------------------
# Boundary traffic: interior boundaries drop to zero
# ---------------------------------------------------------------------------


class TestBoundaryTraffic:
    N, BATCH = 50_000, 8192

    def _run(self, handoff, observe=True):
        def once():
            with mozart.session(executor="fused", batch_elements=self.BATCH,
                                handoff=handoff) as ctx:
                cur = _eval_chain(jnp.linspace(0., 1., self.N, dtype=jnp.float32))
                out = np.asarray(cur) if observe else None
            return out, ctx
        plan_cache.clear()
        once(); once()                   # plan, then warm the cache
        before = stage_exec.bytes_materialized()
        out, ctx = once()
        return out, ctx, stage_exec.bytes_materialized() - before

    def test_interior_boundaries_zero_bytes(self):
        final_bytes = self.N * 4
        _, ctx, on_bytes = self._run(handoff=True)
        assert on_bytes == final_bytes   # ONLY the observed output merged
        assert ctx.stats["streamed_outputs"] == 3
        assert ctx.stats["stream_ingests"] == 2
        _, _, off_bytes = self._run(handoff=False)
        # merge-everything pays ≥ (3 merges + 2 re-splits) x n bytes
        assert off_bytes >= 5 * final_bytes

    def test_unobserved_output_never_materializes(self):
        _, ctx, on_bytes = self._run(handoff=True, observe=False)
        assert on_bytes == 0             # nothing observed: zero merges total

    def test_zero_planner_calls_on_warm_handoff(self):
        _, ctx, _ = self._run(handoff=True)
        assert ctx.stats["planner_calls"] == 0
        assert ctx.stats.get("plan_cache_hits", 0) >= 3

    def test_pipe_ablation_streams_interior(self):
        """pipeline=False (Table-4 "-pipe") makes every op its own stage;
        handoff then removes the per-boundary round trips the ablation used
        to pay INSIDE one evaluation."""
        x = jnp.linspace(0., 1., self.N, dtype=jnp.float32)

        def once(handoff):
            with mozart.session(executor="fused", batch_elements=self.BATCH,
                                pipeline=False, handoff=handoff) as ctx:
                out = np.asarray(anp.multiply(anp.exp(anp.add(x, 1.0)), 0.5))
            return out, ctx
        plan_cache.clear()
        once(True); once(True)
        before = stage_exec.bytes_materialized()
        on_out, ctx = once(True)
        on_bytes = stage_exec.bytes_materialized() - before
        assert ctx.stats["streamed_outputs"] >= 2
        assert on_bytes == self.N * 4
        plan_cache.clear()
        once(False); once(False)
        before = stage_exec.bytes_materialized()
        off_out, _ = once(False)
        assert stage_exec.bytes_materialized() - before >= 5 * self.N * 4
        np.testing.assert_allclose(on_out, off_out, rtol=2e-5)

    def test_incapable_executor_materializes_on_ingest(self):
        """A stream handed to a whole-value executor merges on ingest —
        correct, merely the old cost.  (`eager` is the remaining
        stream-incapable chunking-free strategy; `scan` and `pallas` became
        stream ingesters in the handoff-completion pass.)"""
        x = jnp.linspace(0., 1., self.N, dtype=jnp.float32)
        plan_cache.clear()
        with mozart.session(executor="fused", batch_elements=self.BATCH) as ctx:
            a = anp.multiply(anp.add(x, 1.0), 0.5)
            mozart.evaluate()            # `a` streams (pure output, fused)
            assert isinstance(ctx.graph.nodes[a._node.id].result, ChunkStream)
            mozart.configure(executor="eager")
            out = np.asarray(anp.exp(a))
        assert ctx.stats["stream_materialized"] >= 1
        want = np.exp((np.linspace(0., 1., self.N, dtype=np.float32) + 1) * 0.5)
        np.testing.assert_allclose(out, want, rtol=2e-5)

    def test_scan_ingests_fused_stream(self):
        """`scan` is a stream ingester now: a chunk-list stream from the
        fused driver stacks straight into the carry layout — no
        materialize on the boundary."""
        x = jnp.linspace(0., 1., self.N, dtype=jnp.float32)
        plan_cache.clear()
        with mozart.session(executor="fused", batch_elements=self.BATCH) as ctx:
            a = anp.multiply(anp.add(x, 1.0), 0.5)
            mozart.evaluate()            # `a` streams (pure output, fused)
            assert isinstance(ctx.graph.nodes[a._node.id].result, ChunkStream)
            mozart.configure(executor="scan")
            out = np.asarray(anp.exp(a))
        assert ctx.stats.get("stream_materialized", 0) == 0
        assert ctx.stats["stream_ingests"] >= 1
        want = np.exp((np.linspace(0., 1., self.N, dtype=np.float32) + 1) * 0.5)
        np.testing.assert_allclose(out, want, rtol=2e-5)


# ---------------------------------------------------------------------------
# Donation safety
# ---------------------------------------------------------------------------


class TestDonation:
    def test_alive_future_donates_copies_only(self):
        """A stream whose producer Future is still observable must keep its
        own buffers — the driver gets defensive COPIES to donate, and
        observing the producer after consumption still works."""
        n, b = 20_000, 4096
        x = jnp.linspace(0., 1., n, dtype=jnp.float32)
        plan_cache.clear()
        for _ in range(3):
            with mozart.session(executor="fused", batch_elements=b) as ctx:
                a = anp.multiply(anp.add(x, 1.0), 0.5)
                mozart.evaluate()
                out = np.asarray(anp.exp(a))     # consumes a's stream
                a_val = np.asarray(a)            # a observed AFTER consumption
            if ctx.stats.get("donated_chunks", 0):
                assert ctx.stats["donation_copies"] == ctx.stats["donated_chunks"]
        want_a = (np.linspace(0., 1., n, dtype=np.float32) + 1) * 0.5
        np.testing.assert_allclose(a_val, want_a, rtol=2e-5)
        np.testing.assert_allclose(out, np.exp(want_a), rtol=2e-5)

    def test_liveness_flap_does_not_retrace(self):
        """The donate key set is structural: whether the producer's Future
        happens to be alive on a given call must not change the pinned
        driver variant (zero retraces on warm calls either way)."""
        n, b = 20_000, 4096
        x = jnp.linspace(0., 1., n, dtype=jnp.float32)
        plan_cache.clear()

        def once(hold):
            with mozart.session(executor="fused", batch_elements=b) as ctx:
                a = anp.multiply(anp.add(x, 1.0), 0.5)
                mozart.evaluate()
                e = anp.exp(a)                   # registered; holds a NodeRef
                if not hold:
                    del a                        # Future dies pre-consumption
                out = np.asarray(e)
                if hold:
                    _ = np.asarray(a)            # observe AFTER consumption
            return out, ctx

        once(True); once(True)                   # plan + warm the cache
        before = stage_exec.trace_count()
        o1, c1 = once(True)                      # producer observable: copies
        o2, c2 = once(False)                     # producer dead: real donation
        o3, _ = once(True)
        assert stage_exec.trace_count() == before
        assert c1.stats["exec_builds"] == 0 and c2.stats["exec_builds"] == 0
        assert c1.stats.get("donation_copies", 0) > 0
        assert c2.stats.get("donation_copies", 0) == 0
        assert c2.stats.get("donated_chunks", 0) > 0
        np.testing.assert_allclose(o1, o2, rtol=1e-6)
        np.testing.assert_allclose(o1, o3, rtol=1e-6)

    def test_dead_future_donates_and_stays_correct(self):
        n, b = 20_000, 4096
        x = jnp.linspace(0., 1., n, dtype=jnp.float32)
        plan_cache.clear()

        def once():
            with mozart.session(executor="fused", batch_elements=b) as ctx:
                cur = _eval_chain(x)
                out = np.asarray(cur)
            return out, ctx
        once(); once()
        out, ctx = once()
        assert ctx.stats["donated_chunks"] > 0
        want = np.asarray(x)
        for _ in range(3):
            want = (want + 1.0) * 0.5
        np.testing.assert_allclose(out, want, rtol=2e-5)


# ---------------------------------------------------------------------------
# Handoff decisions replay from MOZART_PLAN_CACHE with zero planner calls
# ---------------------------------------------------------------------------

_PRELUDE = """
import json, sys
import jax.numpy as jnp
import numpy as np
from repro.core import mozart, plan_cache, stage_exec
from repro.core import annotated_numpy as anp

x = jnp.linspace(0.0, 1.0, 30_000, dtype=jnp.float32)

def run():
    with mozart.session(executor="fused", batch_elements=4096) as ctx:
        cur = x
        for _ in range(3):
            cur = anp.multiply(anp.add(cur, 1.0), 0.5)
            mozart.evaluate()
        out = np.asarray(cur)
    return out, ctx
"""

_PROC_A = _PRELUDE + """
run(); run()
out, ctx = run()
print(json.dumps({"sum": float(out.sum()),
                  "streamed": ctx.stats["streamed_outputs"],
                  "ingests": ctx.stats["stream_ingests"]}))
"""

_PROC_B = _PRELUDE + """
b0 = stage_exec.bytes_materialized()
out, ctx = run()
print(json.dumps({"sum": float(out.sum()),
                  "streamed": ctx.stats["streamed_outputs"],
                  "ingests": ctx.stats["stream_ingests"],
                  "planner_calls": ctx.stats["planner_calls"],
                  "bytes": stage_exec.bytes_materialized() - b0,
                  "pc": dict(plan_cache.stats)}))
"""


def _run_subprocess(code, path):
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    existing = env.get("PYTHONPATH")
    env["PYTHONPATH"] = src + (os.pathsep + existing if existing else "")
    env["MOZART_PLAN_CACHE"] = path
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_handoff_decisions_replay_from_persisted_cache(tmp_path):
    """Process A records handoff decisions in its persisted plans; a FRESH
    process B replays them — zero planner calls, streams from call one, and
    interior boundary bytes already zero."""
    path = str(tmp_path / "plans.json")
    a = _run_subprocess(_PROC_A, path)
    assert a["streamed"] == 3 and a["ingests"] == 2
    assert os.path.exists(path)

    b = _run_subprocess(_PROC_B, path)
    assert b["pc"].get("persist_loaded", 0) >= 1
    assert b["planner_calls"] == 0            # decisions replayed, not re-derived
    assert b["streamed"] == 3 and b["ingests"] == 2
    assert b["bytes"] == 30_000 * 4           # final observed output only
    assert np.isclose(a["sum"], b["sum"], rtol=1e-6)


# ---------------------------------------------------------------------------
# scan / pallas stream ingest (the handoff-completion pass)
# ---------------------------------------------------------------------------


class TestScanPallasIngest:
    """Every executor's interior boundary hits zero, not just the chunk
    loops: `scan` stacks incoming streams into its carry layout, `pallas`
    stacks them into the padded launch buffer."""

    N, BATCH = 50_000, 8192

    def _chain(self, executor, handoff=True):
        x = jnp.linspace(0., 1., self.N, dtype=jnp.float32)

        def once():
            with mozart.session(executor=executor, batch_elements=self.BATCH,
                                handoff=handoff) as ctx:
                out = np.asarray(_eval_chain(x))
            return out, ctx

        plan_cache.clear()
        once(); once()                   # plan, then warm (tune + pin)
        stage_exec.reset_materialized()
        t0 = stage_exec.trace_count()
        out, ctx = once()
        return out, ctx, stage_exec.trace_count() - t0

    @pytest.mark.parametrize("executor", ["scan", "pallas"])
    def test_interior_zero_and_zero_retrace(self, executor):
        out, ctx, traces = self._chain(executor)
        assert stage_exec.bytes_interior() == 0
        assert traces == 0               # warm calls: zero jit retraces
        assert ctx.stats["planner_calls"] == 0
        off_out, _, _ = self._chain(executor, handoff=False)
        np.testing.assert_allclose(out, off_out, rtol=2e-5)

    def test_scan_streams_and_donates_carry(self):
        _, ctx, _ = self._chain("scan")
        assert ctx.stats["streamed_outputs"] == 3
        assert ctx.stats["stream_ingests"] == 2
        # dead carries donate for real — no defensive copies on this chain
        assert ctx.stats["donated_chunks"] > 0
        assert ctx.stats.get("donation_copies", 0) == 0
        # observation of the final output is TERMINAL, never interior
        assert stage_exec.bytes_terminal() == self.N * 4

    def test_scan_carry_passthrough_is_stacked(self):
        """A scan stage's streamed output keeps the driver's carry layout
        (ChunkStream.from_stacked) — a scan consumer ingests it without ever
        deriving the chunk list."""
        x = jnp.linspace(0., 1., self.N, dtype=jnp.float32)
        plan_cache.clear()
        with mozart.session(executor="scan", batch_elements=self.BATCH) as ctx:
            a = anp.multiply(anp.add(x, 1.0), 0.5)
            mozart.evaluate()
            res = ctx.graph.nodes[a._node.id].result
            assert isinstance(res, ChunkStream)
            assert res.stacked is not None and res._chunks is None
            out = np.asarray(anp.exp(a))
        want = np.exp((np.asarray(x) + 1) * 0.5)
        np.testing.assert_allclose(out, want, rtol=2e-5)

    def test_pallas_ingests_fused_stream(self):
        """A chunk-list stream stacks straight into the pallas launch
        buffer — no materialize on the boundary."""
        x = jnp.linspace(0., 1., self.N, dtype=jnp.float32)

        def once():
            with mozart.session(executor="fused",
                                batch_elements=self.BATCH) as ctx:
                a = anp.multiply(anp.add(x, 1.0), 0.5)
                mozart.evaluate()
                mozart.configure(executor="pallas")
                out = np.asarray(anp.exp(a))
            return out, ctx

        plan_cache.clear()
        once(); once()
        stage_exec.reset_materialized()
        out, ctx = once()
        assert stage_exec.bytes_interior() == 0
        assert ctx.stats["stream_ingests"] >= 1
        assert ctx.stats.get("stream_materialized", 0) == 0
        assert ctx.stats["pallas_stages"] == 1
        want = np.exp((np.asarray(x) + 1) * 0.5)
        np.testing.assert_allclose(out, want, rtol=2e-5)

    def test_misaligned_grid_rechunks_once(self):
        """A producer grid beyond the consumer's slack re-grids through
        SplitType.rechunk — at most one copy, tallied and counted."""
        x = jnp.linspace(0., 1., 20_000, dtype=jnp.float32)
        plan_cache.clear()
        with mozart.session(executor="fused", batch_elements=6000) as ctx:
            a = anp.multiply(anp.add(x, 1.0), 0.5)
            mozart.evaluate()
            mozart.configure(batch_elements=1024)   # 6000 > 2x1024: re-grid
            stage_exec.reset_materialized()
            out = np.asarray(anp.exp(a))
        assert ctx.stats["handoff_rechunks"] == 1
        # the rechunk pays at most ONE copy of the data (merge+re-split = 2)
        rechunk_bytes = sum(nb for kind, _, nb in stage_exec.materialize_events()
                            if kind == "interior:rechunk")
        assert 0 < rechunk_bytes <= x.nbytes
        want = np.exp((np.asarray(x) + 1) * 0.5)
        np.testing.assert_allclose(out, want, rtol=2e-5)


# ---------------------------------------------------------------------------
# ConcatSplit→ArraySplit handoff (fresh-output producers)
# ---------------------------------------------------------------------------


_REPEAT2 = None


def _make_repeat2():
    # One AnnotatedFn for the whole module: the plan cache matches entries
    # on function identity, so a fresh wrapper per run would always miss.
    global _REPEAT2
    if _REPEAT2 is None:
        from repro.core import splittable

        @splittable(x=st.Along(0), ret=st.Concat("rep2", 0))
        def repeat2(x):
            return jnp.repeat(x, 2)

        _REPEAT2 = repeat2
    return _REPEAT2


_TREE_REPEAT2 = None
_TREE_SCALE = None


def _make_tree_repeat2():
    # Fresh-output producer whose pieces are PYTREES with mixed leaf ranks
    # (the optimizer-state shape) — exercises the per-leaf conversion rule.
    global _TREE_REPEAT2
    if _TREE_REPEAT2 is None:
        from repro.core import splittable

        @splittable(x=st.Along(0), ret=st.Concat("trep2", 0))
        def tree_repeat2(x):
            y = jnp.repeat(x, 2)
            return {"p": y, "m": jnp.stack([y, y * 2.0], axis=1)}

        _TREE_REPEAT2 = tree_repeat2
    return _TREE_REPEAT2


def _make_tree_scale():
    global _TREE_SCALE
    if _TREE_SCALE is None:
        from repro.core import splittable

        @splittable(s=st.Pytree(0), ret=st.Pytree(0))
        def tree_scale(s):
            return {"p": (s["p"] + 1.0) * 0.5, "m": s["m"] * 2.0}

        _TREE_SCALE = tree_scale
    return _TREE_SCALE


class TestConcatHandoff:
    N, BATCH = 10_000, 2048

    def _run(self, handoff):
        repeat2 = _make_repeat2()
        x = jnp.linspace(0., 1., self.N, dtype=jnp.float32)
        with mozart.session(executor="fused", batch_elements=self.BATCH,
                            handoff=handoff) as ctx:
            y = repeat2(x)               # fresh output: ConcatSplit
            out = np.asarray(anp.multiply(anp.add(y, 1.0), 0.5))
        return out, ctx

    def test_concat_producer_hands_off_to_array_consumer(self):
        plan_cache.clear()
        self._run(True); self._run(True)
        stage_exec.reset_materialized()
        out, ctx = self._run(True)
        assert ctx.stats["stream_converted"] == 1
        assert ctx.stats["stream_ingests"] == 1
        assert ctx.stats["planner_calls"] == 0
        assert stage_exec.bytes_interior() == 0
        off, _ = self._run(False)
        np.testing.assert_allclose(out, off, rtol=1e-6)
        want = (np.repeat(np.asarray(x := np.linspace(0., 1., self.N,
                                                      dtype=np.float32)), 2)
                + 1) * 0.5
        np.testing.assert_allclose(out, want, rtol=2e-5)

    def test_conversion_recorded_in_plan_entry(self):
        plan_cache.clear()
        self._run(True)
        recs = [ho for e in plan_cache.entries()
                if e.handoff
                for ho in e.handoff.values() if ho.convert_in]
        assert recs, "ConcatSplit→ArraySplit conversion not recorded"
        ho = recs[0]
        assert ho.convert_in <= ho.stream_in
        # round-trips through the persisted JSON form
        assert (type(ho).from_json(ho.to_json()).convert_in == ho.convert_in)

    def test_protocol_rules(self):
        c = st.ConcatSplit("t", 0)
        assert c.can_handoff(st.ArraySplit((64,), 0))
        assert not c.can_handoff(st.ArraySplit((8, 8), 1))   # axis mismatch
        assert not c.can_handoff(st.ArraySplit((), 0))       # scalar geometry
        assert not c.can_handoff(st.ConcatSplit("t", 0))     # not splittable
        assert not st.ConcatSplit("t", 1).can_handoff(st.ArraySplit((64,), 0))

    def test_total_mismatch_materializes(self):
        """Pieces that do not tile the consumer's geometry fall back to the
        merge — adapt_stream returns None, never a wrong grid."""
        t = st.ConcatSplit("t", 0)
        chunks = [jnp.ones((3,), jnp.float32), jnp.ones((4,), jnp.float32)]
        s = ChunkStream(chunks, [(0, 2), (2, 4)], t,
                        jax.ShapeDtypeStruct((7,), jnp.float32))
        from repro.core.stage_exec import adapt_stream
        good = adapt_stream(s, st.ArraySplit((7,), 0))
        assert good is not None and good.ranges == [(0, 3), (3, 7)]
        assert adapt_stream(s, st.ArraySplit((8,), 0)) is None

    def test_concat_producer_hands_off_to_pytree_consumer(self):
        """Fresh-output producers that emit PYTREES hand off to PytreeSplit
        consumers: the conversion decides per LEAF (mixed ranks/trailing
        dims are fine as long as every leaf of a chunk agrees on its
        split-axis extent) — previously this edge always merged."""
        tree_rep2 = _make_tree_repeat2()
        tree_scale = _make_tree_scale()
        x = jnp.linspace(0., 1., self.N, dtype=jnp.float32)

        def run(handoff):
            plan_cache.clear()
            for _ in range(2):               # plan, then warm
                with mozart.session(executor="fused",
                                    batch_elements=self.BATCH,
                                    handoff=handoff) as ctx:
                    out = jax.tree_util.tree_map(
                        np.asarray, tree_scale(tree_rep2(x)).value)
            return out, ctx

        out, ctx = run(True)
        assert ctx.stats["stream_converted"] == 1
        assert ctx.counters.bytes_interior() == 0
        assert ctx.stats["planner_calls"] == 0
        off, _ = run(False)
        for k in ("p", "m"):
            np.testing.assert_allclose(out[k], off[k], rtol=1e-6)
        want_p = (np.repeat(np.linspace(0., 1., self.N, dtype=np.float32), 2)
                  + 1.0) * 0.5
        np.testing.assert_allclose(out["p"], want_p, rtol=2e-5)

    def test_pytree_protocol_rule(self):
        c = st.ConcatSplit("t", 0)
        assert c.can_handoff(st.PytreeSplit("t", 64, 0))
        assert not c.can_handoff(st.PytreeSplit("t", 64, 1))  # axis mismatch
        assert not st.ConcatSplit("t", 1).can_handoff(st.PytreeSplit("t", 64, 0))

    def test_pytree_leaf_extent_mismatch_materializes(self):
        """Per-leaf rule: every leaf of a chunk must agree on its split-axis
        extent — a disagreeing chunk cannot define one grid range, so
        adapt_stream falls back to the merge (returns None)."""
        from repro.core.stage_exec import adapt_stream
        t = st.ConcatSplit("t", 0)
        aval = {"a": jax.ShapeDtypeStruct((7,), jnp.float32),
                "b": jax.ShapeDtypeStruct((7, 2), jnp.float32)}
        good = [{"a": jnp.ones((3,), jnp.float32),
                 "b": jnp.ones((3, 2), jnp.float32)},
                {"a": jnp.ones((4,), jnp.float32),
                 "b": jnp.ones((4, 2), jnp.float32)}]
        s = ChunkStream(good, [(0, 2), (2, 4)], t, aval)
        ok = adapt_stream(s, st.PytreeSplit("t", 7, 0))
        assert ok is not None and ok.ranges == [(0, 3), (3, 7)]
        # same buffers re-wrapped: zero copies
        assert ok._chunks is s._chunks or ok._chunks == s._chunks

        bad = [{"a": jnp.ones((3,), jnp.float32),
                "b": jnp.ones((4, 2), jnp.float32)}]   # leaves disagree
        s2 = ChunkStream(bad, [(0, 1)], t,
                         {"a": jax.ShapeDtypeStruct((3,), jnp.float32),
                          "b": jax.ShapeDtypeStruct((4, 2), jnp.float32)})
        assert adapt_stream(s2, st.PytreeSplit("t", 3, 0)) is None
        # total mismatch still falls back too
        assert adapt_stream(s, st.PytreeSplit("t", 8, 0)) is None

    def test_empty_concat_pieces_stream(self):
        """Zero-size fresh pieces (filter-to-nothing) hand off as an empty
        grid instead of crashing merge([]) — the zero-chunk hardening."""
        from repro.core import splittable

        @splittable(x=st.Along(0), ret=st.Concat("nil", 0))
        def drop_all(x):
            return x[:0]

        x = jnp.linspace(0., 1., self.N, dtype=jnp.float32)
        plan_cache.clear()
        with mozart.session(executor="fused", batch_elements=self.BATCH) as ctx:
            y = drop_all(x)
            out = np.asarray(anp.add(y, 1.0))
        assert out.shape == (0,)


# ---------------------------------------------------------------------------
# Zero-chunk / empty-stream hardening (regression: PR 4 stream paths)
# ---------------------------------------------------------------------------


class TestZeroChunkStreams:
    AVAL = jax.ShapeDtypeStruct((0,), jnp.float32)

    def test_materialize_zero_chunk_stream(self):
        s = ChunkStream([], [(0, 0)], st.ArraySplit((0,), 0), self.AVAL)
        out = s.materialize()
        assert out.shape == (0,) and out.dtype == jnp.float32

    def test_chunk_accessor_zero_chunk_stream(self):
        s = ChunkStream([], [(0, 0)], st.ArraySplit((0,), 0), self.AVAL)
        assert s.chunk(0).shape == (0,)

    def test_rechunk_degenerate_grids(self):
        """Zero-size destination ranges carve empty slices instead of
        crashing merge([])."""
        t = st.ArraySplit((0,), 0)
        chunks = [jnp.zeros((0,), jnp.float32)] * 3
        out, copied = t.rechunk(chunks, [(0, 0)] * 3, [(0, 0)])
        assert len(out) == 1 and out[0].shape == (0,)
        assert copied == 0

    @pytest.mark.parametrize("executor",
                             [e for e in sorted(available_executors())
                              if e != "sharded"])
    def test_empty_chain_streams_safely(self, executor):
        """n == 0 through a multi-evaluation chain with handoff on: every
        executor's stream ingest/materialize path must survive the
        degenerate single-zero-size-chunk grid."""
        plan_cache.clear()
        with mozart.session(executor=executor, batch_elements=64) as ctx:
            out = np.asarray(_eval_chain(jnp.zeros((0,), jnp.float32)))
        assert out.shape == (0,)


# ---------------------------------------------------------------------------
# Donation: plan-time veto + the pinned runtime backstop
# ---------------------------------------------------------------------------


class TestDonationVeto:
    def test_observable_producer_vetoed_at_plan_time(self):
        """An in-plan producer whose Future is alive at analysis time never
        becomes a donation point: no donated chunks AND no defensive copies
        (before the veto, the runtime burned one copy per chunk)."""
        n, b = 20_000, 4096
        x = jnp.linspace(0., 1., n, dtype=jnp.float32)
        plan_cache.clear()

        def once():
            with mozart.session(executor="fused", batch_elements=b,
                                pipeline=False) as ctx:
                a = anp.add(x, 1.0)          # own stage (pipeline=False)
                out = np.asarray(anp.multiply(a, 0.5))  # a's Future held
                a_val = np.asarray(a)        # observed after consumption
            return out, a_val, ctx

        for _ in range(3):
            out, a_val, ctx = once()
        assert ctx.stats.get("donated_chunks", 0) == 0
        assert ctx.stats.get("donation_copies", 0) == 0
        np.testing.assert_allclose(a_val, np.asarray(x) + 1, rtol=1e-6)
        np.testing.assert_allclose(out, (np.asarray(x) + 1) * 0.5, rtol=1e-6)

    def test_dead_producer_still_donates(self):
        """The veto is scoped: a producer with no live Future at analysis
        time keeps its donation point."""
        n, b = 20_000, 4096
        x = jnp.linspace(0., 1., n, dtype=jnp.float32)
        plan_cache.clear()

        def once():
            with mozart.session(executor="fused", batch_elements=b,
                                pipeline=False) as ctx:
                out = np.asarray(anp.multiply(anp.add(x, 1.0), 0.5))
            return out, ctx

        once(); once()
        out, ctx = once()
        assert ctx.stats.get("donated_chunks", 0) > 0
        np.testing.assert_allclose(out, (np.asarray(x) + 1) * 0.5, rtol=1e-6)

    def test_runtime_backstop_message_pinned(self):
        """The donated-stream late-merge raise stays as the backstop; its
        message is pinned and carries the MZ301 lint code plus the donating
        stage/edge (``ChunkStream.donor``, set by mark_stream_consumed)."""
        t = st.ArraySplit((8,), 0)
        s = ChunkStream([jnp.arange(4, dtype=jnp.float32),
                         jnp.arange(4, dtype=jnp.float32)],
                        [(0, 4), (4, 8)], t,
                        jax.ShapeDtypeStruct((8,), jnp.float32))
        s.consumed = True
        s.donor = "stage 7 input ('in', 0)"
        with pytest.raises(RuntimeError,
                           match="donated to a driver and can no longer be "
                                 "merged") as ei:
            s.materialize()
        assert "[MZ301]" in str(ei.value)
        assert "stage 7 input ('in', 0)" in str(ei.value)
        assert stage_exec.DONATED_MERGE_ERROR.startswith("[MZ301]")
        assert "handoff analysis bug" in stage_exec.DONATED_MERGE_ERROR


# ---------------------------------------------------------------------------
# Donation-veto aging: stale vetoes re-analyze instead of persisting forever
# ---------------------------------------------------------------------------


class TestVetoAging:
    """A plan-time donation decision is a snapshot of Future liveness.  When
    observed liveness disagrees with the recorded ``vetoed``/``last_use``
    sets for ``handoff.STALE_THRESHOLD`` consecutive calls, the entry
    re-analyzes against current liveness — so a producer that stops being
    observed regains its donation point, and one that STARTS being observed
    stops paying per-chunk defensive copies."""

    N, B = 20_000, 4096

    def _once(self, hold):
        x = jnp.linspace(0., 1., self.N, dtype=jnp.float32)
        with mozart.session(executor="fused", batch_elements=self.B,
                            pipeline=False) as ctx:
            a = anp.add(x, 1.0)              # own stage (pipeline=False)
            e = anp.multiply(a, 0.5)
            if not hold:
                del a                        # producer dies pre-analysis
            out = np.asarray(e)
            if hold:
                _ = np.asarray(a)            # observed after consumption
        return out, ctx

    def test_stale_veto_ages_into_donation(self):
        """Producer observable at plan time → vetoed (no donation).  After
        it stops being observed, two stale calls age the veto out and the
        donation point comes back copy-free."""
        from repro.core import handoff as ho_mod
        plan_cache.clear()
        out0, c0 = self._once(hold=True)     # analysis: Future alive → veto
        assert c0.stats.get("donated_chunks", 0) == 0
        assert c0.stats.get("donation_copies", 0) == 0
        _, c1 = self._once(hold=False)       # stale ×1: hysteresis holds
        assert c1.stats.get("handoff_reanalyzed", 0) == 0
        assert c1.stats.get("donated_chunks", 0) == 0
        _, c2 = self._once(hold=False)       # stale ×2 == STALE_THRESHOLD
        assert ho_mod.STALE_THRESHOLD == 2
        assert c2.stats.get("handoff_reanalyzed", 0) == 1
        out3, c3 = self._once(hold=False)    # re-analyzed plan replays
        assert c3.stats.get("handoff_reanalyzed", 0) == 0
        assert c3.stats.get("donated_chunks", 0) > 0
        assert c3.stats.get("donation_copies", 0) == 0   # real donation, no copies
        assert c3.stats.get("planner_calls", 0) == 0     # aging ≠ replanning
        np.testing.assert_allclose(out0, out3, rtol=1e-6)

    def test_fresh_observation_ages_out_donation_copies(self):
        """The reverse direction: a donation point recorded against a dead
        producer ships per-chunk defensive copies once the producer IS
        observed — until aging re-vetoes it and the copies drop to zero."""
        plan_cache.clear()
        out0, c0 = self._once(hold=False)    # analysis: dead → donation point
        assert c0.stats.get("donated_chunks", 0) > 0
        _, c1 = self._once(hold=True)        # runtime backstop: copies
        assert c1.stats.get("donation_copies", 0) > 0
        assert c1.stats.get("handoff_reanalyzed", 0) == 0
        _, c2 = self._once(hold=True)        # stale ×2 → re-analyze → veto
        assert c2.stats.get("handoff_reanalyzed", 0) == 1
        out3, c3 = self._once(hold=True)
        assert c3.stats.get("donation_copies", 0) == 0   # copy count dropped
        assert c3.stats.get("donated_chunks", 0) == 0
        np.testing.assert_allclose(out0, out3, rtol=1e-6)

    def test_single_flap_never_reanalyzes(self):
        """One disagreeing call is noise (liveness legitimately varies);
        the age resets on the next agreeing call."""
        plan_cache.clear()
        self._once(hold=True)                # veto recorded
        _, c1 = self._once(hold=False)       # stale ×1
        assert c1.stats.get("handoff_reanalyzed", 0) == 0
        _, c2 = self._once(hold=True)        # agrees again: age resets
        assert c2.stats.get("handoff_reanalyzed", 0) == 0
        _, c3 = self._once(hold=False)       # stale ×1 again, not ×2
        assert c3.stats.get("handoff_reanalyzed", 0) == 0


# ---------------------------------------------------------------------------
# Per-context counter scoping
# ---------------------------------------------------------------------------


class TestScopedCounters:
    """Boundary traffic and trace counts attribute to the owning session's
    ``ctx.counters`` (plus the process-global aggregate): one session's
    merge round trips can never leak into another session's gate."""

    N, BATCH = 30_000, 4096

    def _once(self, handoff):
        with mozart.session(executor="fused", batch_elements=self.BATCH,
                            handoff=handoff) as ctx:
            out = np.asarray(_eval_chain(
                jnp.linspace(0., 1., self.N, dtype=jnp.float32)))
        return out, ctx

    def test_sessions_see_only_their_own_traffic(self):
        plan_cache.clear()
        self._once(True); self._once(True)   # plan + warm both configs
        self._once(False)
        g_int = stage_exec.bytes_interior()
        g_term = stage_exec.bytes_terminal()
        on_out, on_ctx = self._once(True)
        off_out, off_ctx = self._once(False)
        # Disjoint scoped views: the handoff session's gate reads zero even
        # though a merge-everything session ran in the same process.
        assert on_ctx.counters.bytes_interior() == 0
        assert on_ctx.counters.bytes_terminal() == self.N * 4
        assert off_ctx.counters.bytes_interior() >= 5 * self.N * 4
        assert off_ctx.counters.bytes_terminal() == 0
        # The process-global aggregate is exactly the sum of the scopes.
        assert (stage_exec.bytes_interior() - g_int
                == off_ctx.counters.bytes_interior())
        assert (stage_exec.bytes_terminal() - g_term
                == on_ctx.counters.bytes_terminal())
        np.testing.assert_allclose(on_out, off_out, rtol=2e-5)

    def test_scoped_event_trail_and_traces(self):
        plan_cache.clear()
        self._once(True); self._once(True)
        _, ctx = self._once(True)            # warm: zero scoped retraces
        assert ctx.counters.trace_count() == 0
        kinds = {k.split(":")[0] for k, _, _ in ctx.counters.materialize_events()}
        assert kinds == {"terminal"}         # only the observed output
        _, off_ctx = self._once(False)
        off_kinds = {k.split(":")[0]
                     for k, _, _ in off_ctx.counters.materialize_events()}
        assert off_kinds == {"interior"}

    def test_global_reset_does_not_touch_scoped_views(self):
        plan_cache.clear()
        self._once(True); self._once(True)
        _, ctx = self._once(True)
        before = ctx.counters.bytes_terminal()
        assert before == self.N * 4
        stage_exec.reset_materialized()      # resets the GLOBAL aggregate
        assert stage_exec.bytes_terminal() == 0
        assert ctx.counters.bytes_terminal() == before


# ---------------------------------------------------------------------------
# Interior vs terminal accounting
# ---------------------------------------------------------------------------


class TestByteAccounting:
    N, BATCH = 30_000, 4096

    def test_observed_terminal_output_not_interior(self):
        x = jnp.linspace(0., 1., self.N, dtype=jnp.float32)
        plan_cache.clear()

        def once():
            with mozart.session(executor="fused",
                                batch_elements=self.BATCH) as ctx:
                out = np.asarray(_eval_chain(x))
            return out, ctx

        once(); once()
        stage_exec.reset_materialized()
        once()
        assert stage_exec.bytes_interior() == 0
        assert stage_exec.bytes_terminal() == self.N * 4
        # total stays the back-compat sum
        assert stage_exec.bytes_materialized() == self.N * 4

    def test_merge_everything_is_interior(self):
        x = jnp.linspace(0., 1., self.N, dtype=jnp.float32)
        plan_cache.clear()
        with mozart.session(executor="fused", batch_elements=self.BATCH,
                            handoff=False):
            stage_exec.reset_materialized()
            np.asarray(_eval_chain(x))
            assert stage_exec.bytes_terminal() == 0
            assert stage_exec.bytes_interior() >= 5 * self.N * 4

    def test_reset_clears_counters_and_events(self):
        stage_exec.note_materialized(128, kind="merge", where="test")
        stage_exec.note_materialized(64, terminal=True, kind="materialize",
                                     where="test")
        assert stage_exec.bytes_materialized() >= 192
        assert stage_exec.materialize_events()
        stage_exec.reset_materialized()
        assert stage_exec.bytes_materialized() == 0
        assert stage_exec.bytes_interior() == 0
        assert stage_exec.bytes_terminal() == 0
        assert not stage_exec.materialize_events()

    def test_event_trail_names_the_boundary(self):
        x = jnp.linspace(0., 1., self.N, dtype=jnp.float32)
        plan_cache.clear()
        with mozart.session(executor="fused", batch_elements=self.BATCH,
                            handoff=False):
            stage_exec.reset_materialized()
            np.asarray(_eval_chain(x))
        kinds = {k.split(":")[1] for k, _, _ in stage_exec.materialize_events()}
        assert "merge" in kinds           # producer-side merges
        assert "resplit" in kinds         # consumer-side re-splits
        assert all(w for _, w, _ in stage_exec.materialize_events())


# ---------------------------------------------------------------------------
# rechunk property test (hypothesis-optional)
# ---------------------------------------------------------------------------


from repro.testing import given, settings, hst  # noqa: E402


class TestRechunkProperty:
    @given(n=hst.integers(1, 96), src_b=hst.integers(1, 96),
           dst_b=hst.integers(1, 96))
    @settings(max_examples=60, deadline=None)
    def test_any_grid_pair_at_most_one_copy(self, n, src_b, dst_b):
        """Misaligned grids (src not an integer multiple of dst or vice
        versa) still convert with at most ONE copy of the data; exactly
        aligned grids pass through by reference with zero copies."""
        t = st.ArraySplit((n,), 0)
        x = jnp.arange(n, dtype=jnp.float32)
        src, dst = _ranges(n, src_b), _ranges(n, dst_b)
        chunks = [t.split(x, s, e) for s, e in src]
        out, copied = t.rechunk(chunks, src, dst)
        assert len(out) == len(dst)
        assert copied <= int(x.nbytes)      # merge+re-split always pays two
        if src == dst:
            assert copied == 0
        multiple = (src_b % dst_b == 0 or dst_b % src_b == 0)
        if not multiple and src != dst and n > max(src_b, dst_b):
            # genuinely misaligned grids: some copying is unavoidable
            assert copied > 0
        np.testing.assert_array_equal(np.asarray(t.merge(out)), np.asarray(x))


# ---------------------------------------------------------------------------
# Persistence: ConcatSplit conversions replay; v2 files migrate
# ---------------------------------------------------------------------------

_CONCAT_PRELUDE = """
import json, sys
import jax.numpy as jnp
import numpy as np
from repro.core import mozart, plan_cache, stage_exec, splittable
from repro.core import annotated_numpy as anp
from repro.core import split_types as st

@splittable(x=st.Along(0), ret=st.Concat("rep2", 0))
def repeat2(x):
    return jnp.repeat(x, 2)

x = jnp.linspace(0.0, 1.0, 10_000, dtype=jnp.float32)

def run():
    with mozart.session(executor="fused", batch_elements=2048) as ctx:
        y = repeat2(x)
        out = np.asarray(anp.multiply(anp.add(y, 1.0), 0.5))
    return out, ctx
"""

_CONCAT_A = _CONCAT_PRELUDE + """
run(); run()
out, ctx = run()
print(json.dumps({"sum": float(out.sum()),
                  "converted": ctx.stats["stream_converted"],
                  "ingests": ctx.stats["stream_ingests"]}))
"""

_CONCAT_B = _CONCAT_PRELUDE + """
i0 = stage_exec.bytes_interior()
out, ctx = run()
recorded = [sorted(ho.convert_in)
            for e in plan_cache.entries() if e.handoff
            for ho in e.handoff.values() if ho.convert_in]
print(json.dumps({"sum": float(out.sum()),
                  "converted": ctx.stats["stream_converted"],
                  "planner_calls": ctx.stats["planner_calls"],
                  "interior": stage_exec.bytes_interior() - i0,
                  "recorded": recorded,
                  "pc": dict(plan_cache.stats)}))
"""


def test_concat_handoff_replays_from_persisted_cache(tmp_path):
    """Process A records a ConcatSplit→ArraySplit conversion in its
    persisted plans; a FRESH process B replays it — zero planner calls
    (zero analysis), conversion applied from call one, interior bytes 0."""
    path = str(tmp_path / "plans.json")
    a = _run_subprocess(_CONCAT_A, path)
    assert a["converted"] == 1 and a["ingests"] == 1
    assert os.path.exists(path)

    b = _run_subprocess(_CONCAT_B, path)
    assert b["pc"].get("persist_loaded", 0) >= 1
    assert b["planner_calls"] == 0
    assert b["converted"] == 1
    assert b["interior"] == 0
    assert b["recorded"], "convert_in not rehydrated from disk"
    assert np.isclose(a["sum"], b["sum"], rtol=1e-6)


def test_v2_plan_file_migrates_forward(tmp_path):
    """A schema-v2 cache file (pre ``convert_in``) loads under v3: handoff
    records default the new field to empty instead of rejecting the file."""
    path = str(tmp_path / "plans.json")
    plan_cache.clear()
    x = jnp.linspace(0., 1., 30_000, dtype=jnp.float32)
    with mozart.session(executor="fused", batch_elements=4096):
        np.asarray(_eval_chain(x))
    assert plan_cache.save(path) >= 1

    with open(path) as f:
        payload = json.load(f)
    assert payload["schema"] == plan_cache.SCHEMA_VERSION
    payload["schema"] = 2                 # rewrite as a v2-era file
    for e in payload["entries"]:
        if e.get("handoff"):
            for ho in e["handoff"].values():
                ho.pop("convert_in", None)
    with open(path, "w") as f:
        json.dump(payload, f)

    plan_cache.clear()
    loaded = plan_cache.load(path)
    assert loaded >= 1
    assert plan_cache.stats.get("persist_migrated_v2", 0) == 1
    for e in plan_cache.entries():
        if e.handoff:
            for ho in e.handoff.values():
                assert ho.convert_in == frozenset()

    # and the migrated plans actually replay
    with mozart.session(executor="fused", batch_elements=4096) as ctx:
        out = np.asarray(_eval_chain(x))
    assert ctx.stats["planner_calls"] == 0
    assert ctx.stats["streamed_outputs"] == 3
    want = np.asarray(x)
    for _ in range(3):
        want = (want + 1.0) * 0.5
    np.testing.assert_allclose(out, want, rtol=2e-5)


def test_v3_plan_file_migrates_forward(tmp_path):
    """A schema-v3 cache file (pre ``shard_in``/``vetoed``) loads under v4:
    handoff records default the new fields to empty — correct for every
    pre-bump plan, since the rules they gate did not exist — and the
    migrated plans replay with zero planner calls."""
    path = str(tmp_path / "plans.json")
    plan_cache.clear()
    x = jnp.linspace(0., 1., 30_000, dtype=jnp.float32)
    with mozart.session(executor="fused", batch_elements=4096):
        np.asarray(_eval_chain(x))
    assert plan_cache.save(path) >= 1

    with open(path) as f:
        payload = json.load(f)
    assert payload["schema"] == plan_cache.SCHEMA_VERSION
    payload["schema"] = 3                 # rewrite as a v3-era file
    for e in payload["entries"]:
        if e.get("handoff"):
            for ho in e["handoff"].values():
                ho.pop("shard_in", None)
                ho.pop("vetoed", None)
    with open(path, "w") as f:
        json.dump(payload, f)

    plan_cache.clear()
    before = plan_cache.stats.get("persist_migrated_v3", 0)
    loaded = plan_cache.load(path)
    assert loaded >= 1
    assert plan_cache.stats.get("persist_migrated_v3", 0) == before + 1
    for e in plan_cache.entries():
        if e.handoff:
            for ho in e.handoff.values():
                assert ho.shard_in == frozenset()
                assert ho.vetoed == frozenset()

    # and the migrated plans actually replay
    with mozart.session(executor="fused", batch_elements=4096) as ctx:
        out = np.asarray(_eval_chain(x))
    assert ctx.stats["planner_calls"] == 0
    assert ctx.stats["streamed_outputs"] == 3
    want = np.asarray(x)
    for _ in range(3):
        want = (want + 1.0) * 0.5
    np.testing.assert_allclose(out, want, rtol=2e-5)


def test_unsupported_schema_still_rejected(tmp_path):
    path = str(tmp_path / "plans.json")
    plan_cache.clear()
    x = jnp.linspace(0., 1., 10_000, dtype=jnp.float32)
    with mozart.session(executor="fused", batch_elements=4096):
        np.asarray(_eval_chain(x, evals=1))
    assert plan_cache.save(path) >= 1
    with open(path) as f:
        payload = json.load(f)
    payload["schema"] = 1                 # pre-handoff layouts never migrate
    with open(path, "w") as f:
        json.dump(payload, f)
    plan_cache.clear()
    before = plan_cache.stats.get("persist_rejected_schema", 0)
    assert plan_cache.load(path) == 0
    assert plan_cache.stats.get("persist_rejected_schema", 0) == before + 1
