"""Sharded (mesh) executor tests — run in a subprocess so the forced
device count never leaks into other tests."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(body: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert proc.returncode == 0, f"stdout={proc.stdout}\nstderr={proc.stderr}"
    return proc.stdout


@pytest.mark.slow
def test_sharded_elementwise_and_reduce():
    out = run_with_devices("""
        import warnings; warnings.filterwarnings("ignore")
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import mozart
        from repro.core import annotated_numpy as anp

        mesh = jax.make_mesh((8,), ("data",))
        x = jnp.arange(4096.0, dtype=jnp.float32)
        with mozart.session(executor="sharded", mesh=mesh, batch_elements=64) as ctx:
            b = anp.multiply(anp.log1p(x), 3.0)
            s = anp.sum(b)
            got = np.asarray(b); sgot = float(s)
        want = np.log1p(np.arange(4096.0)) * 3
        assert np.allclose(got, want, rtol=1e-5)
        assert np.isclose(sgot, want.sum(), rtol=1e-5), (sgot, want.sum())
        assert ctx.stats["sharded_stages"] == 1
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_output_sharding_matches_split_axis():
    out = run_with_devices("""
        import warnings; warnings.filterwarnings("ignore")
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import mozart
        from repro.core import annotated_numpy as anp

        mesh = jax.make_mesh((4,), ("data",))
        m = jnp.asarray(np.random.RandomState(0).randn(64, 8), jnp.float32)
        v = jnp.ones(8, jnp.float32)
        with mozart.session(executor="sharded", mesh=mesh) as ctx:
            y = anp.matvec(m, v)     # Along(0): rows sharded, v broadcast
            z = anp.exp(y)
            res = z.value
        shard_shapes = {s.data.shape for s in res.addressable_shards}
        assert shard_shapes == {(16,)}, shard_shapes
        assert np.allclose(np.asarray(res), np.exp(np.asarray(m) @ np.ones(8)), rtol=1e-4)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_multipod_axes():
    """Splits spread over BOTH the pod and data axes (multi-pod DP)."""
    out = run_with_devices("""
        import warnings; warnings.filterwarnings("ignore")
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import mozart
        from repro.core import annotated_numpy as anp

        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        x = jnp.arange(1024.0, dtype=jnp.float32) / 128.0
        with mozart.session(executor="sharded", mesh=mesh,
                            data_axes=("pod", "data")) as ctx:
            y = anp.add(anp.exp(x), 1.0)
            s = anp.sum(y)
            got = np.asarray(y); sg = float(s)
        want = np.exp(np.arange(1024.0) / 128.0) + 1
        assert np.allclose(got, want, rtol=1e-5)
        assert np.isclose(sg, want.sum(), rtol=1e-6)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_stream_to_each_consumer():
    """Differential: a sharded producer's device-resident stream crosses
    into EVERY consumer executor (equal and misaligned consumer grids),
    handoff on vs off — values identical to numpy either way.  The
    sharded→sharded edge must move zero interior bytes and never
    all-gather; non-shard-capable consumers gather honestly (counted)."""
    out = run_with_devices("""
        import warnings; warnings.filterwarnings("ignore")
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import mozart, plan_cache
        from repro.core import annotated_numpy as anp

        mesh = jax.make_mesh((2,), ("data",))
        n = 4096
        x = jnp.linspace(0.0, 1.0, n, dtype=jnp.float32)
        want = np.linspace(0.0, 1.0, n, dtype=np.float32)
        for _ in range(2):
            want = (want + 1.0) * 0.5

        for consumer in ("fused", "scan", "pallas", "sharded"):
            # 2048 matches the 2-shard grid exactly; 1000 leaves an
            # odd-length 96-element tail chunk in the consumer's grid.
            for batch in (2048, 1000):
                if consumer == "sharded" and batch != 2048:
                    continue        # sharded grids come from the mesh
                for handoff in (True, False):
                    plan_cache.clear()
                    with mozart.session(executor="sharded", mesh=mesh,
                                        batch_elements=2048,
                                        handoff=handoff) as ctx:
                        cur = anp.multiply(anp.add(x, 1.0), 0.5)
                        mozart.evaluate()       # sharded producer stage
                        mozart.configure(executor=consumer,
                                         batch_elements=batch)
                        cur = anp.multiply(anp.add(cur, 1.0), 0.5)
                        got = np.asarray(cur)
                    tag = (consumer, batch, handoff)
                    assert np.allclose(got, want, rtol=2e-5), tag
                    if handoff and consumer == "sharded":
                        # zero interior bytes, and no all-gather anywhere
                        # in the scoped event trail
                        assert ctx.counters.bytes_interior() == 0, tag
                        gathers = [e for e in
                                   ctx.counters.materialize_events()
                                   if e[0].startswith("interior:gather")]
                        assert not gathers, (tag, gathers)
                        assert ctx.stats.get("shard_passthrough", 0) >= 1
                    if handoff and consumer != "sharded":
                        # honest fallback: the gather is counted, not hidden
                        assert ctx.stats.get("stream_materialized", 0) >= 1
        print("OK")
    """, n_devices=2)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_chunk_list_ingest_grids():
    """Chunk-list → sharded ingest: a grid equal to the shard layout is
    device_put per shard with zero rechunks; a misaligned grid converts
    through ``rechunk`` exactly once (at most one copy, not merge+re-split
    two)."""
    out = run_with_devices("""
        import warnings; warnings.filterwarnings("ignore")
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import mozart, plan_cache
        from repro.core import annotated_numpy as anp

        mesh = jax.make_mesh((2,), ("data",))
        n = 4096
        x = jnp.linspace(0.0, 1.0, n, dtype=jnp.float32)
        want = np.linspace(0.0, 1.0, n, dtype=np.float32)
        for _ in range(2):
            want = (want + 1.0) * 0.5

        for batch, rechunks in ((2048, 0), (1000, 1)):
            plan_cache.clear()
            with mozart.session(executor="fused", mesh=mesh,
                                batch_elements=batch) as ctx:
                cur = anp.multiply(anp.add(x, 1.0), 0.5)
                mozart.evaluate()           # fused producer: chunk list
                mozart.configure(executor="sharded")
                cur = anp.multiply(anp.add(cur, 1.0), 0.5)
                got = np.asarray(cur)
            assert np.allclose(got, want, rtol=2e-5), batch
            assert ctx.stats.get("shard_ingests", 0) == 1, dict(ctx.stats)
            assert ctx.stats.get("handoff_rechunks", 0) == rechunks, \\
                dict(ctx.stats)
            assert ctx.stats.get("stream_materialized", 0) == 0, \\
                dict(ctx.stats)
        print("OK")
    """, n_devices=2)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_stream_empty_inputs():
    """n == 0 through the new sharded stream paths, both directions — the
    degenerate zero-length grid must survive ingest and egress fallbacks."""
    out = run_with_devices("""
        import warnings; warnings.filterwarnings("ignore")
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import mozart, plan_cache
        from repro.core import annotated_numpy as anp

        mesh = jax.make_mesh((2,), ("data",))
        z = jnp.zeros((0,), jnp.float32)
        for first, second in (("sharded", "fused"), ("fused", "sharded")):
            plan_cache.clear()
            kw = {"mesh": mesh, "batch_elements": 64}
            with mozart.session(executor=first, **kw) as ctx:
                cur = anp.add(z, 1.0)
                mozart.evaluate()
                mozart.configure(executor=second)
                got = np.asarray(anp.multiply(cur, 2.0))
            assert got.shape == (0,), (first, second, got.shape)
        print("OK")
    """, n_devices=2)
    assert "OK" in out


@pytest.mark.slow
def test_elastic_checkpoint_restore_across_meshes(tmp_path):
    """Elastic restart: save on a 1-device layout, restore sharded onto a
    4-device mesh (different topology) — values identical."""
    out = run_with_devices(f"""
        import warnings; warnings.filterwarnings("ignore")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt import checkpoint as ckpt

        tree = {{"w": jnp.arange(64.0).reshape(8, 8),
                "b": jnp.arange(8.0)}}
        ckpt.save(r"{str(tmp_path)}", 3, tree)

        mesh = jax.make_mesh((4,), ("data",))
        sh = {{"w": NamedSharding(mesh, P("data", None)),
              "b": NamedSharding(mesh, P())}}
        avals = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
        r = ckpt.restore(r"{str(tmp_path)}", 3, avals, sh)
        assert len(r["w"].addressable_shards) == 4
        assert r["w"].addressable_shards[0].data.shape == (2, 8)
        np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(tree["w"]))
        print("OK")
    """, n_devices=4)
    assert "OK" in out
