"""Sharded (mesh) executor tests — run in a subprocess so the forced
device count never leaks into other tests."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(body: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert proc.returncode == 0, f"stdout={proc.stdout}\nstderr={proc.stderr}"
    return proc.stdout


@pytest.mark.slow
def test_sharded_elementwise_and_reduce():
    out = run_with_devices("""
        import warnings; warnings.filterwarnings("ignore")
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import mozart
        from repro.core import annotated_numpy as anp

        mesh = jax.make_mesh((8,), ("data",))
        x = jnp.arange(4096.0, dtype=jnp.float32)
        with mozart.session(executor="sharded", mesh=mesh, batch_elements=64) as ctx:
            b = anp.multiply(anp.log1p(x), 3.0)
            s = anp.sum(b)
            got = np.asarray(b); sgot = float(s)
        want = np.log1p(np.arange(4096.0)) * 3
        assert np.allclose(got, want, rtol=1e-5)
        assert np.isclose(sgot, want.sum(), rtol=1e-5), (sgot, want.sum())
        assert ctx.stats["sharded_stages"] == 1
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_output_sharding_matches_split_axis():
    out = run_with_devices("""
        import warnings; warnings.filterwarnings("ignore")
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import mozart
        from repro.core import annotated_numpy as anp

        mesh = jax.make_mesh((4,), ("data",))
        m = jnp.asarray(np.random.RandomState(0).randn(64, 8), jnp.float32)
        v = jnp.ones(8, jnp.float32)
        with mozart.session(executor="sharded", mesh=mesh) as ctx:
            y = anp.matvec(m, v)     # Along(0): rows sharded, v broadcast
            z = anp.exp(y)
            res = z.value
        shard_shapes = {s.data.shape for s in res.addressable_shards}
        assert shard_shapes == {(16,)}, shard_shapes
        assert np.allclose(np.asarray(res), np.exp(np.asarray(m) @ np.ones(8)), rtol=1e-4)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_multipod_axes():
    """Splits spread over BOTH the pod and data axes (multi-pod DP)."""
    out = run_with_devices("""
        import warnings; warnings.filterwarnings("ignore")
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import mozart
        from repro.core import annotated_numpy as anp

        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        x = jnp.arange(1024.0, dtype=jnp.float32) / 128.0
        with mozart.session(executor="sharded", mesh=mesh,
                            data_axes=("pod", "data")) as ctx:
            y = anp.add(anp.exp(x), 1.0)
            s = anp.sum(y)
            got = np.asarray(y); sg = float(s)
        want = np.exp(np.arange(1024.0) / 128.0) + 1
        assert np.allclose(got, want, rtol=1e-5)
        assert np.isclose(sg, want.sum(), rtol=1e-6)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_elastic_checkpoint_restore_across_meshes(tmp_path):
    """Elastic restart: save on a 1-device layout, restore sharded onto a
    4-device mesh (different topology) — values identical."""
    out = run_with_devices(f"""
        import warnings; warnings.filterwarnings("ignore")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt import checkpoint as ckpt

        tree = {{"w": jnp.arange(64.0).reshape(8, 8),
                "b": jnp.arange(8.0)}}
        ckpt.save(r"{str(tmp_path)}", 3, tree)

        mesh = jax.make_mesh((4,), ("data",))
        sh = {{"w": NamedSharding(mesh, P("data", None)),
              "b": NamedSharding(mesh, P())}}
        avals = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
        r = ckpt.restore(r"{str(tmp_path)}", 3, avals, sh)
        assert len(r["w"].addressable_shards) == 4
        assert r["w"].addressable_shards[0].data.shape == (2, 8)
        np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(tree["w"]))
        print("OK")
    """, n_devices=4)
    assert "OK" in out
