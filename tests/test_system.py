"""End-to-end behaviour tests for the paper's system.

The full loop: annotate unmodified functions -> lazy capture -> plan ->
pipelined execution -> results identical to the un-annotated library, on a
real workload (the paper's Black Scholes); plus the training-stack
integration (Mozart-pipelined AdamW inside a convergent train loop) and
validation of the dry-run artifacts when present.
"""

import json
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks import workloads as w
from repro import hardware
from repro.core import mozart

RESULTS = Path(__file__).resolve().parent.parent / "results"


@pytest.mark.parametrize("executor", ["pipelined", "fused", "scan", "pallas"])
def test_black_scholes_end_to_end(executor):
    """The paper's motivating workload: 30+ annotated vector ops, one stage,
    chunk-pipelined, numerically identical to the un-annotated library."""
    d = w.black_scholes_data(50_000)
    ref_call, ref_put = w.black_scholes_np(d)
    with mozart.session(executor=executor, chip=hardware.CPU_HOST) as ctx:
        call, put = w.black_scholes(**d)
        stages = ctx.last_plan()
        # every op pipelines into ONE stage (the paper's headline behaviour)
        assert len(stages) == 1
        got_call, got_put = np.asarray(call), np.asarray(put)
    np.testing.assert_allclose(got_call, ref_call, rtol=2e-3, atol=1e-3)
    np.testing.assert_allclose(got_put, ref_put, rtol=2e-3, atol=1e-3)
    assert ctx.stats["chunks"] + ctx.stats["pallas_stages"] >= 1


def test_shallow_water_stage_boundaries():
    """Stencil rolls are whole-array ops: they bound stages but the
    elementwise body still pipelines (paper §8.2, Shallow Water)."""
    r = np.random.RandomState(0)
    eta = jnp.asarray(1.0 + 0.1 * r.randn(128, 128), jnp.float32)
    u = jnp.zeros((128, 128), jnp.float32)
    v = jnp.zeros((128, 128), jnp.float32)
    ref = w.shallow_water_np(eta, u, v)
    with mozart.session(executor="fused", chip=hardware.CPU_HOST) as ctx:
        outs = w.shallow_water_step(eta, u, v)
        stages = ctx.last_plan()
        assert len(stages) > 1              # rolls force boundaries
        got = [np.asarray(o) for o in outs]
    for g, rr in zip(got, ref):
        np.testing.assert_allclose(g, rr, rtol=1e-3, atol=1e-4)


def test_training_with_mozart_optimizer_converges():
    """The paper's technique inside the training loop: the AdamW update runs
    as a Mozart pipeline and the loss still goes down."""
    import jax
    from repro.configs.registry import get_smoke_config
    from repro.data.pipeline import DataPipeline
    from repro.models import lm, transformer as tfm
    from repro.optim.adamw import AdamWConfig, init
    from repro.optim.mozart_adamw import mozart_adamw_update

    cfg = get_smoke_config("gemma-7b").with_runtime(dtype=jnp.float32)
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    opt = init(params)
    ocfg = AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=12)
    pipe = DataPipeline(cfg, batch=4, seq=32, seed=0)
    grad_fn = jax.jit(jax.value_and_grad(lambda p, b: lm.loss_fn(p, b, cfg)))
    losses = []
    for _ in range(8):
        batch = pipe.batch_for_step(0)      # overfit one batch
        loss, grads = grad_fn(params, batch)
        params, opt, _ = mozart_adamw_update(params, grads, opt, ocfg,
                                             executor="scan")
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_dryrun_artifacts_are_coherent():
    """When the dry-run has produced results, validate the deliverable:
    every compiled cell fits HBM and reports positive flops/collectives."""
    d = RESULTS / "dryrun"
    if not d.exists() or not list(d.glob("*__sp.json")):
        pytest.skip("dry-run results not present")
    n_ok = 0
    for f in d.glob("*__sp.json"):
        r = json.loads(f.read_text())
        if r["status"] == "skipped":
            assert "sub-quadratic" in r["reason"] or "encoder" in r["reason"]
            continue
        assert r["status"] == "ok", (f.name, r.get("error"))
        assert r["memory"]["peak_bytes"] < 16 * 2**30, f.name
        assert r["flops"] > 0
        assert r["n_devices"] in (256, 512)
        n_ok += 1
    assert n_ok >= 30
