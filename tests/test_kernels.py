"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

All kernels run in interpret mode on CPU (the kernel body executes in
Python), so these are true executions of the TPU kernel logic.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, hst, settings  # hypothesis-optional

from repro.kernels import ops, ref


def rand(shape, dtype, seed=0, scale=1.0):
    r = np.random.RandomState(seed)
    return jnp.asarray(r.randn(*shape) * scale, dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,Hq,Hkv,S,D,block",
        [
            (1, 2, 2, 128, 64, 64),     # MHA
            (2, 4, 2, 256, 64, 128),    # GQA group 2
            (1, 8, 1, 128, 128, 64),    # MQA (granite-style kv=1)
            (1, 2, 2, 256, 256, 128),   # gemma-style head_dim 256
        ],
    )
    def test_vs_ref_causal(self, B, Hq, Hkv, S, D, block, dtype):
        q = rand((B, Hq, S, D), dtype, 1)
        k = rand((B, Hkv, S, D), dtype, 2)
        v = rand((B, Hkv, S, D), dtype, 3)
        got = ops.flash_attention(q, k, v, causal=True, block_q=block, block_k=block)
        want = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **TOL[dtype])

    def test_non_causal(self):
        q, k, v = (rand((1, 2, 128, 64), jnp.float32, i) for i in range(3))
        got = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
        want = ref.attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("window", [32, 128])
    def test_sliding_window(self, window):
        q, k, v = (rand((1, 2, 256, 64), jnp.float32, i) for i in range(3))
        got = ops.flash_attention(q, k, v, causal=True, window=window,
                                  block_q=64, block_k=64)
        want = ref.attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)

    def test_cross_attention_kv_longer(self):
        q = rand((1, 2, 64, 64), jnp.float32, 1)
        k = rand((1, 2, 256, 64), jnp.float32, 2)
        v = rand((1, 2, 256, 64), jnp.float32, 3)
        got = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
        want = ref.attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


class TestFusedAdamW:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("n", [1000, 4096, 70001])
    def test_vs_ref(self, n, dtype):
        p = rand((n,), dtype, 0)
        g = rand((n,), dtype, 1)
        m = rand((n,), jnp.float32, 2, 0.01)
        v = jnp.abs(rand((n,), jnp.float32, 3, 0.01))
        kw = dict(lr=jnp.float32(1e-3), b1=0.9, b2=0.95, eps=1e-8, wd=0.1,
                  step=jnp.int32(7), grad_scale=0.5)
        po, mo, vo = ops.fused_adamw(p, g, m, v, block=4096, **kw)
        pr, mr, vr = ref.adamw_ref(p, g, m, v, **kw)
        np.testing.assert_allclose(np.asarray(po, np.float32),
                                   np.asarray(pr, np.float32), **TOL[dtype])
        np.testing.assert_allclose(np.asarray(mo), np.asarray(mr), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(vo), np.asarray(vr), rtol=1e-5, atol=1e-6)

    @given(n=hst.integers(1, 3000), step=hst.integers(1, 100))
    @settings(max_examples=10, deadline=None)
    def test_property_sweep(self, n, step):
        p = rand((n,), jnp.float32, n % 17)
        g = rand((n,), jnp.float32, n % 13)
        m = jnp.zeros((n,), jnp.float32)
        v = jnp.zeros((n,), jnp.float32)
        kw = dict(lr=jnp.float32(3e-4), b1=0.9, b2=0.999, eps=1e-8, wd=0.0,
                  step=jnp.int32(step))
        po, _, _ = ops.fused_adamw(p, g, m, v, block=1024, **kw)
        pr, _, _ = ref.adamw_ref(p, g, m, v, **kw)
        np.testing.assert_allclose(np.asarray(po), np.asarray(pr), rtol=3e-5, atol=3e-6)


class TestRMSNorm:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("shape", [(8, 128), (3, 512), (2, 5, 256), (300, 1024)])
    def test_vs_ref(self, shape, dtype):
        x = rand(shape, dtype, 0)
        w = rand(shape[-1:], jnp.float32, 1) + 1.0
        got = ops.rmsnorm(x, w, row_block=64)
        want = ref.rmsnorm_ref(x, w)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **TOL[dtype])


class TestSplitPipeline:
    @given(n=hst.integers(1, 5000), block=hst.sampled_from([1024, 2048, 4096]))
    @settings(max_examples=10, deadline=None)
    def test_chain_vs_ref(self, n, block):
        x = rand((n,), jnp.float32, 0)
        y = rand((n,), jnp.float32, 1)

        def chain(blocks, bcasts):
            # contract: reduce outputs are PRE-reduction blocks; the kernel
            # (and the oracle) apply the masked reduction.
            a, b = blocks
            (c,) = bcasts
            t = jnp.exp(a * 0.1) + b
            u = jnp.maximum(t, c)
            return [u, u]

        kinds = [("concat", ""), ("reduce", "add")]
        got = ops.split_pipeline(chain, [x, y], [jnp.float32(0.5)], kinds,
                                 [jnp.float32, jnp.float32], block_elems=block)
        want = ref.split_pipeline_ref(chain, [x, y], [jnp.float32(0.5)], kinds)
        np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                                   rtol=2e-5, atol=2e-6)
        np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                                   rtol=1e-4)

    @pytest.mark.parametrize("op", ["add", "max", "min", "mul"])
    def test_reduce_ops_with_padding(self, op):
        n = 1500                      # forces tail padding at block 1024
        x = jnp.asarray(np.random.RandomState(0).rand(n) + 0.5, jnp.float32)

        def chain(blocks, bcasts):
            return [blocks[0]]

        kinds = [("reduce", op)]
        got = ops.split_pipeline(chain, [x], [], kinds, [jnp.float32],
                                 block_elems=1024)[0]
        want = {"add": np.sum, "max": np.max, "min": np.min, "mul": np.prod}[op](
            np.asarray(x, np.float64))
        rtol = 1e-3 if op == "mul" else 1e-5
        assert np.isclose(float(got), float(want), rtol=rtol), (op, got, want)
