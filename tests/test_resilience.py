"""Resilience layer (``repro.core.resilience``): deterministic fault
injection, the executor degradation ladder, chunk-granular OOM recovery,
plan-cache quarantine persistence, hardened persistence, and the serving
failure domains.

The spine is differential: every chaos run must produce EXACTLY the result
of the fault-free eager oracle — degradation is only allowed to cost time,
never correctness.
"""

import json
import subprocess
import sys
import textwrap
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mozart, plan_cache, resilience, splittable, Along
from repro.core import annotated_numpy as anp
from repro.core.resilience import (FaultConfig, FaultPlan, FaultSpec,
                                   InjectedFault, InjectedResourceExhausted,
                                   StepFailure, StepTimer, with_retries,
                                   run_with_restarts)


@pytest.fixture(autouse=True)
def _fresh_resilience():
    """Disarm fault plans and zero the process counters around every test."""
    resilience.clear_faults()
    resilience.clear_events()
    yield
    resilience.clear_faults()
    resilience.clear_events()


@splittable(x=Along(0), y=Along(0), ret=Along(0), elementwise=True)
def saxpy(x, y):
    return 2.0 * x + y


def quickstart(x, y):
    a = saxpy(x, y)
    b = anp.exp(a)
    c = anp.multiply(b, 0.5)
    return c, anp.sum(c)


def chain3(x, y):
    """A multi-stage pipeline: the scalar reduction forces a stage break,
    so downstream stages INGEST upstream results (handoff boundary)."""
    a = saxpy(x, y)
    s = anp.sum(a)                      # stage break: scalar out
    b = anp.multiply(x, 0.5)
    c = anp.subtract(b, s)              # consumes the scalar + a fresh chain
    return anp.sum(anp.exp(anp.multiply(c, 0.01)))


N = 4096
X = jnp.arange(N, dtype=jnp.float32) / N
Y = jnp.ones(N, jnp.float32)


@pytest.fixture(scope="module")
def oracle():
    """Fault-free eager results for both pipelines."""
    with mozart.session(executor="eager"):
        c, s = quickstart(X, Y)
        q = (np.asarray(c), float(s))
        t = float(chain3(X, Y))
    return q, t


# ---------------------------------------------------------------------------
# Fault plans: parsing, firing, arming
# ---------------------------------------------------------------------------


class TestFaultPlan:
    def test_parse_defaults_and_fields(self):
        p = FaultPlan.parse("compile")
        assert p.specs == [FaultSpec("compile", "fail", 1, "", 0)]
        p = FaultPlan.parse("chunk:oom:2, merge:fail:1:stage 0")
        assert p.specs[0] == FaultSpec("chunk", "oom", 2, "", 0)
        assert p.specs[1] == FaultSpec("merge", "fail", 1, "stage 0", 0)

    def test_parse_after_skip(self):
        (spec,) = FaultPlan.parse("chunk:fail:1+3").specs
        assert (spec.count, spec.after) == (1, 3)

    def test_unknown_boundary_and_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown fault boundary"):
            FaultPlan.parse("warp-drive:fail:1")
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultPlan.parse("chunk:frobnicate:1")

    def test_fires_count_times_then_disarms(self):
        p = FaultPlan.parse("merge:fail:2")
        for _ in range(2):
            with pytest.raises(InjectedFault):
                p.check("merge", "stage 0")
        p.check("merge", "stage 0")          # spent: silent
        assert p.fired == [("merge", "stage 0")] * 2

    def test_match_filters_crossings(self):
        p = FaultPlan.parse("merge:fail:1:stage 2")
        p.check("merge", "stage 0")          # no match: skipped, not consumed
        with pytest.raises(InjectedFault):
            p.check("merge", "stage 2")

    def test_after_skips_crossings(self):
        p = FaultPlan.parse("chunk:fail:1+2")
        p.check("chunk", "a")
        p.check("chunk", "b")
        with pytest.raises(InjectedFault):
            p.check("chunk", "c")

    def test_oom_kind_raises_resource_exhausted(self):
        p = FaultPlan.parse("chunk:oom:1")
        with pytest.raises(InjectedResourceExhausted) as ei:
            p.check("chunk", "x")
        assert resilience.is_resource_exhausted(ei.value)

    def test_inject_faults_nests_and_restores(self):
        with mozart.inject_faults("merge:fail:1") as outer:
            with mozart.inject_faults("split:fail:1") as inner:
                resilience.maybe_fail("merge")          # outer masked: silent
                with pytest.raises(InjectedFault):
                    resilience.maybe_fail("split")
            assert inner.fired and not outer.fired
            with pytest.raises(InjectedFault):
                resilience.maybe_fail("merge")          # outer restored
        resilience.maybe_fail("merge")                   # all disarmed

    def test_env_plan_fires_once_and_stays_spent(self, monkeypatch):
        monkeypatch.setenv("MOZART_FAULTS", "merge:fail:1")
        with pytest.raises(InjectedFault):
            resilience.maybe_fail("merge", "env")
        # Re-reading the same env value must NOT re-arm the plan.
        resilience.maybe_fail("merge", "env")
        assert resilience.stats["MZ401"] == 1

    def test_is_resource_exhausted_matches_xla_strings(self):
        assert resilience.is_resource_exhausted(MemoryError())
        assert resilience.is_resource_exhausted(
            RuntimeError("RESOURCE_EXHAUSTED: out of memory on device"))
        assert not resilience.is_resource_exhausted(RuntimeError("boom"))


# ---------------------------------------------------------------------------
# Chaos sweep: injected boundary faults, exact differential parity
# ---------------------------------------------------------------------------

SWEEP_EXECUTORS = ("pipelined", "fused", "scan", "pallas", "auto")
SWEEP_BOUNDARIES = ("split", "chunk", "compile", "ingest")


@pytest.mark.parametrize("executor", SWEEP_EXECUTORS)
@pytest.mark.parametrize("boundary", SWEEP_BOUNDARIES)
def test_boundary_fault_parity(executor, boundary, oracle):
    """A fault at the FIRST crossing of each boundary: the run completes
    bit-identically to the fault-free oracle (ladder demotion, probe
    swallow, or the boundary simply not being exercised — all are fine,
    a wrong answer is not)."""
    (want_c, want_s), _ = oracle
    with mozart.inject_faults(f"{boundary}:fail:1") as plan:
        with mozart.session(executor=executor, batch_elements=512) as ctx:
            c, s = quickstart(X, Y)
            got_c, got_s = np.asarray(c), float(s)
    np.testing.assert_allclose(got_c, want_c, rtol=2e-5, atol=1e-6)
    assert np.isclose(got_s, want_s, rtol=1e-5)
    if plan.fired:
        # The fault really happened and was recovered from — and the
        # recovery is observable (MZ401 fire record at minimum).
        assert resilience.stats["MZ401"] >= 1


@pytest.mark.parametrize("executor", ("pipelined", "scan"))
def test_merge_fault_parity(executor, oracle):
    """Merge faults recover for non-donating drives (donating attempts are
    deliberately NOT re-driven: freed buffers must never be re-read)."""
    (want_c, want_s), _ = oracle
    with mozart.inject_faults("merge:fail:1") as plan:
        with mozart.session(executor=executor, batch_elements=512) as ctx:
            c, s = quickstart(X, Y)
            got_c, got_s = np.asarray(c), float(s)
    np.testing.assert_allclose(got_c, want_c, rtol=2e-5, atol=1e-6)
    assert np.isclose(got_s, want_s, rtol=1e-5)
    assert plan.fired
    assert ctx.stats["exec_demotions"] >= 1


def test_handoff_chain_fault_parity(oracle):
    """The 3-stage handoff chain survives an ingest fault mid-chain."""
    _, want = oracle
    with mozart.inject_faults("ingest:fail:1") as plan:
        with mozart.session(executor="fused", batch_elements=512) as ctx:
            got = float(chain3(X, Y))
    assert np.isclose(got, want, rtol=1e-5)
    assert ctx.stats["stages"] >= 2 or ctx.stats["evaluations"] >= 1


def test_compile_fault_demotes_and_quarantines(oracle):
    """A compile-time failure walks the ladder (fused -> pipelined), records
    MZ402/MZ404, and quarantines the broken choice in the plan entry so the
    NEXT call skips it outright."""
    (want_c, want_s), _ = oracle
    with mozart.session(executor="fused", batch_elements=512) as ctx:
        with mozart.inject_faults("compile:fail:1") as plan:
            c, s = quickstart(X, Y)
            got = (np.asarray(c), float(s))
        assert plan.fired
        assert ctx.stats["exec_demotions"] >= 1
        assert resilience.stats["MZ402"] >= 1
        assert resilience.stats["MZ404"] >= 1
        skips_before = ctx.stats["exec_quarantine_skips"]
        # Warm call, no fault armed: the quarantined executor is skipped.
        c2, s2 = quickstart(X, Y)
        got2 = (np.asarray(c2), float(s2))
        assert ctx.stats["exec_quarantine_skips"] > skips_before
    np.testing.assert_allclose(got[0], want_c, rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(got2[0], want_c, rtol=2e-5, atol=1e-6)
    assert np.isclose(got[1], want_s, rtol=1e-5)
    assert np.isclose(got2[1], want_s, rtol=1e-5)
    # The quarantine is persisted state on the entry.
    assert any(e.quarantined for e in plan_cache.entries())


def test_chunk_oom_halves_batch_and_repins(oracle):
    """Injected RESOURCE_EXHAUSTED on the first chunk drive: the batch is
    halved below the ladder, the run completes exactly, and the surviving
    size is re-pinned into the tuner state."""
    (want_c, want_s), _ = oracle
    with mozart.inject_faults("chunk:oom:1") as plan:
        with mozart.session(executor="fused", batch_elements=512) as ctx:
            c, s = quickstart(X, Y)
            got_c, got_s = np.asarray(c), float(s)
    np.testing.assert_allclose(got_c, want_c, rtol=2e-5, atol=1e-6)
    assert np.isclose(got_s, want_s, rtol=1e-5)
    assert plan.fired
    assert ctx.stats["chunk_oom_halvings"] >= 1
    assert resilience.stats["MZ403"] >= 1
    # No executor demotion needed: recovery happened below the ladder.
    assert ctx.stats["exec_demotions"] == 0
    assert 256 in set(plan_cache.tuned_batches().values())


def test_sustained_oom_bounded_then_ladder_finishes_on_eager(oracle):
    """OOM on EVERY chunk drive: each chunked executor halves at most
    MAX_OOM_HALVINGS times before the failure escalates to the ladder,
    which lands on eager — the unchunked baseline that cannot OOM-inject —
    and still produces the exact answer.  No unbounded retry loop."""
    (want_c, want_s), _ = oracle
    with mozart.inject_faults("chunk:oom:999"):
        with mozart.session(executor="fused", batch_elements=512) as ctx:
            c, s = quickstart(X, Y)
            got_c, got_s = np.asarray(c), float(s)
    np.testing.assert_allclose(got_c, want_c, rtol=2e-5, atol=1e-6)
    assert np.isclose(got_s, want_s, rtol=1e-5)
    assert ctx.stats["exec_demoted_to_eager"] >= 1
    # Halvings are bounded PER ATTEMPT; the ladder tried two chunked rungs.
    assert ctx.stats["chunk_oom_halvings"] <= 2 * resilience.MAX_OOM_HALVINGS


class _Ctx:
    def __init__(self, **stats):
        self.stats = dict(stats)


def test_sanitizer_errors_are_never_demoted_around():
    from repro.core.stage_exec import SanitizerError
    assert not resilience._recoverable(SanitizerError("bad merge"), _Ctx(), 0)


def test_donating_attempt_is_not_redriven():
    ctx = _Ctx(donated_chunks=3)
    assert not resilience._recoverable(RuntimeError("x"), ctx, 0)
    assert resilience._recoverable(RuntimeError("x"), ctx, 3)


def test_demotion_ladder_order():
    assert resilience.demotion_ladder("pallas") == [
        "sharded", "scan", "fused", "pipelined", "eager"]
    assert resilience.demotion_ladder("eager") == []
    # Unknown / meta names restart from the top, minus themselves.
    assert resilience.demotion_ladder("auto") == list(resilience.DEGRADE_ORDER)


# ---------------------------------------------------------------------------
# Quarantine aging
# ---------------------------------------------------------------------------


def test_quarantine_ages_out(oracle):
    """After TTL warm dispatches the quarantined executor is retried."""
    (want_c, want_s), _ = oracle
    with mozart.session(executor="fused", batch_elements=512) as ctx:
        with mozart.inject_faults("compile:fail:1"):
            _, s = quickstart(X, Y)
            float(s)
        entry = next(e for e in plan_cache.entries() if e.quarantined)
        (sid,) = [k for k, v in entry.quarantined.items() if "fused" in v]
        assert entry.quarantined_execs(sid) == {"fused"}
        # Unit-level aging: each tick ages by one, TTL drops the ban.
        assert entry.tick_quarantine(sid, ttl=2) == {"fused"}   # age 1 of 2
        assert entry.tick_quarantine(sid, ttl=2) == set()       # age 2: out
        assert entry.quarantined_execs(sid) == set()
        # Post-quarantine the executor runs again (fault long spent).
        c, s = quickstart(X, Y)
        np.testing.assert_allclose(np.asarray(c), want_c, rtol=2e-5,
                                   atol=1e-6)


def test_tick_quarantine_multiple_names():
    with mozart.session(executor="fused", batch_elements=512):
        _, s = quickstart(X, Y)
        float(s)
    entry = plan_cache.entries()[0]
    entry.quarantine_exec(7, "pallas")
    entry.quarantine_exec(7, "scan")
    assert entry.quarantined_execs(7) == {"pallas", "scan"}
    assert entry.tick_quarantine(7, ttl=2) == {"pallas", "scan"}
    assert entry.tick_quarantine(7, ttl=2) == set()
    assert 7 not in entry.quarantined


# ---------------------------------------------------------------------------
# Plan-cache persistence hardening
# ---------------------------------------------------------------------------


def _warm_cache():
    with mozart.session(executor="fused", batch_elements=512):
        _, s = quickstart(X, Y)
        float(s)


class TestPersistence:
    def test_persist_fault_leaves_existing_file_intact(self, tmp_path):
        path = str(tmp_path / "plans.json")
        _warm_cache()
        assert plan_cache.save(path, force=True) >= 1
        before = json.loads(open(path).read())
        with mozart.inject_faults("persist:fail:1"):
            with pytest.raises(InjectedFault):
                plan_cache.save(path, force=True)
        # The fault fired before the tmp-write + atomic rename: the
        # previous payload is untouched and still loads.
        assert json.loads(open(path).read()) == before
        plan_cache.clear()
        assert plan_cache.load(path) >= 1

    def test_quarantine_round_trips_through_persistence(self, tmp_path):
        path = str(tmp_path / "plans.json")
        _warm_cache()
        entry = plan_cache.entries()[0]
        entry.quarantine_exec(0, "pallas")
        assert plan_cache.save(path, force=True) >= 1
        plan_cache.clear()
        assert plan_cache.load(path) >= 1
        loaded = plan_cache.entries()[0]
        assert loaded.quarantined_execs(0) == {"pallas"}

    def test_v5_file_forward_migrates(self, tmp_path):
        path = str(tmp_path / "plans.json")
        _warm_cache()
        assert plan_cache.save(path, force=True) >= 1
        payload = json.loads(open(path).read())
        assert payload["schema"] == plan_cache.SCHEMA_VERSION
        payload["schema"] = 5
        for e in payload["entries"]:
            e.pop("quarantined", None)       # v5 files predate the field
        open(path, "w").write(json.dumps(payload))
        plan_cache.clear()
        assert plan_cache.load(path) >= 1
        assert plan_cache.stats["persist_migrated_v5"] >= 1
        assert plan_cache.entries()[0].quarantined == {}

    def test_cross_process_saves_merge_not_clobber(self, tmp_path):
        """Two processes sharing one MOZART_PLAN_CACHE path: the second
        save must MERGE the first process's entries (read-merge-write under
        the advisory lock), not overwrite them."""
        path = str(tmp_path / "shared.json")
        script = textwrap.dedent("""\
            import sys
            import jax.numpy as jnp
            from repro.core import mozart, plan_cache
            from repro.core import annotated_numpy as anp
            n = int(sys.argv[1])
            x = jnp.linspace(0.0, 1.0, n, dtype=jnp.float32)
            with mozart.session(executor="fused", batch_elements=512):
                s = anp.sum(anp.multiply(anp.exp(x), 0.5))
                float(s)
            print(plan_cache.save(sys.argv[2], force=True))
        """)
        for n in (1024, 2048):               # distinct shapes: distinct keys
            r = subprocess.run([sys.executable, "-c", script, str(n), path],
                               capture_output=True, text=True, timeout=300)
            assert r.returncode == 0, r.stderr
        payload = json.loads(open(path).read())
        assert len(payload["entries"]) == 2
        plan_cache.clear()
        assert plan_cache.load(path) == 2


# ---------------------------------------------------------------------------
# Observability: MZ4xx vocabulary + counted swallows
# ---------------------------------------------------------------------------


def test_mz4xx_codes_registered():
    from repro.core.analysis import CODES
    for code in ("MZ401", "MZ402", "MZ403", "MZ404", "MZ405", "MZ406"):
        assert code in CODES


def test_note_swallowed_is_counted_and_evented():
    resilience.note_swallowed("unit_test", ValueError("nope"))
    assert resilience.stats["swallowed_errors"] == 1
    assert resilience.stats["swallowed:unit_test"] == 1
    diags = resilience.events()
    assert any(d.code == "MZ406" and "unit_test" in d.subject for d in diags)


# ---------------------------------------------------------------------------
# Absorbed seed-era fault helpers (runtime/fault.py shim)
# ---------------------------------------------------------------------------


def test_fault_shim_reexports_same_objects():
    from repro.runtime import fault
    assert fault.with_retries is resilience.with_retries
    assert fault.StepTimer is resilience.StepTimer
    assert fault.FaultConfig is resilience.FaultConfig
    assert fault.run_with_restarts is resilience.run_with_restarts
    assert fault.TRANSIENT_ERRORS is resilience.TRANSIENT_ERRORS


class TestWithRetries:
    def test_transient_retried_then_succeeds(self):
        calls = []

        def flaky():
            calls.append(1)
            if len(calls) < 3:
                raise RuntimeError("transient")
            return 42

        assert with_retries(flaky, retries=4) == 42
        assert len(calls) == 3
        assert resilience.stats["step_retries"] == 2

    def test_non_transient_propagates_immediately(self):
        calls = []

        def buggy():
            calls.append(1)
            raise KeyError("programming error")

        with pytest.raises(KeyError):
            with_retries(buggy, retries=5)
        assert len(calls) == 1

    def test_exhaustion_raises_step_failure_with_cause(self):
        boom = RuntimeError("always")

        def always():
            raise boom

        with pytest.raises(StepFailure) as ei:
            with_retries(always, retries=2)
        assert ei.value.__cause__ is boom

    def test_backoff_sleeps_exponentially(self, monkeypatch):
        slept = []
        monkeypatch.setattr(time, "sleep", slept.append)

        def always():
            raise RuntimeError("x")

        with pytest.raises(StepFailure):
            with_retries(always, retries=3, backoff_s=0.1)
        assert slept == [0.1, 0.2, 0.4]      # no sleep after the last try


class TestStepTimer:
    def test_straggler_flagged_and_hook_called(self):
        hits = []
        cfg = FaultConfig(min_steps_for_baseline=3, straggler_factor=2.0)
        t = StepTimer(cfg, on_straggler=lambda s, sec, med: hits.append((s, sec, med)))
        for i in range(3):
            assert not t.record(i, 0.01)
        assert t.record(3, 0.05)
        assert t.stragglers == [3]
        assert hits and hits[0][0] == 3 and hits[0][1] == 0.05
        assert resilience.stats["stragglers"] == 1

    def test_no_flag_before_baseline(self):
        t = StepTimer(FaultConfig(min_steps_for_baseline=5))
        assert not t.record(0, 100.0)        # no baseline yet: never flagged


def test_run_with_restarts_restarts_from_checkpoint():
    calls = {"n": 0}
    ckpts = [None, 3, 7]

    def make_state(step):
        return ({"from": step}, step or 0)

    def run_from(state, start):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError(f"crash {calls['n']}")
        return ("done", start)

    result = run_with_restarts(
        make_state, run_from,
        fault_cfg=FaultConfig(max_restarts=3, backoff_s=0.0),
        latest_step=lambda: ckpts[min(calls["n"], 2)])
    assert result == ("done", 7)             # resumed from the NEWEST ckpt
    assert resilience.stats["restarts"] == 2


def test_run_with_restarts_gives_up_after_max():
    def run_from(state, start):
        raise RuntimeError("always down")

    with pytest.raises(RuntimeError, match="always down"):
        run_with_restarts(
            lambda step: (None, 0), run_from,
            fault_cfg=FaultConfig(max_restarts=1, backoff_s=0.0),
            latest_step=lambda: None)
