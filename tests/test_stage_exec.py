"""StageExecutor subsystem tests: registry, cross-executor differential
parity vs the "eager" (un-annotated library) oracle, plan cache, auto-tuner,
cost-model executor auto-selection.  (The full executor × library-surface
differential matrix lives in tests/test_differential.py.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import hardware
from repro.core import cost_model, mozart, plan_cache, planner, splittable, Along
from repro.core import annotated_numpy as anp
from repro.core.stage_exec import (
    StageExecutor,
    available_executors,
    candidate_batches,
    get_executor,
    register_executor,
)

ALL_EXECUTORS = ("eager", "pipelined", "fused", "scan", "sharded", "pallas", "auto")


#: a tiny fast-memory tier so the §5.2 estimate lands well below our array
#: sizes and the tuner has a real candidate spread to measure.
TINY_CHIP = hardware.Chip(
    name="tiny_test_chip",
    peak_bf16_flops=1e11,
    hbm_bandwidth=2e10,
    ici_link_bandwidth=1e10,
    ici_links=1,
    hbm_bytes=2**30,
    vmem_bytes=64 * 1024,
    mozart_c=1.0,
)


@splittable(x=Along(0), y=Along(0), ret=Along(0), elementwise=True)
def saxpy(x, y):
    return 2.0 * x + y


def quickstart(x, y):
    """The examples/quickstart.py pipeline: saxpy -> exp -> scale -> sum."""
    a = saxpy(x, y)
    b = anp.exp(a)
    c = anp.multiply(b, 0.5)
    return c, anp.sum(c)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_all_strategies_registered(self):
        names = set(available_executors())
        assert set(ALL_EXECUTORS) <= names
        for n in names:
            assert isinstance(get_executor(n), StageExecutor)
            assert get_executor(n).name == n

    def test_unknown_executor_raises(self):
        with pytest.raises(ValueError, match="unknown executor"):
            get_executor("warp-drive")

    def test_get_executor_returns_singleton(self):
        assert get_executor("fused") is get_executor("fused")

    def test_custom_registration(self):
        @register_executor("test-noop")
        class NoopExecutor(StageExecutor):
            def execute(self, stage, concrete, ctx):
                for node in stage.nodes:
                    node.result = None
                    node.done = True

        try:
            assert "test-noop" in available_executors()
            assert isinstance(get_executor("test-noop"), NoopExecutor)
        finally:
            from repro.core import stage_exec
            stage_exec._REGISTRY.pop("test-noop", None)
            stage_exec._INSTANCES.pop("test-noop", None)


# ---------------------------------------------------------------------------
# Cross-executor differential: everyone must match the eager oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("executor", sorted(available_executors()))
def test_quickstart_differential_vs_eager(executor):
    n = 4096
    x = jnp.arange(n, dtype=jnp.float32) / n
    y = jnp.ones(n, jnp.float32)

    with mozart.session(executor="eager"):
        c0, s0 = quickstart(x, y)
        want_c, want_s = np.asarray(c0), float(s0)

    kwargs = {"batch_elements": 512}
    if executor == "sharded":
        kwargs["mesh"] = jax.make_mesh((1,), ("data",))
    with mozart.session(executor=executor, **kwargs) as ctx:
        c, s = quickstart(x, y)
        got_c, got_s = np.asarray(c), float(s)

    np.testing.assert_allclose(got_c, want_c, rtol=2e-5, atol=1e-6)
    assert np.isclose(got_s, want_s, rtol=1e-5), (executor, got_s, want_s)
    assert ctx.stats["stages"] >= 1


@pytest.mark.parametrize("executor", ["pipelined", "fused", "scan", "pallas"])
def test_differential_with_autotuned_batches(executor):
    """Parity must survive the tuner's candidate re-executions too."""
    n = 30_000
    x = jnp.linspace(0.0, 1.0, n, dtype=jnp.float32)
    y = jnp.ones(n, jnp.float32)

    with mozart.session(executor="eager"):
        _, s0 = quickstart(x, y)
        want = float(s0)

    plan_cache.clear()
    got = []
    for _ in range(3):   # miss -> tuning hit -> pinned hit
        with mozart.session(executor=executor, chip=TINY_CHIP):
            _, s = quickstart(x, y)
            got.append(float(s))
    assert all(np.isclose(g, want, rtol=1e-5) for g in got), (executor, got, want)
    assert plan_cache.tuned_batches(), "tuner pinned nothing"


# ---------------------------------------------------------------------------
# Plan cache
# ---------------------------------------------------------------------------


def _pipeline(x):
    return anp.sum(anp.multiply(anp.exp(x), 0.5))


class TestPlanCache:
    def test_second_run_performs_zero_planner_calls(self):
        x = jnp.linspace(0.0, 1.0, 2048, dtype=jnp.float32)

        with mozart.session(executor="fused") as ctx1:
            v1 = float(_pipeline(x))
        assert ctx1.stats["planner_calls"] == 1
        assert ctx1.stats["plan_cache_misses"] == 1

        before = planner.N_CALLS
        with mozart.session(executor="fused") as ctx2:
            v2 = float(_pipeline(x))
        assert planner.N_CALLS == before          # the planner never ran
        assert ctx2.stats["planner_calls"] == 0
        assert ctx2.stats["plan_cache_hits"] == 1
        assert np.isclose(v1, v2)

    def test_fresh_data_same_shape_hits(self):
        with mozart.session(executor="fused") as ctx1:
            _ = float(_pipeline(jnp.linspace(0.0, 1.0, 512)))
        with mozart.session(executor="fused") as ctx2:
            v = float(_pipeline(jnp.linspace(1.0, 2.0, 512)))
        assert ctx2.stats["plan_cache_hits"] == 1
        want = float(np.sum(np.exp(np.linspace(1.0, 2.0, 512)) * 0.5))
        assert np.isclose(v, want, rtol=1e-5)

    def test_shape_change_misses(self):
        with mozart.session(executor="fused") as ctx1:
            _ = float(_pipeline(jnp.linspace(0.0, 1.0, 128)))
        with mozart.session(executor="fused") as ctx2:
            _ = float(_pipeline(jnp.linspace(0.0, 1.0, 256)))
        assert ctx2.stats["plan_cache_hits"] == 0
        assert ctx2.stats["plan_cache_misses"] == 1

    def test_executor_is_part_of_the_key(self):
        x = jnp.linspace(0.0, 1.0, 256)
        with mozart.session(executor="fused"):
            _ = float(_pipeline(x))
        with mozart.session(executor="scan") as ctx:
            _ = float(_pipeline(x))
        assert ctx.stats["plan_cache_hits"] == 0

    def test_mesh_is_part_of_the_key(self):
        """A plan (and any pinned `sharded` choice) from a mesh session must
        never replay in a mesh-less session of the same pipeline."""
        x = jnp.arange(64.0)
        mesh = jax.make_mesh((1,), ("data",))
        with mozart.session(executor="auto", mesh=mesh, batch_elements=16):
            _ = float(_pipeline(x))
        with mozart.session(executor="auto", batch_elements=16) as ctx:
            _ = float(_pipeline(x))
        assert ctx.stats["plan_cache_hits"] == 0
        assert ctx.stats["plan_cache_misses"] == 1

    def test_aliased_arguments_key_differently(self):
        """add(x, x) and add(x, y) have different plans (one split vs two)."""
        x = jnp.arange(64.0)
        y = jnp.ones(64) * 2
        with mozart.session(executor="pipelined", batch_elements=16):
            np.testing.assert_allclose(np.asarray(anp.add(x, x)), np.arange(64.0) * 2)
        with mozart.session(executor="pipelined", batch_elements=16) as ctx:
            np.testing.assert_allclose(np.asarray(anp.add(x, y)), np.arange(64.0) + 2)
        assert ctx.stats["plan_cache_hits"] == 0

    def test_plan_cache_can_be_disabled(self):
        x = jnp.linspace(0.0, 1.0, 256)
        for _ in range(2):
            with mozart.session(executor="fused", plan_cache=False) as ctx:
                _ = float(_pipeline(x))
        assert ctx.stats["planner_calls"] == 1
        assert ctx.stats["plan_cache_hits"] == 0
        assert plan_cache.cache_info()["entries"] == 0

    def test_table_pipeline_hits_via_fingerprint_hook(self):
        from repro.core import annotated_table as tb
        r = np.random.RandomState(0)
        t = tb.Table({
            "pop": r.rand(100).astype(np.float64) * 1000,
            "crime": r.rand(100).astype(np.float64) * 10,
        })
        def run():
            with mozart.session(executor="pipelined", batch_elements=17) as ctx:
                idx = anp.divide(anp.multiply(tb.col(t, "crime"), 100.0),
                                 tb.col(t, "pop"))
                return float(anp.sum(idx)), ctx
        v1, c1 = run()
        v2, c2 = run()
        assert c1.stats["plan_cache_misses"] == 1
        assert c2.stats["plan_cache_hits"] == 1
        assert np.isclose(v1, v2)

    def test_consumed_done_future_replans_correctly(self):
        """NodeRefs to already-materialized nodes rebind across cache hits."""
        x = jnp.arange(16.0)
        for _ in range(2):
            with mozart.session(executor="fused") as ctx:
                a = anp.exp(x)
                _ = a.value                       # materialize
                b = anp.add(a, x)                 # consumes a DONE node
                np.testing.assert_allclose(
                    np.asarray(b), np.exp(np.arange(16.0)) + np.arange(16.0),
                    rtol=1e-5)


# ---------------------------------------------------------------------------
# Auto-tuner
# ---------------------------------------------------------------------------


class TestAutoTuner:
    def _run(self, x, **kw):
        with mozart.session(executor="fused", chip=TINY_CHIP, **kw) as ctx:
            v = float(_pipeline(x))
        return v, ctx

    def test_tunes_on_first_cached_execution_then_pins(self):
        x = jnp.linspace(0.0, 1.0, 100_000, dtype=jnp.float32)
        v1, c1 = self._run(x)       # miss: plan + §5.2 estimate
        assert c1.stats["autotuned_stages"] == 0
        v2, c2 = self._run(x)       # first hit: measure candidates
        assert c2.stats["autotuned_stages"] == 1
        tuned = plan_cache.tuned_batches()
        assert tuned, "no chunk size pinned"
        (entry,) = plan_cache.entries()
        assert all(len(t) >= 2 for t in entry.trials.values())   # 2-3 candidates
        v3, c3 = self._run(x)       # later hits: reuse the pinned size
        assert c3.stats["autotuned_stages"] == 0
        assert c3.stats["plan_cache_hits"] == 1
        pinned = list(tuned.values())[0]
        assert c3.stats["chunks"] == int(np.ceil(100_000 / pinned))
        assert np.isclose(v1, v2) and np.isclose(v2, v3)

    def test_explicit_batch_elements_disables_tuning(self):
        x = jnp.linspace(0.0, 1.0, 50_000, dtype=jnp.float32)
        for _ in range(3):
            _, ctx = self._run(x, batch_elements=7000)
        assert ctx.stats["autotuned_stages"] == 0
        assert not plan_cache.tuned_batches()
        assert ctx.stats["chunks"] == int(np.ceil(50_000 / 7000))

    def test_autotune_flag_off(self):
        x = jnp.linspace(0.0, 1.0, 50_000, dtype=jnp.float32)
        for _ in range(3):
            _, ctx = self._run(x, autotune=False)
        assert ctx.stats["autotuned_stages"] == 0
        assert not plan_cache.tuned_batches()

    def test_candidate_batches_bracket_the_estimate(self):
        assert candidate_batches(100, 1000) == [50, 100, 200]
        assert candidate_batches(100, 150) == [50, 100, 150]
        assert candidate_batches(100, 80) == [80]       # one chunk: no tuning
        assert candidate_batches(1, 1000) == [1, 2]
        assert candidate_batches(100, 0) == [1]         # empty split

    def test_tuning_cost_is_a_bounded_sample(self):
        """ROADMAP fix: the tuner times a bounded sample of chunks per
        candidate (extrapolating to full-stage seconds) instead of 2 full
        stage executions each.  Structural bound: the elements re-executed
        for measurement stay below ONE extra full stage execution."""
        n = 100_000
        x = jnp.linspace(0.0, 1.0, n, dtype=jnp.float32)
        _, c1 = self._run(x)        # miss: plan
        _, c2 = self._run(x)        # first hit: sampled tuning
        assert c2.stats["autotuned_stages"] == 1
        assert 0 < c2.stats["tuning_sample_elems"] < n
        assert plan_cache.tuned_batches(), "tuner pinned nothing"
        _, c3 = self._run(x)        # pinned: no further sampling
        assert c3.stats["tuning_sample_elems"] == 0


# ---------------------------------------------------------------------------
# Cost-model executor auto-selection
# ---------------------------------------------------------------------------


def _feats(**kw):
    base = dict(n=100_000, elem_bytes=12, n_nodes=3, flops_per_elem=24.0,
                dynamic=False, pallas_eligible=True, mesh_devices=0,
                on_tpu=False)
    base.update(kw)
    return cost_model.StageFeatures(**base)


class TestAutoSelection:
    def _run(self, x, **kw):
        with mozart.session(executor="auto", chip=TINY_CHIP, **kw) as ctx:
            v = float(_pipeline(x))
        return v, ctx

    def test_choice_is_deterministic_in_recorded_timings(self):
        """Same features + same recorded timings => same pick, regardless of
        dict insertion order or repetition."""
        ctx = mozart.MozartContext(chip=TINY_CHIP)
        f = _feats()
        t_fwd = {"fused": 0.010, "scan": 0.020, "pipelined": 0.030}
        t_rev = dict(reversed(list(t_fwd.items())))
        picks = {cost_model.choose(f, ctx, t) for t in (t_fwd, t_rev)}
        picks |= {cost_model.choose(f, ctx, t_fwd) for _ in range(5)}
        assert picks == {"fused"}

    def test_ties_break_by_fixed_preference_order(self):
        ctx = mozart.MozartContext(chip=TINY_CHIP)
        tie = {"fused": 0.01, "scan": 0.01, "eager": 0.01}
        assert cost_model.choose(_feats(), ctx, tie) == "scan"

    def test_analytic_prior_prefers_low_dispatch_strategies(self):
        ctx = mozart.MozartContext(chip=TINY_CHIP)
        f = _feats()
        scores = {n: cost_model.analytic_seconds(n, f, TINY_CHIP)
                  for n in ("scan", "fused", "pipelined", "eager")}
        assert scores["scan"] < scores["pipelined"]     # 1 dispatch vs many
        assert scores["fused"] < scores["pipelined"]    # 1/chunk vs nodes/chunk
        # interpret-mode pallas is effectively vetoed off-TPU
        assert cost_model.analytic_seconds("pallas", f, TINY_CHIP) > 100 * scores["scan"]
        # sharded needs a mesh
        assert cost_model.analytic_seconds("sharded", f, TINY_CHIP) == float("inf")
        assert "sharded" not in cost_model.candidates(f, ctx)

    def test_dynamic_stage_excludes_traced_strategies(self):
        """Dynamic-shape chains cannot be traced: only the raw-per-chunk
        driver (pipelined) and the whole-value baseline (eager) may run."""
        ctx = mozart.MozartContext(chip=TINY_CHIP)
        f = _feats(dynamic=True)
        assert set(cost_model.candidates(f, ctx)) == {"pipelined", "eager"}
        assert cost_model.choose(f, ctx) in ("pipelined", "eager")

    def test_same_pipeline_same_timings_same_per_stage_choice(self, tmp_path):
        """End-to-end determinism: measured timings persisted and replayed
        (with the pinned choice stripped) reproduce the identical pick."""
        x = jnp.linspace(0.0, 1.0, 60_000, dtype=jnp.float32)
        self._run(x)                          # miss
        self._run(x)                          # measurement pass
        (entry,) = plan_cache.entries()
        (sid,) = entry.chosen_exec
        first_pick = entry.chosen_exec[sid]
        assert entry.exec_timings[sid], "no timings recorded"

        path = str(tmp_path / "plans.json")
        plan_cache.save(path)
        for _ in range(3):
            plan_cache.clear()
            plan_cache.load(path)
            (e2,) = plan_cache.entries()
            del e2.chosen_exec[sid]           # force a re-choice from timings
            # autotune=False: no fresh measurement may perturb the inputs
            _, ctx = self._run(x, autotune=False)
            assert ctx.stats[f"auto_pick_{first_pick}"] == 1
            assert e2.chosen_exec == {}       # nothing pinned without tuning

    def test_poisoned_cost_entry_overridden_by_fresh_measurement(self):
        x = jnp.linspace(0.0, 1.0, 60_000, dtype=jnp.float32)
        v0, _ = self._run(x)                  # miss: entry exists, unmeasured
        (entry,) = plan_cache.entries()
        sid = 0                               # single-stage pipeline
        # poison: claim `eager` finishes in a femtosecond
        entry.exec_timings[sid] = {"eager": 1e-15}
        v1, ctx = self._run(x)                # first hit: measurement pass
        assert ctx.stats["auto_measured_stages"] == 1
        # the lie was overwritten by a real measurement...
        assert entry.exec_timings[sid]["eager"] > 1e-9
        # ...and the pin agrees with the fresh numbers, not the poison
        assert entry.chosen_exec[sid] == min(
            sorted(entry.exec_timings[sid]), key=entry.exec_timings[sid].get)
        assert np.isclose(v0, v1, rtol=1e-5)

    def test_auto_measures_then_replays_pinned(self):
        x = jnp.linspace(0.0, 1.0, 60_000, dtype=jnp.float32)
        _, c1 = self._run(x)
        assert c1.stats["auto_stages"] == 1
        assert c1.stats["auto_measured_stages"] == 0
        _, c2 = self._run(x)
        assert c2.stats["auto_measured_stages"] == 1
        _, c3 = self._run(x)
        assert c3.stats["auto_measured_stages"] == 0
        assert c3.stats["auto_pinned_replays"] == 1
        (entry,) = plan_cache.entries()
        assert entry.chosen_exec and entry.exec_timings

    def test_auto_respects_explicit_batch_elements(self):
        x = jnp.linspace(0.0, 1.0, 10_000, dtype=jnp.float32)
        want = float(np.sum(np.exp(np.linspace(0.0, 1.0, 10_000,
                                               dtype=np.float32)) * 0.5))
        for _ in range(3):
            v, ctx = self._run(x, batch_elements=1024)
        assert np.isclose(v, want, rtol=1e-5)
        assert not plan_cache.tuned_batches()   # explicit batch: no tuning


# ---------------------------------------------------------------------------
# Future inspection
# ---------------------------------------------------------------------------


def test_future_exposes_split_type():
    x = jnp.arange(8.0)
    with mozart.session(executor="fused"):
        f = saxpy(x, x)
        assert f.split_type.name == "ArraySplit"
        _ = f.value


# ---------------------------------------------------------------------------
# Pallas block-shape-aware tuning (ROADMAP satellite)
# ---------------------------------------------------------------------------


class TestPallasBlockShapeTuning:
    def test_candidates_round_to_hardware_blocks(self):
        """Raw element-count candidates resolving to the SAME 8x128 block are
        duplicates — the tuner must measure each compiled block shape once."""
        from repro.core.stage_exec import get_executor
        from repro.kernels.split_pipeline import MIN_BLOCK
        ex = get_executor("pallas")
        ctx = mozart.MozartContext(executor="pallas")
        n = 1 << 16
        # est=700 -> raw bracket {350, 700, 1400} all round to 1024/2048
        cands = ex.tuning_candidates(None, {}, ctx, 700, n)
        assert cands == sorted(set(cands))
        assert all(c == n or c % MIN_BLOCK == 0 for c in cands)
        assert len(cands) <= 2
        # huge estimate clamps to n; empty split degenerates to [1]
        assert ex.tuning_candidates(None, {}, ctx, 10 * n, n) == [n]
        assert ex.tuning_candidates(None, {}, ctx, 512, 0) == [1]

    def test_chosen_block_shape_recorded_in_plan_entry(self):
        x = jnp.linspace(0.0, 1.0, 6000, dtype=jnp.float32)

        def run():
            with mozart.session(executor="pallas", chip=hardware.CPU_HOST) as c:
                out = float(anp.sum(anp.multiply(anp.exp(x), 0.5)))
            return out, c

        plan_cache.clear()
        run(); run(); _, ctx = run()
        (entry,) = plan_cache.entries()
        assert entry.block_shape, "pallas recorded no block shape"
        from repro.kernels.split_pipeline import MIN_BLOCK
        for sid, (sub, block) in entry.block_shape.items():
            assert sub == 1 and block % MIN_BLOCK == 0
            # the recorded shape is what the pinned batch compiles to
            if sid in entry.tuned_batch:
                from repro.core.pallas_exec import _effective_block
                assert block == _effective_block(entry.tuned_batch[sid], 6000)

    def test_block_shape_persists(self, tmp_path):
        x = jnp.linspace(0.0, 1.0, 6000, dtype=jnp.float32)
        plan_cache.clear()
        for _ in range(2):
            with mozart.session(executor="pallas", chip=hardware.CPU_HOST):
                float(anp.sum(anp.multiply(anp.exp(x), 0.5)))
        (entry,) = plan_cache.entries()
        want = dict(entry.block_shape)
        assert want
        path = str(tmp_path / "plans.json")
        plan_cache.save(path)
        plan_cache.clear()
        assert plan_cache.load(path) == 1
        (loaded,) = plan_cache.entries()
        assert dict(loaded.block_shape) == want
