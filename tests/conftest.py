"""Shared fixtures.  NOTE: XLA_FLAGS / device-count overrides are deliberately
NOT set here — smoke tests and benchmarks must see the real (1-device) CPU.
Multi-device tests spawn subprocesses with their own XLA_FLAGS."""

import warnings

import numpy as np
import pytest

warnings.filterwarnings("ignore", category=DeprecationWarning)


@pytest.fixture
def rng():
    return np.random.RandomState(0)
