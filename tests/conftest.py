"""Shared fixtures.  NOTE: XLA_FLAGS / device-count overrides are deliberately
NOT set here — smoke tests and benchmarks must see the real (1-device) CPU.
Multi-device tests spawn subprocesses with their own XLA_FLAGS."""

import warnings

import numpy as np
import pytest

warnings.filterwarnings("ignore", category=DeprecationWarning)


@pytest.fixture
def rng():
    return np.random.RandomState(0)


@pytest.fixture(autouse=True)
def _fresh_plan_cache():
    """Each test starts with an empty plan cache so cache hits / tuner runs
    never leak between tests (chunk-count assertions stay exact)."""
    from repro.core import plan_cache

    plan_cache.clear()
    yield
