"""Tests for the Pandas / ImageMagick analogue integrations (paper §7)."""

import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, hst, settings  # hypothesis-optional

from repro.core import mozart
from repro.core import annotated_numpy as anp
from repro.core import annotated_table as tb
from repro.core import annotated_image as img


def make_table(n=100, seed=0):
    r = np.random.RandomState(seed)
    return tb.Table({
        "city": r.randint(0, 7, n).astype(np.int64),
        "pop": r.randint(1, 1000, n).astype(np.float64),
        "crime": r.rand(n).astype(np.float64) * 10,
    })


class TestTable:
    @pytest.mark.parametrize("executor", ["eager", "pipelined"])
    def test_col_then_vector_math_pipelines(self, executor):
        t = make_table()
        with mozart.session(executor=executor, batch_elements=17) as ctx:
            pop = tb.col(t, "pop")
            crime = tb.col(t, "crime")
            idx = anp.divide(anp.multiply(crime, 100.0), pop)
            s = anp.sum(idx)
            stages = ctx.last_plan()
            assert len(stages) == 1                  # all in one stage
            got = float(s)
        want = float((t.cols["crime"] * 100 / t.cols["pop"]).sum())
        assert np.isclose(got, want, rtol=1e-6)

    def test_filter_pipeline(self):
        t = make_table()
        with mozart.session(executor="pipelined", batch_elements=13) as ctx:
            mask = anp.greater(tb.col(t, "pop"), 500.0)
            kept = tb.filter_rows(t, mask)
            stages = ctx.last_plan()
            assert len(stages) == 1
            out = kept.value
        m = t.cols["pop"] > 500
        assert out.nrows == int(m.sum())
        np.testing.assert_allclose(np.asarray(out.cols["crime"]), t.cols["crime"][m])

    @pytest.mark.parametrize("op", ["sum", "count", "mean", "max", "min"])
    def test_groupby_partials_reaggregate(self, op):
        t = make_table(n=173)
        with mozart.session(executor="pipelined", batch_elements=10) as ctx:
            g = tb.groupby_agg(t, key="city", val="pop", op=op)
            res = g.value
            assert ctx.stats["chunks"] > 10          # really chunked
        if op == "mean":
            res = tb.finalize_mean(res, "city")
        keys = np.asarray(res.cols["city"])
        vals = np.asarray(res.cols[op])
        for k, v in zip(keys, vals):
            rows = t.cols["pop"][t.cols["city"] == k]
            want = dict(sum=rows.sum(), count=len(rows), mean=rows.mean(),
                        max=rows.max(), min=rows.min())[op]
            assert np.isclose(v, want), (op, k, v, want)

    def test_join_splits_left_broadcasts_right(self):
        left = make_table(n=64)
        right = tb.Table({
            "city": np.arange(7, dtype=np.int64),
            "name_len": np.arange(7, dtype=np.float64) + 3,
        })
        with mozart.session(executor="pipelined", batch_elements=9) as ctx:
            j = tb.join_inner(left, right, on="city")
            out = j.value
        assert out.nrows == left.nrows               # every key matches
        np.testing.assert_allclose(
            np.asarray(out.cols["name_len"]),
            left.cols["city"].astype(np.float64) + 3)

    @given(n=hst.integers(2, 300), batch=hst.integers(1, 64), seed=hst.integers(0, 5))
    @settings(max_examples=10, deadline=None)
    def test_groupby_chunking_invariant(self, n, batch, seed):
        """Property: partial aggregation + re-aggregation == one-shot."""
        t = make_table(n=n, seed=seed)
        with mozart.session(executor="pipelined", batch_elements=batch):
            g = tb.groupby_agg(t, key="city", val="crime", op="sum").value
        whole = tb._group_reduce(t, "city", "crime", "sum")
        np.testing.assert_allclose(
            np.asarray(g.cols["sum"]), np.asarray(whole.cols["sum"]), rtol=1e-9)


class TestImage:
    def _image(self, h=32, w=16, seed=0):
        return jnp.asarray(np.random.RandomState(seed).rand(h, w, 3), jnp.float32)

    def test_hsv_roundtrip(self):
        im = self._image()
        rt = img._hsv_to_rgb(img._rgb_to_hsv(im))
        np.testing.assert_allclose(np.asarray(rt), np.asarray(im), atol=1e-5)

    @pytest.mark.parametrize("executor", ["eager", "pipelined", "fused", "scan"])
    def test_filter_pipeline_matches_eager(self, executor):
        im = self._image(h=40)
        def pipeline():
            a = img.colortone(im, (0.2, 0.2, 0.6), 0.3, True)
            b = img.gamma(a, 1.2)
            c = img.modulate(b, 110.0, 140.0, 100.0)
            d = img.contrast(c, 1.1)
            return d
        with mozart.session(executor="eager") as ctx:
            want = np.asarray(pipeline())
        with mozart.session(executor=executor, batch_elements=7) as ctx:
            got_f = pipeline()
            stages = ctx.last_plan()
            assert len(stages) == 1                  # whole filter = 1 stage
            got = np.asarray(got_f)
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_histogram_reduction(self):
        im = self._image(h=64)
        with mozart.session(executor="pipelined", batch_elements=5):
            h = img.brightness_histogram(im).value
        assert int(np.asarray(h).sum()) == 64 * 16

    def test_blur_not_annotated(self):
        from repro.core.annotation import AnnotatedFn
        assert not isinstance(img.blur, AnnotatedFn)
        im = self._image()
        out = img.blur(im, radius=1)
        assert out.shape == im.shape


class TestNLP:
    """spaCy-analogue integration (paper §7: minibatch split + pipeline)."""

    def test_speech_tag_pipeline(self):
        from repro.core import annotated_nlp as nlp
        import jax
        corpus = nlp.make_corpus(50, max_len=32, vocab=200, seed=0)
        emb = jax.random.normal(jax.random.PRNGKey(0), (200, 16))
        head = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
        with mozart.session(executor="eager") as ctx:
            want_tags = np.asarray(nlp.pos_tag(
                nlp.normalize_case(corpus, 200), emb, head))
            want_count = int(nlp.token_counts(corpus).value)
        with mozart.session(executor="pipelined", batch_elements=7) as ctx:
            normalized = nlp.normalize_case(corpus, 200)
            tags = nlp.pos_tag(normalized, emb, head)
            count = nlp.token_counts(corpus)
            stages = ctx.last_plan()
            # normalize -> tag pipelines (same CorpusSplit)
            names = [[n.fn.name for n in s.nodes] for s in stages]
            assert any("normalize_case" in st_ and "pos_tag" in st_
                       for st_ in names), names
            got_tags = np.asarray(tags)
            got_count = int(count)
        np.testing.assert_array_equal(got_tags, want_tags)
        assert got_count == want_count
        assert ctx.stats["chunks"] > 2

    def test_corpus_split_roundtrip(self):
        from repro.core import annotated_nlp as nlp
        c = nlp.make_corpus(17, max_len=8, vocab=50)
        t = nlp.CorpusSplit(17)
        pieces = [t.split(c, s, min(s + 5, 17)) for s in range(0, 17, 5)]
        merged = t.merge(pieces)
        np.testing.assert_array_equal(np.asarray(merged.tokens),
                                      np.asarray(c.tokens))
