"""Verifier tests: the law matrix, seeded mutants, dataflow analysis, and
the MOZART_SANITIZE boundary checks.

The MZ1xx property suite is NOT hand-written per law: it parameterizes over
``analysis.CONTRACT_LAWS`` x ``analysis.builtin_probes()`` — the exact list
the linter sweeps — so adding a law (or a probe) to analysis.py grows this
suite automatically.  The mutant tests then prove each law has teeth by
feeding it a deliberately broken SplitType and pinning the MZ code it must
emit."""

import json
from types import SimpleNamespace

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analysis
from repro.core import annotated_numpy as anp
from repro.core import annotated_table as tbl
from repro.core import mozart, plan_cache, stage_exec
from repro.core import split_types as st
from repro.core.annotation import annotate
from repro.core.graph import NodeRef

PROBES = analysis.builtin_probes()


def _error_codes(diags):
    return {d.code for d in diags if d.severity == "error"}


# ---------------------------------------------------------------------------
# The law matrix: every contract law against every shipped probe.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("law", analysis.CONTRACT_LAWS, ids=lambda l: l.name)
@pytest.mark.parametrize("probe", PROBES, ids=lambda p: p.name)
def test_contract_law_holds(law, probe):
    diags = [d for d in analysis.check_split_type(probe, laws=[law])
             if d.severity == "error"]
    assert not diags, "\n".join(str(d) for d in diags)


def test_laws_cover_every_contract_code():
    """Each MZ1xx code is either a law or checked by a dedicated sweep
    (MZ108 = check_annotated_fn, MZ110 = the config-registry sweep)."""
    law_codes = {law.code for law in analysis.CONTRACT_LAWS}
    contract = {c for c in analysis.CODES if c.startswith("MZ1")}
    assert contract - law_codes == {"MZ108", "MZ110"}


def test_builtin_sweep_has_zero_errors():
    rep = analysis.check_split_types(probes=PROBES)
    assert rep.ok, "\n".join(str(d) for d in rep.errors)
    assert rep.checked == len(PROBES)


def test_annotated_ops_sweep_has_zero_errors():
    rep = analysis.check_annotated_ops(n=10)
    assert rep.ok, "\n".join(str(d) for d in rep.errors)
    assert rep.checked > 40            # every integration contributes ops


# ---------------------------------------------------------------------------
# Seeded mutants: each broken SplitType must trip its law's MZ code.
# ---------------------------------------------------------------------------

_N = 8
_M = jnp.arange(_N * 3, dtype=jnp.float32).reshape(_N, 3) / (_N * 3)


def _array_probe(split_type, **kw):
    return analysis.Probe("mutant", split_type, value=_M,
                          extent_of=lambda v: int(v.shape[0]), **kw)


class _WrongAxisMerge(st.ArraySplit):
    """Splits rows apart but glues them back as columns."""

    def merge(self, chunks):
        return jnp.concatenate([jnp.asarray(c) for c in chunks], axis=1)


class _LossyRechunk(st.ArraySplit):
    """Re-grids correctly, then drops the first row of every chunk."""

    def rechunk(self, chunks, src, dst):
        new, copied = super().rechunk(chunks, src, dst)
        return [c[1:] for c in new], copied


class _OverconfidentHandoff(st.ArraySplit):
    """Grants handoff to any consumer grid, compatible or not."""

    def can_handoff(self, consumer):
        return True


class _LyingReduce(st.ReduceSplit):
    """Claims the declared combiner but always folds with addition."""

    def merge(self, chunks):
        out = jnp.asarray(chunks[0])
        for c in chunks[1:]:
            out = out + jnp.asarray(c)
        return out


class _SilentEmptyMerge(st.ArraySplit):
    def merge(self, chunks):
        if not chunks:
            return jnp.zeros((0, 3), jnp.float32)
        return super().merge(chunks)


def test_mutant_wrong_merge_axis_trips_mz101():
    probe = _array_probe(_WrongAxisMerge((_N, 3), 0))
    assert "MZ101" in _error_codes(analysis.check_split_type(probe))


def test_mutant_lossy_rechunk_trips_mz106():
    probe = _array_probe(_LossyRechunk((_N, 3), 0))
    assert "MZ106" in _error_codes(analysis.check_split_type(probe))


def test_mutant_false_can_handoff_trips_mz105():
    probe = _array_probe(
        _OverconfidentHandoff((_N, 3), 0),
        consumers=(st.ArraySplit((_N, 3), 1),))
    assert "MZ105" in _error_codes(analysis.check_split_type(probe))


def test_mutant_wrong_reduce_combiner_trips_mz104():
    pieces = [jnp.asarray([1.0, 5.0]), jnp.asarray([4.0, 2.0])]
    probe = analysis.Probe("mutant", _LyingReduce("max"), pieces=pieces)
    assert "MZ104" in _error_codes(analysis.check_split_type(probe))


def test_mutant_silent_empty_merge_trips_mz109():
    probe = _array_probe(_SilentEmptyMerge((_N, 3), 0))
    diags = analysis.check_split_type(probe)
    assert any(d.code == "MZ109" and d.severity == "warning" for d in diags)


def test_sa_condition_catches_unchunkable_function():
    """cumsum annotated Along(0) is a lie: each chunk's prefix sums ignore
    the rows before it, so F(a) != merge(F(a1..ak)) -> MZ108."""
    bad = annotate(lambda x: jnp.cumsum(x), name="bad_cumsum",
                   x=st.Along(0), ret=st.Along(0))
    diags = analysis.check_annotated_fn(bad, {"x": jnp.arange(12.0)})
    assert "MZ108" in _error_codes(diags)


def test_sa_condition_accepts_chunkable_function():
    good = annotate(lambda x: jnp.exp(x), name="good_exp",
                    x=st.Along(0), ret=st.Along(0))
    assert analysis.check_annotated_fn(good, {"x": jnp.arange(12.0)}) == []


# ---------------------------------------------------------------------------
# Regression: GroupSplit key/val must not shadow SplitType.key() (MZ107).
# ---------------------------------------------------------------------------


def test_group_split_params_do_not_shadow_identity():
    a = tbl.GroupSplit("sum", "k", "v")
    b = tbl.GroupSplit("sum", "k", "v")
    assert a == b and len({a, b}) == 1
    assert callable(a.key)             # still the identity method, not a str
    probe = analysis.Probe("GroupSplit/sum", a)
    assert analysis._law_params_round_trip(probe) == []


# ---------------------------------------------------------------------------
# Dataflow analyzer (MZ2xx)
# ---------------------------------------------------------------------------


def test_dataflow_dead_stage_and_axis_mismatch():
    m = jnp.arange(48.0, dtype=jnp.float32).reshape(8, 6) / 48.0
    v = jnp.linspace(0.1, 1.0, 6, dtype=jnp.float32)

    def crafted(m, v):
        a = anp.exp(m)
        anp.log1p(a)                   # result dropped on the floor: dead
        nm = anp.normalize_axis(a, axis=0)     # output split on axis 1
        return anp.matvec(nm, v)               # consumer splits on axis 0

    rep = analysis.verify_pipeline(crafted, m, v,
                                   executor="eager", pipeline=False)
    assert "MZ201" in rep.codes()
    mismatches = [d for d in rep.diagnostics
                  if d.code == "MZ203" and d.severity == "warning"]
    assert any("axis mismatch" in d.message for d in mismatches)


def test_dataflow_scalar_only_stage_is_whole_value():
    rep = analysis.verify_pipeline(
        lambda: anp.add(jnp.float32(1.0), jnp.float32(2.0)),
        executor="eager", pipeline=False)
    assert "MZ204" in rep.codes()


def test_dataflow_clean_chain_has_no_errors():
    x = jnp.linspace(0.1, 0.9, 16, dtype=jnp.float32)

    def chain(x):
        return anp.sum(anp.multiply(anp.exp(x), 0.5))

    rep = analysis.verify_pipeline(chain, x, executor="fused")
    assert rep.ok, "\n".join(str(d) for d in rep.errors)


def test_verify_dispatcher():
    x = jnp.linspace(0.1, 0.9, 16, dtype=jnp.float32)
    rep = mozart.verify(lambda x: anp.sum(anp.exp(x)), x, executor="fused")
    assert isinstance(rep, analysis.Report) and rep.ok
    with pytest.raises(TypeError):
        analysis.verify(42)


# ---------------------------------------------------------------------------
# Plan-cache guard audit (MZ205)
# ---------------------------------------------------------------------------


def test_plan_cache_unreplayable_live_entry(monkeypatch):
    from repro.core import plan_cache as pc

    key = ("ghost-executor", "ghost-chip", "p", "m", "h")
    with pc._lock:
        pc._entries[key] = SimpleNamespace()
    try:
        rep = analysis.check_plan_cache()
    finally:
        with pc._lock:
            pc._entries.pop(key, None)
    assert any(d.code == "MZ205" and d.severity == "error"
               and "ghost-executor" in d.subject for d in rep.diagnostics)
    assert any(d.code == "MZ205" and d.severity == "warning"
               and "chip guard" in d.message for d in rep.diagnostics)


def test_plan_cache_persisted_file_audit(tmp_path):
    stale = tmp_path / "stale.json"
    stale.write_text(json.dumps({"schema": 1, "chip": "x", "entries": []}))
    rep = analysis.check_plan_cache(str(stale))
    assert any(d.code == "MZ205" and d.severity == "error"
               and "schema" in d.message for d in rep.diagnostics)

    broken = tmp_path / "broken.json"
    broken.write_text('{"schema": 5, "entr')
    rep = analysis.check_plan_cache(str(broken))
    assert any(d.code == "MZ205" and "unreadable" in d.message
               for d in rep.diagnostics)


# ---------------------------------------------------------------------------
# Boundary sanitizer (MZ3xx, MOZART_SANITIZE=1)
# ---------------------------------------------------------------------------


def _stream(n=6):
    t = st.ArraySplit((n, 2), 0)
    x = jnp.arange(n * 2, dtype=jnp.float32).reshape(n, 2)
    h = n // 2
    chunks = [t.split(x, 0, h), t.split(x, h, n)]
    return stage_exec.ChunkStream(chunks, [(0, h), (h, n)], t, st.aval_of(x)), x


def _fake_donation(stream, orig):
    """Minimal stage/ctx shims: just the surface mark_stream_consumed uses."""
    node = SimpleNamespace(result=orig)
    ctx = SimpleNamespace(graph=SimpleNamespace(nodes={11: node}))
    si = SimpleNamespace(value=NodeRef(11), split_type=stream.split_type)
    stage = SimpleNamespace(id=7, inputs={("x", 0): si}, ckey=lambda k: k)
    stage_exec.mark_stream_consumed(stage, {("x", 0): stream}, ctx,
                                    {("x", 0)})


def test_use_after_donate_raises_mz301(monkeypatch):
    monkeypatch.setenv("MOZART_SANITIZE", "1")
    s, _ = _stream()
    orig, _ = _stream()
    _fake_donation(s, orig)
    assert s.consumed and s.donor == "stage 7 input ('x', 0)"
    with pytest.raises(stage_exec.SanitizerError, match=r"MZ301") as ei:
        list(s._chunks)
    assert "stage 7 input ('x', 0)" in str(ei.value)
    with pytest.raises(stage_exec.SanitizerError, match=r"MZ301"):
        orig._chunks[0]                # the graph-node alias is poisoned too
    with pytest.raises(RuntimeError, match=r"MZ301") as ei:
        s.materialize()
    assert "stage 7 input ('x', 0)" in str(ei.value)


def test_donation_not_poisoned_when_sanitize_off(monkeypatch):
    monkeypatch.delenv("MOZART_SANITIZE", raising=False)
    s, _ = _stream()
    orig, _ = _stream()
    _fake_donation(s, orig)
    assert s.consumed                  # backstop flag always set...
    assert len(s._chunks) == 2         # ...but the buffers stay readable
    with pytest.raises(RuntimeError, match=r"MZ301"):
        s.materialize()                # the pinned backstop still fires


def test_stream_tiling_violations_raise_mz302():
    t = st.ArraySplit((6, 2), 0)
    s, x = _stream(6)
    stage_exec._check_stream_tiles(s, t, "edge")       # clean: no raise

    hole = stage_exec.ChunkStream(list(s._chunks), [(0, 2), (3, 6)], t,
                                  st.aval_of(x))
    with pytest.raises(stage_exec.SanitizerError, match=r"MZ302") as ei:
        stage_exec._check_stream_tiles(hole, t, "stage 1 input ('x', 0)")
    assert "do not tile" in str(ei.value)
    assert "stage 1 input ('x', 0)" in str(ei.value)

    with pytest.raises(stage_exec.SanitizerError, match=r"MZ302") as ei:
        stage_exec._check_stream_tiles(s, st.ArraySplit((8, 2), 0), "edge")
    assert "stream extent" in str(ei.value)


def test_corrupt_scoped_counters_raise_mz303(monkeypatch):
    monkeypatch.setenv("MOZART_SANITIZE", "1")
    c = stage_exec.BoundaryCounters()
    with pytest.raises(stage_exec.SanitizerError, match=r"MZ303"):
        with stage_exec.counter_scope(c):
            c.interior += 4096         # scoped bump with no global event

    # Honest attribution passes the cross-check.
    c2 = stage_exec.BoundaryCounters()
    with stage_exec.counter_scope(c2):
        stage_exec.note_materialized(128)
    assert c2.interior == 128

    # An exception inside the scope propagates untouched — the MZ303 check
    # must never shadow the real failure.
    c3 = stage_exec.BoundaryCounters()
    with pytest.raises(ValueError, match="boom"):
        with stage_exec.counter_scope(c3):
            c3.interior += 1
            raise ValueError("boom")


def test_sanitized_handoff_chain_runs_clean(monkeypatch):
    """End-to-end: a real donating handoff chain under MOZART_SANITIZE=1
    completes with full parity and zero sanitizer trips."""
    monkeypatch.setenv("MOZART_SANITIZE", "1")
    n = 4096
    x = jnp.linspace(0.1, 2.0, n, dtype=jnp.float32)
    plan_cache.clear()
    with mozart.session(executor="fused", handoff=True):
        a = anp.exp(x)
        mozart.evaluate()              # stage boundary: streamed + donated
        b = anp.add(a, 1.0)
        out = float(np.asarray(anp.sum(b)))
    want = float((np.exp(np.asarray(x)) + 1.0).sum())
    assert np.isclose(out, want, rtol=1e-4)
