"""Model substrate tests: family coverage, decode parity, invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm
from repro.models import transformer as tfm
from repro.models.config import (
    AttnConfig, ModelConfig, MoEConfig, RWKVConfig, SSMConfig, param_count,
    active_param_count,
)

F32 = jnp.float32


def tiny(name="t", family="dense", **kw):
    base = dict(
        name=name, family=family, n_layers=2, d_model=64, d_ff=128,
        vocab_size=97, dtype=F32,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16),
    )
    base.update(kw)
    return ModelConfig(**base)


CONFIGS = {
    "dense": tiny(),
    "local": tiny(attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                                  window=6, pattern_period=2), n_layers=4),
    # capacity_factor=8 -> no token drops, so decode parity is exact; drops
    # are exercised separately in test_moe_aux_loss_positive_and_capacity_drops
    "moe": tiny(family="moe", moe=MoEConfig(n_experts=4, top_k=2, d_expert=32,
                                            n_shared=1, first_k_dense=1,
                                            capacity_factor=8.0),
                n_layers=3,
                attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=16)),
    "hybrid": tiny(family="hybrid", ssm=SSMConfig(state_dim=4),
                   attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16, window=6),
                   subquadratic=True),
    "rwkv": tiny(family="ssm", attn=None, rwkv=RWKVConfig(head_dim=16),
                 d_ff=224, subquadratic=True),
    "qk_norm": tiny(attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                                    qk_norm=True, logit_softcap=30.0)),
}


@pytest.mark.parametrize("kind", list(CONFIGS))
def test_decode_matches_teacher_forcing(kind):
    """prefill+decode logits == train-mode logits, token by token."""
    cfg = CONFIGS[kind]
    key = jax.random.PRNGKey(0)
    params = tfm.init_model(key, cfg)
    tokens = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)

    # teacher-forced logits at every position
    full_logits, _ = tfm.forward_train(params, cfg, tokens=tokens)

    # prefill on the first 6 tokens, decode the rest one by one
    caches = tfm.init_caches(cfg, 2, 16)
    pf_logits, caches = tfm.prefill(params, cfg, tokens=tokens[:, :6],
                                    caches=caches)
    np.testing.assert_allclose(np.asarray(pf_logits[:, 0]),
                               np.asarray(full_logits[:, 5]),
                               rtol=2e-3, atol=2e-3)
    for t in range(6, 12):
        logits, caches = tfm.decode_step(params, cfg, tokens[:, t:t + 1], caches)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, t]),
            rtol=2e-3, atol=2e-3, err_msg=f"{kind} step {t}")


def test_sliding_window_limits_context():
    """With window w, logits at position t must not depend on tokens < t-w."""
    cfg = tiny(attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16, window=4))
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab_size)
    t2 = t1.at[:, 0].set((t1[:, 0] + 1) % cfg.vocab_size)   # differ at pos 0
    l1, _ = tfm.forward_train(params, cfg, tokens=t1)
    l2, _ = tfm.forward_train(params, cfg, tokens=t2)
    # position 11 attends keys > 11-4=7 in every layer; with 2 layers the
    # receptive field reaches back 2*(w-1)=6 positions, still > 0: pos 0 is out
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                               rtol=1e-4, atol=1e-5)
    assert not np.allclose(np.asarray(l1[:, 1]), np.asarray(l2[:, 1]))


def test_causality():
    cfg = CONFIGS["dense"]
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, 10), 0, cfg.vocab_size)
    t2 = t1.at[:, -1].set((t1[:, -1] + 1) % cfg.vocab_size)
    l1, _ = tfm.forward_train(params, cfg, tokens=t1)
    l2, _ = tfm.forward_train(params, cfg, tokens=t2)
    np.testing.assert_allclose(np.asarray(l1[:, :-1]), np.asarray(l2[:, :-1]),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("kind", ["dense", "moe", "hybrid", "rwkv"])
def test_grads_finite(kind):
    cfg = CONFIGS[kind]
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, cfg.vocab_size)
    loss, grads = jax.value_and_grad(lm.loss_fn)(params, {"tokens": tokens}, cfg)
    assert np.isfinite(float(loss))
    for leaf in jax.tree_util.tree_leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))


def test_blocked_attention_matches_dense():
    cfg = tiny(dense_attn_threshold=4, attn_block_k=5)   # force blocked path
    cfg2 = tiny()
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 13), 0, cfg.vocab_size)
    l1, _ = tfm.forward_train(params, cfg, tokens=tokens)
    l2, _ = tfm.forward_train(params, cfg2, tokens=tokens)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-4, atol=2e-4)


def test_moe_aux_loss_positive_and_capacity_drops():
    cfg = CONFIGS["moe"]
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, cfg.vocab_size)
    logits, aux = tfm.forward_train(params, cfg, tokens=tokens)
    assert float(aux) > 0.0
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


def test_param_count_sane():
    cfg = CONFIGS["dense"]
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
    approx = param_count(cfg)
    assert abs(actual - approx) / actual < 0.15, (actual, approx)
    assert active_param_count(CONFIGS["moe"]) < param_count(CONFIGS["moe"])


def test_banded_attention_matches_dense_windowed():
    """H-1 path: O(S·w) banded attention is exact for sliding windows."""
    import numpy as np
    from repro.models.attention import _banded_attention, _dense_attention
    r = np.random.RandomState(0)
    for (B, Hq, Hkv, S, D, w) in [(2, 4, 2, 96, 16, 16), (1, 2, 1, 130, 8, 32)]:
        q = jnp.asarray(r.randn(B, Hq, S, D), jnp.float32)
        k = jnp.asarray(r.randn(B, Hkv, S, D), jnp.float32)
        v = jnp.asarray(r.randn(B, Hkv, S, D), jnp.float32)
        got = _banded_attention(q, k, v, window=w)
        want = _dense_attention(q, k, v, causal=True, window=w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_banded_config_path_matches_full_model():
    """banded_attention=True produces the same logits as the default path."""
    cfg = tiny(attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16, window=8),
               n_layers=2)
    cfg_banded = cfg.with_runtime(banded_attention=True)
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 40), 0, cfg.vocab_size)
    l1, _ = tfm.forward_train(params, cfg, tokens=tokens)
    l2, _ = tfm.forward_train(params, cfg_banded, tokens=tokens)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-4, atol=2e-4)


def test_nested_layer_scan_matches_flat():
    """√L-nested layer scan (M-5) is numerically identical to flat scan."""
    cfg = tiny(n_layers=8)
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    flat = cfg.with_runtime(layer_scan_inner=1)
    nested = cfg.with_runtime(layer_scan_inner=4)
    l1, _ = tfm.forward_train(params, flat, tokens=tokens)
    l2, _ = tfm.forward_train(params, nested, tokens=tokens)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5, atol=1e-5)
