"""Unit tests for split-type *identity* and *unification* (paper §3).

The algebraic laws themselves (split/merge round trip, merge associativity,
reduce combiners, rechunk bounds, degenerate merges, ...) are NOT tested
here: tests/test_analysis.py parameterizes them over
``analysis.CONTRACT_LAWS`` x ``analysis.builtin_probes()`` — the same
single-source-of-truth matrix the lint gate sweeps — so each law is stated
exactly once, in src/repro/core/analysis.py."""

import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, hst, settings  # hypothesis-optional

from repro.core import split_types as st


class TestIdentity:
    def test_equality_is_name_plus_params(self):
        assert st.ArraySplit((10,), 0) == st.ArraySplit((10,), 0)
        assert st.ArraySplit((10,), 0) != st.ArraySplit((20,), 0)
        assert st.ArraySplit((4, 6), 0) != st.ArraySplit((4, 6), 1)
        assert st.ReduceSplit("add") == st.ReduceSplit("add")
        assert st.ReduceSplit("add") != st.ReduceSplit("max")

    def test_unknown_is_unique(self):
        a, b = st.UnknownSplit(), st.UnknownSplit()
        assert a != b and a == a

    def test_broadcast_all_equal(self):
        assert st.ScalarSplit() == st.BROADCAST

    def test_hashable(self):
        assert len({st.ArraySplit((3,), 0), st.ArraySplit((3,), 0)}) == 1


class TestConcatSplit:
    def test_identity_is_tag_plus_axis(self):
        assert st.ConcatSplit("a", 0) == st.ConcatSplit("a", 0)
        assert st.ConcatSplit("a", 0) != st.ConcatSplit("b", 0)
        assert st.ConcatSplit("a", 0) != st.ConcatSplit("a", 1)

    def test_not_splittable(self):
        t = st.ConcatSplit()
        assert not t.splittable
        assert t.info(jnp.arange(4.0)) is None
        with pytest.raises(TypeError):
            t.split(jnp.arange(4.0), 0, 2)

    def test_merges_pytrees_leafwise(self):
        t = st.ConcatSplit(axis=0)
        pieces = [{"a": jnp.arange(2.0)}, {"a": jnp.arange(2.0) + 2}]
        out = t.merge(pieces)
        np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(4.0))

    def test_spec_constructs_type(self):
        spec = st.Concat("enc", axis=1)
        t = spec.construct(None, {}, {})
        assert t == st.ConcatSplit("enc", 1)


class TestUnification:
    def test_var_binds_concrete(self):
        env = st.TypeEnv()
        v = st.GenericVar("S")
        env.unify(v, st.ArraySplit((10,), 0))
        assert env.resolve(v) == st.ArraySplit((10,), 0)

    def test_var_var_then_concrete(self):
        env = st.TypeEnv()
        a, b = st.GenericVar("S"), st.GenericVar("T")
        env.unify(a, b)
        env.unify(b, st.ArraySplit((5,), 0))
        assert env.resolve(a) == st.ArraySplit((5,), 0)

    def test_concrete_mismatch_raises(self):
        env = st.TypeEnv()
        with pytest.raises(st.UnificationError):
            env.unify(st.ArraySplit((5,), 0), st.ArraySplit((6,), 0))

    def test_var_binds_unknown_but_unknowns_conflict(self):
        env = st.TypeEnv()
        v = st.GenericVar("S")
        u1, u2 = st.UnknownSplit(), st.UnknownSplit()
        env.unify(v, u1)
        with pytest.raises(st.UnificationError):
            env.unify(v, u2)

    def test_snapshot_restore(self):
        env = st.TypeEnv()
        v = st.GenericVar("S")
        snap = env.snapshot()
        env.unify(v, st.ArraySplit((5,), 0))
        env.restore(snap)
        assert isinstance(env.resolve(v), st.GenericVar)

    @given(hst.lists(hst.integers(0, 4), min_size=2, max_size=8))
    @settings(max_examples=30, deadline=None)
    def test_transitive_unification(self, chain):
        """Property: unifying a chain of vars then binding one end binds all."""
        env = st.TypeEnv()
        vars_ = [st.GenericVar(f"v{i}") for i in range(len(chain))]
        for a, b in zip(vars_, vars_[1:]):
            env.unify(a, b)
        t = st.ArraySplit((7,), 0)
        env.unify(vars_[chain[0] % len(vars_)], t)
        assert all(env.resolve(v) == t for v in vars_)


def test_default_split_type():
    assert st.default_split_type(jnp.zeros((4, 2))) == st.ArraySplit((4, 2), 0)
    assert st.default_split_type(jnp.float32(3.0)) == st.BROADCAST
