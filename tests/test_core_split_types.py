"""Unit + property tests for the split-type algebra (paper §3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, hst, settings  # hypothesis-optional

from repro.core import split_types as st


class TestIdentity:
    def test_equality_is_name_plus_params(self):
        assert st.ArraySplit((10,), 0) == st.ArraySplit((10,), 0)
        assert st.ArraySplit((10,), 0) != st.ArraySplit((20,), 0)
        assert st.ArraySplit((4, 6), 0) != st.ArraySplit((4, 6), 1)
        assert st.ReduceSplit("add") == st.ReduceSplit("add")
        assert st.ReduceSplit("add") != st.ReduceSplit("max")

    def test_unknown_is_unique(self):
        a, b = st.UnknownSplit(), st.UnknownSplit()
        assert a != b and a == a

    def test_broadcast_all_equal(self):
        assert st.ScalarSplit() == st.BROADCAST

    def test_hashable(self):
        assert len({st.ArraySplit((3,), 0), st.ArraySplit((3,), 0)}) == 1


class TestSplitMergeRoundTrip:
    @given(
        n=hst.integers(1, 200),
        batch=hst.integers(1, 64),
        axis=hst.integers(0, 1),
    )
    @settings(max_examples=40, deadline=None)
    def test_array_split_roundtrip(self, n, batch, axis):
        x = jnp.arange(n * 3, dtype=jnp.float32).reshape(n, 3)
        if axis == 1:
            x = x.T
        t = st.ArraySplit(x.shape, axis)
        pieces = [t.split(x, s, min(s + batch, n)) for s in range(0, n, batch)]
        merged = t.merge(pieces)
        np.testing.assert_array_equal(np.asarray(merged), np.asarray(x))

    @given(n=hst.integers(1, 100), batch=hst.integers(1, 32))
    @settings(max_examples=30, deadline=None)
    def test_reduce_merge_associative(self, n, batch):
        x = np.random.RandomState(n).randn(n).astype(np.float32)
        t = st.ArraySplit(x.shape, 0)
        r = st.ReduceSplit("add")
        partials = [
            jnp.sum(t.split(jnp.asarray(x), s, min(s + batch, n)))
            for s in range(0, n, batch)
        ]
        assert np.isclose(float(r.merge(partials)), x.sum(), rtol=1e-4)

    def test_pytree_split(self):
        tree = {"a": jnp.arange(12.0).reshape(6, 2), "b": jnp.arange(6.0)}
        leaves, td = jax.tree_util.tree_flatten(tree)
        t = st.PytreeSplit(str(td), 6, 0)
        pieces = [t.split(tree, s, s + 2) for s in range(0, 6, 2)]
        merged = t.merge(pieces)
        np.testing.assert_array_equal(np.asarray(merged["a"]), np.asarray(tree["a"]))
        np.testing.assert_array_equal(np.asarray(merged["b"]), np.asarray(tree["b"]))

    def test_info(self):
        x = jnp.zeros((8, 4), jnp.float32)
        t = st.ArraySplit((8, 4), 0)
        info = t.info(x)
        assert info.num_elements == 8
        assert info.elem_bytes == 4 * 4


def _chunk(xs, batch):
    return [xs[s:s + batch] for s in range(0, len(xs), batch)]


class TestMergeAssociativity:
    """merge must be associative (paper §3.2): Mozart may merge partials in
    any grouping — pairwise trees, left folds, or all at once."""

    @given(n=hst.integers(2, 120), batch=hst.integers(1, 16),
           cut=hst.integers(1, 119))
    @settings(max_examples=25, deadline=None)
    def test_array_split_grouped_merge(self, n, batch, cut):
        x = jnp.arange(n * 2, dtype=jnp.float32).reshape(n, 2)
        t = st.ArraySplit(x.shape, 0)
        pieces = [t.split(x, s, min(s + batch, n)) for s in range(0, n, batch)]
        cut = 1 + cut % max(len(pieces) - 1, 1) if len(pieces) > 1 else 1
        flat = t.merge(pieces)
        grouped = t.merge([t.merge(pieces[:cut]), t.merge(pieces[cut:])]) \
            if len(pieces) > 1 else flat
        np.testing.assert_array_equal(np.asarray(flat), np.asarray(grouped))
        np.testing.assert_array_equal(np.asarray(flat), np.asarray(x))

    @given(n=hst.integers(2, 200), batch=hst.integers(1, 32),
           op=hst.sampled_from(["add", "max", "min", "mul"]))
    @settings(max_examples=25, deadline=None)
    def test_reduce_split_grouped_merge(self, n, batch, op):
        r = st.ReduceSplit(op)
        vals = np.random.RandomState(n).rand(n).astype(np.float32) + 0.5
        partials = [jnp.asarray(p.sum()) for p in _chunk(vals, batch)]
        flat = float(r.merge(partials))
        if len(partials) > 1:
            for cut in {1, len(partials) // 2, len(partials) - 1}:
                grouped = float(r.merge([r.merge(partials[:cut]),
                                         r.merge(partials[cut:])]))
                rtol = 1e-3 if op == "mul" else 1e-5
                assert np.isclose(flat, grouped, rtol=rtol), (op, cut)

    @given(n=hst.integers(1, 150), batch=hst.integers(1, 24))
    @settings(max_examples=25, deadline=None)
    def test_concat_split_merge_is_concatenation(self, n, batch):
        x = np.arange(n, dtype=np.float32)
        t = st.ConcatSplit("rows", 0)
        pieces = [jnp.asarray(p) for p in _chunk(x, batch)]
        merged = t.merge(pieces)
        np.testing.assert_array_equal(np.asarray(merged), x)
        if len(pieces) > 1:
            grouped = t.merge([t.merge(pieces[:1]), t.merge(pieces[1:])])
            np.testing.assert_array_equal(np.asarray(grouped), x)


class TestConcatSplit:
    def test_identity_is_tag_plus_axis(self):
        assert st.ConcatSplit("a", 0) == st.ConcatSplit("a", 0)
        assert st.ConcatSplit("a", 0) != st.ConcatSplit("b", 0)
        assert st.ConcatSplit("a", 0) != st.ConcatSplit("a", 1)

    def test_not_splittable(self):
        t = st.ConcatSplit()
        assert not t.splittable
        assert t.info(jnp.arange(4.0)) is None
        with pytest.raises(TypeError):
            t.split(jnp.arange(4.0), 0, 2)

    def test_merges_pytrees_leafwise(self):
        t = st.ConcatSplit(axis=0)
        pieces = [{"a": jnp.arange(2.0)}, {"a": jnp.arange(2.0) + 2}]
        out = t.merge(pieces)
        np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(4.0))

    def test_spec_constructs_type(self):
        spec = st.Concat("enc", axis=1)
        t = spec.construct(None, {}, {})
        assert t == st.ConcatSplit("enc", 1)


class TestUnification:
    def test_var_binds_concrete(self):
        env = st.TypeEnv()
        v = st.GenericVar("S")
        env.unify(v, st.ArraySplit((10,), 0))
        assert env.resolve(v) == st.ArraySplit((10,), 0)

    def test_var_var_then_concrete(self):
        env = st.TypeEnv()
        a, b = st.GenericVar("S"), st.GenericVar("T")
        env.unify(a, b)
        env.unify(b, st.ArraySplit((5,), 0))
        assert env.resolve(a) == st.ArraySplit((5,), 0)

    def test_concrete_mismatch_raises(self):
        env = st.TypeEnv()
        with pytest.raises(st.UnificationError):
            env.unify(st.ArraySplit((5,), 0), st.ArraySplit((6,), 0))

    def test_var_binds_unknown_but_unknowns_conflict(self):
        env = st.TypeEnv()
        v = st.GenericVar("S")
        u1, u2 = st.UnknownSplit(), st.UnknownSplit()
        env.unify(v, u1)
        with pytest.raises(st.UnificationError):
            env.unify(v, u2)

    def test_snapshot_restore(self):
        env = st.TypeEnv()
        v = st.GenericVar("S")
        snap = env.snapshot()
        env.unify(v, st.ArraySplit((5,), 0))
        env.restore(snap)
        assert isinstance(env.resolve(v), st.GenericVar)

    @given(hst.lists(hst.integers(0, 4), min_size=2, max_size=8))
    @settings(max_examples=30, deadline=None)
    def test_transitive_unification(self, chain):
        """Property: unifying a chain of vars then binding one end binds all."""
        env = st.TypeEnv()
        vars_ = [st.GenericVar(f"v{i}") for i in range(len(chain))]
        for a, b in zip(vars_, vars_[1:]):
            env.unify(a, b)
        t = st.ArraySplit((7,), 0)
        env.unify(vars_[chain[0] % len(vars_)], t)
        assert all(env.resolve(v) == t for v in vars_)


def test_default_split_type():
    assert st.default_split_type(jnp.zeros((4, 2))) == st.ArraySplit((4, 2), 0)
    assert st.default_split_type(jnp.float32(3.0)) == st.BROADCAST
